"""Partitioned-HLO collective census with while-loop trip accounting.

Parses ``compiled.as_text()`` (post-SPMD, per-device shapes) and sums the
bytes moved by every collective, using ring-transfer models:

  all-gather / reduce-scatter   bytes * (g-1)/g     per device
  all-reduce                    2 * bytes * (g-1)/g (RS + AG)
  all-to-all                    bytes * (g-1)/g
  collective-permute            bytes

``cost_analysis`` counts a scan body once, and so does a naive text scan —
so this census builds the while-loop nesting tree (body/cond computation
names), parses each loop's trip count from its canonical condition
(compare against a constant), and weights every computation's collectives
by the product of enclosing trip counts.  The result is the true
per-device, per-step collective traffic.
"""

from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_OP_RE = re.compile(
    r"=\s*([a-z0-9]+)\[([0-9,]*)\][^ ]*\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_TUPLE_OP_RE = re.compile(
    r"=\s*\((.*?)\)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_HEADER_RE = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{")
_WHILE_RE = re.compile(r"\bwhile\(")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_COMPARE_RE = re.compile(r"compare\(")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return 1


@dataclass
class _Comp:
    name: str
    lines: list = field(default_factory=list)
    whiles: list = field(default_factory=list)  # (body, cond)
    colls: dict = field(default_factory=lambda: defaultdict(
        lambda: {"count": 0, "bytes": 0, "transfer_bytes": 0}))
    const_ints: list = field(default_factory=list)
    has_compare: bool = False


def _parse_computations(text: str) -> dict[str, _Comp]:
    comps: dict[str, _Comp] = {}
    current: _Comp | None = None
    entry: str | None = None
    for line in text.splitlines():
        h = _HEADER_RE.match(line)
        if h:
            current = _Comp(h.group(1))
            comps[current.name] = current
            if line.lstrip().startswith("ENTRY"):
                entry = current.name
            continue
        if current is None:
            continue
        current.lines.append(line)
        if _WHILE_RE.search(line):
            b = _BODY_RE.search(line)
            c = _COND_RE.search(line)
            if b:
                current.whiles.append((b.group(1),
                                       c.group(1) if c else None))
        for m in _CONST_RE.finditer(line):
            current.const_ints.append(int(m.group(1)))
        if _COMPARE_RE.search(line):
            current.has_compare = True
        if "-done(" in line or "-done." in line:
            continue
        m = _OP_RE.search(line)
        if m:
            kind = m.group(3)
            nbytes = _shape_bytes(m.group(1), m.group(2))
        else:
            mt = _TUPLE_OP_RE.search(line)
            if not mt:
                continue
            kind = mt.group(2)
            nbytes = sum(_shape_bytes(d, s)
                         for d, s in _SHAPE_RE.findall(mt.group(1)))
        g = _group_size(line)
        if kind in ("all-gather", "reduce-scatter", "all-to-all"):
            transfer = nbytes * (g - 1) // max(g, 1)
        elif kind == "all-reduce":
            transfer = 2 * nbytes * (g - 1) // max(g, 1)
        else:
            transfer = nbytes
        current.colls[kind]["count"] += 1
        current.colls[kind]["bytes"] += nbytes
        current.colls[kind]["transfer_bytes"] += transfer
    comps["__entry__"] = comps.get(entry, _Comp("__missing__"))
    return comps


def _trip_count(comps: dict[str, _Comp], cond_name: str | None) -> int:
    """Trip count from a canonical scan condition (compare vs constant).

    The compare itself may be wrapped in a fusion on some backends, so the
    signal is just the loop-bound constant in the condition body (max, to
    skip init-value constants in canonical scans)."""
    if cond_name is None or cond_name not in comps:
        return 1
    cond = comps[cond_name]
    if not cond.const_ints:
        return 1
    return max(cond.const_ints)


def collective_census(hlo_text: str) -> dict:
    """Trip-weighted per-device collective census.

    Returns per-kind {count, bytes, transfer_bytes} both raw (one visit per
    computation) and trip-weighted, plus the loop tree that produced the
    weights.
    """
    comps = _parse_computations(hlo_text)
    entry = comps["__entry__"]

    weights: dict[str, float] = defaultdict(float)
    loop_tree: list = []

    def visit(comp: _Comp, mult: float, depth: int):
        weights[comp.name] += mult
        for body, cond in comp.whiles:
            trips = _trip_count(comps, cond)
            loop_tree.append({"body": body, "trips": trips, "depth": depth,
                              "outer_mult": mult})
            if body in comps:
                visit(comps[body], mult * trips, depth + 1)

    visit(entry, 1.0, 0)

    weighted = {k: {"count": 0.0, "bytes": 0.0, "transfer_bytes": 0.0}
                for k in COLLECTIVES}
    raw = {k: {"count": 0, "bytes": 0, "transfer_bytes": 0}
           for k in COLLECTIVES}
    for name, comp in comps.items():
        if name == "__entry__":
            continue
        w = weights.get(name, 0.0)
        for kind, st in comp.colls.items():
            for f in ("count", "bytes", "transfer_bytes"):
                raw[kind][f] += st[f]
                if w:
                    weighted[kind][f] += st[f] * w
    total_weighted = sum(v["transfer_bytes"] for v in weighted.values())
    return {
        "weighted": weighted,
        "raw": raw,
        "transfer_bytes_per_step": total_weighted,
        "loops": loop_tree,
    }
