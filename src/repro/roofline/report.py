"""Roofline report generator: dryrun.json + analytic ledger -> §Roofline.

Per (arch x cell) on the single-pod mesh:
  compute/memory/collective terms (seconds), dominant term, MODEL_FLOPS,
  MODEL_FLOPS/ledger-FLOPs ratio, mfu bound, and a one-line lever note.

Usage:  PYTHONPATH=src python -m repro.roofline.report [--json results/dryrun.json]
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

from .. import configs
from ..launch import policies, shapes
from . import analysis

LEVERS = {
    "compute_s": "already compute-bound: raise MFU via kernel fusion "
                 "(flash attention / fused scans) and drop remat recompute",
    "memory_s": "cut HBM traffic: larger microbatches amortise weight "
                "reads; selective remat; bf16 activations end-to-end",
    "collective_s": "shrink wire bytes: wider data axis vs model axis, "
                    "int8 gradient all-reduce, overlap FSDP gathers with "
                    "compute",
}


def build_rows(dryrun_path: Path, mesh_name: str = "single") -> list[dict]:
    records = json.loads(Path(dryrun_path).read_text())
    rows = []
    for rec in records:
        if rec.get("mesh") != mesh_name or not rec.get("ok"):
            continue
        cfg0 = configs.get(rec["arch"])
        cell = shapes.SHAPE_CELLS[rec["cell"]]
        cfg = policies.arch_for_cell(cfg0, cell)
        scfg = policies.default_sharding(cfg, cell)
        n_chips = rec["n_devices"]
        ledger = analysis.analytic_cost(cfg, cell, scfg, n_chips=n_chips)
        coll = rec["collectives"]["transfer_bytes_per_step"]
        terms = analysis.roofline_terms(ledger, coll, n_chips)
        rows.append({
            "arch": rec["arch"], "cell": rec["cell"], "n_chips": n_chips,
            "peak_gb": rec["memory"]["peak_per_device_gb"],
            "xla_flops_raw": rec["cost_analysis"]["flops"],
            **{k: terms[k] for k in
               ("compute_s", "memory_s", "collective_s", "dominant",
                "step_time_bound_s", "roofline_fraction", "model_flops",
                "hlo_flops", "useful_flops_ratio", "mfu_bound")},
            "lever": LEVERS[terms["dominant"]],
        })
    return rows


def to_markdown(rows: list[dict]) -> str:
    hdr = ("| arch | cell | compute s | memory s | collective s | dominant "
           "| bound s | MFU bound | useful-FLOP ratio | peak GiB/dev |\n"
           "|---|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['cell']} | {r['compute_s']:.4f} "
            f"| {r['memory_s']:.4f} | {r['collective_s']:.4f} "
            f"| {r['dominant'].replace('_s','')} "
            f"| {r['step_time_bound_s']:.4f} | {r['mfu_bound']*100:.1f}% "
            f"| {r['useful_flops_ratio']:.2f} | {r['peak_gb']:.1f} |")
    return hdr + "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    root = Path(__file__).resolve().parents[3]
    ap.add_argument("--json", default=str(root / "results" / "dryrun.json"))
    ap.add_argument("--out", default=str(root / "results" / "roofline.json"))
    args = ap.parse_args()
    rows = build_rows(Path(args.json))
    Path(args.out).write_text(json.dumps(rows, indent=1))
    print(to_markdown(rows))
    print(f"\n{len(rows)} cells -> {args.out}")


if __name__ == "__main__":
    main()
