"""Roofline analysis: compute / memory / collective terms per (arch x cell).

TPU v5e hardware model (assignment constants):
    197 TFLOP/s bf16 per chip, 819 GB/s HBM, ~50 GB/s/link ICI.

Because every production model scans over layer groups (and microbatches),
XLA's ``cost_analysis`` counts loop bodies ONCE (verified empirically —
see DESIGN.md), so FLOPs/HBM-bytes come from the analytic ledger below
(formulas validated against ``cost_analysis`` on unrolled smoke configs in
``tests/test_roofline.py``), while collective bytes come from the
trip-weighted partitioned-HLO census (``repro.roofline.hlo`` — exact).

Terms (per assignment):
    compute term    = FLOPs / (chips * peak)
    memory term     = HBM bytes / (chips * hbm_bw)     [per-chip bytes / bw]
    collective term = collective bytes / link_bw       [per-chip bytes]

MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE); the ratio
MODEL_FLOPS / ledger FLOPs flags remat/redundancy waste.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field

from ..dist.sharding import ShardingConfig
from ..launch.shapes import ShapeCell
from ..models.config import ArchConfig, MambaConfig, RwkvConfig

__all__ = ["HW", "Ledger", "analytic_cost", "roofline_terms", "model_flops"]


@dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12        # bf16 / chip
    hbm_bw: float = 819e9             # B/s / chip
    ici_bw: float = 50e9              # B/s / link
    hbm_gb: float = 16.0


V5E = HW()


@dataclass
class Ledger:
    """Per-step cost breakdown. FLOPs are GLOBAL; bytes are PER-CHIP."""
    flops: float = 0.0
    hbm_bytes: float = 0.0
    model_flops: float = 0.0
    detail: dict = field(default_factory=dict)

    def add(self, name: str, flops: float = 0.0, hbm: float = 0.0):
        self.flops += flops
        self.hbm_bytes += hbm
        d = self.detail.setdefault(name, {"flops": 0.0, "hbm": 0.0})
        d["flops"] += flops
        d["hbm"] += hbm


def _bytes_of(dtype: str) -> int:
    return {"bfloat16": 2, "float32": 4, "float16": 2, "int8": 1}[dtype]


def model_flops(cfg: ArchConfig, cell: ShapeCell) -> float:
    """6*N_active*tokens (train) / 2*N_active*tokens (inference).

    Enc-dec splits N over the two streams (encoder params see encoder
    tokens, decoder params see decoder tokens); prefill excludes the
    unembedding (logits are computed for the last position only).
    """
    n = cfg.active_param_count()
    emb = cfg.vocab_size * cfg.d_model
    if cfg.encdec:
        breakdown = cfg.param_breakdown()
        n_enc = sum(c for k, c in breakdown if k.startswith("enc_"))
        n_dec = n - n_enc - emb * (1 if cfg.tie_embeddings else 2)
        mult = 6.0 if cell.kind == "train" else 2.0
        # the encoder runs at train/prefill; decode touches decoder params only
        enc_tokens = (0 if cell.kind == "decode"
                      else cell.global_batch * cell.seq_len)
        dec_tokens = (cell.global_batch * cfg.decoder_len
                      if cell.kind == "train"
                      else (0 if cell.kind == "prefill"
                            else cell.global_batch))
        return mult * (n_enc * enc_tokens + n_dec * dec_tokens)
    if cell.kind == "train":
        return 6.0 * n * cell.global_batch * cell.seq_len
    if cell.kind == "prefill":
        # unembedding runs once per sequence, not per token
        return 2.0 * (n - emb) * cell.global_batch * cell.seq_len
    return 2.0 * n * cell.global_batch  # decode: one token per sequence


# -- per-layer forward FLOPs (global, per `tokens` new tokens) -----------------

def _attn_flops(cfg: ArchConfig, tokens: float, ctx: float,
                causal: bool) -> tuple[float, float]:
    """(projection flops, attention-matmul flops)."""
    d, hd = cfg.d_model, cfg.head_dim
    proj = 2.0 * tokens * d * (cfg.n_heads + 2 * cfg.n_kv_heads) * hd \
        + 2.0 * tokens * cfg.n_heads * hd * d
    eff_ctx = ctx / 2.0 if (causal and tokens == ctx) else ctx
    attn = 2.0 * 2.0 * tokens * eff_ctx * cfg.n_heads * hd
    return proj, attn


def _mlp_flops(cfg: ArchConfig, tokens: float, d_ff: int | None = None) -> float:
    w = 3 if cfg.mlp_type == "swiglu" else 2
    return 2.0 * tokens * w * cfg.d_model * (d_ff or cfg.d_ff)


def _moe_flops(cfg: ArchConfig, tokens: float) -> float:
    m = cfg.moe
    w = 3 if cfg.mlp_type == "swiglu" else 2
    routed = 2.0 * tokens * m.top_k * m.capacity_factor * w * cfg.d_model \
        * m.d_expert
    shared = _mlp_flops(cfg, tokens, m.d_shared) if m.n_shared else 0.0
    router = 2.0 * tokens * cfg.d_model * m.n_experts
    return routed + shared + router


def _mamba_flops(cfg: ArchConfig, tokens: float) -> float:
    m = cfg.mamba or MambaConfig()
    d = cfg.d_model
    d_in = m.expand * d
    r = m.dt_rank or -(-d // 16)
    proj = 2.0 * tokens * (d * 2 * d_in + d_in * (r + 2 * m.d_state)
                           + r * d_in + d_in * d)
    conv = 2.0 * tokens * m.d_conv * d_in
    scan = 6.0 * tokens * d_in * m.d_state
    return proj + conv + scan


def _rwkv_flops(cfg: ArchConfig, tokens: float) -> float:
    r = cfg.rwkv or RwkvConfig()
    d = cfg.d_model
    proj = 2.0 * tokens * 5 * d * d                      # r,k,v,g,o
    lora = 2.0 * tokens * (d * 5 * r.lora_rank_mix + 5 * r.lora_rank_mix * d
                           + d * r.lora_rank_decay + r.lora_rank_decay * d)
    wkv = 4.0 * tokens * d * r.head_dim                  # state update + read
    cmix = 2.0 * tokens * (2 * d * cfg.d_ff + d * d)
    return proj + lora + wkv + cmix


def _layers_fwd_flops(cfg: ArchConfig, tokens: float, ctx: float,
                      ledger: Ledger, causal: bool = True,
                      include_encoder: bool = True) -> None:
    moe_mask = cfg.moe_layer_mask()
    for i, kind in enumerate(cfg.layer_kinds):
        if kind == "attn":
            proj, attn = _attn_flops(cfg, tokens, ctx, causal)
            ledger.add("attn_proj", flops=proj)
            ledger.add("attn_matmul", flops=attn)
        elif kind == "mamba":
            ledger.add("mamba", flops=_mamba_flops(cfg, tokens))
        else:
            ledger.add("rwkv", flops=_rwkv_flops(cfg, tokens))
        if kind == "rwkv":
            pass                                          # cmix inside rwkv
        elif moe_mask[i]:
            ledger.add("moe", flops=_moe_flops(cfg, tokens))
        else:
            ledger.add("mlp", flops=_mlp_flops(cfg, tokens))
    if cfg.encdec:
        if include_encoder:
            for _ in range(cfg.n_encoder_layers):
                proj, attn = _attn_flops(cfg, ctx, ctx, causal=False)
                ledger.add("enc_attn", flops=proj + attn)
                ledger.add("enc_mlp", flops=_mlp_flops(cfg, ctx))
        # decoder cross attention (precomputed cross-KV at decode: 1024 ctx)
        cross_ctx = ctx if include_encoder else 1024
        for _ in range(cfg.n_layers):
            proj, attn = _attn_flops(cfg, tokens, cross_ctx, causal=False)
            ledger.add("cross_attn", flops=proj + attn)


# -- HBM traffic model (documented coefficients) -------------------------------

_ACT_COEF = 12.0   # reads+writes of qkv/mlp/norm intermediates per token-layer
_REMAT_COEF = 1.5  # remat recompute multiplies forward activation traffic


def _train_hbm_bytes(cfg: ArchConfig, cell: ShapeCell, scfg: ShardingConfig,
                     n_chips: int, ledger: Ledger) -> None:
    pb = _bytes_of(cfg.param_dtype)
    params = cfg.param_count()
    n_model = n_chips // _data_shards(scfg, n_chips)
    local_params = params / n_chips
    n_micro = scfg.microbatches
    # weights: full (per model shard) read fwd+bwd each microbatch
    ledger.add("w_read", hbm=2.0 * n_micro * params * pb / n_model /
               _data_shards(scfg, n_chips) * _data_shards(scfg, n_chips) / n_chips * n_chips / n_chips
               if False else 2.0 * n_micro * params * pb / n_model)
    # optimizer: read g,m,v,p + write p,m,v on local shards
    mb = 1 if scfg.moments_dtype == "int8" else 4
    ledger.add("opt", hbm=local_params * (4 + pb + 2 * mb + 4 + pb + 2 * mb))
    # activations
    tokens_local = cell.global_batch * cell.seq_len / _data_shards(
        scfg, n_chips)
    act = _ACT_COEF * _REMAT_COEF * 3.0 * tokens_local * cfg.d_model * 2 \
        * cfg.n_layers / n_model
    ledger.add("activations", hbm=act)
    if getattr(scfg, "remat_policy", "full") == "save_dots":
        # saved qkv / mlp-hidden / layer outputs: one write + one read
        w_ff = 3 if cfg.mlp_type == "swiglu" else 2
        per_tok = ((w_ff - 1) * cfg.d_ff
                   + (cfg.n_heads + 2 * cfg.n_kv_heads) * cfg.head_dim
                   + 2 * cfg.d_model)
        ledger.add("saved_dots",
                   hbm=2.0 * tokens_local * per_tok * 2 * cfg.n_layers
                   / n_model)
    # attention KV streaming (flash blocks re-read K/V per q block)
    s = cell.seq_len
    n_attn = sum(1 for k in cfg.layer_kinds if k == "attn")
    if n_attn:
        q_block = 512
        kv_bytes = s * cfg.n_kv_heads * cfg.head_dim * 2 * 2  # k+v bf16
        reads = (tokens_local / q_block) * kv_bytes / n_model
        ledger.add("attn_kv_stream", hbm=3.0 * n_attn * reads)
    # logits chunks
    v_local = cfg.vocab_size / n_model
    ledger.add("logits", hbm=3.0 * 2.0 * tokens_local * v_local * 2)


def _data_shards(scfg: ShardingConfig, n_chips: int) -> int:
    # data axes hold batch; single-pod (16,16) -> 16, multi-pod -> 32
    return max(1, int(round(n_chips / 16)))


def analytic_cost(cfg: ArchConfig, cell: ShapeCell, scfg: ShardingConfig,
                  n_chips: int = 256) -> Ledger:
    """Global FLOPs + per-chip HBM bytes for one step of this cell."""
    ledger = Ledger()
    ledger.model_flops = model_flops(cfg, cell)
    pb = _bytes_of("bfloat16" if cell.kind != "train" else cfg.param_dtype)
    n_model = max(1, n_chips // _data_shards(scfg, n_chips))

    if cell.kind == "train":
        tokens = cell.global_batch * (cell.seq_len if not cfg.encdec
                                      else cfg.decoder_len)
        ctx = cell.seq_len
        _layers_fwd_flops(cfg, tokens, ctx, ledger)
        emb_tokens = tokens + (cell.global_batch * cell.seq_len
                               if cfg.encdec else 0)
        ledger.add("logits", flops=2.0 * tokens * cfg.d_model
                   * cfg.vocab_size)
        # bwd = 2x fwd; remat recompute depends on the policy:
        #   full      -> +1.0 fwd (recompute everything)
        #   save_dots -> re-run only attention matmuls + elementwise
        fwd = ledger.flops
        if scfg.remat and getattr(scfg, "remat_policy", "full") == "save_dots":
            recompute = (ledger.detail.get("attn_matmul",
                                           {"flops": 0.0})["flops"]
                         + 0.05 * fwd)           # elementwise/norm replay
        elif scfg.remat:
            recompute = fwd
        else:
            recompute = 0.0
        ledger.add("bwd_and_remat", flops=fwd * 2.0 + recompute)
        _train_hbm_bytes(cfg, cell, scfg, n_chips, ledger)
        return ledger

    if cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        if cfg.encdec:
            # encoder + cross-kv precompute only
            for _ in range(cfg.n_encoder_layers):
                proj, attn = _attn_flops(cfg, tokens, cell.seq_len, False)
                ledger.add("enc_attn", flops=proj + attn)
                ledger.add("enc_mlp", flops=_mlp_flops(cfg, tokens))
            ledger.add("cross_kv", flops=2.0 * tokens * cfg.d_model
                       * 2 * cfg.n_kv_heads * cfg.head_dim * cfg.n_layers)
        else:
            _layers_fwd_flops(cfg, tokens, cell.seq_len, ledger)
            ledger.add("logits", flops=2.0 * cell.global_batch * cfg.d_model
                       * cfg.vocab_size)
        tokens_local = tokens / _data_shards(scfg, n_chips)
        ledger.add("w_read", hbm=cfg.param_count() * pb / n_model)
        ledger.add("activations",
                   hbm=_ACT_COEF * tokens_local * cfg.d_model * 2
                   * cfg.n_layers / n_model)
        n_attn = sum(1 for k in cfg.layer_kinds if k == "attn")
        if n_attn:
            kv_bytes = cell.seq_len * cfg.n_kv_heads * cfg.head_dim * 4
            reads = (tokens_local / 512) * kv_bytes / n_model
            ledger.add("attn_kv_stream", hbm=n_attn * reads)
        ledger.add("kv_write", hbm=_decode_state_bytes(cfg, cell) / n_chips)
        return ledger

    # decode: one token per sequence (enc-dec: decoder-side work only)
    b = cell.global_batch
    _layers_fwd_flops(cfg, b, cell.seq_len, ledger, causal=True,
                      include_encoder=False)
    ledger.add("logits", flops=2.0 * b * cfg.d_model * cfg.vocab_size)
    ledger.add("w_read", hbm=cfg.param_count() * pb / n_model)
    ledger.add("cache_read", hbm=_decode_state_bytes(cfg, cell) / n_chips)
    return ledger


def _decode_state_bytes(cfg: ArchConfig, cell: ShapeCell) -> float:
    """Global decode-state footprint (KV caches + SSM/RWKV states)."""
    b, s = cell.global_batch, cell.seq_len
    total = 0.0
    m = cfg.mamba or MambaConfig()
    r = cfg.rwkv or RwkvConfig()
    for kind in cfg.layer_kinds:
        if kind == "attn":
            total += 2 * b * s * cfg.n_kv_heads * cfg.head_dim * 2
        elif kind == "mamba":
            d_in = m.expand * cfg.d_model
            total += b * d_in * m.d_state * 4 + b * (m.d_conv - 1) * d_in * 2
        else:
            h = cfg.d_model // r.head_dim
            total += b * h * r.head_dim ** 2 * 4 + 2 * b * cfg.d_model * 2
    if cfg.encdec:
        total += 2 * b * 1024 * cfg.n_kv_heads * cfg.head_dim * 2  # cross
    return total


def analytic_collective_bytes(cfg: ArchConfig, cell: ShapeCell,
                              scfg: ShardingConfig, n_chips: int = 256
                              ) -> float:
    """Per-chip collective traffic estimate (ring models) for one step.

    Used by the sharding tuner's fast evaluator; the compiled-HLO census is
    the ground truth it is validated against.
    """
    pb = _bytes_of("bfloat16" if cell.kind != "train" else cfg.param_dtype)
    n_data = _data_shards(scfg, n_chips)
    n_model = max(1, n_chips // n_data)
    params = cfg.param_count()
    total = 0.0
    if cell.kind == "train":
        n_micro = scfg.microbatches
        if scfg.fsdp_axes:
            # per-microbatch fwd + bwd re-gather of the fsdp-sharded params
            total += 2.0 * n_micro * params * pb / n_model
        # grad reduction over data axis (f32 if accumulated)
        total += 2.0 * params * 4 / n_model
        # TP activation reductions: 2 per layer per microbatch
        tokens_local = cell.global_batch * cell.seq_len / n_data
        total += (2.0 * cfg.n_layers * n_micro
                  * (tokens_local / n_micro) * cfg.d_model * 2 * 2)
        if cfg.moe is not None:
            cap_frac = cfg.moe.top_k * cfg.moe.capacity_factor
            n_moe = sum(cfg.moe_layer_mask())
            total += 2.0 * n_moe * tokens_local * cap_frac * cfg.d_model * 2
    elif cell.kind == "prefill":
        tokens_local = cell.global_batch * cell.seq_len / n_data
        total += params * pb / n_model if scfg.fsdp_axes else 0.0
        total += 2.0 * cfg.n_layers * tokens_local * cfg.d_model * 2 * 2
    else:
        b_local = max(1.0, cell.global_batch / n_data)
        total += 2.0 * cfg.n_layers * b_local * cfg.d_model * 4 * 2
        if scfg.fsdp_axes:
            total += params * pb / n_model / max(n_data, 1) * 2
    return total


# -- roofline -------------------------------------------------------------------

def roofline_terms(ledger: Ledger, collective_bytes_per_chip: float,
                   n_chips: int, hw: HW = V5E) -> dict:
    t_compute = ledger.flops / (n_chips * hw.peak_flops)
    t_memory = ledger.hbm_bytes / hw.hbm_bw
    t_coll = collective_bytes_per_chip / hw.ici_bw
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    return {
        **terms,
        "dominant": dominant,
        "step_time_bound_s": bound,
        "roofline_fraction": t_compute / bound if bound else 0.0,
        "model_flops": ledger.model_flops,
        "hlo_flops": ledger.flops,
        "useful_flops_ratio": (ledger.model_flops / ledger.flops
                               if ledger.flops else 0.0),
        "mfu_bound": (ledger.model_flops / (n_chips * hw.peak_flops) / bound
                      if bound else 0.0),
    }
