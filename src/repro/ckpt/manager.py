"""Sharded, asynchronous, atomic checkpointing with elastic restore.

Layout (one directory per step):

    <root>/step_000120.tmp/      — written first
        manifest.json            — tree structure, shapes, dtypes, step,
                                   data-pipeline cursor, wall-clock
        arr_000000.npy ...       — one file per leaf (row-sliced per host)
    <root>/step_000120/          — atomic os.rename after fsync

Design notes for multi-host (this container runs one process, the layout
is process-aware): each host writes only rows of leaves it owns
(``addressable_shards``) into ``arr_XXXXXX.pN.npy``; the manifest is
written by process 0; restore re-assembles from whatever subset of files
covers the global shape, so a checkpoint taken on 512 devices restores
onto 8 (elastic re-mesh) — ``restore`` simply ``device_put``s every leaf
with the *target* mesh's NamedSharding.

Async: ``save`` snapshots leaves to host memory synchronously (cheap,
device->host copy) and does file IO on a worker thread; a subsequent save
or ``wait()`` joins it.  Atomicity means a crash mid-save never corrupts
the latest complete checkpoint — the restart tests kill mid-run and
restore bit-exactly.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Callable

import numpy as np

import jax


class CheckpointManager:
    def __init__(self, root: str | Path, keep: int = 3,
                 async_save: bool = True):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_save = async_save
        self._worker: threading.Thread | None = None

    # -- save ------------------------------------------------------------------
    def save(self, step: int, state: Any, extra: dict | None = None) -> None:
        self.wait()
        leaves, treedef = jax.tree_util.tree_flatten(state)
        host_leaves = [np.asarray(x) for x in leaves]   # sync device->host
        manifest = {
            "step": int(step),
            "treedef": jax.tree_util.tree_structure(state).serialize_using_proto().hex(),
            "n_leaves": len(host_leaves),
            "shapes": [list(a.shape) for a in host_leaves],
            "dtypes": [str(a.dtype) for a in host_leaves],
            "extra": extra or {},
            "time": time.time(),
        }

        def write():
            tmp = self.root / f"step_{step:09d}.tmp"
            final = self.root / f"step_{step:09d}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            for i, arr in enumerate(host_leaves):
                # numpy has no bf16/f8: persist as a same-width uint view;
                # the manifest dtype restores the real type on load
                if arr.dtype.kind == "V":
                    arr = arr.view({1: np.uint8, 2: np.uint16,
                                    4: np.uint32}[arr.dtype.itemsize])
                np.save(tmp / f"arr_{i:06d}.npy", arr)
            (tmp / "manifest.json").write_text(json.dumps(manifest))
            if final.exists():
                shutil.rmtree(final)
            os.replace(tmp, final)
            self._gc()

        if self.async_save:
            self._worker = threading.Thread(target=write, daemon=True)
            self._worker.start()
        else:
            write()

    def wait(self) -> None:
        if self._worker is not None:
            self._worker.join()
            self._worker = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self.root / f"step_{s:09d}", ignore_errors=True)

    # -- restore ---------------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for p in self.root.iterdir():
            if p.is_dir() and p.name.startswith("step_") \
                    and not p.name.endswith(".tmp") \
                    and (p / "manifest.json").exists():
                out.append(int(p.name[5:]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int | None = None,
                shardings: Any = None) -> tuple[int, Any, dict]:
        """Returns (step, state, extra).

        ``shardings``: optional pytree of NamedSharding (matching the state
        tree) — pass the TARGET mesh's shardings to restore onto a
        different device count / topology (elastic re-mesh)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.root}")
        d = self.root / f"step_{step:09d}"
        manifest = json.loads((d / "manifest.json").read_text())
        treedef = jax.tree_util.PyTreeDef.deserialize_using_proto(
            jax.tree_util.default_registry,
            bytes.fromhex(manifest["treedef"]))
        import ml_dtypes
        leaves = []
        for i in range(manifest["n_leaves"]):
            arr = np.load(d / f"arr_{i:06d}.npy")
            want = manifest["dtypes"][i]
            if str(arr.dtype) != want:
                arr = arr.view(np.dtype(getattr(ml_dtypes, want, want)))
            leaves.append(arr)
        state = jax.tree_util.tree_unflatten(treedef, leaves)
        if shardings is not None:
            state = jax.tree.map(
                lambda arr, s: jax.device_put(arr, s), state, shardings)
        else:
            state = jax.tree.map(jax.device_put, state)
        return manifest["step"], state, manifest.get("extra", {})
