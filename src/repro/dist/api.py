"""Mesh-rules API: install rules, query them, constrain intermediates.

Model code annotates intermediates with *logical* axis names::

    x = constrain(x, "batch", "seq", None)

and the launch layer installs a :class:`~repro.dist.sharding.MeshRules`
table around tracing::

    with use_rules(scfg.rules(mesh)):
        step = jax.jit(fn, ...)
        step.lower(...)

``constrain`` resolves each logical name through the active table into a
``with_sharding_constraint`` on the bound mesh.  With no rules installed
(single host, plain tests) every call is the identity, so unsharded
paths never pay for the subsystem.  Dimensions whose extent the mapped
mesh axes do not divide are left unsharded rather than erroring — the
rules are hints to GSPMD, not hard partitioning.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from .sharding import MeshRules

__all__ = ["constrain", "constrain_leading", "current_rules", "use_rules"]

_STATE = threading.local()


def _stack() -> list:
    if not hasattr(_STATE, "stack"):
        _STATE.stack = []
    return _STATE.stack


def current_rules() -> MeshRules | None:
    """The innermost installed rules table, or None when unsharded."""
    stack = _stack()
    return stack[-1] if stack else None


@contextlib.contextmanager
def use_rules(rules: MeshRules | None):
    """Install ``rules`` for the dynamic extent of the block.

    ``None`` is accepted and pushes an explicit "no rules" scope — useful
    to locally disable sharding inside a ruled region.
    """
    stack = _stack()
    stack.append(rules)
    try:
        yield rules
    finally:
        stack.pop()


def constrain(x: Any, *names: str | None) -> Any:
    """Annotate ``x`` with the sharding the active rules give ``names``.

    One logical name (or None) per array dimension.  No-op when no rules
    are installed; per-dimension fallback to replication when the mapped
    axes do not divide that dimension.
    """
    rules = current_rules()
    if rules is None:
        return x
    shape = getattr(x, "shape", None)
    if shape is None or len(shape) != len(names):
        return x
    dims = [rules.spec_dim(name, extent)
            for extent, name in zip(shape, names)]
    if all(d is None for d in dims):
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, P(*dims)))


def constrain_leading(tree: Any, name: str = "batch") -> Any:
    """Constrain dimension 0 of every array leaf to logical axis ``name``.

    The chunked scheduler (``repro.runtime.scheduler``) annotates each
    dispatched chunk this way: chunks are row slices of a batch pytree,
    so only the leading dimension carries the data-parallel layout.
    Like ``constrain`` this is the identity when no rules are installed.
    """
    if current_rules() is None:
        return tree

    def leaf(x):
        ndim = getattr(x, "ndim", None)
        if not ndim:            # scalars and non-arrays pass through
            return x
        return constrain(x, name, *([None] * (ndim - 1)))

    return jax.tree.map(leaf, tree)
