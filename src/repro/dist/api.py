"""Sharding-rules API — stub implementation (see package docstring).

``constrain``/``current_rules`` have working single-host semantics (no-op /
no rules) because every model forward pass calls them; ``use_rules`` raises
until the real mesh-rules subsystem lands.
"""

from __future__ import annotations

from typing import Any

__all__ = ["constrain", "current_rules", "use_rules"]


def constrain(x: Any, *_names: Any, **_kw: Any) -> Any:
    """Sharding-constraint annotation. Single-host stub: identity."""
    return x


def current_rules() -> None:
    """Active mesh sharding rules. Stub: none are ever active."""
    return None


def use_rules(*_a: Any, **_kw: Any):
    raise NotImplementedError(
        "repro.dist.api.use_rules: the mesh-rules subsystem is a stub "
        "(see src/repro/dist/__init__.py); full dist support is a future PR")
