"""Sequence-sharded single-token decode attention.

For long-context decode the KV cache is sharded along its *sequence*
dimension (each shard owns a contiguous stripe of positions).  One decode
step is then:

  1. the shard whose stripe contains ``pos`` writes the new K/V row
     locally (everyone runs the same masked dynamic-update, so no
     divergence between shards);
  2. every shard runs flash-decode over its stripe, producing a partial
     (accumulator, logsumexp max, normalizer) triple;
  3. the partials combine across the sequence axes with the standard
     cross-shard logsumexp recombination: ``pmax`` of the maxima, then a
     ``psum`` of the rescaled accumulators/normalizers.

GSPMD lowers the combine to one small all-reduce of (B, H)-shaped
tensors — independent of context length — which is what makes 500k-token
caches servable.  ``models.attention.decode_attention`` dispatches here
whenever the active mesh rules map ``"kv_seq"`` to real axes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..compat import shard_map

__all__ = ["seq_decode_attention"]

NEG_INF = -1e30


def seq_decode_attention(q: jax.Array, k_new: jax.Array, v_new: jax.Array,
                         cache_k: jax.Array, cache_v: jax.Array,
                         pos: jax.Array, *, mesh, seq_axes,
                         batch_axes=()) -> tuple[jax.Array, jax.Array,
                                                 jax.Array]:
    """One GQA decode step against a sequence-sharded cache.

    q: (B, H, hd); k_new/v_new: (B, KV, hd); cache k/v: (B, S, KV, hd)
    sharded ``P(batch_axes, seq_axes, None, None)``; ``pos`` scalar int32
    (write position; attention spans positions <= pos).  Returns
    ``(out f32 (B, H, hd), new_cache_k, new_cache_v)`` with the caches
    still sequence-sharded.
    """
    b, h, hd = q.shape
    kv = cache_k.shape[2]
    rep = h // kv
    ba = tuple(batch_axes)
    sa = tuple(seq_axes)

    def local(q, kn, vn, ck, cv, pos):
        s_local = ck.shape[1]
        # flattened shard index along the sequence axes (row-major in the
        # order given, matching PartitionSpec semantics)
        idx = jnp.int32(0)
        for a in sa:
            idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
        s0 = idx * s_local

        # masked local write of the new K/V row at global position `pos`
        li = pos - s0
        in_range = (li >= 0) & (li < s_local)
        lc = jnp.clip(li, 0, s_local - 1)
        ck = jnp.where(in_range,
                       jax.lax.dynamic_update_slice_in_dim(
                           ck, kn[:, None].astype(ck.dtype), lc, 1), ck)
        cv = jnp.where(in_range,
                       jax.lax.dynamic_update_slice_in_dim(
                           cv, vn[:, None].astype(cv.dtype), lc, 1), cv)

        # local flash-decode over this stripe
        bl = q.shape[0]
        qf = (q.astype(jnp.float32) * hd ** -0.5).reshape(bl, kv, rep, hd)
        scores = jnp.einsum("bgrh,bsgh->bgrs", qf, ck.astype(jnp.float32))
        valid = (s0 + jnp.arange(s_local)) <= pos
        scores = jnp.where(valid[None, None, None, :], scores, NEG_INF)
        m = scores.max(axis=-1)                              # (B, KV, rep)
        p = jnp.exp(scores - m[..., None])
        l = p.sum(axis=-1)
        acc = jnp.einsum("bgrs,bsgh->bgrh", p, cv.astype(jnp.float32))

        # cross-shard logsumexp combine (stripes with no valid rows have
        # m = -inf and contribute exactly zero)
        if sa:
            m_all = jax.lax.pmax(m, sa)
            c = jnp.exp(m - m_all)
            l = jax.lax.psum(l * c, sa)
            acc = jax.lax.psum(acc * c[..., None], sa)
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out.reshape(bl, h, hd), ck, cv

    row_spec = P(ba if ba else None, None, None)
    cache_spec = P(ba if ba else None, sa if sa else None, None, None)
    fn = shard_map(local, mesh,
                   in_specs=(row_spec, row_spec, row_spec,
                             cache_spec, cache_spec, P()),
                   out_specs=(row_spec, cache_spec, cache_spec))
    return fn(q, k_new, v_new, cache_k, cache_v, pos)
