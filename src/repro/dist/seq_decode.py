"""Sequence-sharded decode attention — stub (see ``repro.dist``)."""

from __future__ import annotations

__all__ = ["seq_decode_attention"]

_MSG = ("repro.dist.seq_decode is a stub (see src/repro/dist/__init__.py); "
        "sequence-sharded decode is a future PR")


def seq_decode_attention(*_a, **_kw):
    raise NotImplementedError(_MSG)


def __getattr__(name: str):
    if name.startswith("__"):  # import machinery probes __path__ etc.
        raise AttributeError(name)
    raise NotImplementedError(f"{_MSG} (accessed {name!r})")
