"""Gradient compression — stub (see ``repro.dist`` package docstring)."""

from __future__ import annotations

__all__ = [
    "CompressionConfig", "compress_with_feedback", "init_error_state",
    "quantize_int8", "dequantize_int8", "topk_compress", "topk_decompress",
    "compressed_allreduce_mean", "wire_bytes",
]

_MSG = ("repro.dist.compression is a stub (see src/repro/dist/__init__.py); "
        "gradient compression is a future PR")


class CompressionConfig:
    def __init__(self, *_a, **_kw):
        raise NotImplementedError(_MSG)


def _stub(*_a, **_kw):
    raise NotImplementedError(_MSG)


compress_with_feedback = _stub
init_error_state = _stub
quantize_int8 = _stub
dequantize_int8 = _stub
topk_compress = _stub
topk_decompress = _stub
compressed_allreduce_mean = _stub
wire_bytes = _stub


def __getattr__(name: str):
    if name.startswith("__"):  # import machinery probes __path__ etc.
        raise AttributeError(name)
    raise NotImplementedError(f"{_MSG} (accessed {name!r})")
