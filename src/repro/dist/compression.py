"""Gradient compression substrates with error feedback.

Two wire formats and the error-feedback (EF) wrapper that makes them safe
for SGD/Adam:

  * ``quantize_int8``/``dequantize_int8`` — per-tensor absmax int8; the
    roundtrip error is bounded by ``absmax/254`` per element.
  * ``topk_compress``/``topk_decompress`` — keep the ``frac`` fraction of
    largest-|g| entries as (values, flat indices).

``compress_with_feedback`` implements the standard EF recurrence
(Seide et al. / Karimireddy et al.): the residual of each step's
compression is added back into the next step's gradient, so the scheme
stays unbiased in the long run and convergence matches uncompressed
training closely (tested in ``tests/test_substrates.py``).

``compressed_allreduce_mean`` is the collective: each shard quantizes its
local block before the reduction, modelling an int8-on-the-wire
all-reduce; ``wire_bytes`` accounts for exactly what such a transport
would move per step (the number the roofline's collective term wants).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..compat import shard_map

__all__ = [
    "CompressionConfig", "compress_with_feedback", "init_error_state",
    "quantize_int8", "dequantize_int8", "topk_compress", "topk_decompress",
    "compressed_allreduce_mean", "wire_bytes",
]


@dataclass(frozen=True)
class CompressionConfig:
    """Wire-format knobs: ``scheme`` in {"none", "int8", "topk"};
    ``topk_frac`` is the kept fraction for the top-k scheme."""

    scheme: str = "none"
    topk_frac: float = 0.25

    def __post_init__(self):
        if self.scheme not in ("none", "int8", "topk"):
            raise ValueError(f"unknown compression scheme {self.scheme!r}")


# -- int8 ----------------------------------------------------------------------

def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-tensor absmax quantization -> (int8 codes, f32 scale)."""
    x32 = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(x32)) / 127.0
    q = jnp.round(x32 / jnp.maximum(scale, 1e-30))
    return jnp.clip(q, -127, 127).astype(jnp.int8), scale.astype(jnp.float32)


def dequantize_int8(q: jax.Array, scale: jax.Array,
                    shape: tuple[int, ...]) -> jax.Array:
    return (q.astype(jnp.float32) * scale).reshape(shape)


# -- top-k ----------------------------------------------------------------------

def _topk_k(n: int, frac: float) -> int:
    return max(1, min(n, int(round(n * frac))))


def topk_compress(x: jax.Array, frac: float) -> tuple[jax.Array, jax.Array]:
    """Keep the ``frac`` largest-|x| entries -> (values, flat int32 idx)."""
    flat = x.reshape(-1).astype(jnp.float32)
    k = _topk_k(flat.shape[0], frac)
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    return flat[idx], idx.astype(jnp.int32)


def topk_decompress(values: jax.Array, idx: jax.Array,
                    shape: tuple[int, ...]) -> jax.Array:
    n = 1
    for d in shape:
        n *= d
    out = jnp.zeros((n,), jnp.float32).at[idx].set(values)
    return out.reshape(shape)


# -- error feedback -------------------------------------------------------------

def init_error_state(params: Any) -> Any:
    """Zero EF residual tree, shaped (and shardable) like the params."""
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _compress_leaf(g: jax.Array, cfg: CompressionConfig) -> jax.Array:
    """Compress-then-decompress one leaf (the EF update needs the
    decompressed representative anyway)."""
    if cfg.scheme == "int8":
        q, s = quantize_int8(g)
        return dequantize_int8(q, s, g.shape)
    v, i = topk_compress(g, cfg.topk_frac)
    return topk_decompress(v, i, g.shape)


def compress_with_feedback(grads: Any, err: Any, cfg: CompressionConfig
                           ) -> tuple[Any, Any]:
    """EF step: compress (grad + residual), carry the new residual.

    Returns ``(compressed_grads, new_err)`` with the same tree structure
    as ``grads``; with ``scheme="none"`` it is the identity.
    """
    if cfg.scheme == "none":
        return grads, err

    def leaf(g, e):
        total = g.astype(jnp.float32) + e
        c = _compress_leaf(total, cfg)
        return c.astype(g.dtype), total - c

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(err)
    out = [leaf(g, e) for g, e in zip(flat_g, flat_e)]
    return (tdef.unflatten([o[0] for o in out]),
            tdef.unflatten([o[1] for o in out]))


# -- collectives ----------------------------------------------------------------

def compressed_allreduce_mean(x: jax.Array, mesh, axis: str,
                              scheme: str = "int8",
                              topk_frac: float = 0.25) -> jax.Array:
    """All-reduce-mean of ``x`` over mesh axis ``axis`` with each shard's
    contribution compressed before the reduction.

    ``x``'s leading dimension is sharded over ``axis``; the result has
    ``x``'s shape with every row holding the global mean (what an
    int8-on-the-wire ring all-reduce delivers, error model included).
    """
    cfg = CompressionConfig(scheme=scheme, topk_frac=topk_frac)
    size = mesh.shape[axis]

    def local(xl):
        contrib = xl.astype(jnp.float32)
        if cfg.scheme != "none":
            contrib = _compress_leaf(contrib, cfg)
        return jax.lax.psum(contrib, axis) / size

    spec = P(axis, *([None] * (x.ndim - 1)))
    return shard_map(local, mesh, in_specs=(spec,), out_specs=spec)(x)


# -- wire accounting ------------------------------------------------------------

def wire_bytes(grads: Any, cfg: CompressionConfig) -> int:
    """Bytes one replica puts on the wire per step under ``cfg``.

    none: raw elements at their dtype width.  int8: one byte per element
    plus a f32 scale per leaf.  topk: (f32 value + int32 index) per kept
    entry.
    """
    total = 0
    for g in jax.tree.leaves(grads):
        n = g.size
        if cfg.scheme == "none":
            total += n * jnp.dtype(g.dtype).itemsize
        elif cfg.scheme == "int8":
            total += n + 4
        else:
            total += _topk_k(n, cfg.topk_frac) * (4 + 4)
    return total
