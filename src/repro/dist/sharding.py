"""Mesh-rules sharding configuration.

``ShardingConfig`` is the single declarative description of how one
workload is distributed over a mesh: which mesh axes carry data
parallelism, tensor (model) parallelism, FSDP parameter sharding, expert
parallelism, and how decode KV caches are laid out.  ``rules(mesh)``
compiles it into a :class:`MeshRules` table mapping the *logical* axis
names the model code uses (``"batch"``, ``"heads"``, ``"ff"``,
``"vocab"``, ``"expert"``, ``"kv_seq"``, ...) onto concrete mesh axes;
``repro.dist.api.constrain`` consults the active table at trace time, so
the same model source lowers unsharded on one device and fully
distributed on a pod.

The ``*_specs`` helpers derive :class:`~jax.sharding.PartitionSpec` trees
for parameters, optimizer state, data batches and decode caches from
shape trees.  Every placement is divisibility-checked against the actual
leaf shape and falls back to replication for that dimension when the
shard count does not divide it — a config is never invalid, only less
sharded.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Mapping

import jax
from jax.sharding import Mesh, PartitionSpec as P

__all__ = ["ShardingConfig", "MeshRules", "param_specs", "opt_specs",
           "batch_specs", "cache_specs"]

Axes = tuple[str, ...]


@dataclass(frozen=True)
class MeshRules:
    """Logical-axis -> mesh-axes table bound to one mesh.

    ``rules["batch"]`` etc. are tuples of mesh axis names (possibly
    empty).  The table is what ``use_rules`` installs and what
    ``constrain``/``current_rules`` read back; model code never sees the
    ShardingConfig itself.
    """

    mesh: Mesh
    rules: Mapping[str, Axes] = field(default_factory=dict)

    def axes(self, name: str | None) -> Axes:
        if name is None:
            return ()
        return tuple(self.rules.get(name, ()))

    def axes_size(self, axes: Axes) -> int:
        return _axes_size(self.mesh, axes)

    def spec_dim(self, name: str | None, extent: int):
        """PartitionSpec entry for one dimension of extent ``extent``."""
        return _dim_entry(self.mesh, self.axes(name), extent)


def _present(axes, mesh: Mesh) -> Axes:
    return tuple(a for a in axes if a in mesh.axis_names)


@dataclass(frozen=True)
class ShardingConfig:
    """Declarative distribution policy for one workload.

    data_axes / model_axes / fsdp_axes / expert_axes name mesh axes (they
    are filtered against the mesh actually in use, so one config works on
    both the 8-device host mesh and the 256-chip pod).  ``kv_shard``
    picks the decode-cache layout:

      * ``"heads"``     — KV heads over the model axes (default)
      * ``"batch_seq"`` — batch over data axes, cache sequence over model
                          axes (sequence-sharded decode path)
      * ``"seq"``       — cache sequence over the data axes, batch
                          replicated (single-sequence long-context decode)
      * ``"none"``      — batch over data axes only

    ``grad_compression`` ("none" | "int8" | "topk") switches the train
    step to error-feedback compressed gradients (see
    ``repro.dist.compression``).
    """

    data_axes: Axes = ("data",)
    model_axes: Axes = ("model",)
    fsdp_axes: Axes = ()
    expert_axes: Axes = ()
    kv_shard: str = "heads"          # "heads" | "batch_seq" | "seq" | "none"
    seq_parallel: bool = False
    microbatches: int = 1
    remat: bool = False
    remat_policy: str = "full"       # "full" | "save_dots"
    mamba_tp: bool = False
    moments_dtype: str = "float32"
    grad_compression: str = "none"   # "none" | "int8" | "topk"

    # -- derived ---------------------------------------------------------------
    def batch_axes(self, mesh: Mesh) -> Axes:
        """Mesh axes carrying the batch dimension (pod axis included)."""
        if self.kv_shard == "seq":
            return ()                 # single-sequence decode: replicate batch
        pod = ("pod",) if "pod" in mesh.axis_names else ()
        return pod + _present(self.data_axes, mesh)

    def kv_seq_axes(self, mesh: Mesh) -> Axes:
        if self.kv_shard == "seq":
            pod = ("pod",) if "pod" in mesh.axis_names else ()
            return pod + _present(self.data_axes, mesh)
        if self.kv_shard == "batch_seq":
            return _present(self.model_axes, mesh)
        return ()

    def rules(self, mesh: Mesh) -> MeshRules:
        """Compile this config into the logical-axis table for ``mesh``."""
        model = _present(self.model_axes, mesh)
        return MeshRules(mesh=mesh, rules={
            "batch": self.batch_axes(mesh),
            "seq": model if self.seq_parallel else (),
            "heads": model,
            "kv_heads": model if self.kv_shard == "heads" else (),
            "ff": model,
            "mamba_ff": model if self.mamba_tp else (),
            "vocab": model,
            "expert": _present(self.expert_axes, mesh),
            "kv_seq": self.kv_seq_axes(mesh),
        })


# -- PartitionSpec derivation ---------------------------------------------------

def _axes_size(mesh: Mesh, axes: Axes) -> int:
    return math.prod(mesh.shape[a] for a in axes) if axes else 1


def _dim_entry(mesh: Mesh, axes: Axes, extent: int):
    """PartitionSpec entry for one dimension: ``axes`` when they divide
    ``extent``, else None (the subsystem-wide replication fallback)."""
    size = _axes_size(mesh, axes)
    if not axes or size <= 1 or extent < size or extent % size:
        return None
    return axes if len(axes) > 1 else axes[0]


def _is_shape_leaf(x: Any) -> bool:
    return hasattr(x, "shape")


def _weight_spec(shape: tuple[int, ...], mesh: Mesh,
                 scfg: ShardingConfig) -> P:
    """2D weight sharding: one dim over the model axes (TP), another over
    the FSDP axes — largest divisible dims win, replicate otherwise."""
    spec: list = [None] * len(shape)
    used: set[str] = set()
    for axes in (_present(scfg.model_axes, mesh),
                 _present(scfg.fsdp_axes, mesh)):
        # a mesh axis may appear in both roles (e.g. fsdp over the model
        # axes); it can shard only one dim of any given leaf
        axes = tuple(a for a in axes if a not in used)
        size = _axes_size(mesh, axes)
        if size <= 1:
            continue
        cands = sorted(
            (i for i in range(len(shape))
             if spec[i] is None and shape[i] >= size and shape[i] % size == 0),
            key=lambda i: (-shape[i], i))
        if cands:
            spec[cands[0]] = axes if len(axes) > 1 else axes[0]
            used.update(axes)
    return P(*spec)


def param_specs(shapes: Any, mesh: Mesh, scfg: ShardingConfig) -> Any:
    """PartitionSpec tree for a parameter (or parameter-shaped) tree."""
    return jax.tree.map(lambda l: _weight_spec(tuple(l.shape), mesh, scfg),
                        shapes, is_leaf=_is_shape_leaf)


def opt_specs(opt_shapes: Any, param_shapes: Any, mesh: Mesh,
              scfg: ShardingConfig) -> Any:
    """PartitionSpec tree for AdamW state ({m, v, count}).

    Moment leaves (fp32 mirrors, or int8 {q, scale, minv} blocks whose
    last axis is block-padded) get the same 2D weight treatment as the
    parameters they shadow; divisibility fallback handles the padding.
    ``param_shapes`` is accepted for API symmetry with the callers.
    """
    del param_shapes
    return jax.tree.map(lambda l: _weight_spec(tuple(l.shape), mesh, scfg),
                        opt_shapes, is_leaf=_is_shape_leaf)


def batch_specs(shapes: Any, mesh: Mesh, scfg: ShardingConfig) -> Any:
    """PartitionSpec tree for a host data batch: leading dim over the
    batch axes (when divisible), everything else replicated."""
    batch = scfg.batch_axes(mesh)

    def leaf(l) -> P:
        shape = tuple(l.shape)
        if not shape:
            return P()
        return P(_dim_entry(mesh, batch, shape[0]),
                 *([None] * (len(shape) - 1)))

    return jax.tree.map(leaf, shapes, is_leaf=_is_shape_leaf)


def cache_specs(shapes: Any, mesh: Mesh, scfg: ShardingConfig) -> Any:
    """PartitionSpec tree for stacked decode state.

    Leaves carry a leading per-group stack axis.  Attention KV caches —
    the 5-D ``(G, B, S, KV, hd)`` leaves keyed ``"k"``/``"v"`` — are laid
    out per ``kv_shard``; every other state leaf (SSM / RWKV / conv,
    including the 5-D ``"wkv"`` state) shards batch only.
    """
    batch = scfg.batch_axes(mesh)
    kv_seq = scfg.kv_seq_axes(mesh)
    kv_heads = (_present(scfg.model_axes, mesh)
                if scfg.kv_shard == "heads" else ())

    def leaf(path, l) -> P:
        shape = tuple(l.shape)
        key = getattr(path[-1], "key", None) if path else None
        if len(shape) == 5 and key in ("k", "v"):
            return P(None, _dim_entry(mesh, batch, shape[1]),
                     _dim_entry(mesh, kv_seq, shape[2]),
                     _dim_entry(mesh, kv_heads, shape[3]), None)
        if len(shape) >= 2:
            return P(None, _dim_entry(mesh, batch, shape[1]),
                     *([None] * (len(shape) - 2)))
        return P(*([None] * len(shape)))

    return jax.tree_util.tree_map_with_path(leaf, shapes,
                                            is_leaf=_is_shape_leaf)
