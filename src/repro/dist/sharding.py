"""Sharding configuration — stub (see ``repro.dist`` package docstring)."""

from __future__ import annotations

__all__ = ["ShardingConfig"]

_MSG = ("repro.dist.sharding is a stub (see src/repro/dist/__init__.py); "
        "the full sharding subsystem is a future PR")


class ShardingConfig:
    """Placeholder so imports and annotations resolve; unusable until the
    real subsystem lands."""

    def __init__(self, *_a, **_kw):
        raise NotImplementedError(_MSG)


def __getattr__(name: str):
    if name.startswith("__"):  # import machinery probes __path__ etc.
        raise AttributeError(name)
    raise NotImplementedError(f"{_MSG} (accessed {name!r})")
