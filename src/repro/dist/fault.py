"""Supervised restarts around a checkpointing training loop.

``run_with_restarts`` is the single-process supervisor: it invokes the
training callable, and on any exception re-invokes it so the loop's own
checkpoint auto-resume (``repro.launch.train.train_loop`` restores the
latest complete checkpoint and the data pipeline replays from the step
counter) continues the run.  Because checkpoints are atomic and the
pipeline is counter-indexed, the recovered trajectory is bitwise
identical to an uninterrupted run (tested in
``tests/test_fault_tolerance.py``).

``fail_at_step`` injects a one-shot failure into the *first* attempt —
the supervisor strips it from retries, mirroring a transient node loss
rather than a deterministic bug.  After ``max_restarts`` failed retries
the last exception propagates.
"""

from __future__ import annotations

import inspect
from dataclasses import dataclass, field
from typing import Any, Callable

__all__ = ["GroupFailure", "RestartReport", "run_with_restarts"]


class GroupFailure(RuntimeError):
    """A device group failed at dispatch or completion time.

    The shared failure type of both fault layers: the *training* path
    treats it like any other exception (``run_with_restarts`` retries
    from the last checkpoint), while the *serving* path recognizes it
    structurally — ``repro.runtime.ChunkedScheduler`` demotes the
    raising group, re-projects the surviving shares and re-dispatches
    the group's unfinished chunks to survivors (see
    ``docs/resilience.md``).  Fault injection
    (``repro.runtime.simulate.FaultInjector``) raises it for scripted
    kill/transient events so tests exercise exactly the production
    demotion path.
    """


def _accepts_fail_at_step(fn: Callable[..., Any]) -> bool:
    try:
        params = inspect.signature(fn).parameters.values()
    except (TypeError, ValueError):
        # not introspectable: fail closed — injecting anyway could raise a
        # TypeError the retry loop would silently absorb
        return False
    return any(p.kind is inspect.Parameter.VAR_KEYWORD
               or p.name == "fail_at_step" for p in params)


@dataclass
class RestartReport:
    """What the supervisor observed: total ``attempts`` (including the
    successful one), the failure messages, and the final result."""

    attempts: int
    failures: list[str] = field(default_factory=list)
    result: Any = None


def run_with_restarts(fn: Callable[..., Any], *, max_restarts: int = 3,
                      fail_at_step: int | None = None,
                      **kwargs: Any) -> RestartReport:
    """Run ``fn(**kwargs)`` under restart supervision.

    ``fn`` must be resumable: each invocation should pick up from its own
    durable state (for ``train_loop``, pass ``ckpt_dir``).  Returns a
    :class:`RestartReport`; raises the last exception once
    ``max_restarts`` retries are exhausted.
    """
    if fail_at_step is not None and not _accepts_fail_at_step(fn):
        # injecting into a fn that can't take the kwarg would raise a
        # TypeError that the supervisor dutifully retries without the
        # injection — the recovery path would never actually run
        raise TypeError(
            "fail_at_step injection requires fn to accept a "
            "'fail_at_step' keyword (as train_loop does)")
    failures: list[str] = []
    attempts = 0
    while True:
        attempts += 1
        call_kw = dict(kwargs)
        if attempts == 1 and fail_at_step is not None:
            call_kw["fail_at_step"] = fail_at_step
        try:
            result = fn(**call_kw)
        except Exception as e:  # noqa: BLE001 — supervisor boundary
            failures.append(f"{type(e).__name__}: {e}")
            if attempts > max_restarts:
                raise
            continue
        return RestartReport(attempts=attempts, failures=failures,
                             result=result)
