"""Fault tolerance — stub (see ``repro.dist`` package docstring)."""

from __future__ import annotations

__all__ = ["run_with_restarts"]

_MSG = ("repro.dist.fault is a stub (see src/repro/dist/__init__.py); "
        "fault tolerance is a future PR")


def run_with_restarts(*_a, **_kw):
    raise NotImplementedError(_MSG)


def __getattr__(name: str):
    if name.startswith("__"):  # import machinery probes __path__ etc.
        raise AttributeError(name)
    raise NotImplementedError(f"{_MSG} (accessed {name!r})")
