"""Distribution subsystem: mesh rules, compression, seq-decode, restarts.

The JAX analogue of the paper's work-distribution runtime, packaged as
four orthogonal substrates (see ``docs/dist.md`` for the usage guide and
``docs/ARCHITECTURE.md`` for the paper -> code map):

``sharding`` / ``api`` — the mesh-rules system.
    :class:`~repro.dist.sharding.ShardingConfig` declares how a workload
    maps onto mesh axes (data / model / FSDP / expert parallelism, KV
    layouts, microbatching, remat); ``scfg.rules(mesh)`` compiles it to a
    logical-axis table that :func:`~repro.dist.api.use_rules` installs
    around tracing and :func:`~repro.dist.api.constrain` consults from
    inside model code.  With no rules installed every annotation is the
    identity, so single-host paths are unaffected.

``compression`` — gradient wire formats.
    Per-tensor int8 and top-k substrates, the error-feedback wrapper
    (``compress_with_feedback``), a compressed all-reduce-mean, and
    ``wire_bytes`` accounting for the roofline's collective term.

``seq_decode`` — sequence-sharded decode attention.
    Flash-decode over a sequence-sharded KV cache with a cross-shard
    logsumexp combine; ``models.attention.decode_attention`` dispatches
    here whenever the active rules map ``"kv_seq"`` to real mesh axes.

``fault`` — supervised restarts.
    ``run_with_restarts`` re-invokes a checkpointing training loop after
    failures; combined with atomic checkpoints and the counter-indexed
    data pipeline the recovery is bitwise identical to an uninterrupted
    run.
"""

from . import api, compression, fault, seq_decode, sharding  # noqa: F401

__all__ = ["api", "compression", "fault", "seq_decode", "sharding"]
