"""Distribution subsystem — STUB package.

Model and launch code import sharding/compression primitives from here;
the real implementations (mesh rules, gradient compression, fault
tolerance, sequence-sharded decode) are a future PR.  This package exists
so that the single-host paths (models, core autotuner, kernels) import and
run today:

  * ``api.constrain`` is a no-op passthrough (single-host: nothing to
    constrain) and ``api.current_rules`` returns ``None`` (no mesh rules
    active), which the model code already treats as "run unsharded".
  * Everything else raises ``NotImplementedError`` with a pointer here.

``IS_STUB`` lets tests (see ``tests/conftest.py``) skip the suites that
exercise the real distributed behaviour.
"""

IS_STUB = True

from . import api  # noqa: E402,F401

__all__ = ["api", "IS_STUB"]
