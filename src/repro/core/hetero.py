"""Heterogeneous work distribution across JAX device groups.

The paper's runtime mapped onto a JAX cluster: two device groups of
different speed (host/accelerator there; mixed pod generations, or a
degraded/straggling pod, here) process complementary fractions of every
batch.  Both dispatches are asynchronous, so the step time is
``E = max(T_a, T_b)`` — exactly the paper's objective (Eq. 2) — and the
work fraction is the paper's tunable.

Two tuning modes:
  * ``proportional_rebalance`` — online controller from observed rates
    (straggler mitigation: a slowing group sheds work every step);
  * the full paper loop — ``Autotuner`` (SAM/SAML) over the fraction
    space with measured step times as the objective, for the initial
    configuration search.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Sequence

import numpy as np

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["DeviceGroup", "HeterogeneousRunner", "proportional_rebalance"]


def result_ready_time(result) -> float | None:
    """Exact completion instant of a dispatch result, when knowable.

    Emulated results (``repro.runtime.simulate.SimReadyAt``) expose
    ``ready_at`` — the absolute instant (wall or virtual clock) the
    result became ready; returning it makes timing independent of
    thread wake-up latency, which is what lets whole trajectories run
    on a deterministic :class:`~repro.runtime.simulate.VirtualClock`.
    Real ``jax.Array`` leaves have no such attribute: return ``None``
    and the caller falls back to reading its clock after blocking.
    """
    ts = None
    for leaf in jax.tree.leaves(result):
        t = getattr(leaf, "ready_at", None)
        if t is None:
            return None
        ts = t if ts is None else max(ts, t)
    return ts


@dataclass
class DeviceGroup:
    name: str
    devices: list                       # jax devices
    work_multiplier: int = 1            # test hook: emulate a slower group

    def mesh(self) -> Mesh:
        return Mesh(np.asarray(self.devices), ("data",))


def proportional_rebalance(fraction: float, t_a: float, t_b: float,
                           damping: float = 0.5,
                           min_fraction: float = 1e-3) -> float:
    """New fraction for group A from observed per-group times.

    Observed rates: r_a = f/t_a, r_b = (1-f)/t_b; the equal-finish-time
    split is r_a/(r_a+r_b).  ``damping`` smooths measurement noise.

    Degenerate measurements (zero or negative time on either side —
    clock skew, dropped timer) carry no rate information, so the current
    split is kept.  The result is always clamped to
    ``[min_fraction, 1 - min_fraction]``: a group may be starved of
    *almost* all work but never permanently — it keeps receiving a sliver
    of each batch, so a recovered straggler produces a finite time and
    wins work back.  (The N-group generalization is
    ``repro.runtime.scheduler.ewma_rebalance``.)
    """
    f = min(max(fraction, min_fraction), 1.0 - min_fraction)
    if t_a <= 0.0 or t_b <= 0.0:
        return float(f)
    r_a = f / t_a
    r_b = (1.0 - f) / t_b
    target = r_a / (r_a + r_b)
    out = (1 - damping) * f + damping * target
    return float(min(max(out, min_fraction), 1.0 - min_fraction))


class HeterogeneousRunner:
    """Split each batch between two device groups by a tunable fraction."""

    def __init__(self, step_builder: Callable[[DeviceGroup], Callable],
                 group_a: DeviceGroup, group_b: DeviceGroup,
                 fraction: float = 0.5, *, clock=None):
        """``step_builder(group)`` returns ``fn(batch_rows) -> result`` that
        runs on that group's devices (the builder jits with the group's
        mesh).  ``fraction`` is group A's share of each batch.  ``clock``
        (anything with ``now()``, e.g. a ``runtime.simulate.VirtualClock``
        shared with a simulated builder) replaces the wall clock so
        simulated trajectories are deterministic."""
        self.group_a = group_a
        self.group_b = group_b
        self.fraction = fraction
        self.clock = clock
        self._fn_a = step_builder(group_a)
        self._fn_b = step_builder(group_b)
        self.history: list[dict] = []

    def _now(self) -> float:
        return self.clock.now() if self.clock is not None \
            else time.perf_counter()

    def _split(self, batch: dict) -> tuple[dict, dict]:
        n = jax.tree.leaves(batch)[0].shape[0]
        ga, gb = len(self.group_a.devices), len(self.group_b.devices)
        n_a = int(round(n * self.fraction / ga)) * ga
        n_a = min(max(n_a, ga), n - gb)
        a = jax.tree.map(lambda x: x[:n_a], batch)
        b = jax.tree.map(lambda x: x[n_a:], batch)
        return a, b

    @staticmethod
    def _block(result) -> None:
        # duck-typed so step functions may return anything with jax.Array
        # block semantics (e.g. a simulated-device result in tests)
        for leaf in jax.tree.leaves(result):
            blocker = getattr(leaf, "block_until_ready", None)
            if blocker is not None:
                blocker()

    def step(self, batch: dict, rebalance: bool = True) -> dict:
        a, b = self._split(batch)
        t0 = self._now()
        ra = self._fn_a(a)                      # async dispatch
        rb = self._fn_b(b)                      # overlaps with group A
        self._block(ra)
        ready_a = result_ready_time(ra)
        t_a = (ready_a if ready_a is not None else self._now()) - t0
        self._block(rb)
        ready_b = result_ready_time(rb)
        t_b = (ready_b if ready_b is not None else self._now()) - t0
        rec = {
            "fraction": self.fraction,
            "t_a": t_a, "t_b": t_b, "t_step": max(t_a, t_b),
            "rows_a": jax.tree.leaves(a)[0].shape[0],
            "rows_b": jax.tree.leaves(b)[0].shape[0],
        }
        self.history.append(rec)
        if rebalance:
            self.fraction = proportional_rebalance(self.fraction, t_a, t_b)
        return rec

    # -- the paper's offline search over the fraction space -------------------
    def workload(self, batch: dict) -> dict:
        """Workload-signature payload for the tuning cache: batch shapes
        plus the device-group topology (see ``repro.runtime.store``)."""
        shapes = {k: (tuple(v.shape), str(getattr(v, "dtype", "")))
                  for k, v in sorted(batch.items())}
        groups = [(g.name, len(g.devices), g.work_multiplier)
                  for g in (self.group_a, self.group_b)]
        return {"batch": shapes, "groups": groups}

    def tuning_session(self, batch: dict, *, store=None, **session_kw):
        """A ``repro.tune.TuningSession`` over this runner's fraction space.

        The evaluator dispatches the batch at the candidate fraction and
        returns the measured step metrics (``time`` = max(T_a, T_b), the
        per-group times under ``t_host``/``t_device`` so an ``online=``
        surrogate loop can consume them).  ``store`` (a
        ``repro.runtime.store.TuningStore`` or a path) caches results
        under this workload's signature.
        """
        from ..tune import TuningSession
        from .space import ConfigSpace, Param

        space = ConfigSpace([Param("fraction", tuple(range(5, 100, 5)))])

        def measure(cfg):
            self.fraction = cfg["fraction"] / 100.0
            rec = self.step(batch, rebalance=False)
            return {"time": rec["t_step"], "t_host": rec["t_a"],
                    "t_device": rec["t_b"]}

        return TuningSession(
            space, evaluator=measure, store=store,
            workload=self.workload(batch) if store is not None else None,
            **session_kw)

    def tune_fraction(self, batch: dict, *, strategy: str = "sam",
                      iterations: int = 30, seed: int = 0, store=None,
                      **session_kw) -> float:
        """Tune the work fraction with any registered strategy (default:
        the paper's SAM — simulated annealing with measured step times)
        and apply the winner."""
        session = self.tuning_session(batch, store=store, **session_kw)
        result = session.run(strategy, iterations=iterations, seed=seed)
        self.fraction = result.best_config["fraction"] / 100.0
        return self.fraction

    def tune_fraction_sa(self, batch: dict, *, iterations: int = 30,
                         seed: int = 0, store=None) -> float:
        """Deprecated alias of ``tune_fraction(strategy="sam")``.

        .. deprecated:: use :meth:`tune_fraction` (or build a
           :meth:`tuning_session` directly) — same seeded search, same
           cache behaviour.
        """
        import warnings
        warnings.warn(
            "HeterogeneousRunner.tune_fraction_sa is deprecated; use "
            "tune_fraction(strategy='sam') / tuning_session(...) "
            "(see docs/tune.md)", DeprecationWarning, stacklevel=2)
        return self.tune_fraction(batch, strategy="sam",
                                  iterations=iterations, seed=seed,
                                  store=store)
