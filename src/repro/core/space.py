"""Discrete configuration spaces for combinatorial optimization.

The paper (Memeti & Pllana, ICPPW'16) searches a product space of discrete
parameters (threads, affinity, workload fraction).  ``ConfigSpace`` is the
generic substrate: an ordered set of named parameters, each with a finite
value tuple, plus the three operations every search strategy needs:

  * ``random``     — uniform sample (SA initialisation),
  * ``neighbor``   — local move (SA proposal): ordinal parameters step to an
                     adjacent value, categorical parameters resample,
  * ``encode``     — map a config to a numeric feature vector for the
                     machine-learning evaluator (ordinal -> value,
                     categorical -> one-hot).

Configs are plain dicts ``{param_name: value}``; an index-vector codec
(``to_indices``/``from_indices``) supports the vectorized JAX SA chains.
"""

from __future__ import annotations

import itertools
import math
from dataclasses import dataclass, field
from typing import Any, Iterator, Mapping, Sequence

import numpy as np

__all__ = ["Param", "ConfigSpace"]


@dataclass(frozen=True)
class Param:
    """One discrete parameter.

    ``ordinal=True`` means the values have a meaningful order (e.g. thread
    counts, workload fraction): neighbor moves step to adjacent values and
    the ML encoding uses the numeric value.  Categorical parameters (e.g.
    thread affinity) resample uniformly and are one-hot encoded.
    """

    name: str
    values: tuple
    ordinal: bool = True

    def __post_init__(self):
        if len(self.values) == 0:
            raise ValueError(f"parameter {self.name!r} has no values")
        if len(set(self.values)) != len(self.values):
            raise ValueError(f"parameter {self.name!r} has duplicate values")

    @property
    def cardinality(self) -> int:
        return len(self.values)


class ConfigSpace:
    """Cartesian product of discrete parameters."""

    def __init__(self, params: Sequence[Param]):
        if not params:
            raise ValueError("empty config space")
        names = [p.name for p in params]
        if len(set(names)) != len(names):
            raise ValueError("duplicate parameter names")
        self.params: tuple[Param, ...] = tuple(params)
        self._by_name = {p.name: p for p in self.params}
        self._value_index = {
            p.name: {v: i for i, v in enumerate(p.values)} for p in self.params
        }

    # -- basic structure ----------------------------------------------------
    @property
    def names(self) -> tuple[str, ...]:
        return tuple(p.name for p in self.params)

    def __getitem__(self, name: str) -> Param:
        return self._by_name[name]

    def size(self) -> int:
        """Total number of configurations (Eq. 1 of the paper)."""
        return math.prod(p.cardinality for p in self.params)

    def validate(self, cfg: Mapping[str, Any]) -> None:
        for p in self.params:
            if p.name not in cfg:
                raise KeyError(f"config missing parameter {p.name!r}")
            if cfg[p.name] not in self._value_index[p.name]:
                raise ValueError(
                    f"value {cfg[p.name]!r} not in domain of {p.name!r}"
                )

    # -- sampling and local moves -------------------------------------------
    def random(self, rng: np.random.Generator) -> dict:
        return {p.name: p.values[rng.integers(p.cardinality)] for p in self.params}

    def neighbor(self, cfg: Mapping[str, Any], rng: np.random.Generator,
                 n_moves: int = 1) -> dict:
        """Propose a nearby configuration by perturbing ``n_moves`` parameters."""
        new = dict(cfg)
        # choose distinct parameters to move
        idxs = rng.choice(len(self.params), size=min(n_moves, len(self.params)),
                          replace=False)
        for i in np.atleast_1d(idxs):
            p = self.params[int(i)]
            cur = self._value_index[p.name][new[p.name]]
            if p.ordinal and p.cardinality > 1:
                # step +-1 or +-2 (paper's SA moves within value neighbourhoods)
                step = int(rng.integers(1, 3)) * (1 if rng.random() < 0.5 else -1)
                nxt = min(max(cur + step, 0), p.cardinality - 1)
                if nxt == cur:  # bounced off the boundary: go the other way
                    nxt = min(max(cur - step, 0), p.cardinality - 1)
            else:
                nxt = int(rng.integers(p.cardinality))
            new[p.name] = p.values[nxt]
        return new

    def enumerate(self) -> Iterator[dict]:
        """All configurations — the paper's 'enumeration (brute force)'."""
        for combo in itertools.product(*(p.values for p in self.params)):
            yield dict(zip(self.names, combo))

    # -- batched enumeration (vectorized search engine) ----------------------
    def index_grid(self) -> np.ndarray:
        """All configurations as value-index rows, shape (size, n_params).

        Row order matches ``enumerate()`` (last parameter varies fastest),
        so ``from_indices(index_grid()[k])`` is the k-th enumerated config.
        """
        cards = self.cardinalities
        return np.indices(cards).reshape(len(cards), -1).T.astype(np.int32)

    def enumerate_columns(self, grid: np.ndarray | None = None
                          ) -> dict[str, np.ndarray]:
        """All configurations as per-parameter value columns (size,) each.

        The column-oriented view is what batched oracles consume: no
        per-config dicts are materialized anywhere on the batched path.
        Pass a precomputed ``index_grid()`` to avoid rebuilding it.
        """
        if grid is None:
            grid = self.index_grid()
        return {
            p.name: np.asarray(p.values)[grid[:, i]]
            for i, p in enumerate(self.params)
        }

    def encode_all(self) -> np.ndarray:
        """Feature matrix for the whole space, shape (size, feature_dim).

        Vectorized equivalent of stacking ``encode`` over ``enumerate()``
        (same row order), built by gathering ``index_feature_table`` rows.
        """
        return self.encode_indices(self.index_grid())

    def encode_indices(self, grid: np.ndarray) -> np.ndarray:
        """Encode index rows (n, n_params) into features (n, feature_dim)."""
        grid = np.asarray(grid, dtype=np.int64)
        table, _ = self.index_feature_table()
        out = np.zeros((grid.shape[0], self.feature_dim))
        for i in range(len(self.params)):
            out += table[i, grid[:, i], :]
        return out

    def enumerate_encoded(self) -> tuple[np.ndarray, np.ndarray]:
        """(index_grid, feature_matrix) for the whole space, enumerate order."""
        grid = self.index_grid()
        return grid, self.encode_indices(grid)

    # -- index-vector codec (for vectorized SA) ------------------------------
    def to_indices(self, cfg: Mapping[str, Any]) -> np.ndarray:
        return np.array(
            [self._value_index[p.name][cfg[p.name]] for p in self.params],
            dtype=np.int32,
        )

    def from_indices(self, idx: Sequence[int]) -> dict:
        return {
            p.name: p.values[int(i)] for p, i in zip(self.params, idx, strict=True)
        }

    @property
    def cardinalities(self) -> np.ndarray:
        return np.array([p.cardinality for p in self.params], dtype=np.int32)

    # -- ML feature encoding --------------------------------------------------
    @property
    def feature_dim(self) -> int:
        return sum(1 if p.ordinal else p.cardinality for p in self.params)

    @property
    def feature_names(self) -> list[str]:
        out: list[str] = []
        for p in self.params:
            if p.ordinal:
                out.append(p.name)
            else:
                out.extend(f"{p.name}={v}" for v in p.values)
        return out

    def encode(self, cfg: Mapping[str, Any]) -> np.ndarray:
        """Config -> float feature vector (ordinal value / categorical one-hot)."""
        feats: list[float] = []
        for p in self.params:
            if p.ordinal:
                feats.append(float(cfg[p.name]))
            else:
                one_hot = [0.0] * p.cardinality
                one_hot[self._value_index[p.name][cfg[p.name]]] = 1.0
                feats.extend(one_hot)
        return np.asarray(feats, dtype=np.float64)

    def encode_many(self, cfgs: Sequence[Mapping[str, Any]]) -> np.ndarray:
        return np.stack([self.encode(c) for c in cfgs]) if cfgs else \
            np.zeros((0, self.feature_dim))

    # Encoding table used by the vectorized (index-based) JAX SA: row i maps
    # value-index -> feature columns for parameter i.
    def index_feature_table(self) -> tuple[np.ndarray, np.ndarray]:
        """Returns (table, col_offsets).

        ``table[i, j, :]`` is the feature contribution of parameter ``i``
        taking value-index ``j``, padded to the max cardinality; summing the
        per-parameter rows into their column ranges reproduces ``encode``.
        """
        max_card = int(self.cardinalities.max())
        table = np.zeros((len(self.params), max_card, self.feature_dim))
        col = 0
        offsets = []
        for i, p in enumerate(self.params):
            offsets.append(col)
            if p.ordinal:
                for j, v in enumerate(p.values):
                    table[i, j, col] = float(v)
                col += 1
            else:
                for j in range(p.cardinality):
                    table[i, j, col + j] = 1.0
                col += p.cardinality
        return table, np.asarray(offsets, dtype=np.int32)

    def __repr__(self) -> str:
        inner = ", ".join(f"{p.name}[{p.cardinality}]" for p in self.params)
        return f"ConfigSpace({inner}, size={self.size()})"


def paper_space(workload_step: int = 1) -> ConfigSpace:
    """The exact parameter space of the paper (Table I).

    ``workload_step=1`` gives fractions {0..100} and a total of
    7*9*3*3*101 = 57,267 raw combinations; the paper reports 19,926
    *experiments* because host-only/device-only rows collapse the other
    side's parameters.  ``ConfigSpace`` counts raw combinations; the
    effort accounting in the autotuner de-duplicates collapsed configs.
    """
    return ConfigSpace([
        Param("host_threads", (2, 4, 6, 12, 24, 36, 48)),
        Param("device_threads", (2, 4, 8, 16, 30, 60, 120, 180, 240)),
        Param("host_affinity", ("none", "scatter", "compact"), ordinal=False),
        Param("device_affinity", ("balanced", "scatter", "compact"), ordinal=False),
        Param("host_fraction", tuple(range(0, 101, workload_step))),
    ])
