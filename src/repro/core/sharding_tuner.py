"""The paper's method applied to the pod-scale distribution config space.

This is the framework's first-class integration of the contribution: the
system configuration of a (model x workload x 256-chip pod) — mesh
factorization, microbatch count, remat, FSDP, sequence parallelism, KV
layout — is a discrete space exactly like the paper's (threads, affinity,
fraction).  A *measurement* is a full ``.lower().compile()`` + trip-
weighted collective census + roofline evaluation (tens of seconds, like
the paper's minutes-long runs: expensive enough that search-budget
reduction matters).  The *surrogate* is the same from-scratch BDTR over
encoded configs.  SAM / SAML / EM then transfer unchanged.

Objective: the roofline step-time bound max(compute, memory, collective)
— the pod-level analogue of E = max(T_host, T_device).
"""

from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from typing import Any

import numpy as np

import jax

from ..dist.sharding import ShardingConfig
from ..launch import policies, shapes, steps
from ..launch.mesh import make_production_mesh, set_mesh
from ..models.config import ArchConfig
from ..roofline import analysis
from ..roofline.hlo import collective_census
from ..tune import TuneResult, TuningSession
from .bdtr import BoostedTreesRegressor
from .space import ConfigSpace, Param

__all__ = ["ShardingTuner", "sharding_space", "evaluate_config"]


def sharding_space(cell: shapes.ShapeCell) -> ConfigSpace:
    """Discrete distribution-config space for one shape cell."""
    params = [
        Param("mesh_factor", ((8, 32), (16, 16), (32, 8), (64, 4))),
        Param("logit_chunk", (128, 256, 512)),
    ]
    if cell.kind == "train":
        params += [
            Param("microbatches", (1, 2, 4, 8, 16)),
            Param("remat", ("full", "save_dots", "none"), ordinal=False),
            Param("fsdp", (True, False), ordinal=False),
            Param("seq_parallel", (True, False), ordinal=False),
            Param("mamba_tp", (True, False), ordinal=False),
        ]
    else:
        params += [
            Param("kv_shard", ("heads", "batch_seq", "seq", "none"),
                  ordinal=False),
            Param("fsdp", (True, False), ordinal=False),
        ]
    return ConfigSpace(params)


def _to_scfg(point: dict, cell: shapes.ShapeCell) -> ShardingConfig:
    if cell.kind == "train":
        return ShardingConfig(
            data_axes=("data",), model_axes=("model",),
            fsdp_axes=("data",) if point["fsdp"] else (),
            microbatches=int(point["microbatches"]),
            remat=point["remat"] != "none",
            remat_policy=(point["remat"] if point["remat"] != "none"
                          else "full"),
            seq_parallel=bool(point["seq_parallel"]),
            mamba_tp=bool(point["mamba_tp"]),
        )
    return ShardingConfig(
        data_axes=("data",), model_axes=("model",),
        fsdp_axes=("data",) if point["fsdp"] else (),
        kv_shard=str(point["kv_shard"]),
        remat=False,
    )


def _valid(point: dict, cfg: ArchConfig, cell: shapes.ShapeCell) -> bool:
    d_axis = point["mesh_factor"][0]
    if cell.kind == "train":
        per = cell.global_batch // int(point["microbatches"])
        if per * int(point["microbatches"]) != cell.global_batch:
            return False
        if per % d_axis and d_axis % per:
            return False
    if cell.kind != "train" and point["kv_shard"] == "seq" \
            and cell.global_batch > 1:
        return False
    return True


def evaluate_config(arch_cfg: ArchConfig, cell: shapes.ShapeCell,
                    point: dict, *, mode: str = "analytic",
                    hw: analysis.HW = analysis.V5E) -> dict:
    """One 'experiment': evaluate a distribution config point.

    mode="analytic": instant (ledger + analytic collectives).
    mode="compiled": lower+compile on the production mesh, trip-weighted
    census for collectives (the real measurement; tens of seconds).
    """
    d, m = point["mesh_factor"]
    cfg = dataclasses.replace(
        policies.arch_for_cell(arch_cfg, cell),
        logit_chunk=int(point["logit_chunk"]))
    scfg = _to_scfg(point, cell)
    n_chips = d * m
    ledger = analysis.analytic_cost(cfg, cell, scfg, n_chips=n_chips)
    if mode == "analytic":
        coll = analysis.analytic_collective_bytes(cfg, cell, scfg,
                                                  n_chips=n_chips)
        peak_gb = None
        t_wall = 0.0
    else:
        t0 = time.time()
        mesh = make_production_mesh(shape=(d, m), axes=("data", "model"))
        with set_mesh(mesh):
            if cell.kind == "train":
                bundle = steps.make_train_step(
                    cfg, scfg, mesh, policies.default_opt(cfg),
                    shapes.batch_specs_for(cfg, cell))
            elif cell.kind == "prefill":
                bundle = steps.make_prefill_step(
                    cfg, scfg, mesh, shapes.batch_specs_for(cfg, cell),
                    max_len=cell.seq_len)
            else:
                bundle = steps.make_serve_step(cfg, scfg, mesh,
                                               cell.global_batch,
                                               cell.seq_len)
            compiled = bundle.lower().compile()
            census = collective_census(compiled.as_text())
            ma = compiled.memory_analysis()
        coll = census["transfer_bytes_per_step"]
        peak_gb = (ma.argument_size_in_bytes + ma.output_size_in_bytes
                   + ma.temp_size_in_bytes - ma.alias_size_in_bytes) / 2**30
        t_wall = time.time() - t0
    terms = analysis.roofline_terms(ledger, coll, n_chips, hw)
    # memory-capacity penalty: infeasible configs must lose the search
    hbm_cap = hw.hbm_gb * 1.0
    if peak_gb is not None and peak_gb > 2.5 * hbm_cap:
        terms["step_time_bound_s"] *= 10.0
    return {**terms, "peak_gb": peak_gb, "eval_seconds": t_wall,
            "collective_bytes": coll, "point": dict(point)}


@dataclass
class ShardingTuner:
    """EM / SAM / SAML over the distribution space of one (arch x cell)."""

    arch_cfg: ArchConfig
    cell: shapes.ShapeCell
    mode: str = "analytic"            # evaluator for 'measurements'
    history: list = field(default_factory=list)

    def __post_init__(self):
        self.space = sharding_space(self.cell)
        self._cache: dict[tuple, float] = {}
        self.n_measurements = 0

    def _energy(self, point: dict) -> float:
        key = tuple(point[n] for n in self.space.names)
        if key in self._cache:
            return self._cache[key]
        if not _valid(point, self.arch_cfg, self.cell):
            return 1e9
        rec = evaluate_config(self.arch_cfg, self.cell, point, mode=self.mode)
        self.n_measurements += 1
        e = rec["step_time_bound_s"]
        self._cache[key] = e
        self.history.append(rec)
        return e

    def session(self, *, store=None, surrogate=None,
                **session_kw) -> TuningSession:
        """A ``repro.tune.TuningSession`` over this cell's config space.

        The evaluator is the roofline measurement (``self._energy``,
        internally cached + validity-penalised); ``surrogate`` may be a
        plain ``point -> predicted bound`` callable (see
        :meth:`fit_surrogate`).  ``store`` caches results under the
        (arch, cell, mode) workload signature.
        """
        return TuningSession(
            self.space, evaluator=self._energy, surrogate=surrogate,
            store=store, workload=self._workload() if store is not None
            else None, **session_kw)

    def _workload(self) -> dict:
        return {"arch": self.arch_cfg.name, "cell": self.cell.name,
                "mode": self.mode}

    def fit_surrogate(self, *, train_samples: int = 40, seed: int = 0):
        """Sample+measure valid points and fit the BDTR surrogate.

        Returns a plain ``point -> predicted bound`` callable (invalid
        points score 1e9, as in the measurement path) usable as the
        ``surrogate=`` of a session — the sharding analogue of the
        paper's one-time training grid.
        """
        rng = np.random.default_rng(seed)
        X, y = [], []
        while len(y) < train_samples:
            point = self.space.random(rng)
            if not _valid(point, self.arch_cfg, self.cell):
                continue
            e = self._energy(point)
            X.append(self._encode(point))
            y.append(e)
        model = BoostedTreesRegressor(n_estimators=120, max_depth=4,
                                      seed=seed).fit(np.stack(X),
                                                     np.asarray(y))

        def predicted(point):
            if not _valid(point, self.arch_cfg, self.cell):
                return 1e9
            return float(model.predict(self._encode(point)[None, :])[0])

        return predicted

    def tune_sam(self, iterations: int = 60, seed: int = 0) -> TuneResult:
        """The paper's SAM over the distribution space (roofline energy)."""
        return self.session().run("sam", iterations=iterations, seed=seed)

    def tune_saml(self, *, train_samples: int = 40, iterations: int = 2000,
                  seed: int = 0) -> TuneResult:
        """Paper's SAML: sample+measure, fit BDTR, SA on the surrogate.

        The search runs on the fitted surrogate; the suggested
        configuration is then measured once (the session's ground-truth
        re-scoring — the paper's final check)."""
        surrogate = self.fit_surrogate(train_samples=train_samples,
                                       seed=seed)
        # the session's ground-truth re-scoring measures the suggested
        # config once through self._energy (the evaluator fallback)
        return self.session(surrogate=surrogate).run(
            "saml", iterations=iterations, seed=seed)

    def _encode(self, point: dict) -> np.ndarray:
        feats = []
        for p in self.space.params:
            v = point[p.name]
            if p.name == "mesh_factor":
                feats.extend([float(v[0]), float(v[1])])
            elif p.ordinal:
                feats.append(float(v))
            else:
                feats.extend([1.0 if v == val else 0.0 for val in p.values])
        return np.asarray(feats)

    def baseline(self) -> dict:
        """The static default policy's roofline (paper-faithful baseline)."""
        scfg = policies.default_sharding(self.arch_cfg, self.cell)
        point = {
            "mesh_factor": (16, 16),
            "logit_chunk": 256,
        }
        if self.cell.kind == "train":
            point.update(microbatches=scfg.microbatches,
                         remat="full" if scfg.remat else "none",
                         fsdp=bool(scfg.fsdp_axes),
                         seq_parallel=scfg.seq_parallel,
                         mamba_tp=scfg.mamba_tp)
        else:
            point.update(kv_shard=scfg.kv_shard, fsdp=bool(scfg.fsdp_axes))
        return evaluate_config(self.arch_cfg, self.cell, point,
                               mode=self.mode)
