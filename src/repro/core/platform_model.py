"""Parametric performance model of the paper's experimental platform.

The paper measures a DNA-sequence-analysis application on "Emil": a host
with 2x Intel Xeon E5-2695v2 (48 hw threads, 30 MB L3, ~59.7 GB/s) plus an
Intel Xeon Phi 7120P (61 cores / 244 threads, 352 GB/s, PCIe-attached).
This container is CPU-only, so the *faithful reproduction* replaces the
physical node with a calibrated analytic model with the same observable
structure the paper reports:

  * saturating thread-scaling on both sides (memory-bound stream workload),
  * affinity multipliers (compact hurts, scatter/balanced help),
  * offload overhead on the device side = fixed runtime startup + PCIe
    transfer proportional to the offloaded bytes,
  * mild cache superlinearity (smaller working set -> lower per-byte cost;
    both sides have ~30 MB LLC, so partial fractions run disproportionately
    faster — this is what makes the tuned split beat the naive
    rate-proportional split, as in the paper's measurements),
  * multiplicative lognormal measurement noise (seeded, reproducible).

Calibration targets (from the paper): host-side execution times span
~0.74-5.5 s and device-side ~0.9-42 s across the measured grid; the best
split sits around 60/40-70/30 host/device for large inputs with 48 host
threads (Fig. 2b); tuned-vs-host-only speedup ~1.7-1.95x and
tuned-vs-device-only ~2.1-2.36x (Tables VIII-IX).  ``tests/test_platform_model.py``
asserts these bands.

The model evaluates E = max(T_host, T_device) (paper Eq. 2) — host and
device shares run concurrently under the offload-overlap execution model.

Beyond the paper, the model also carries an **energy column** (joules):
each side draws base + per-thread watts while its share runs (the Phi is
the power-hungry side), enabling the energy-aware objectives of
``repro.tune`` (``metrics`` / ``metrics_batch`` / ``evaluator`` return
``{"time", "energy", "t_host", "t_device"}`` records).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Mapping

import numpy as np

__all__ = ["EmilPlatformModel", "DATASETS_GB"]

# Real-world DNA sequence sizes used in the paper (GB).
DATASETS_GB: dict[str, float] = {
    "human": 3.17,
    "mouse": 2.77,
    "cat": 2.43,
    "dog": 2.38,
}


@dataclass(frozen=True)
class EmilPlatformModel:
    """Analytic execution-time model for one (host, device) node."""

    # Host: saturating rate R(h) = rate_max * h / (h + k)  [GB/s]
    host_rate_max: float = 2.0
    host_rate_k: float = 6.0
    # Device (Xeon Phi): needs many threads to saturate.
    device_rate_max: float = 3.5
    device_rate_k: float = 80.0
    # Offload overhead: fixed runtime startup + PCIe transfer of the share.
    device_startup_s: float = 0.35
    pcie_gbps: float = 6.0
    # Cache superlinearity: per-byte cost multiplier  c0 + c1 * min(1, GB/ref)
    host_cache_c0: float = 0.76
    host_cache_c1: float = 0.24
    device_cache_c0: float = 0.80
    device_cache_c1: float = 0.20
    cache_ref_gb: float = 3.2
    # Affinity multipliers on execution time.
    host_affinity_mult: Mapping[str, float] | None = None
    device_affinity_mult: Mapping[str, float] | None = None
    # Measurement noise (lognormal sigma); 0 disables.
    noise_sigma: float = 0.015
    # Power draw (watts) for the energy-to-solution column: each side
    # consumes base + per-thread power while its share runs.  Defaults
    # approximate the platform's TDPs (2x Xeon E5-2695v2 ~230 W total at
    # 48 threads; Xeon Phi 7120P ~300 W at 240 threads) — the Phi is the
    # power-hungry side, so time- and energy-optimal splits differ.
    host_base_w: float = 80.0
    host_thread_w: float = 3.2
    device_base_w: float = 110.0
    device_thread_w: float = 0.85

    _DEFAULT_HOST_AFF = {"none": 1.00, "scatter": 0.98, "compact": 1.10}
    _DEFAULT_DEVICE_AFF = {"balanced": 0.96, "scatter": 1.00, "compact": 1.12}

    def _host_aff(self, aff: str) -> float:
        table = self.host_affinity_mult or self._DEFAULT_HOST_AFF
        return table[aff]

    def _device_aff(self, aff: str, threads: int) -> float:
        table = self.device_affinity_mult or self._DEFAULT_DEVICE_AFF
        m = table[aff]
        # compact packs 4 threads/core: with few threads it strands cores.
        if aff == "compact" and threads <= 60:
            m *= 1.10
        return m

    # -- component times -------------------------------------------------------
    def host_time(self, gb: float, threads: int, affinity: str) -> float:
        """Noise-free host execution time for ``gb`` of input."""
        if gb <= 0.0:
            return 0.0
        rate = self.host_rate_max * threads / (threads + self.host_rate_k)
        cache = self.host_cache_c0 + self.host_cache_c1 * min(
            1.0, gb / self.cache_ref_gb
        )
        return gb / rate * self._host_aff(affinity) * cache

    def device_time(self, gb: float, threads: int, affinity: str) -> float:
        """Noise-free device execution time (incl. offload overhead)."""
        if gb <= 0.0:
            return 0.0
        rate = self.device_rate_max * threads / (threads + self.device_rate_k)
        cache = self.device_cache_c0 + self.device_cache_c1 * min(
            1.0, gb / self.cache_ref_gb
        )
        compute = gb / rate * self._device_aff(affinity, threads) * cache
        return self.device_startup_s + gb / self.pcie_gbps + compute

    # -- vectorized component times --------------------------------------------
    @staticmethod
    def _aff_lookup(aff: np.ndarray, table: Mapping[str, float]) -> np.ndarray:
        """Vectorized table lookup; unknown names raise like the scalar path."""
        out = np.empty(len(aff))
        seen = np.zeros(len(aff), dtype=bool)
        for name, mult in table.items():
            m = aff == name
            out[m] = mult
            seen |= m
        if not seen.all():
            raise KeyError(str(np.unique(aff[~seen]).tolist()))
        return out

    def _host_aff_array(self, aff: np.ndarray) -> np.ndarray:
        return self._aff_lookup(
            aff, self.host_affinity_mult or self._DEFAULT_HOST_AFF)

    def _device_aff_array(self, aff: np.ndarray, threads: np.ndarray
                          ) -> np.ndarray:
        out = self._aff_lookup(
            aff, self.device_affinity_mult or self._DEFAULT_DEVICE_AFF)
        return np.where((aff == "compact") & (threads <= 60), out * 1.10, out)

    def host_time_batch(self, gb: np.ndarray, threads: np.ndarray,
                        affinity: np.ndarray) -> np.ndarray:
        """Vectorized ``host_time`` over aligned arrays."""
        gb = np.asarray(gb, dtype=np.float64)
        threads = np.asarray(threads, dtype=np.float64)
        rate = self.host_rate_max * threads / (threads + self.host_rate_k)
        cache = self.host_cache_c0 + self.host_cache_c1 * np.minimum(
            1.0, gb / self.cache_ref_gb
        )
        t = gb / rate * self._host_aff_array(np.asarray(affinity)) * cache
        return np.where(gb > 0.0, t, 0.0)

    def device_time_batch(self, gb: np.ndarray, threads: np.ndarray,
                          affinity: np.ndarray) -> np.ndarray:
        """Vectorized ``device_time`` over aligned arrays."""
        gb = np.asarray(gb, dtype=np.float64)
        threads = np.asarray(threads, dtype=np.float64)
        rate = self.device_rate_max * threads / (threads + self.device_rate_k)
        cache = self.device_cache_c0 + self.device_cache_c1 * np.minimum(
            1.0, gb / self.cache_ref_gb
        )
        compute = (gb / rate * cache
                   * self._device_aff_array(np.asarray(affinity), threads))
        t = self.device_startup_s + gb / self.pcie_gbps + compute
        return np.where(gb > 0.0, t, 0.0)

    def energy_batch(self, columns: Mapping[str, np.ndarray],
                     dataset_gb: float,
                     rng: np.random.Generator | None = None) -> np.ndarray:
        """Vectorized ``energy`` over a column-oriented batch of configs.

        ``columns`` maps the paper's parameter names to aligned value
        arrays (e.g. ``ConfigSpace.enumerate_columns()``).  One call
        replaces ``space.size()`` scalar measurements; noise draws are
        independent per entry, as in repeated scalar calls.
        """
        f = np.asarray(columns["host_fraction"], dtype=np.float64) / 100.0
        th = self.host_time_batch(dataset_gb * f,
                                  np.asarray(columns["host_threads"]),
                                  np.asarray(columns["host_affinity"]))
        td = self.device_time_batch(dataset_gb * (1.0 - f),
                                    np.asarray(columns["device_threads"]),
                                    np.asarray(columns["device_affinity"]))
        if rng is not None and self.noise_sigma > 0:
            th = th * np.where(th > 0,
                               np.exp(rng.normal(0.0, self.noise_sigma,
                                                 th.shape)), 1.0)
            td = td * np.where(td > 0,
                               np.exp(rng.normal(0.0, self.noise_sigma,
                                                 td.shape)), 1.0)
        return np.maximum(th, td)

    # -- the measurement oracle -------------------------------------------------
    def measure(self, config: Mapping, dataset_gb: float,
                rng: np.random.Generator | None = None) -> tuple[float, float]:
        """(T_host, T_device) for a full system configuration.

        ``config`` uses the paper's parameter names (see ``space.paper_space``):
        host_threads, device_threads, host_affinity, device_affinity,
        host_fraction (percent of work mapped to the host).
        """
        f = float(config["host_fraction"]) / 100.0
        th = self.host_time(dataset_gb * f, int(config["host_threads"]),
                            str(config["host_affinity"]))
        td = self.device_time(dataset_gb * (1.0 - f),
                              int(config["device_threads"]),
                              str(config["device_affinity"]))
        if rng is not None and self.noise_sigma > 0:
            th *= math.exp(rng.normal(0.0, self.noise_sigma)) if th > 0 else 1.0
            td *= math.exp(rng.normal(0.0, self.noise_sigma)) if td > 0 else 1.0
        return th, td

    def energy(self, config: Mapping, dataset_gb: float,
               rng: np.random.Generator | None = None) -> float:
        """E = max(T_host, T_device)   (paper Eq. 2)."""
        th, td = self.measure(config, dataset_gb, rng)
        return max(th, td)

    # -- the energy column (joules) and multi-metric oracles ---------------------
    def _power_w(self, host_threads: Any, device_threads: Any
                 ) -> tuple[Any, Any]:
        """Per-side power draw (watts) while that side's share runs."""
        ph = self.host_base_w + self.host_thread_w * host_threads
        pd = self.device_base_w + self.device_thread_w * device_threads
        return ph, pd

    def joules(self, config: Mapping, dataset_gb: float,
               rng: np.random.Generator | None = None) -> float:
        """Energy-to-solution: sum of per-side time x power draws."""
        return self.metrics(config, dataset_gb, rng)["energy"]

    def metrics(self, config: Mapping, dataset_gb: float,
                rng: np.random.Generator | None = None) -> dict[str, float]:
        """One measurement as a metrics record.

        Returns ``{"time", "energy", "t_host", "t_device"}`` — the
        paper's E = max(T_host, T_device) under ``"time"`` and the
        energy-to-solution column (joules) under ``"energy"``, from a
        single pair of (possibly noisy) per-side measurements.
        """
        th, td = self.measure(config, dataset_gb, rng)
        ph, pd = self._power_w(float(config["host_threads"]),
                               float(config["device_threads"]))
        return {"time": max(th, td), "energy": th * ph + td * pd,
                "t_host": th, "t_device": td}

    def metrics_batch(self, columns: Mapping[str, np.ndarray],
                      dataset_gb: float,
                      rng: np.random.Generator | None = None
                      ) -> dict[str, np.ndarray]:
        """Vectorized ``metrics`` over a column-oriented config batch.

        Noise draws consume ``rng`` in the same order as ``energy_batch``
        (one host vector, then one device vector), so seeded scores on
        the ``"time"`` column match the time-only batched oracle.
        """
        f = np.asarray(columns["host_fraction"], dtype=np.float64) / 100.0
        ht = np.asarray(columns["host_threads"], dtype=np.float64)
        dt = np.asarray(columns["device_threads"], dtype=np.float64)
        th = self.host_time_batch(dataset_gb * f, ht,
                                  np.asarray(columns["host_affinity"]))
        td = self.device_time_batch(dataset_gb * (1.0 - f), dt,
                                    np.asarray(columns["device_affinity"]))
        if rng is not None and self.noise_sigma > 0:
            th = th * np.where(th > 0,
                               np.exp(rng.normal(0.0, self.noise_sigma,
                                                 th.shape)), 1.0)
            td = td * np.where(td > 0,
                               np.exp(rng.normal(0.0, self.noise_sigma,
                                                 td.shape)), 1.0)
        ph, pd = self._power_w(ht, dt)
        return {"time": np.maximum(th, td), "energy": th * ph + td * pd,
                "t_host": th, "t_device": td}

    def evaluator(self, dataset_gb: float,
                  rng: np.random.Generator | None = None):
        """Both oracle paths bundled for ``repro.tune.TuningSession``.

        Returns a ``MetricsEvaluator`` whose scalar and batch paths share
        ``rng`` (pass ``None`` for noise-free ground truth).
        """
        from ..tune.objective import MetricsEvaluator
        return MetricsEvaluator(
            lambda cfg: self.metrics(cfg, dataset_gb, rng),
            lambda cols: self.metrics_batch(cols, dataset_gb, rng))

    # -- reference points used by the paper's speedup tables ---------------------
    def host_only_time(self, dataset_gb: float, threads: int = 48,
                       affinity: str = "scatter") -> float:
        return self.host_time(dataset_gb, threads, affinity)

    def device_only_time(self, dataset_gb: float, threads: int = 240,
                         affinity: str = "balanced") -> float:
        return self.device_time(dataset_gb, threads, affinity)
