"""Boosted Decision Tree Regression (BDTR), from scratch.

The paper evaluates candidate system configurations with a supervised
regression model and reports that Boosted Decision Tree Regression was the
most accurate of the models they tried.  This module implements
least-squares gradient boosting (Friedman's LSBoost) over depth-limited
regression trees:

    F_0(x)   = mean(y)
    r_m      = y - F_{m-1}(X)
    tree_m   = fit_regression_tree(X, r_m)
    F_m(x)   = F_{m-1}(x) + lr * tree_m(x)

Trees are grown greedily with exact SSE-minimising splits over (optionally
quantile-binned) thresholds.  Fitting runs in numpy on the host; prediction
is available both in numpy and as a jit-compatible JAX function over packed
node arrays, so the vectorized SA chains can query the surrogate thousands
of times per second.

Two tree-growing engines share the same tree semantics:

  * ``tree_method="exact"`` — per-node argsort over every feature
    (the original reference splitter),
  * ``tree_method="hist"``  — LightGBM-style histogram fitting: features
    are quantile-binned ONCE per ``fit``, per-node split search is two
    ``bincount`` calls + prefix sums, and each child inherits its
    histogram from the parent by sibling subtraction.  On data whose
    features have at most ``max_bins`` distinct values (e.g. the paper's
    measurement grids) the candidate splits partition the training rows
    exactly like the exact splitter's, so predictions agree at every
    trained value; threshold *placement* uses global bin edges, so the
    two engines may route queries differently inside value gaps the
    node's rows do not straddle (off-grid inputs).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

import numpy as np

import jax
import jax.numpy as jnp

__all__ = ["BoostedTreesRegressor", "fit_tree", "fit_tree_hist",
           "BinnedFeatures", "bin_features", "bin_rows", "append_rows",
           "Tree"]


@dataclass
class Tree:
    """A regression tree packed into arrays (complete-traversal friendly).

    ``feature[i] < 0`` marks node ``i`` as a leaf with prediction
    ``value[i]``; internal nodes route ``x[feature] <= threshold`` to
    ``left`` else ``right``.
    """

    feature: np.ndarray      # (n_nodes,) int32, -1 for leaves
    threshold: np.ndarray    # (n_nodes,) float64
    left: np.ndarray         # (n_nodes,) int32
    right: np.ndarray        # (n_nodes,) int32
    value: np.ndarray        # (n_nodes,) float64
    depth: int

    def predict(self, X: np.ndarray) -> np.ndarray:
        n = X.shape[0]
        node = np.zeros(n, dtype=np.int32)
        for _ in range(self.depth + 1):
            feat = self.feature[node]
            is_leaf = feat < 0
            go_left = X[np.arange(n), np.maximum(feat, 0)] <= self.threshold[node]
            nxt = np.where(go_left, self.left[node], self.right[node])
            node = np.where(is_leaf, node, nxt).astype(np.int32)
        return self.value[node]


def _best_split(x: np.ndarray, y: np.ndarray, min_leaf: int,
                max_bins: int) -> tuple[float, float] | None:
    """Best SSE-reducing threshold for one feature, or None.

    Returns ``(gain, threshold)``; gain is the SSE reduction.
    """
    order = np.argsort(x, kind="stable")
    xs, ys = x[order], y[order]
    n = len(xs)
    # prefix sums for O(1) SSE of any prefix/suffix
    csum = np.cumsum(ys)
    total = csum[-1]
    # split after position i (1-based count i+1 on the left); only at value
    # boundaries, and respecting min_samples_leaf
    boundary = np.nonzero(xs[:-1] < xs[1:])[0]  # split between i and i+1
    if len(boundary) == 0:
        return None
    boundary = boundary[(boundary + 1 >= min_leaf) & (n - boundary - 1 >= min_leaf)]
    if len(boundary) == 0:
        return None
    if len(boundary) > max_bins:
        sel = np.linspace(0, len(boundary) - 1, max_bins).astype(int)
        boundary = boundary[sel]
    nl = boundary + 1.0
    nr = n - nl
    sl = csum[boundary]
    sr = total - sl
    # SSE reduction = sl^2/nl + sr^2/nr - total^2/n
    gain = sl * sl / nl + sr * sr / nr - total * total / n
    k = int(np.argmax(gain))
    thr = 0.5 * (xs[boundary[k]] + xs[boundary[k] + 1])
    return float(gain[k]), float(thr)


def fit_tree(X: np.ndarray, y: np.ndarray, *, max_depth: int = 4,
             min_samples_leaf: int = 4, max_bins: int = 64,
             min_gain: float = 1e-12) -> Tree:
    """Greedy SSE-minimising regression tree."""
    n, d = X.shape
    feature: list[int] = []
    threshold: list[float] = []
    left: list[int] = []
    right: list[int] = []
    value: list[float] = []

    def new_node() -> int:
        feature.append(-1)
        threshold.append(0.0)
        left.append(0)
        right.append(0)
        value.append(0.0)
        return len(feature) - 1

    def grow(idx: np.ndarray, depth: int) -> int:
        node = new_node()
        value[node] = float(y[idx].mean())
        if depth >= max_depth or len(idx) < 2 * min_samples_leaf:
            return node
        best: tuple[float, int, float] | None = None
        for f in range(d):
            res = _best_split(X[idx, f], y[idx], min_samples_leaf, max_bins)
            if res is not None and (best is None or res[0] > best[0]):
                best = (res[0], f, res[1])
        if best is None or best[0] <= min_gain:
            return node
        _, f, thr = best
        mask = X[idx, f] <= thr
        feature[node] = f
        threshold[node] = thr
        left[node] = grow(idx[mask], depth + 1)
        right[node] = grow(idx[~mask], depth + 1)
        return node

    grow(np.arange(n), 0)
    return Tree(
        feature=np.asarray(feature, dtype=np.int32),
        threshold=np.asarray(threshold, dtype=np.float64),
        left=np.asarray(left, dtype=np.int32),
        right=np.asarray(right, dtype=np.int32),
        value=np.asarray(value, dtype=np.float64),
        depth=max_depth,
    )


# ---------------------------------------------------------------------------
# Histogram-based fitting (LightGBM-style).
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class BinnedFeatures:
    """Per-fit binning of a feature matrix (computed once, reused by every
    boosting iteration — the bins depend on X only, not on the residuals).

    ``codes[i, f]`` is the bin index of sample ``i`` on feature ``f``;
    ``split_value[f][b]`` is the real-valued threshold realising the split
    "bin <= b goes left" (midpoint between bin b's upper edge and the
    smallest data value above it, so ``x <= thr`` partitions exactly like
    the bin codes on training data).
    """

    codes: np.ndarray            # (n, d) int32
    n_bins: np.ndarray           # (d,) int64
    split_value: tuple           # d arrays of shape (n_bins[f] - 1,)
    uppers: tuple                # d arrays of per-bin upper edges (n_bins[f],)


def bin_features(X: np.ndarray, max_bins: int) -> BinnedFeatures:
    """Quantile-bin every feature into at most ``max_bins`` bins.

    Features with <= ``max_bins`` distinct values get one bin per value
    (the histogram splitter is then exact).
    """
    X = np.asarray(X, dtype=np.float64)
    n, d = X.shape
    codes = np.empty((n, d), dtype=np.int32)
    n_bins = np.empty(d, dtype=np.int64)
    split_value = []
    all_uppers = []
    for f in range(d):
        x = X[:, f]
        u = np.unique(x)
        if len(u) > max_bins:
            qs = np.quantile(x, np.linspace(0.0, 1.0, max_bins + 1)[1:])
            uppers = np.unique(qs)
            uppers[-1] = u[-1]          # quantile interpolation can undershoot
        else:
            uppers = u
        c = np.searchsorted(uppers, x, side="left")
        codes[:, f] = np.minimum(c, len(uppers) - 1)
        n_bins[f] = len(uppers)
        all_uppers.append(uppers)
        # smallest data value strictly above each interior bin boundary
        nxt_i = np.minimum(np.searchsorted(u, uppers[:-1], side="right"),
                           len(u) - 1)
        split_value.append(0.5 * (uppers[:-1] + u[nxt_i]))
    return BinnedFeatures(codes=codes, n_bins=n_bins,
                          split_value=tuple(split_value),
                          uppers=tuple(all_uppers))


def bin_rows(binned: BinnedFeatures, X_new: np.ndarray) -> np.ndarray:
    """Code new rows with an existing binning's edges (no re-binning).

    Values above the top edge clamp into the last bin (tree ensembles
    cannot extrapolate anyway); values below the bottom edge land in bin
    0.  This is what keeps incremental refits cheap: the per-fit
    quantile pass runs once, and every later batch of observations is a
    ``searchsorted`` against the frozen edges.
    """
    X_new = np.asarray(X_new, dtype=np.float64)
    if X_new.ndim != 2 or X_new.shape[1] != binned.codes.shape[1]:
        raise ValueError("X_new must be (n, d) with d matching the binning")
    codes = np.empty(X_new.shape, dtype=np.int32)
    for f in range(X_new.shape[1]):
        c = np.searchsorted(binned.uppers[f], X_new[:, f], side="left")
        codes[:, f] = np.minimum(c, binned.n_bins[f] - 1)
    return codes


def append_rows(binned: BinnedFeatures, X_new: np.ndarray) -> BinnedFeatures:
    """Extend a binning with new rows, reusing the existing bin edges."""
    return BinnedFeatures(
        codes=np.concatenate([binned.codes, bin_rows(binned, X_new)]),
        n_bins=binned.n_bins, split_value=binned.split_value,
        uppers=binned.uppers)


def fit_tree_hist(binned: BinnedFeatures, y: np.ndarray, *,
                  row_idx: np.ndarray | None = None, max_depth: int = 4,
                  min_samples_leaf: int = 4, min_gain: float = 1e-12,
                  return_pred: bool = False):
    """Greedy SSE-minimising regression tree over pre-binned features.

    Split search per node is O(n_node * d) via ``bincount`` + prefix sums
    (vs. the exact splitter's per-node, per-feature argsort); one child's
    histogram is derived from the parent's by sibling subtraction.

    With ``return_pred=True`` returns ``(tree, pred)`` where ``pred`` holds
    the tree's prediction for every training row covered by ``row_idx``
    (leaf assignments fall out of the partition built while growing, so
    the boosting loop can skip a full ``Tree.predict`` pass).
    """
    codes, n_bins, split_value = binned.codes, binned.n_bins, binned.split_value
    n_all, d = codes.shape
    B = int(n_bins.max())
    y = np.asarray(y, dtype=np.float64)
    if row_idx is None:
        row_idx = np.arange(n_all)
    offsets = np.arange(d, dtype=np.int64) * B
    # interior split positions exist only below each feature's bin count
    _cols = np.arange(max(B - 1, 1))[None, :]
    interior = _cols < (n_bins[:, None] - 1)       # (d, B-1) static mask

    feature: list[int] = []
    threshold: list[float] = []
    left: list[int] = []
    right: list[int] = []
    value: list[float] = []

    def new_node() -> int:
        feature.append(-1)
        threshold.append(0.0)
        left.append(0)
        right.append(0)
        value.append(0.0)
        return len(feature) - 1

    def hist_of(idx: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        flat = (codes[idx].astype(np.int64) + offsets).ravel()
        cnt = np.bincount(flat, minlength=d * B).reshape(d, B)
        sm = np.bincount(flat, weights=np.repeat(y[idx], d),
                         minlength=d * B).reshape(d, B)
        return cnt, sm

    def best_split(cnt, sm, m):
        """-> (gain, f, b, left_count, left_sum) or None."""
        if B < 2:
            return None
        # the last column is never a split point — drop it before cumsum
        nl = np.cumsum(cnt[:, :-1], axis=1)
        sl = np.cumsum(sm[:, :-1], axis=1)
        total = float(sm[0].sum())    # every feature's bins sum to sum(y)
        nr = m - nl
        sr = total - sl
        # SSE reduction, same formula as the exact splitter (0-count bins
        # divide to inf/nan; masked out just below — errstate is hoisted
        # to the caller).  The constant -total^2/m term does not affect
        # the argmax; it is applied to the winner only.
        gain = sl * sl / nl + sr * sr / nr
        # children must be non-empty even when min_samples_leaf == 0, or
        # an empty bin's NaN/inf gain would win the argmax
        min_child = max(min_samples_leaf, 1)
        ok = interior & (nl >= min_child) & (nr >= min_child)
        gain = np.where(ok, gain, -np.inf)
        k = int(np.argmax(gain))
        f, b = divmod(k, B - 1)
        g = float(gain[f, b]) - total * total / m
        if not np.isfinite(g) or g <= min_gain:
            return None
        return g, f, b, int(nl[f, b]), float(sl[f, b])

    pred = np.empty(n_all) if return_pred else None

    def grow(idx: np.ndarray, depth: int, mean: float, hist=None) -> int:
        node = new_node()
        value[node] = mean
        if depth >= max_depth or len(idx) < 2 * min_samples_leaf:
            if pred is not None:
                pred[idx] = mean
            return node
        cnt, sm = hist if hist is not None else hist_of(idx)
        res = best_split(cnt, sm, len(idx))
        if res is None:
            if pred is not None:
                pred[idx] = mean
            return node
        _, f, b, nl, sl = res
        mask = codes[idx, f] <= b
        li, ri = idx[mask], idx[~mask]
        feature[node] = f
        threshold[node] = float(split_value[f][b])
        # Child means fall out of the split sums — no per-node y gather.
        l_mean = sl / nl
        r_mean = (mean * len(idx) - sl) / (len(idx) - nl)
        # Build child histograms only for children that can still split;
        # when both need one, build the smaller child's and derive the
        # other by sibling subtraction.
        def splittable(child):
            return depth + 1 < max_depth and len(child) >= 2 * min_samples_leaf
        lh = rh = None
        if splittable(li) and splittable(ri):
            if len(li) <= len(ri):
                lh = hist_of(li)
                rh = (cnt - lh[0], sm - lh[1])
            else:
                rh = hist_of(ri)
                lh = (cnt - rh[0], sm - rh[1])
        left[node] = grow(li, depth + 1, l_mean, lh)
        right[node] = grow(ri, depth + 1, r_mean, rh)
        return node

    row_idx = np.asarray(row_idx)
    with np.errstate(divide="ignore", invalid="ignore"):
        grow(row_idx, 0, float(y[row_idx].mean()))
    tree = Tree(
        feature=np.asarray(feature, dtype=np.int32),
        threshold=np.asarray(threshold, dtype=np.float64),
        left=np.asarray(left, dtype=np.int32),
        right=np.asarray(right, dtype=np.int32),
        value=np.asarray(value, dtype=np.float64),
        depth=max_depth,
    )
    return (tree, pred) if return_pred else tree


@dataclass
class BoostedTreesRegressor:
    """LSBoost ensemble with packed-array JAX prediction."""

    n_estimators: int = 200
    learning_rate: float = 0.1
    max_depth: int = 4
    min_samples_leaf: int = 4
    max_bins: int = 64
    subsample: float = 1.0
    seed: int = 0
    tree_method: str = "exact"       # "exact" | "hist"
    # fitted state
    base_: float = 0.0
    trees_: list = field(default_factory=list)
    _packed: tuple | None = None

    def fit(self, X: np.ndarray, y: np.ndarray) -> "BoostedTreesRegressor":
        X = np.asarray(X, dtype=np.float64)
        y = np.asarray(y, dtype=np.float64)
        if X.ndim != 2 or len(X) != len(y):
            raise ValueError("X must be (n, d) and aligned with y")
        if self.tree_method not in ("exact", "hist"):
            raise ValueError(f"unknown tree_method {self.tree_method!r}")
        rng = np.random.default_rng(self.seed)
        self.base_ = float(y.mean())
        pred = np.full_like(y, self.base_)
        self.trees_ = []
        n = len(y)
        # bins depend on X only: compute once, reuse across all estimators
        binned = (bin_features(X, self.max_bins)
                  if self.tree_method == "hist" else None)
        for _ in range(self.n_estimators):
            resid = y - pred
            if self.subsample < 1.0:
                idx = rng.choice(n, size=max(2 * self.min_samples_leaf,
                                             int(self.subsample * n)),
                                 replace=False)
            else:
                idx = np.arange(n)
            if binned is not None and self.subsample >= 1.0:
                # full-data fit: the grower hands back every row's leaf
                # value, so no predict pass is needed
                tree, tpred = fit_tree_hist(
                    binned, resid, row_idx=idx, max_depth=self.max_depth,
                    min_samples_leaf=self.min_samples_leaf, return_pred=True)
            elif binned is not None:
                tree = fit_tree_hist(binned, resid, row_idx=idx,
                                     max_depth=self.max_depth,
                                     min_samples_leaf=self.min_samples_leaf)
                tpred = None
            else:
                tree = fit_tree(X[idx], resid[idx], max_depth=self.max_depth,
                                min_samples_leaf=self.min_samples_leaf,
                                max_bins=self.max_bins)
                tpred = None
            self.trees_.append(tree)
            pred = pred + self.learning_rate * (
                tpred if tpred is not None else tree.predict(X))
        self._packed = None
        return self

    def fit_more(self, X: np.ndarray, y: np.ndarray, n_more: int, *,
                 binned: BinnedFeatures | None = None,
                 ) -> "BoostedTreesRegressor":
        """Continue boosting: append ``n_more`` trees fit on ``(X, y)``.

        The existing ensemble (``base_`` + ``trees_``) is kept and the new
        trees chase the residuals ``y - predict(X)`` — warm refit from
        live observations instead of a full retrain.  ``X`` need not be
        the original training matrix; with ``tree_method="hist"`` pass a
        precomputed ``binned`` (e.g. grown incrementally via
        ``append_rows``) to skip the quantile pass entirely.  New trees
        always fit the full row set (``subsample`` applies to ``fit``
        only).
        """
        if not self.trees_:
            raise ValueError("fit_more needs a fitted ensemble; call fit first")
        X = np.asarray(X, dtype=np.float64)
        y = np.asarray(y, dtype=np.float64)
        if X.ndim != 2 or len(X) != len(y):
            raise ValueError("X must be (n, d) and aligned with y")
        if self.tree_method == "hist" and binned is None:
            binned = bin_features(X, self.max_bins)
        if binned is not None and len(binned.codes) != len(y):
            raise ValueError("binned row count does not match y")
        pred = self.predict(X)
        idx = np.arange(len(y))
        for _ in range(n_more):
            resid = y - pred
            if binned is not None:
                tree, tpred = fit_tree_hist(
                    binned, resid, row_idx=idx, max_depth=self.max_depth,
                    min_samples_leaf=self.min_samples_leaf, return_pred=True)
            else:
                tree = fit_tree(X, resid, max_depth=self.max_depth,
                                min_samples_leaf=self.min_samples_leaf,
                                max_bins=self.max_bins)
                tpred = tree.predict(X)
            self.trees_.append(tree)
            pred = pred + self.learning_rate * tpred
        self._packed = None
        return self

    # -- numpy prediction ----------------------------------------------------
    def predict(self, X: np.ndarray) -> np.ndarray:
        X = np.asarray(X, dtype=np.float64)
        out = np.full(X.shape[0], self.base_)
        for t in self.trees_:
            out += self.learning_rate * t.predict(X)
        return out

    # -- packed JAX prediction -------------------------------------------------
    def pack(self) -> tuple:
        """Stack all trees into padded (M, n_nodes) arrays for JAX."""
        if self._packed is not None:
            return self._packed
        m = len(self.trees_)
        max_nodes = max(len(t.feature) for t in self.trees_)

        def pad(a, fill, dtype):
            out = np.full((m, max_nodes), fill, dtype=dtype)
            for i, t in enumerate(self.trees_):
                arr = getattr(t, a)
                out[i, : len(arr)] = arr
            return out

        packed = (
            jnp.asarray(pad("feature", -1, np.int32)),
            jnp.asarray(pad("threshold", 0.0, np.float32)),
            jnp.asarray(pad("left", 0, np.int32)),
            jnp.asarray(pad("right", 0, np.int32)),
            jnp.asarray(pad("value", 0.0, np.float32)),
            jnp.float32(self.base_),
            jnp.float32(self.learning_rate),
            int(max(t.depth for t in self.trees_)),
        )
        self._packed = packed
        return packed

    def predict_fn_jax(self) -> Callable[[jnp.ndarray], jnp.ndarray]:
        """Returns a jit-compatible ``f(X: (n, d)) -> (n,)`` predictor."""
        feat, thr, left, right, value, base, lr, depth = self.pack()
        m = feat.shape[0]

        def predict_one_tree(ti, x):  # x: (d,)
            def body(_, node):
                f = feat[ti, node]
                is_leaf = f < 0
                go_left = x[jnp.maximum(f, 0)] <= thr[ti, node]
                nxt = jnp.where(go_left, left[ti, node], right[ti, node])
                return jnp.where(is_leaf, node, nxt)

            node = jax.lax.fori_loop(0, depth + 1, body, jnp.int32(0))
            return value[ti, node]

        def predict(X):
            def one(x):
                vals = jax.vmap(lambda ti: predict_one_tree(ti, x))(jnp.arange(m))
                return base + lr * vals.sum()

            return jax.vmap(one)(X.astype(jnp.float32))

        return predict


# -- paper's accuracy metrics (Eqs. 5-6) --------------------------------------

def absolute_error(t_measured: np.ndarray, t_predicted: np.ndarray) -> np.ndarray:
    return np.abs(np.asarray(t_measured) - np.asarray(t_predicted))


def percent_error(t_measured: np.ndarray, t_predicted: np.ndarray) -> np.ndarray:
    t_measured = np.asarray(t_measured)
    return 100.0 * absolute_error(t_measured, t_predicted) / t_measured
