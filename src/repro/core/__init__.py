"""The paper's primary contribution: combinatorial optimization (simulated
annealing) + machine learning (boosted decision-tree regression) to find
near-optimal work-distribution configurations on heterogeneous systems.

Public surface:
  ConfigSpace/Param      — discrete parameter spaces (space.py)
  simulated_annealing    — the paper's SA (sa.py), + vectorized_sa
  BoostedTreesRegressor  — from-scratch BDTR (bdtr.py)
  Autotuner              — deprecated shim over ``repro.tune`` (the
                           EM / EML / SAM / SAML engines now live in the
                           strategy registry; see docs/tune.md)
  EmilPlatformModel      — calibrated simulator of the paper's platform
                           (time + energy metric columns)
  fit_emil_surrogates    — the paper's 7200-experiment training pipeline

New code should tune through ``repro.tune.TuningSession``.
"""

from .autotuner import (Autotuner, TuneReport, emil_training_grids,
                        fit_emil_surrogates)
from .bdtr import (BoostedTreesRegressor, absolute_error, bin_features,
                   fit_tree_hist, percent_error)
from .evaluators import (BatchedLearnedEvaluator, LearnedEvaluator,
                         MeasurementEvaluator, SurrogatePair)
from .platform_model import DATASETS_GB, EmilPlatformModel
from .sa import SAResult, SASchedule, simulated_annealing, vectorized_sa
from .space import ConfigSpace, Param, paper_space

__all__ = [
    "Autotuner", "TuneReport", "emil_training_grids", "fit_emil_surrogates",
    "BoostedTreesRegressor", "absolute_error", "percent_error",
    "bin_features", "fit_tree_hist",
    "BatchedLearnedEvaluator", "LearnedEvaluator", "MeasurementEvaluator",
    "SurrogatePair",
    "DATASETS_GB", "EmilPlatformModel",
    "SAResult", "SASchedule", "simulated_annealing", "vectorized_sa",
    "ConfigSpace", "Param", "paper_space",
]
