"""The paper's four optimization strategies: EM, EML, SAM, SAML.

  EM    enumeration + measurements        (optimal, very high effort)
  EML   enumeration + machine learning    (near-optimal, high effort)
  SAM   simulated annealing + measurements (near-optimal, medium effort)
  SAML  simulated annealing + machine learning — the paper's headline method

.. deprecated::
    ``Autotuner`` is a thin compatibility shim over the unified facade
    in :mod:`repro.tune` (see ``docs/tune.md``).  The search engines now
    live in the strategy registry (``repro.tune.strategy``) and every
    ``tune_*`` method routes through a ``TuningSession``, emitting a
    ``DeprecationWarning`` — results are bit-identical to the seed
    engines on a fixed seed.  New code should build sessions directly:

        from repro.tune import TuningSession
        TuningSession(space, evaluator=measure, surrogate=pair).run(
            "saml", iterations=1000, engine="vectorized")

The surrogate-training pipeline (``emil_training_grids`` /
``fit_emil_surrogates``, Sec. III-B of the paper) still lives here and
is not deprecated.

Every strategy takes an ``engine=`` knob selecting the execution path.
With deterministic oracles the enumeration engines (EM/EML) return
identical seeded results and accounting; the vectorized SAML engine runs
``n_chains`` chains at once (its prediction count covers every chain, and
its PRNG stream differs from the scalar chain's):

  * ``tune_em(engine=...)``    — ``"scalar"`` walks configs through the
    measurement oracle one at a time; ``"batched"`` scores the whole
    space with one ``measure_batch`` call (pass ``measure_batch=`` to
    the constructor, e.g. ``lambda cols:
    platform.energy_batch(cols, gb, rng)``).  ``"auto"`` picks batched
    when available.  A noisy oracle draws noise in a different order per
    engine, so seeded noisy results can differ.
  * ``tune_eml(engine=...)``   — ``"scalar"`` is the seed per-config
    loop; ``"batched"`` (default) materializes the space once and scores
    it with two ensemble ``predict`` calls.
  * ``tune_saml(engine=...)``  — ``"scalar"`` (default) is the paper's
    single chain; ``"vectorized"`` runs multi-chain jitted SA
    (``sa.vectorized_sa``) over the packed BDTR pair with the
    max(T_host, T_device) objective evaluated in JAX.
"""

from __future__ import annotations

import warnings
from typing import Any, Callable, Mapping, Sequence

import numpy as np

from ..tune.result import TuneResult
from .bdtr import BoostedTreesRegressor
from .evaluators import SurrogatePair
from .platform_model import EmilPlatformModel
from .space import ConfigSpace

__all__ = ["Autotuner", "TuneReport", "emil_training_grids",
           "fit_emil_surrogates"]

# The unified result record superseded the seed's report; the name (and
# the persisted-cache schema) stay importable from here.
TuneReport = TuneResult


class Autotuner:
    """Search a ConfigSpace for the configuration minimising measured energy."""

    def __init__(
        self,
        space: ConfigSpace,
        measure: Callable[[Mapping[str, Any]], float],
        *,
        truth: Callable[[Mapping[str, Any]], float] | None = None,
        surrogate: SurrogatePair | None = None,
        n_training_experiments: int = 0,
        measure_batch: Callable[[Mapping[str, np.ndarray]], np.ndarray] |
        None = None,
        warm_start=None,
        record_to=None,
        workload: Mapping[str, Any] | None = None,
    ):
        """``measure`` is the (possibly noisy) measurement oracle; ``truth``
        is the noise-free oracle used only for *reporting* (defaults to
        ``measure``).  ``surrogate`` enables EML/SAML.  ``measure_batch``
        (columns -> energies, e.g. ``lambda cols:
        platform.energy_batch(cols, gb, rng)``) enables the batched EM
        engine.

        ``warm_start`` / ``record_to`` attach a persistent tuning cache
        (``repro.runtime.store.TuningStore``, or a path to one; pass the
        same store to both for read-write caching).  ``workload``
        describes the tuned workload beyond the space itself — shapes,
        device topology, anything that changes measured times — and is
        folded into the cache key.  ``tune()`` consults ``warm_start``
        before searching (a hit performs zero new measurements) and
        records fresh results to ``record_to``; the per-strategy
        ``tune_*`` methods always search.
        """
        self.space = space
        self.measure = measure
        self.truth = truth or measure
        self.surrogate = surrogate
        self.n_training_experiments = n_training_experiments
        self.measure_batch = measure_batch
        self.warm_start = self._as_store(warm_start)
        self.record_to = self._as_store(record_to)
        self.workload = workload

    @staticmethod
    def _as_store(store):
        if store is None or hasattr(store, "lookup"):
            return store
        # deferred import: core must stay importable without runtime
        from ..runtime.store import TuningStore
        return TuningStore(store)

    # -- the deprecated shim over repro.tune --------------------------------
    def _session(self):
        from ..tune import TuningSession
        return TuningSession(
            self.space, evaluator=self.measure,
            evaluator_batch=self.measure_batch, surrogate=self.surrogate,
            truth=self.truth,
            n_training_experiments=self.n_training_experiments)

    def _run(self, name: str, strategy: str, **opts) -> TuneReport:
        warnings.warn(
            f"Autotuner.{name} is deprecated; use "
            f"repro.tune.TuningSession(...).run({strategy!r}) instead "
            "(see docs/tune.md)",
            DeprecationWarning, stacklevel=3)
        if strategy in ("eml", "saml") and self.surrogate is None:
            raise ValueError("strategy needs a trained surrogate "
                             "(pass surrogate= to Autotuner)")
        return self._session().run(strategy, **opts)

    # -- strategies (legacy surface; identical seeded results) --------------
    def tune_em(self, *, engine: str = "auto") -> TuneReport:
        return self._run("tune_em", "em", engine=engine)

    def tune_eml(self, *, engine: str = "batched") -> TuneReport:
        return self._run("tune_eml", "eml", engine=engine)

    def tune_sam(self, *, iterations: int = 1000, seed: int = 0,
                 checkpoints: Sequence[int] = ()) -> TuneReport:
        return self._run("tune_sam", "sam", iterations=iterations, seed=seed,
                         checkpoints=checkpoints)

    def tune_saml(self, *, iterations: int = 1000, seed: int = 0,
                  checkpoints: Sequence[int] = (), engine: str = "scalar",
                  n_chains: int = 32) -> TuneReport:
        return self._run("tune_saml", "saml", iterations=iterations,
                         seed=seed, checkpoints=checkpoints, engine=engine,
                         n_chains=n_chains)

    def tune(self, strategy: str, **kw) -> TuneReport:
        strategy = strategy.upper()
        fn = {
            "EM": self.tune_em, "EML": self.tune_eml,
            "SAM": self.tune_sam, "SAML": self.tune_saml,
        }.get(strategy)
        if fn is None:
            raise ValueError(f"unknown strategy {strategy!r}")
        if self.warm_start is not None:
            hit = self.warm_start.lookup(self.space, self.workload, strategy)
            if hit is not None:
                return hit
        report = fn(**kw)
        if self.record_to is not None:
            self.record_to.record(self.space, self.workload, strategy, report)
        return report


# ---------------------------------------------------------------------------
# Surrogate training for the Emil platform (paper Sec. III-B / IV-B).
# ---------------------------------------------------------------------------

def _one_hot_cols(vals: np.ndarray, domain: Sequence[str]) -> np.ndarray:
    return (np.asarray(vals)[:, None] ==
            np.asarray(domain)[None, :]).astype(np.float64)


def emil_training_grids(
    platform: EmilPlatformModel,
    *,
    datasets_gb: Sequence[float],
    host_threads: Sequence[int] = (2, 6, 12, 24, 36, 48),
    device_threads: Sequence[int] = (2, 4, 8, 16, 30, 60, 120, 180, 240),
    host_affinities: Sequence[str] = ("none", "scatter", "compact"),
    device_affinities: Sequence[str] = ("balanced", "scatter", "compact"),
    fractions: Sequence[float] | None = None,
    rng: np.random.Generator | None = None,
    seed: int = 0,
):
    """Vectorized generation of the paper's host/device training grids.

    Returns ``((host_X, host_y), (device_X, device_y))`` with feature rows
    [input_gb, threads, affinity one-hot..., fraction_pct] and noisy
    execution times (lognormal, ``platform.noise_sigma``).  Row order
    matches the paper's nested experiment loops (fraction fastest), and
    the noise draws consume ``rng`` exactly like per-row scalar draws
    would — so the grids are bit-reproducible for a given seed.
    """
    if rng is None:
        rng = np.random.default_rng(seed)
    if fractions is None:
        fractions = [2.5 * i for i in range(1, 41)]  # 2.5 .. 100 step 2.5

    def side(threads, affinities, time_batch):
        gb, t, a, f = (g.ravel() for g in np.meshgrid(
            np.asarray(datasets_gb, dtype=np.float64),
            np.asarray(threads, dtype=np.float64),
            np.arange(len(affinities)),
            np.asarray(fractions, dtype=np.float64),
            indexing="ij"))
        aff = np.asarray(affinities)[a]
        tt = time_batch(gb * f / 100.0, t, aff)
        tt = tt * np.exp(rng.normal(0, platform.noise_sigma, tt.shape))
        X = np.column_stack([gb, t, _one_hot_cols(aff, affinities), f])
        return X, tt

    return (side(host_threads, host_affinities, platform.host_time_batch),
            side(device_threads, device_affinities,
                 platform.device_time_batch))


def fit_emil_surrogates(
    platform: EmilPlatformModel,
    dataset_gb: float,
    *,
    datasets_gb: Sequence[float] | None = None,
    host_threads: Sequence[int] = (2, 6, 12, 24, 36, 48),
    device_threads: Sequence[int] = (2, 4, 8, 16, 30, 60, 120, 180, 240),
    host_affinities: Sequence[str] = ("none", "scatter", "compact"),
    device_affinities: Sequence[str] = ("balanced", "scatter", "compact"),
    fractions: Sequence[float] | None = None,
    seed: int = 0,
    n_estimators: int = 150,
    max_depth: int = 5,
    tree_method: str = "hist",
    return_eval: bool = False,
):
    """Generate the paper's training grid and fit per-side BDTR models.

    The paper runs 2880 host experiments (4 datasets x 6 thread counts x 3
    affinities x 40 fractions) and 4320 device experiments (9 thread
    counts), then trains on half and evaluates on the other half.  Feature
    vectors are [input_gb, threads, affinity one-hot..., fraction_pct].

    The grid is generated vectorized (meshgrid + the platform's batch
    evaluators) and the BDTR pair is histogram-fit by default; because the
    grid features take few distinct values, the histogram splitter
    partitions the training rows exactly like the exact one, though
    off-grid queries can route differently where thresholds land inside
    value gaps (``tree_method="exact"`` restores the reference splitter).

    The returned ``SurrogatePair`` also carries the batched feature
    builders (column batches -> model features) and a jit-compatible
    energy-function builder, enabling ``Autotuner.tune_eml`` /
    ``tune_saml(engine="vectorized")`` fast paths.

    Returns (surrogate, n_experiments[, eval_tables]).
    """
    rng = np.random.default_rng(seed)
    if fractions is None:
        fractions = [2.5 * i for i in range(1, 41)]  # 2.5 .. 100 step 2.5
    if datasets_gb is None:
        datasets_gb = (dataset_gb,)

    def one_hot(val: str, domain: Sequence[str]) -> list[float]:
        return [1.0 if val == d else 0.0 for d in domain]

    (host_X, host_y), (dev_X, dev_y) = emil_training_grids(
        platform, datasets_gb=datasets_gb, host_threads=host_threads,
        device_threads=device_threads, host_affinities=host_affinities,
        device_affinities=device_affinities, fractions=fractions, rng=rng)
    n_experiments = len(host_y) + len(dev_y)

    # half train / half eval (paper's "standard validation methodology")
    def split(X, y):
        idx = rng.permutation(len(y))
        half = len(y) // 2
        return (X[idx[:half]], y[idx[:half]]), (X[idx[half:]], y[idx[half:]])

    (hXtr, hytr), (hXev, hyev) = split(host_X, host_y)
    (dXtr, dytr), (dXev, dyev) = split(dev_X, dev_y)

    host_model = BoostedTreesRegressor(
        n_estimators=n_estimators, max_depth=max_depth, seed=seed,
        tree_method=tree_method).fit(hXtr, hytr)
    dev_model = BoostedTreesRegressor(
        n_estimators=n_estimators, max_depth=max_depth, seed=seed + 1,
        tree_method=tree_method).fit(dXtr, dytr)

    def host_features(cfg: Mapping[str, Any]) -> np.ndarray:
        return np.asarray([
            dataset_gb, float(cfg["host_threads"]),
            *one_hot(str(cfg["host_affinity"]), host_affinities),
            float(cfg["host_fraction"]),
        ])

    def device_features(cfg: Mapping[str, Any]) -> np.ndarray:
        return np.asarray([
            dataset_gb, float(cfg["device_threads"]),
            *one_hot(str(cfg["device_affinity"]), device_affinities),
            100.0 - float(cfg["host_fraction"]),
        ])

    def host_features_cols(cols: Mapping[str, np.ndarray]) -> np.ndarray:
        t = np.asarray(cols["host_threads"], dtype=np.float64)
        return np.column_stack([
            np.full(t.shape, dataset_gb), t,
            _one_hot_cols(cols["host_affinity"], host_affinities),
            np.asarray(cols["host_fraction"], dtype=np.float64),
        ])

    def device_features_cols(cols: Mapping[str, np.ndarray]) -> np.ndarray:
        t = np.asarray(cols["device_threads"], dtype=np.float64)
        return np.column_stack([
            np.full(t.shape, dataset_gb), t,
            _one_hot_cols(cols["device_affinity"], device_affinities),
            100.0 - np.asarray(cols["host_fraction"], dtype=np.float64),
        ])

    def energy_fn_jax_builder(space: ConfigSpace):
        """Jitted E(cfg) = max(T_h_hat, T_d_hat) over a space's encoded
        features.  The space must use the paper's parameter names."""
        import jax.numpy as jnp

        names = space.feature_names
        i_ht = names.index("host_threads")
        i_dt = names.index("device_threads")
        i_f = names.index("host_fraction")
        h_idx = [names.index(f"host_affinity={a}") for a in host_affinities]
        d_idx = [names.index(f"device_affinity={a}") for a in
                 device_affinities]
        fn_h = host_model.predict_fn_jax()
        fn_d = dev_model.predict_fn_jax()

        def energy(X):
            X = jnp.asarray(X)
            f = X[:, i_f]
            gb = jnp.full_like(f, dataset_gb)
            Xh = jnp.stack([gb, X[:, i_ht], *(X[:, j] for j in h_idx), f],
                           axis=1)
            Xd = jnp.stack([gb, X[:, i_dt], *(X[:, j] for j in d_idx),
                            100.0 - f], axis=1)
            th = jnp.where(f > 0, fn_h(Xh), 0.0)
            td = jnp.where(f < 100, fn_d(Xd), 0.0)
            return jnp.maximum(th, td)

        return energy

    surrogate = SurrogatePair(host=host_model, device=dev_model,
                              host_features=host_features,
                              device_features=device_features,
                              host_features_cols=host_features_cols,
                              device_features_cols=device_features_cols,
                              energy_fn_jax_builder=energy_fn_jax_builder)
    if return_eval:
        eval_tables = {
            "host": (hXev, hyev, host_model.predict(hXev)),
            "device": (dXev, dyev, dev_model.predict(dXev)),
        }
        return surrogate, n_experiments, eval_tables
    return surrogate, n_experiments
