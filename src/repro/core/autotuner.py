"""The paper's four optimization strategies: EM, EML, SAM, SAML.

  EM    enumeration + measurements        (optimal, very high effort)
  EML   enumeration + machine learning    (near-optimal, high effort)
  SAM   simulated annealing + measurements (near-optimal, medium effort)
  SAML  simulated annealing + machine learning — the paper's headline method

``Autotuner`` binds a config space to a measurement oracle, owns the
surrogate-model lifecycle (training-data generation + BDTR fitting,
Sec. III-B of the paper) and exposes one ``tune`` call per strategy.
All effort (experiments vs predictions) is accounted in the returned
``TuneReport`` so benchmarks can reproduce the paper's Result 3
("~5 % of the experiments of EM").
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Mapping, Sequence

import numpy as np

from .bdtr import BoostedTreesRegressor
from .evaluators import LearnedEvaluator, MeasurementEvaluator, SurrogatePair
from .platform_model import EmilPlatformModel
from .sa import SASchedule, simulated_annealing
from .space import ConfigSpace

__all__ = ["Autotuner", "TuneReport", "fit_emil_surrogates"]


@dataclass
class TuneReport:
    strategy: str
    best_config: dict
    best_energy_search: float      # energy the search itself saw (pred or meas)
    best_energy_measured: float    # ground-truth (noise-free) energy
    n_experiments: int             # measurements performed during the search
    n_predictions: int             # surrogate queries during the search
    n_training_experiments: int    # one-time surrogate training measurements
    space_size: int
    # {iteration: (measured energy of best-so-far config, config)}
    checkpoints: dict[int, tuple[float, dict]] = field(default_factory=dict)

    @property
    def experiments_fraction(self) -> float:
        """Search experiments as a fraction of the enumeration count."""
        return self.n_experiments / max(self.space_size, 1)


class Autotuner:
    """Search a ConfigSpace for the configuration minimising measured energy."""

    def __init__(
        self,
        space: ConfigSpace,
        measure: Callable[[Mapping[str, Any]], float],
        *,
        truth: Callable[[Mapping[str, Any]], float] | None = None,
        surrogate: SurrogatePair | None = None,
        n_training_experiments: int = 0,
    ):
        """``measure`` is the (possibly noisy) measurement oracle; ``truth``
        is the noise-free oracle used only for *reporting* (defaults to
        ``measure``).  ``surrogate`` enables EML/SAML."""
        self.space = space
        self.measure = measure
        self.truth = truth or measure
        self.surrogate = surrogate
        self.n_training_experiments = n_training_experiments

    # -- strategies --------------------------------------------------------
    def tune_em(self) -> TuneReport:
        ev = MeasurementEvaluator(self.measure, self.space)
        best_cfg, best_e = None, float("inf")
        for cfg in self.space.enumerate():
            e = ev(cfg)
            if e < best_e:
                best_cfg, best_e = cfg, e
        return self._report("EM", best_cfg, best_e, ev.n_experiments, 0)

    def tune_eml(self) -> TuneReport:
        surrogate = self._require_surrogate()
        ev = LearnedEvaluator(surrogate)
        best_cfg, best_e = None, float("inf")
        for cfg in self.space.enumerate():
            e = ev(cfg)
            if e < best_e:
                best_cfg, best_e = cfg, e
        return self._report("EML", best_cfg, best_e, 0, ev.n_predictions)

    def tune_sam(self, *, iterations: int = 1000, seed: int = 0,
                 checkpoints: Sequence[int] = ()) -> TuneReport:
        ev = MeasurementEvaluator(self.measure, self.space)
        res = simulated_annealing(
            self.space, ev, seed=seed,
            schedule=SASchedule.for_iterations(iterations),
            max_iterations=iterations, checkpoint_at=checkpoints,
        )
        return self._report("SAM", res.best_config, res.best_energy,
                            ev.n_experiments, 0, res.checkpoints)

    def tune_saml(self, *, iterations: int = 1000, seed: int = 0,
                  checkpoints: Sequence[int] = ()) -> TuneReport:
        surrogate = self._require_surrogate()
        ev = LearnedEvaluator(surrogate)
        res = simulated_annealing(
            self.space, ev, seed=seed,
            schedule=SASchedule.for_iterations(iterations),
            max_iterations=iterations, checkpoint_at=checkpoints,
        )
        return self._report("SAML", res.best_config, res.best_energy,
                            0, ev.n_predictions, res.checkpoints)

    def tune(self, strategy: str, **kw) -> TuneReport:
        strategy = strategy.upper()
        fn = {
            "EM": self.tune_em, "EML": self.tune_eml,
            "SAM": self.tune_sam, "SAML": self.tune_saml,
        }.get(strategy)
        if fn is None:
            raise ValueError(f"unknown strategy {strategy!r}")
        return fn(**kw)

    # -- helpers -----------------------------------------------------------
    def _require_surrogate(self) -> SurrogatePair:
        if self.surrogate is None:
            raise ValueError("strategy needs a trained surrogate "
                             "(pass surrogate= to Autotuner)")
        return self.surrogate

    def _report(self, strategy: str, cfg: dict, search_e: float,
                n_exp: int, n_pred: int,
                checkpoints: Mapping[int, tuple[float, dict]] | None = None,
                ) -> TuneReport:
        # For fair comparison the paper evaluates suggested configs with
        # *measured* values (Sec. IV-C) — re-measure checkpoints with truth.
        measured_cp = {
            it: (float(self.truth(c)), dict(c))
            for it, (_, c) in (checkpoints or {}).items()
        }
        return TuneReport(
            strategy=strategy,
            best_config=dict(cfg),
            best_energy_search=float(search_e),
            best_energy_measured=float(self.truth(cfg)),
            n_experiments=n_exp,
            n_predictions=n_pred,
            n_training_experiments=(self.n_training_experiments
                                    if strategy in ("EML", "SAML") else 0),
            space_size=self.space.size(),
            checkpoints=measured_cp,
        )


# ---------------------------------------------------------------------------
# Surrogate training for the Emil platform (paper Sec. III-B / IV-B).
# ---------------------------------------------------------------------------

def fit_emil_surrogates(
    platform: EmilPlatformModel,
    dataset_gb: float,
    *,
    datasets_gb: Sequence[float] | None = None,
    host_threads: Sequence[int] = (2, 6, 12, 24, 36, 48),
    device_threads: Sequence[int] = (2, 4, 8, 16, 30, 60, 120, 180, 240),
    host_affinities: Sequence[str] = ("none", "scatter", "compact"),
    device_affinities: Sequence[str] = ("balanced", "scatter", "compact"),
    fractions: Sequence[float] | None = None,
    seed: int = 0,
    n_estimators: int = 150,
    max_depth: int = 5,
    return_eval: bool = False,
):
    """Generate the paper's training grid and fit per-side BDTR models.

    The paper runs 2880 host experiments (4 datasets x 6 thread counts x 3
    affinities x 40 fractions) and 4320 device experiments (9 thread
    counts), then trains on half and evaluates on the other half.  Feature
    vectors are [input_gb, threads, affinity one-hot..., fraction_pct].

    Returns (surrogate, n_experiments[, eval_tables]).
    """
    rng = np.random.default_rng(seed)
    if fractions is None:
        fractions = [2.5 * i for i in range(1, 41)]  # 2.5 .. 100 step 2.5
    if datasets_gb is None:
        datasets_gb = (dataset_gb,)

    def one_hot(val: str, domain: Sequence[str]) -> list[float]:
        return [1.0 if val == d else 0.0 for d in domain]

    host_rows, host_y = [], []
    for gb in datasets_gb:
        for t in host_threads:
            for aff in host_affinities:
                for f in fractions:
                    tt = platform.host_time(gb * f / 100.0, t, aff)
                    tt *= float(np.exp(rng.normal(0, platform.noise_sigma)))
                    host_rows.append([gb, t, *one_hot(aff, host_affinities), f])
                    host_y.append(tt)
    dev_rows, dev_y = [], []
    for gb in datasets_gb:
        for t in device_threads:
            for aff in device_affinities:
                for f in fractions:
                    tt = platform.device_time(gb * f / 100.0, t, aff)
                    tt *= float(np.exp(rng.normal(0, platform.noise_sigma)))
                    dev_rows.append([gb, t, *one_hot(aff, device_affinities), f])
                    dev_y.append(tt)

    host_X = np.asarray(host_rows)
    host_y = np.asarray(host_y)
    dev_X = np.asarray(dev_rows)
    dev_y = np.asarray(dev_y)
    n_experiments = len(host_y) + len(dev_y)

    # half train / half eval (paper's "standard validation methodology")
    def split(X, y):
        idx = rng.permutation(len(y))
        half = len(y) // 2
        return (X[idx[:half]], y[idx[:half]]), (X[idx[half:]], y[idx[half:]])

    (hXtr, hytr), (hXev, hyev) = split(host_X, host_y)
    (dXtr, dytr), (dXev, dyev) = split(dev_X, dev_y)

    host_model = BoostedTreesRegressor(
        n_estimators=n_estimators, max_depth=max_depth, seed=seed).fit(hXtr, hytr)
    dev_model = BoostedTreesRegressor(
        n_estimators=n_estimators, max_depth=max_depth, seed=seed + 1).fit(dXtr, dytr)

    def host_features(cfg: Mapping[str, Any]) -> np.ndarray:
        return np.asarray([
            dataset_gb, float(cfg["host_threads"]),
            *one_hot(str(cfg["host_affinity"]), host_affinities),
            float(cfg["host_fraction"]),
        ])

    def device_features(cfg: Mapping[str, Any]) -> np.ndarray:
        return np.asarray([
            dataset_gb, float(cfg["device_threads"]),
            *one_hot(str(cfg["device_affinity"]), device_affinities),
            100.0 - float(cfg["host_fraction"]),
        ])

    surrogate = SurrogatePair(host=host_model, device=dev_model,
                              host_features=host_features,
                              device_features=device_features)
    if return_eval:
        eval_tables = {
            "host": (hXev, hyev, host_model.predict(hXev)),
            "device": (dXev, dyev, dev_model.predict(dXev)),
        }
        return surrogate, n_experiments, eval_tables
    return surrogate, n_experiments
