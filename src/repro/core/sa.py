"""Simulated Annealing over discrete config spaces.

Faithful implementation of the paper's algorithm (Fig. 3):

    T <- initial temperature; s <- random config
    while T > T_min:
        s' <- neighbor(s)
        if E(s') < E(s): accept
        else: accept with p = exp((E - E') / T)       (Eq. 4)
        T <- T * (1 - coolingRate)                    (Eq. 3)

Two engines are provided:

  * ``simulated_annealing`` — the reference scalar chain.  One energy
    evaluation per iteration; this is what the paper runs, and what SAM /
    SAML wrap (with a measurement or an ML model as ``energy_fn``).
  * ``vectorized_sa`` — beyond-paper: many independent chains advanced in
    lockstep under ``jax.vmap`` + ``lax.scan`` with a jitted energy function
    (e.g. the jitted BDTR predictor).  Thousands of iterations/second on the
    prediction oracle instead of one measurement per iteration.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Callable, Mapping, Sequence

import numpy as np

import jax
import jax.numpy as jnp

from .space import ConfigSpace

__all__ = ["SAResult", "SASchedule", "simulated_annealing", "vectorized_sa"]


@dataclass(frozen=True)
class SASchedule:
    """Annealing schedule — the paper's geometric cooling (Eq. 3)."""

    initial_temp: float = 10.0
    cooling_rate: float = 0.003
    min_temp: float = 1e-4
    # Normalise acceptance by the initial energy so the schedule does not
    # depend on the absolute scale of the objective (seconds vs ms).
    relative_energy: bool = True

    def n_iterations(self) -> int:
        """Iterations until T < min_temp under geometric cooling."""
        return int(
            math.ceil(
                math.log(self.min_temp / self.initial_temp)
                / math.log(1.0 - self.cooling_rate)
            )
        )

    @staticmethod
    def for_iterations(n: int, initial_temp: float = 10.0,
                       min_temp: float = 1e-4) -> "SASchedule":
        """Pick the cooling rate so the chain runs ~n iterations (paper's
        'we can adjust the number of iterations ... by adjusting the cooling
        function')."""
        rate = 1.0 - (min_temp / initial_temp) ** (1.0 / max(n, 1))
        return SASchedule(initial_temp=initial_temp, cooling_rate=rate,
                          min_temp=min_temp)


@dataclass
class SAResult:
    best_config: dict
    best_energy: float
    n_iterations: int
    n_evaluations: int
    # history rows: (iteration, current_energy, best_energy, temperature)
    history: list[tuple[int, float, float, float]] = field(default_factory=list)
    # best-so-far (energy, config) sampled at requested checkpoints
    checkpoints: dict[int, tuple[float, dict]] = field(default_factory=dict)


def simulated_annealing(
    space: ConfigSpace,
    energy_fn: Callable[[Mapping[str, Any]], float],
    *,
    schedule: SASchedule = SASchedule(),
    seed: int = 0,
    initial: Mapping[str, Any] | None = None,
    max_iterations: int | None = None,
    checkpoint_at: Sequence[int] = (),
    record_history: bool = False,
) -> SAResult:
    """Reference scalar SA chain (the paper's algorithm)."""
    rng = np.random.default_rng(seed)
    cur = dict(initial) if initial is not None else space.random(rng)
    space.validate(cur)
    cur_e = float(energy_fn(cur))
    best, best_e = dict(cur), cur_e
    scale = abs(cur_e) if (schedule.relative_energy and cur_e) else 1.0

    t = schedule.initial_temp
    n_evals = 1
    it = 0
    history: list[tuple[int, float, float, float]] = []
    checkpoints: dict[int, float] = {}
    checkpoint_set = set(int(c) for c in checkpoint_at)
    limit = max_iterations if max_iterations is not None else schedule.n_iterations()

    while t > schedule.min_temp and it < limit:
        cand = space.neighbor(cur, rng)
        cand_e = float(energy_fn(cand))
        n_evals += 1
        if cand_e < cur_e:
            accept = True
        else:
            # Paper Eq. 4: p = exp((E - E') / T); with optional energy
            # normalisation so temperatures are unit-free.
            p = math.exp((cur_e - cand_e) / scale / t)
            accept = rng.random() < p
        if accept:
            cur, cur_e = cand, cand_e
        if cur_e < best_e:
            best, best_e = dict(cur), cur_e
        it += 1
        t *= 1.0 - schedule.cooling_rate
        if record_history:
            history.append((it, cur_e, best_e, t))
        if it in checkpoint_set:
            checkpoints[it] = (best_e, dict(best))

    return SAResult(best_config=best, best_energy=best_e, n_iterations=it,
                    n_evaluations=n_evals, history=history,
                    checkpoints=checkpoints)


# ---------------------------------------------------------------------------
# Vectorized multi-chain SA (beyond-paper optimization).
# ---------------------------------------------------------------------------

def vectorized_sa(
    space: ConfigSpace,
    energy_fn_jax: Callable[[jnp.ndarray], jnp.ndarray],
    *,
    n_chains: int = 32,
    n_iterations: int = 2000,
    schedule: SASchedule = SASchedule(),
    seed: int = 0,
    checkpoint_at: Sequence[int] = (),
) -> SAResult:
    """Run ``n_chains`` independent SA chains in lockstep under jit/vmap.

    ``energy_fn_jax`` maps a feature matrix ``(n, feature_dim)`` (as produced
    by ``space.encode``) to energies ``(n,)`` and must be jit-compatible —
    e.g. ``bdtr.predict_jax``.  Configurations are carried as per-parameter
    value-index vectors; features are built by table lookup.

    ``checkpoint_at`` records, for each given (1-based) iteration number,
    the best-so-far (energy, config) across ALL chains at that iteration
    — the multi-chain analogue of the scalar engine's best-so-far
    checkpoints (``history``, by contrast, follows the winning chain).
    """
    card = jnp.asarray(space.cardinalities)
    n_params = len(space.params)
    table, _ = space.index_feature_table()
    table_j = jnp.asarray(table)  # (n_params, max_card, feat_dim)
    ordinal = jnp.asarray([p.ordinal for p in space.params])

    def encode_idx(idx):  # idx: (n_params,) int32 -> (feat_dim,)
        rows = table_j[jnp.arange(n_params), idx]  # (n_params, feat_dim)
        return rows.sum(axis=0)

    def energy_of(idx):
        return energy_fn_jax(encode_idx(idx)[None, :])[0]

    temps = schedule.initial_temp * (1.0 - schedule.cooling_rate) ** jnp.arange(
        n_iterations
    )

    def chain(key):
        key, k0 = jax.random.split(key)
        idx0 = jax.random.randint(k0, (n_params,), 0, card, dtype=jnp.int32)
        e0 = energy_of(idx0)
        scale = jnp.where(schedule.relative_energy, jnp.abs(e0) + 1e-12, 1.0)

        def step(state, t):
            idx, e, best_idx, best_e, key = state
            # one key per decision: param choice, step size, step direction,
            # categorical resample, acceptance (kd must NOT be reused for
            # the categorical draw, or resampled values correlate with the
            # step direction)
            key, kp, ks, kd, kc, ka = jax.random.split(key, 6)
            which = jax.random.randint(kp, (), 0, n_params)
            # ordinal: +-1/2 step clipped; categorical: resample
            step_sz = jax.random.randint(ks, (), 1, 3) * jnp.where(
                jax.random.bernoulli(kd), 1, -1
            )
            cur_val = idx[which]
            c = card[which]
            ord_val = jnp.clip(cur_val + step_sz, 0, c - 1)
            ord_val = jnp.where(ord_val == cur_val,
                                jnp.clip(cur_val - step_sz, 0, c - 1), ord_val)
            cat_val = jax.random.randint(kc, (), 0, c)
            new_val = jnp.where(ordinal[which], ord_val, cat_val).astype(jnp.int32)
            cand = idx.at[which].set(new_val)
            ce = energy_of(cand)
            accept = jnp.logical_or(
                ce < e,
                jax.random.uniform(ka) < jnp.exp((e - ce) / scale / t),
            )
            idx = jnp.where(accept, cand, idx)
            e = jnp.where(accept, ce, e)
            better = e < best_e
            best_idx = jnp.where(better, idx, best_idx)
            best_e = jnp.where(better, e, best_e)
            return (idx, e, best_idx, best_e, key), (best_e, best_idx)

        (idx, e, best_idx, best_e, _), trace = jax.lax.scan(
            step, (idx0, e0, idx0, e0, key), temps
        )
        return best_idx, best_e, trace

    keys = jax.random.split(jax.random.PRNGKey(seed), n_chains)
    best_idx, best_e, (trace_e, trace_idx) = jax.jit(jax.vmap(chain))(keys)
    winner = int(jnp.argmin(best_e))
    cfg = space.from_indices(np.asarray(best_idx[winner]))
    trace_e = np.asarray(trace_e)        # (n_chains, n_iterations)
    trace_idx = np.asarray(trace_idx)    # (n_chains, n_iterations, n_params)
    win_e = trace_e[winner]
    # a checkpoint is the best-so-far across ALL chains at that iteration
    # (every chain has spent its budget by then), not the eventual
    # winner's state — the winner may lag at intermediate iterations
    checkpoints = {}
    for it in checkpoint_at:
        it = int(it)
        if not 1 <= it <= n_iterations:
            continue
        c = int(np.argmin(trace_e[:, it - 1]))
        checkpoints[it] = (float(trace_e[c, it - 1]),
                           space.from_indices(trace_idx[c, it - 1]))
    return SAResult(
        best_config=cfg,
        best_energy=float(best_e[winner]),
        n_iterations=n_iterations,
        n_evaluations=n_chains * (n_iterations + 1),
        history=[(i + 1, float(win_e[i]), float(win_e[i]), 0.0)
                 for i in range(0, n_iterations, max(1, n_iterations // 64))],
        checkpoints=checkpoints,
    )
