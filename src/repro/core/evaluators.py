"""Evaluation oracles for proposed system configurations.

The paper distinguishes evaluating a configuration by *measurement*
(running the experiment) from evaluating it by *machine learning*
(predicting with the trained BDTR model).  Both are exposed behind the
same callable interface so every search strategy (enumeration / SA) can be
paired with either oracle — giving the paper's four methods EM, EML, SAM,
SAML (Table II).

``MeasurementEvaluator`` counts *experiments* (deduplicated — re-measuring
an identical configuration is free in the paper's accounting since results
are recorded); ``LearnedEvaluator`` counts predictions, which are
effectively free.  The counters feed the effort comparison in
EXPERIMENTS.md (Result 3: SAML needs ~5 % of EM's experiments).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Mapping

import numpy as np

from .bdtr import BoostedTreesRegressor
from .space import ConfigSpace

__all__ = ["MeasurementEvaluator", "LearnedEvaluator", "SurrogatePair"]


class MeasurementEvaluator:
    """Wraps a measurement function; counts distinct experiments."""

    def __init__(self, fn: Callable[[Mapping[str, Any]], float],
                 space: ConfigSpace, dedup: bool = True):
        self._fn = fn
        self._space = space
        self._dedup = dedup
        self._cache: dict[tuple, float] = {}
        self.n_experiments = 0

    def _key(self, cfg: Mapping[str, Any]) -> tuple:
        return tuple(cfg[n] for n in self._space.names)

    def __call__(self, cfg: Mapping[str, Any]) -> float:
        key = self._key(cfg)
        if self._dedup and key in self._cache:
            return self._cache[key]
        val = float(self._fn(cfg))
        self.n_experiments += 1
        if self._dedup:
            self._cache[key] = val
        return val


@dataclass
class SurrogatePair:
    """Host + device execution-time models (the paper trains per side).

    The combined objective is E(cfg) = max(T_host_hat, T_device_hat)
    (paper Eq. 2 evaluated on predictions).
    """

    host: BoostedTreesRegressor
    device: BoostedTreesRegressor
    host_features: Callable[[Mapping[str, Any]], np.ndarray]
    device_features: Callable[[Mapping[str, Any]], np.ndarray]

    def predict_energy(self, cfg: Mapping[str, Any]) -> float:
        f = float(cfg["host_fraction"])
        th = self.host.predict(self.host_features(cfg)[None, :])[0] if f > 0 else 0.0
        td = (self.device.predict(self.device_features(cfg)[None, :])[0]
              if f < 100 else 0.0)
        return float(max(th, td))


class LearnedEvaluator:
    """ML oracle: predicts E(cfg); counts predictions (not experiments)."""

    def __init__(self, surrogate: SurrogatePair):
        self._surrogate = surrogate
        self.n_predictions = 0

    def __call__(self, cfg: Mapping[str, Any]) -> float:
        self.n_predictions += 1
        return self._surrogate.predict_energy(cfg)
