"""Evaluation oracles for proposed system configurations.

The paper distinguishes evaluating a configuration by *measurement*
(running the experiment) from evaluating it by *machine learning*
(predicting with the trained BDTR model).  Both are exposed behind the
same callable interface so every search strategy (enumeration / SA) can be
paired with either oracle — giving the paper's four methods EM, EML, SAM,
SAML (Table II).

``MeasurementEvaluator`` counts *experiments* (deduplicated — re-measuring
an identical configuration is free in the paper's accounting since results
are recorded); ``LearnedEvaluator`` counts predictions, which are
effectively free.  The counters feed the effort comparison in
EXPERIMENTS.md (Result 3: SAML needs ~5 % of EM's experiments).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Mapping

import numpy as np

from .bdtr import BoostedTreesRegressor
from .space import ConfigSpace

__all__ = ["MeasurementEvaluator", "LearnedEvaluator",
           "BatchedLearnedEvaluator", "SurrogatePair"]


class MeasurementEvaluator:
    """Wraps a measurement function; counts distinct experiments."""

    def __init__(self, fn: Callable[[Mapping[str, Any]], float],
                 space: ConfigSpace, dedup: bool = True):
        self._fn = fn
        self._space = space
        self._dedup = dedup
        self._cache: dict[tuple, float] = {}
        self.n_experiments = 0

    def _key(self, cfg: Mapping[str, Any]) -> tuple:
        return tuple(cfg[n] for n in self._space.names)

    def __call__(self, cfg: Mapping[str, Any]) -> float:
        key = self._key(cfg)
        if self._dedup and key in self._cache:
            return self._cache[key]
        val = float(self._fn(cfg))
        self.n_experiments += 1
        if self._dedup:
            self._cache[key] = val
        return val


@dataclass
class SurrogatePair:
    """Host + device execution-time models (the paper trains per side).

    The combined objective is E(cfg) = max(T_host_hat, T_device_hat)
    (paper Eq. 2 evaluated on predictions).
    """

    host: BoostedTreesRegressor
    device: BoostedTreesRegressor
    host_features: Callable[[Mapping[str, Any]], np.ndarray]
    device_features: Callable[[Mapping[str, Any]], np.ndarray]
    # Optional batched feature builders: map column-oriented config batches
    # ({param_name: (n,) value array}) to model feature matrices (n, d).
    # When absent, the batched paths fall back to stacking the scalar
    # builders (still one model ``predict`` per sweep instead of n).
    host_features_cols: Callable[[Mapping[str, np.ndarray]], np.ndarray] | \
        None = None
    device_features_cols: Callable[[Mapping[str, np.ndarray]], np.ndarray] | \
        None = None
    # Optional builder of a jit-compatible energy function over a space's
    # *encoded* feature matrix: energy_fn_jax_builder(space) -> f((n, F))
    # -> (n,) predicted E = max(T_host, T_device).  Powers the vectorized
    # SA engine (see sa.vectorized_sa / Autotuner.tune_saml).
    energy_fn_jax_builder: Callable[[ConfigSpace], Callable] | None = None

    def predict_energy(self, cfg: Mapping[str, Any]) -> float:
        f = float(cfg["host_fraction"])
        th = self.host.predict(self.host_features(cfg)[None, :])[0] if f > 0 else 0.0
        td = (self.device.predict(self.device_features(cfg)[None, :])[0]
              if f < 100 else 0.0)
        return float(max(th, td))

    def _feature_matrices(self, columns: Mapping[str, np.ndarray]
                          ) -> tuple[np.ndarray, np.ndarray]:
        if self.host_features_cols is not None and \
                self.device_features_cols is not None:
            return (np.asarray(self.host_features_cols(columns)),
                    np.asarray(self.device_features_cols(columns)))
        # fallback: per-row dicts through the scalar builders (model
        # prediction — the expensive part — stays batched)
        names = list(columns)
        rows = zip(*(np.asarray(columns[n]) for n in names))
        cfgs = [dict(zip(names, r)) for r in rows]
        return (np.stack([self.host_features(c) for c in cfgs]),
                np.stack([self.device_features(c) for c in cfgs]))

    def predict_energy_batch(self, columns: Mapping[str, np.ndarray]
                             ) -> np.ndarray:
        """Vectorized ``predict_energy`` over a column-oriented batch.

        Two ensemble ``predict`` calls total; the host-only/device-only
        collapse (T=0 when the side receives no work) is an array op, so
        results match the scalar path exactly.
        """
        f = np.asarray(columns["host_fraction"], dtype=np.float64)
        Xh, Xd = self._feature_matrices(columns)
        th = np.where(f > 0, self.host.predict(Xh), 0.0)
        td = np.where(f < 100, self.device.predict(Xd), 0.0)
        return np.maximum(th, td)


class LearnedEvaluator:
    """ML oracle: predicts E(cfg); counts predictions (not experiments)."""

    def __init__(self, surrogate: SurrogatePair):
        self._surrogate = surrogate
        self.n_predictions = 0

    def __call__(self, cfg: Mapping[str, Any]) -> float:
        self.n_predictions += 1
        return self._surrogate.predict_energy(cfg)


class BatchedLearnedEvaluator:
    """Batched ML oracle: scores whole config batches per call.

    Same prediction accounting as ``LearnedEvaluator`` (one count per
    config scored) so the paper's effort comparison is unchanged; the
    difference is purely mechanical — a sweep over ``space.size()``
    configs is a handful of numpy ``predict`` calls instead of
    ``space.size()`` Python calls.
    """

    def __init__(self, surrogate: SurrogatePair):
        self._surrogate = surrogate
        self.n_predictions = 0

    def __call__(self, columns: Mapping[str, np.ndarray]) -> np.ndarray:
        n = len(np.asarray(next(iter(columns.values()))))
        self.n_predictions += n
        return self._surrogate.predict_energy_batch(columns)
