"""Grouped-query attention: training/prefill (blockwise online-softmax) and
single-token decode with a KV cache.

The XLA path is the reference/distribution implementation (what the
multi-pod dry-run lowers); ``attn_impl="pallas"`` switches the hot loops to
the Pallas TPU kernels in ``repro.kernels`` (validated against the same
math in interpret mode).  Prefill never materialises the (S x S) score
matrix: a two-level ``lax.scan`` over query/key chunks runs the standard
online-softmax recurrence, so 32k-token prefill fits activation memory.

KV caches are logical-axis sharded: ``kv_seq`` maps to nothing for normal
decode and to the data axes for long-context decode (sequence-sharded
cache + global logsumexp combine, which GSPMD lowers to the psum pattern).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name

from ..dist.api import constrain
from .config import ArchConfig
from .layers import apply_rope, dense_init

Params = dict[str, Any]

NEG_INF = -1e30


def init_attention(key, cfg: ArchConfig, cross: bool = False) -> Params:
    d, hd = cfg.d_model, cfg.head_dim
    dt = cfg.param_dtype
    ks = jax.random.split(key, 4)
    p: Params = {
        "wq": dense_init(ks[0], (d, cfg.n_heads, hd), dt),
        "wk": dense_init(ks[1], (d, cfg.n_kv_heads, hd), dt),
        "wv": dense_init(ks[2], (d, cfg.n_kv_heads, hd), dt),
        "wo": dense_init(ks[3], (cfg.n_heads, hd, d), dt, in_axis=0),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((cfg.n_heads, hd), dt)
        p["bk"] = jnp.zeros((cfg.n_kv_heads, hd), dt)
        p["bv"] = jnp.zeros((cfg.n_kv_heads, hd), dt)
    return p


def _project_q(p: Params, x: jax.Array, cfg: ArchConfig,
               positions: jax.Array | None) -> jax.Array:
    dt = jnp.dtype(cfg.compute_dtype)
    q = jnp.einsum("btd,dnh->btnh", x.astype(dt), p["wq"].astype(dt))
    if "bq" in p:
        q = q + p["bq"].astype(dt)
    if positions is not None and cfg.positions == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
    return checkpoint_name(constrain(q, "batch", None, "heads", None),
                           "qkv_out")


def _project_kv(p: Params, x: jax.Array, cfg: ArchConfig,
                positions: jax.Array | None) -> tuple[jax.Array, jax.Array]:
    dt = jnp.dtype(cfg.compute_dtype)
    k = jnp.einsum("btd,dnh->btnh", x.astype(dt), p["wk"].astype(dt))
    v = jnp.einsum("btd,dnh->btnh", x.astype(dt), p["wv"].astype(dt))
    if "bk" in p:
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    if positions is not None and cfg.positions == "rope":
        k = apply_rope(k, positions, cfg.rope_theta)
    k = checkpoint_name(constrain(k, "batch", None, "kv_heads", None),
                        "qkv_out")
    v = checkpoint_name(constrain(v, "batch", None, "kv_heads", None),
                        "qkv_out")
    return k, v


def _repeat_kv(k: jax.Array, n_heads: int) -> jax.Array:
    """(B,T,KV,hd) -> (B,T,H,hd) by repeating each kv head H/KV times."""
    b, t, kv, hd = k.shape
    if kv == n_heads:
        return k
    rep = n_heads // kv
    return jnp.broadcast_to(k[:, :, :, None, :], (b, t, kv, rep, hd)) \
        .reshape(b, t, n_heads, hd)


def blockwise_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool, q_chunk: int = 512,
                        kv_chunk: int = 1024,
                        q_offset: int = 0) -> jax.Array:
    """Online-softmax attention without materialising (S x S) scores.

    q: (B, Tq, H, hd); k, v: (B, Tk, H, hd) (kv already head-repeated).
    ``q_offset`` shifts query positions for causal masking (prefill
    continuation).  Returns (B, Tq, H, hd) in q.dtype.
    """
    b, tq, h, hd = q.shape
    tk = k.shape[1]
    q_chunk = min(q_chunk, tq)
    kv_chunk = min(kv_chunk, tk)
    n_q, n_k = tq // q_chunk, tk // kv_chunk
    assert tq % q_chunk == 0 and tk % kv_chunk == 0
    scale = hd ** -0.5
    qr = ((q.astype(jnp.float32) * scale).astype(q.dtype)
          .reshape(b, n_q, q_chunk, h, hd))
    kr = k.reshape(b, n_k, kv_chunk, h, hd)
    vr = v.reshape(b, n_k, kv_chunk, h, hd)

    def q_step(_, qi_idx):
        qi, iq = qi_idx  # (b, q_chunk, h, hd), scalar chunk index

        def kv_step(carry, kv_idx):
            acc, m, l = carry
            kj, vj, jk = kv_idx
            s = jnp.einsum("bqhd,bkhd->bhqk", qi, kj,
                           preferred_element_type=jnp.float32)
            if causal:
                qpos = q_offset + iq * q_chunk + jnp.arange(q_chunk)
                kpos = jk * kv_chunk + jnp.arange(kv_chunk)
                mask = qpos[:, None] >= kpos[None, :]
                s = jnp.where(mask[None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            pexp = jnp.exp(s - m_new[..., None])
            l = l * alpha + pexp.sum(axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", pexp.astype(vj.dtype), vj,
                preferred_element_type=jnp.float32)
            return (acc, m_new, l), None

        acc0 = jnp.zeros((b, h, q_chunk, hd), jnp.float32)
        m0 = jnp.full((b, h, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, q_chunk), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(
            kv_step, (acc0, m0, l0),
            (kr.swapaxes(0, 1), vr.swapaxes(0, 1), jnp.arange(n_k)))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out.swapaxes(1, 2)  # (b, q_chunk, h, hd)

    _, chunks = jax.lax.scan(
        q_step, None, (qr.swapaxes(0, 1), jnp.arange(n_q)))
    out = chunks.swapaxes(0, 1).reshape(b, tq, h, hd)
    return out.astype(q.dtype)


def full_attention(p: Params, x: jax.Array, cfg: ArchConfig, *,
                   positions: jax.Array, causal: bool = True,
                   kv_states: jax.Array | None = None,
                   kv_positions: jax.Array | None = None,
                   return_kv: bool = False):
    """Training / prefill attention over full sequences.

    ``kv_states`` switches to cross-attention (keys/values from the encoder
    stream, no RoPE on either side for enc-dec models).  ``return_kv``
    additionally returns the (pre-repeat) keys/values for cache fills.
    """
    q = _project_q(p, x, cfg, positions if kv_states is None else None)
    src = x if kv_states is None else kv_states
    if kv_states is None and kv_positions is None:
        kv_positions = positions                      # self-attention RoPE
    k, v = _project_kv(p, src, cfg,
                       kv_positions if kv_states is None else None)
    kr = _repeat_kv(k, cfg.n_heads)
    vr = _repeat_kv(v, cfg.n_heads)

    if cfg.attn_impl == "pallas":
        from ..kernels.flash_attention import ops as fa_ops
        # tuned=None: resolves the cached best launch params when kernel
        # tuning is enabled (repro.tune.kernels.configure; serve.py's
        # --tuned-kernels), hardcoded defaults otherwise
        out = fa_ops.flash_attention(q, kr, vr, causal=causal, tuned=None)
    else:
        out = blockwise_attention(q, kr, vr, causal=causal)
    out = constrain(out, "batch", None, "heads", None)
    dt = jnp.dtype(cfg.compute_dtype)
    res = jnp.einsum("btnh,nhd->btd", out.astype(dt), p["wo"].astype(dt))
    res = constrain(res, "batch", "seq", None)
    if return_kv:
        return res, {"k": k, "v": v}
    return res


# -- decode -------------------------------------------------------------------

def init_kv_cache(cfg: ArchConfig, batch: int, max_len: int,
                  dtype=None) -> Params:
    dt = dtype or jnp.dtype(cfg.compute_dtype)
    shape = (batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}


def decode_attention(p: Params, x: jax.Array, cache: Params,
                     cfg: ArchConfig, *, pos: jax.Array,
                     cross: bool = False) -> tuple[jax.Array, Params]:
    """One-token decode. x: (B, 1, D); cache k/v: (B, S, KV, hd).

    ``pos`` is the current position (scalar int32): the new KV is written
    at ``pos`` and attention spans positions <= pos.  For cross-attention
    the cache holds precomputed encoder KV and is not updated.
    """
    from ..dist.api import current_rules

    b = x.shape[0]
    q = _project_q(p, x, cfg, None if cross else jnp.full((b, 1), pos))
    rules = current_rules()
    kvseq_axes = tuple(rules.rules.get("kv_seq", ())) if rules else ()
    batch_axes = tuple(rules.rules.get("batch", ())) if rules else ()
    if kvseq_axes:
        # the sharded path needs shard_map-divisible extents; fall back to
        # the dense path otherwise (rules are hints, not hard partitioning)
        if cache["k"].shape[1] % rules.axes_size(kvseq_axes) \
                or (batch_axes and b % rules.axes_size(batch_axes)):
            kvseq_axes = ()
    if not cross and kvseq_axes:
        # sequence-sharded cache: shard_map'd local update + flash-decode
        # with cross-shard logsumexp combine (see dist.seq_decode).
        from ..dist.seq_decode import seq_decode_attention
        k_new, v_new = _project_kv(p, x, cfg, jnp.full((b, 1), pos))
        out32, ck, cv = seq_decode_attention(
            q[:, 0], k_new[:, 0], v_new[:, 0], cache["k"], cache["v"], pos,
            mesh=rules.mesh, seq_axes=kvseq_axes, batch_axes=batch_axes)
        cache = {"k": ck, "v": cv}
        dt = jnp.dtype(cfg.compute_dtype)
        out = out32.astype(dt)[:, None]                       # (B,1,H,hd)
        res = jnp.einsum("btnh,nhd->btd", out, p["wo"].astype(dt))
        return constrain(res, "batch", None, None), cache
    if not cross:
        k_new, v_new = _project_kv(p, x, cfg, jnp.full((b, 1), pos))
        cache = {
            "k": jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new, pos, 1),
            "v": jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new, pos, 1),
        }
        cache = {n: constrain(c, "batch", "kv_seq", "kv_heads", None)
                 for n, c in cache.items()}
    k, v = cache["k"], cache["v"]
    kv_len = k.shape[1]

    if cfg.attn_impl == "pallas":
        from ..kernels.decode_attention import ops as da_ops
        out = da_ops.decode_attention(q[:, 0], k, v,
                                      length=None if cross else pos + 1,
                                      tuned=None)
    else:
        scale = cfg.head_dim ** -0.5
        kh = _repeat_kv(k, cfg.n_heads)
        vh = _repeat_kv(v, cfg.n_heads)
        # bf16 operands + fp32 accumulation: never materialise an fp32
        # copy of the cache.
        qs = (q.astype(jnp.float32) * scale).astype(kh.dtype)
        s = jnp.einsum("bqnh,bknh->bnqk", qs, kh,
                       preferred_element_type=jnp.float32)
        if not cross:
            valid = jnp.arange(kv_len)[None, None, None, :] <= pos
            s = jnp.where(valid, s, NEG_INF)
        w = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bnqk,bknh->bqnh", w.astype(vh.dtype), vh,
                         preferred_element_type=jnp.float32)
        out = out[:, 0]
    out = out.astype(jnp.dtype(cfg.compute_dtype))[:, None]  # (B,1,H,hd)
    dt = jnp.dtype(cfg.compute_dtype)
    res = jnp.einsum("btnh,nhd->btd", out, p["wo"].astype(dt))
    return constrain(res, "batch", None, None), cache


def precompute_cross_kv(p: Params, enc: jax.Array, cfg: ArchConfig) -> Params:
    k, v = _project_kv(p, enc, cfg, None)
    return {"k": k, "v": v}
