"""Whisper-style encoder-decoder backbone (audio family).

The conv/mel frontend is a STUB per the assignment: ``input_specs`` feeds
precomputed frame embeddings (B, S_enc, D) straight into the encoder.
Encoder layers are bidirectional self-attention; decoder layers are causal
self-attention + cross-attention + MLP.  Sinusoidal positions on both
streams (deviation from Whisper's learned decoder positions — noted in
DESIGN.md; irrelevant to systems behaviour).

Decode cells: the self-attention cache has the cell's ``seq_len`` capacity
(per the assignment's decode-shape definition) while cross-attention reads
a fixed-length precomputed encoder state (``cross_len``).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from ..dist.api import constrain
from .attention import (decode_attention, full_attention, init_attention,
                        init_kv_cache, precompute_cross_kv)
from .config import ArchConfig
from .layers import (apply_mlp, apply_norm, embed_tokens, init_embed,
                     init_mlp, init_norm, sinusoidal_positions)
from .lm import _remat_policy, chunked_xent

Params = dict[str, Any]


def _init_enc_layer(key, cfg: ArchConfig) -> Params:
    k1, k2 = jax.random.split(key)
    return {"norm1": init_norm(cfg), "mixer": init_attention(k1, cfg),
            "norm2": init_norm(cfg), "channel": init_mlp(k2, cfg)}


def _init_dec_layer(key, cfg: ArchConfig) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {"norm1": init_norm(cfg), "self": init_attention(k1, cfg),
            "norm_x": init_norm(cfg), "cross": init_attention(k2, cfg,
                                                              cross=True),
            "norm2": init_norm(cfg), "channel": init_mlp(k3, cfg)}


@dataclass(frozen=True)
class EncDec:
    cfg: ArchConfig

    def init(self, key) -> Params:
        cfg = self.cfg
        k_emb, k_enc, k_dec = jax.random.split(key, 3)
        enc_keys = jax.random.split(k_enc, cfg.n_encoder_layers)
        dec_keys = jax.random.split(k_dec, cfg.n_layers)
        return {
            "embed": init_embed(k_emb, cfg),
            "encoder": jax.vmap(lambda k: _init_enc_layer(k, cfg))(enc_keys),
            "enc_norm": init_norm(cfg),
            "decoder": jax.vmap(lambda k: _init_dec_layer(k, cfg))(dec_keys),
            "final_norm": init_norm(cfg),
        }

    # -- encoder -----------------------------------------------------------------
    def encode(self, params: Params, frames: jax.Array,
               remat: bool = False) -> jax.Array:
        cfg = self.cfg
        dtc = jnp.dtype(cfg.compute_dtype)
        pos = sinusoidal_positions(frames.shape[1], cfg.d_model).astype(dtc)
        x = frames.astype(dtc) + pos
        x = constrain(x, "batch", "seq", None)
        positions = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])

        def body(h, lp):
            a = full_attention(lp["mixer"], apply_norm(lp["norm1"], h, cfg),
                               cfg, positions=positions, causal=False)
            h = h + a
            h = h + apply_mlp(lp["channel"], apply_norm(lp["norm2"], h, cfg),
                              cfg)
            return h, None

        if remat:
            body = jax.checkpoint(body, policy=_remat_policy(remat))
        x, _ = jax.lax.scan(body, x, params["encoder"])
        return apply_norm(params["enc_norm"], x, cfg)

    # -- decoder (teacher-forced training) ------------------------------------------
    def decode_train(self, params: Params, tokens: jax.Array,
                     enc: jax.Array, remat: bool = False
                     ) -> jax.Array:
        cfg = self.cfg
        dtc = jnp.dtype(cfg.compute_dtype)
        x = embed_tokens(params["embed"], tokens, cfg)
        x = x + sinusoidal_positions(x.shape[1], cfg.d_model).astype(dtc)
        positions = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])

        def body(h, lp):
            a = full_attention(lp["self"], apply_norm(lp["norm1"], h, cfg),
                               cfg, positions=positions, causal=True)
            h = h + a
            c = full_attention(lp["cross"], apply_norm(lp["norm_x"], h, cfg),
                               cfg, positions=positions, causal=False,
                               kv_states=enc)
            h = h + c
            h = h + apply_mlp(lp["channel"], apply_norm(lp["norm2"], h, cfg),
                              cfg)
            return h, None

        if remat:
            body = jax.checkpoint(body, policy=_remat_policy(remat))
        x, _ = jax.lax.scan(body, x, params["decoder"])
        return apply_norm(params["final_norm"], x, cfg)

    def loss(self, params: Params, batch: dict, *, remat: bool = False
             ) -> tuple[jax.Array, dict]:
        cfg = self.cfg
        enc = self.encode(params, batch["frame_embeds"], remat=remat)
        h = self.decode_train(params, batch["tokens"], enc, remat=remat)
        mask = batch.get("loss_mask")
        if mask is None:
            mask = jnp.ones(batch["labels"].shape, jnp.float32)
        head_w = (params["embed"]["tokens"].T if cfg.tie_embeddings
                  else params["embed"]["lm_head"])
        xent = chunked_xent(h, head_w, batch["labels"], mask, cfg)
        return xent, {"xent": xent, "aux": jnp.float32(0.0)}

    # -- serving -----------------------------------------------------------------
    def init_decode_state(self, batch: int, max_len: int,
                          cross_len: int = 1024) -> Params:
        cfg = self.cfg

        def one(_):
            return {"self": init_kv_cache(cfg, batch, max_len),
                    "cross": init_kv_cache(cfg, batch, cross_len)}

        return jax.vmap(one)(jnp.arange(cfg.n_layers))

    def prefill_cross(self, params: Params, state: Params,
                      frames: jax.Array) -> Params:
        """Run the encoder and fill the cross-attention caches."""
        cfg = self.cfg
        enc = self.encode(params, frames)

        def per_layer(lp, _):
            return precompute_cross_kv(lp["cross"], enc, cfg)

        cross = jax.lax.map(lambda lp: per_layer(lp, None), params["decoder"])
        return {"self": state["self"], "cross": cross}

    def decode_step(self, params: Params, state: Params, tokens: jax.Array,
                    pos: jax.Array) -> tuple[jax.Array, Params]:
        cfg = self.cfg
        dtc = jnp.dtype(cfg.compute_dtype)
        x = embed_tokens(params["embed"], tokens, cfg)
        pos_emb = sinusoidal_positions(cfg.decoder_len + 1, cfg.d_model)
        x = x + jax.lax.dynamic_slice_in_dim(
            pos_emb, jnp.minimum(pos, pos_emb.shape[0] - 1), 1, axis=0
        ).astype(dtc)

        def body(h, scanned):
            lp, ls = scanned
            a, self_cache = decode_attention(
                lp["self"], apply_norm(lp["norm1"], h, cfg), ls["self"], cfg,
                pos=pos)
            h = h + a
            c, _ = decode_attention(
                lp["cross"], apply_norm(lp["norm_x"], h, cfg), ls["cross"],
                cfg, pos=pos, cross=True)
            h = h + c
            h = h + apply_mlp(lp["channel"], apply_norm(lp["norm2"], h, cfg),
                              cfg)
            return h, {"self": self_cache, "cross": ls["cross"]}

        x, new_state = jax.lax.scan(body, x, (params["decoder"], state))
        x = apply_norm(params["final_norm"], x, cfg)
        head_w = (params["embed"]["tokens"].T if cfg.tie_embeddings
                  else params["embed"]["lm_head"])
        logits = (x.astype(dtc) @ head_w.astype(dtc)).astype(jnp.float32)
        return constrain(logits, "batch", None, "vocab"), new_state
