"""Shared building blocks: initializers, norms, RoPE, MLPs, embeddings.

Everything is functional: ``init_*`` builds a parameter pytree from a PRNG
key, ``apply``-style functions are pure.  Compute runs in
``cfg.compute_dtype`` (bf16 on TPU); parameters live in ``cfg.param_dtype``.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name

from ..dist.api import constrain
from .config import ArchConfig

Params = dict[str, Any]


def chunked_scan(step, carry, xs, chunk: int, remat: bool = True):
    """``lax.scan`` over time in remat'd chunks.

    Backward memory for a plain scan is O(T x per-step residuals); chunking
    saves the carry only at T/chunk boundaries and rematerialises inside a
    chunk — O(T/L x carry + L x residuals), the standard SSM/linear-attn
    training layout (and how the Pallas kernels block the recurrences).

    xs leaves have leading dim T (divisible by ``chunk``); returns
    (final_carry, ys) with ys leading dim T.
    """
    t = jax.tree.leaves(xs)[0].shape[0]
    chunk = min(chunk, t)
    while t % chunk:
        chunk -= 1
    n = t // chunk
    xs_c = jax.tree.map(lambda a: a.reshape(n, chunk, *a.shape[1:]), xs)

    def chunk_body(c, x):
        return jax.lax.scan(step, c, x)

    if remat:
        chunk_body = jax.checkpoint(
            chunk_body, policy=jax.checkpoint_policies.nothing_saveable)
    carry, ys = jax.lax.scan(chunk_body, carry, xs_c)
    ys = jax.tree.map(lambda a: a.reshape(t, *a.shape[2:]), ys)
    return carry, ys


# -- initializers -------------------------------------------------------------

def dense_init(key, shape, dtype, in_axis: int = 0) -> jax.Array:
    """Truncated-normal fan-in initializer (std = 1/sqrt(fan_in))."""
    fan_in = shape[in_axis] if isinstance(in_axis, int) else 1
    std = fan_in ** -0.5
    return (std * jax.random.truncated_normal(key, -2.0, 2.0, shape)).astype(dtype)


def embed_init(key, shape, dtype) -> jax.Array:
    return (jax.random.normal(key, shape) * 0.02).astype(dtype)


# -- norms --------------------------------------------------------------------

def init_norm(cfg: ArchConfig, with_bias: bool | None = None) -> Params:
    bias = cfg.norm_type == "layernorm" if with_bias is None else with_bias
    p: Params = {"scale": jnp.ones((cfg.d_model,), cfg.param_dtype)}
    if bias:
        p["bias"] = jnp.zeros((cfg.d_model,), cfg.param_dtype)
    return p


def apply_norm(p: Params, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    if cfg.norm_type == "rmsnorm" and "bias" not in p:
        inv = jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True)
                            + cfg.norm_eps)
        out = x32 * inv * p["scale"].astype(jnp.float32)
    else:
        mu = jnp.mean(x32, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(x32 - mu), axis=-1, keepdims=True)
        out = (x32 - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        out = out * p["scale"].astype(jnp.float32)
        if "bias" in p:
            out = out + p["bias"].astype(jnp.float32)
    return out.astype(dt)


def group_norm(x: jax.Array, n_groups: int, eps: float = 64e-5) -> jax.Array:
    """GroupNorm over the last dim (RWKV's per-head wkv normalisation)."""
    dt = x.dtype
    shape = x.shape
    x32 = x.astype(jnp.float32).reshape(*shape[:-1], n_groups, -1)
    mu = x32.mean(axis=-1, keepdims=True)
    var = jnp.square(x32 - mu).mean(axis=-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return out.reshape(shape).astype(dt)


# -- rotary embeddings ----------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., T, H, hd); positions: broadcastable to (..., T)."""
    dt = x.dtype
    freqs = rope_frequencies(x.shape[-1], theta)          # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., T, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]                # (..., T, 1, hd/2)
    sin = jnp.sin(angles)[..., :, None, :]
    x32 = x.astype(jnp.float32)
    x1, x2 = jnp.split(x32, 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(dt)


def sinusoidal_positions(n_pos: int, d_model: int) -> jax.Array:
    pos = jnp.arange(n_pos, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d_model // 2, dtype=jnp.float32)[None, :]
    angle = pos / (10_000.0 ** (2 * dim / d_model))
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1)


# -- MLPs --------------------------------------------------------------------

def init_mlp(key, cfg: ArchConfig, d_ff: int | None = None) -> Params:
    d_ff = d_ff or cfg.d_ff
    d = cfg.d_model
    dt = cfg.param_dtype
    ks = jax.random.split(key, 3)
    p: Params = {"w_in": dense_init(ks[0], (d, d_ff), dt),
                 "w_out": dense_init(ks[1], (d_ff, d), dt)}
    if cfg.mlp_type == "swiglu":
        p["w_gate"] = dense_init(ks[2], (d, d_ff), dt)
    return p


def apply_mlp(p: Params, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    dt = jnp.dtype(cfg.compute_dtype)
    x = x.astype(dt)
    mid = [None] * (x.ndim - 2)
    h = x @ p["w_in"].astype(dt)
    h = checkpoint_name(constrain(h, "batch", *mid, "ff"), "mlp_hidden")
    if cfg.mlp_type == "swiglu":
        g = x @ p["w_gate"].astype(dt)
        h = jax.nn.silu(g) * h
    elif cfg.mlp_type == "squared_relu":
        h = jnp.square(jax.nn.relu(h))
    elif cfg.mlp_type == "gelu":
        h = jax.nn.gelu(h)
    else:
        raise ValueError(cfg.mlp_type)
    out = h @ p["w_out"].astype(dt)
    return constrain(out, "batch", *(["seq"] if x.ndim == 3 else mid), None)


# -- embeddings & heads ---------------------------------------------------------

def init_embed(key, cfg: ArchConfig) -> Params:
    ks = jax.random.split(key, 2)
    p: Params = {"tokens": embed_init(ks[0], (cfg.vocab_size, cfg.d_model),
                                      cfg.param_dtype)}
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(ks[1], (cfg.d_model, cfg.vocab_size),
                                  cfg.param_dtype)
    return p


def embed_tokens(p: Params, tokens: jax.Array, cfg: ArchConfig) -> jax.Array:
    emb = p["tokens"].astype(jnp.dtype(cfg.compute_dtype))
    out = jnp.take(emb, tokens, axis=0)
    return constrain(out, "batch", "seq", None)
