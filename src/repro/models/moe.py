"""Mixture-of-Experts layer: top-k routing, capacity-bounded gather/scatter
dispatch, shared experts, expert-parallel sharding.

Dispatch uses real gathers (argless cumsum slotting) rather than the
GShard one-hot einsum, so XLA's cost analysis counts honest FLOPs and the
TPU lowering is a collective-permute/all-to-all over the expert axis
instead of a dense (tokens x experts*capacity) matmul.  Tokens overflowing
an expert's capacity fall through to the residual path (standard
capacity-factor semantics).

The auxiliary load-balancing loss is the Switch/GShard form
``E * sum_e f_e * p_e`` returned alongside the output.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from ..dist.api import constrain
from .config import ArchConfig
from .layers import dense_init, init_mlp, apply_mlp

Params = dict[str, Any]


def init_moe(key, cfg: ArchConfig) -> Params:
    assert cfg.moe is not None
    m = cfg.moe
    d, dt = cfg.d_model, cfg.param_dtype
    ks = jax.random.split(key, 6)
    p: Params = {
        "router": dense_init(ks[0], (d, m.n_experts), dt),
        "w_in": dense_init(ks[1], (m.n_experts, d, m.d_expert), dt, in_axis=1),
        "w_out": dense_init(ks[2], (m.n_experts, m.d_expert, d), dt, in_axis=1),
    }
    if cfg.mlp_type == "swiglu":
        p["w_gate"] = dense_init(ks[3], (m.n_experts, d, m.d_expert), dt,
                                 in_axis=1)
    if m.n_shared:
        import dataclasses
        shared_cfg = dataclasses.replace(cfg, d_ff=m.d_shared)
        p["shared"] = init_mlp(ks[4], shared_cfg, d_ff=m.d_shared)
        p["shared_gate"] = dense_init(ks[5], (d, 1), dt)
    return p


def capacity(n_tokens: int, cfg: ArchConfig) -> int:
    m = cfg.moe
    c = int(math.ceil(n_tokens * m.top_k / m.n_experts * m.capacity_factor))
    return max(4, -(-c // 4) * 4)  # round up to a multiple of 4


def apply_moe(p: Params, x: jax.Array, cfg: ArchConfig
              ) -> tuple[jax.Array, jax.Array]:
    """x: (B, T, D) -> (out (B, T, D), aux_loss scalar).

    Group-wise dispatch (GShard/T5X layout): each batch row is a routing
    group, so every routing op (one-hot, cumsum slotting, gather/scatter)
    is per-group along T and the whole dispatch stays sharded over the
    batch axes — no cross-shard cumsum, no globally-replicated dispatch
    buffers.  The (group-sharded -> expert-sharded) reshard of the
    (B, E, C, D) dispatch tensor is the canonical MoE all-to-all.
    """
    assert cfg.moe is not None
    m = cfg.moe
    b, t, d = x.shape
    cap = capacity(t, cfg)                                      # per group
    dt = jnp.dtype(cfg.compute_dtype)
    xf = x.astype(dt)                                           # (B, T, D)

    logits = (xf @ p["router"].astype(dt)).astype(jnp.float32)  # (B, T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, m.top_k)                # (B, T, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # slotting within each group, token-major over (T, k)
    flat_e = top_e.reshape(b, t * m.top_k)                      # (B, Tk)
    onehot = jax.nn.one_hot(flat_e, m.n_experts, dtype=jnp.int32)
    ranks = jnp.cumsum(onehot, axis=1) - onehot                 # exclusive
    pos = jnp.take_along_axis(ranks, flat_e[..., None], axis=2)[..., 0]
    keep = pos < cap
    slot = jnp.where(keep, flat_e * cap + pos, m.n_experts * cap)  # (B, Tk)

    # dispatch: per-group scatter of token ids, then gather rows
    token_id = jnp.repeat(jnp.arange(t), m.top_k)[None, :].repeat(b, 0)
    token_of_slot = jnp.zeros((b, m.n_experts * cap), jnp.int32) \
        .at[jnp.arange(b)[:, None], slot].set(token_id, mode="drop")
    occupied = jnp.zeros((b, m.n_experts * cap), jnp.bool_) \
        .at[jnp.arange(b)[:, None], slot].set(True, mode="drop")
    xe = jnp.take_along_axis(xf, token_of_slot[..., None], axis=1)
    xe = jnp.where(occupied[..., None], xe, 0)                  # (B, EC, D)
    xe = xe.reshape(b, m.n_experts, cap, d)
    xe = constrain(xe, "batch", "expert", None, None)

    # expert FFNs (E-sharded einsums; g stays batch-sharded)
    h = jnp.einsum("gecd,edf->gecf", xe, p["w_in"].astype(dt))
    if cfg.mlp_type == "swiglu":
        g = jnp.einsum("gecd,edf->gecf", xe, p["w_gate"].astype(dt))
        h = jax.nn.silu(g) * h
    elif cfg.mlp_type == "squared_relu":
        h = jnp.square(jax.nn.relu(h))
    else:
        h = jax.nn.gelu(h)
    ye = jnp.einsum("gecf,efd->gecd", h, p["w_out"].astype(dt))
    ye = constrain(ye, "batch", "expert", None, None)

    # combine: per-group gather of expert outputs back to (token, choice)
    ye_flat = ye.reshape(b, m.n_experts * cap, d)
    ye_pad = jnp.concatenate([ye_flat, jnp.zeros((b, 1, d), ye.dtype)],
                             axis=1)
    back = jnp.take_along_axis(ye_pad, slot[..., None], axis=1)
    back = back.reshape(b, t, m.top_k, d)
    weights = (top_p * keep.reshape(b, t, m.top_k)).astype(jnp.float32)
    out = jnp.einsum("gtkd,gtk->gtd", back.astype(jnp.float32),
                     weights).astype(dt)

    if m.n_shared:
        gate = jax.nn.sigmoid((xf @ p["shared_gate"].astype(dt))
                              .astype(jnp.float32)).astype(dt)
        out = out + gate * apply_mlp(p["shared"], xf, cfg)

    # load-balance aux (Switch eq. 4-6), computed globally
    frac = (jnp.zeros((b, m.n_experts), jnp.float32)
            .at[jnp.arange(b)[:, None], flat_e]
            .add(keep.astype(jnp.float32), mode="drop"))
    frac = frac.sum(0) / jnp.maximum(keep.sum(), 1.0)
    mean_p = probs.mean(axis=(0, 1))
    aux = m.n_experts * jnp.sum(frac * mean_p)
    out = constrain(out, "batch", "seq", None)
    return out, aux
