"""RWKV-6 "Finch" mixer: data-dependent token-shift (ddlerp), data-dependent
per-channel decay, and the wkv matrix-state recurrence.

Training runs the wkv recurrence as a ``lax.scan`` carrying the per-head
(hd x hd) state in fp32 — the XLA reference.  The chunked Pallas kernel
(``repro.kernels.rwkv6_wkv``) implements the same recurrence blockwise in
VMEM for the TPU target and is validated against this math via ``ref.py``.

Per head h with state S in R^{hd x hd} (key-dim x value-dim):

    y_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T

with w_t = exp(-exp(decay_t)) computed per channel from the token stream
(the "data-dependent decay" that distinguishes RWKV-6 from RWKV-4/5).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from ..dist.api import constrain
from .config import ArchConfig, RwkvConfig
from .layers import chunked_scan, dense_init, group_norm

Params = dict[str, Any]

_MIX_NAMES = ("r", "k", "v", "g", "w")


def _rcfg(cfg: ArchConfig) -> RwkvConfig:
    return cfg.rwkv or RwkvConfig()


def n_rwkv_heads(cfg: ArchConfig) -> int:
    return cfg.d_model // _rcfg(cfg).head_dim


def init_rwkv_tmix(key, cfg: ArchConfig) -> Params:
    d = cfg.d_model
    r = _rcfg(cfg)
    h = n_rwkv_heads(cfg)
    dt = cfg.param_dtype
    ks = jax.random.split(key, 12)
    return {
        "mu_base": (jax.random.uniform(ks[0], (d,)) * 0.5).astype(dt),
        "mix_lora_a": dense_init(ks[1], (d, 5 * r.lora_rank_mix), dt),
        "mix_lora_b": (jax.random.normal(ks[2], (5, r.lora_rank_mix, d))
                       * 0.01).astype(dt),
        "mu": (jax.random.uniform(ks[3], (5, d)) * 0.5).astype(dt),
        "decay_base": jnp.zeros((d,), jnp.float32) - 4.0,
        "decay_lora_a": dense_init(ks[4], (d, r.lora_rank_decay), dt),
        "decay_lora_b": (jax.random.normal(ks[5], (r.lora_rank_decay, d))
                         * 0.01).astype(dt),
        "wr": dense_init(ks[6], (d, d), dt),
        "wk": dense_init(ks[7], (d, d), dt),
        "wv": dense_init(ks[8], (d, d), dt),
        "wg": dense_init(ks[9], (d, d), dt),
        "wo": dense_init(ks[10], (d, d), dt),
        "u": (jax.random.normal(ks[11], (h, r.head_dim)) * 0.1).astype(
            jnp.float32),
        "ln_scale": jnp.ones((d,), dt),
        "ln_bias": jnp.zeros((d,), dt),
    }


def _token_shift(x: jax.Array, prev: jax.Array | None) -> jax.Array:
    """x_{t-1} stream; ``prev`` is the last token of the previous segment."""
    if prev is None:
        prev = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def _ddlerp(p: Params, x: jax.Array, shifted: jax.Array, cfg: ArchConfig):
    """Data-dependent interpolation producing the 5 mixed streams."""
    dtc = jnp.dtype(cfg.compute_dtype)
    dx = (shifted - x).astype(dtc)
    base = x.astype(dtc) + dx * p["mu_base"].astype(dtc)
    lora = jnp.tanh(base @ p["mix_lora_a"].astype(dtc))      # (B,T,5R)
    lora = lora.reshape(*lora.shape[:-1], 5, -1)
    adj = jnp.einsum("btfr,frd->btfd", lora, p["mix_lora_b"].astype(dtc))
    mixes = p["mu"].astype(dtc) + adj                         # (B,T,5,D)
    return [x.astype(dtc) + dx * mixes[..., i, :] for i in range(5)]


def wkv_scan(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
             u: jax.Array, s0: jax.Array | None = None
             ) -> tuple[jax.Array, jax.Array]:
    """Reference wkv recurrence.

    r,k,v,w: (B, T, H, hd) fp32 (w already as multiplicative decay in (0,1));
    u: (H, hd); s0: (B, H, hd, hd).  Returns (y (B,T,H,hd), s_T).
    """
    b, t, h, hd = r.shape
    if s0 is None:
        s0 = jnp.zeros((b, h, hd, hd), jnp.float32)

    def step(s, inp):
        r_t, k_t, v_t, w_t = inp                              # (B,H,hd)
        kv = k_t[..., :, None] * v_t[..., None, :]            # (B,H,hd,hd)
        y = jnp.einsum("bhk,bhkv->bhv", r_t, s + u[..., :, None] * kv)
        s = w_t[..., :, None] * s + kv
        return s, y

    s, ys = chunked_scan(step, s0,
                         (r.swapaxes(0, 1), k.swapaxes(0, 1),
                          v.swapaxes(0, 1), w.swapaxes(0, 1)), chunk=64)
    return ys.swapaxes(0, 1), s


def apply_rwkv_tmix(p: Params, x: jax.Array, cfg: ArchConfig,
                    state: Params | None = None,
                    return_state: bool = False
                    ) -> tuple[jax.Array, Params | None]:
    """Time-mix over a full segment. x: (B, T, D)."""
    b, t, d = x.shape
    hd = _rcfg(cfg).head_dim
    h = n_rwkv_heads(cfg)
    dtc = jnp.dtype(cfg.compute_dtype)
    prev = state["tmix_prev"][:, None] if state is not None else None
    shifted = _token_shift(x, prev)
    xr, xk, xv, xg, xw = _ddlerp(p, x, shifted, cfg)

    r = (xr @ p["wr"].astype(dtc)).reshape(b, t, h, hd)
    k = (xk @ p["wk"].astype(dtc)).reshape(b, t, h, hd)
    v = (xv @ p["wv"].astype(dtc)).reshape(b, t, h, hd)
    g = xg @ p["wg"].astype(dtc)
    r = constrain(r, "batch", None, "heads", None)
    k = constrain(k, "batch", None, "heads", None)
    v = constrain(v, "batch", None, "heads", None)

    decay = (p["decay_base"]
             + (jnp.tanh(xw @ p["decay_lora_a"].astype(dtc))
                @ p["decay_lora_b"].astype(dtc)).astype(jnp.float32))
    w = jnp.exp(-jnp.exp(decay)).reshape(b, t, h, hd)

    if cfg.attn_impl == "pallas":
        from ..kernels.rwkv6_wkv import ops as wkv_ops
        s0 = state["wkv"] if state is not None else None
        # tuned=None resolves cached launch params when tuning is
        # enabled; the op's Pallas custom_vjp means jax.grad here runs
        # tuned forward AND backward kernels ("rwkv6_wkv_bwd" space).
        y, s_t = wkv_ops.wkv6(r.astype(jnp.float32), k.astype(jnp.float32),
                              v.astype(jnp.float32), w, p["u"], s0,
                              tuned=None)
    else:
        s0 = state["wkv"] if state is not None else None
        y, s_t = wkv_scan(r.astype(jnp.float32), k.astype(jnp.float32),
                          v.astype(jnp.float32), w, p["u"], s0)

    y = group_norm(y.reshape(b, t, d), h)
    y = y * p["ln_scale"].astype(y.dtype) + p["ln_bias"].astype(y.dtype)
    out = (y.astype(dtc) * jax.nn.silu(g)) @ p["wo"].astype(dtc)
    out = constrain(out, "batch", "seq", None)
    new_state = None
    if state is not None or return_state:
        new_state = {"tmix_prev": x[:, -1], "wkv": s_t}
    return out, new_state


# -- channel mix ----------------------------------------------------------------

def init_rwkv_cmix(key, cfg: ArchConfig) -> Params:
    d, dt = cfg.d_model, cfg.param_dtype
    ks = jax.random.split(key, 3)
    return {
        "mu_k": (jax.random.uniform(ks[0], (d,)) * 0.5).astype(dt),
        "mu_r": (jax.random.uniform(ks[0], (d,)) * 0.5).astype(dt),
        "wk_ff": dense_init(ks[1], (d, cfg.d_ff), dt),
        "wv_ff": dense_init(ks[2], (cfg.d_ff, d), dt),
        "wr_ff": dense_init(ks[0], (d, d), dt),
    }


def apply_rwkv_cmix(p: Params, x: jax.Array, cfg: ArchConfig,
                    state: Params | None = None,
                    return_state: bool = False
                    ) -> tuple[jax.Array, Params | None]:
    dtc = jnp.dtype(cfg.compute_dtype)
    prev = state["cmix_prev"][:, None] if state is not None else None
    shifted = _token_shift(x, prev)
    dx = (shifted - x).astype(dtc)
    xk = x.astype(dtc) + dx * p["mu_k"].astype(dtc)
    xr = x.astype(dtc) + dx * p["mu_r"].astype(dtc)
    k = jnp.square(jax.nn.relu(xk @ p["wk_ff"].astype(dtc)))
    k = constrain(k, "batch", None, "ff")
    vv = k @ p["wv_ff"].astype(dtc)
    r = jax.nn.sigmoid(xr @ p["wr_ff"].astype(dtc))
    out = constrain(r * vv, "batch", "seq", None)
    new_state = ({"cmix_prev": x[:, -1]}
                 if (state is not None or return_state) else None)
    return out, new_state


def init_rwkv_state(cfg: ArchConfig, batch: int) -> Params:
    hd = _rcfg(cfg).head_dim
    h = n_rwkv_heads(cfg)
    return {
        "tmix_prev": jnp.zeros((batch, cfg.d_model), jnp.dtype(cfg.compute_dtype)),
        "cmix_prev": jnp.zeros((batch, cfg.d_model), jnp.dtype(cfg.compute_dtype)),
        "wkv": jnp.zeros((batch, h, hd, hd), jnp.float32),
    }
