"""Architecture configuration schema.

One frozen dataclass describes every architecture in the assigned pool
(dense / MoE / SSM / hybrid / enc-dec audio / VLM).  ``layer_kinds`` gives
the per-layer mixer type; homogeneous stacks scan over single layers,
heterogeneous stacks (Jamba) scan over repeating groups.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Literal

__all__ = ["ArchConfig", "MoEConfig", "MambaConfig", "RwkvConfig"]


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int                 # per-expert FFN hidden size
    n_shared: int = 0             # shared (always-on) experts
    d_shared: int = 0             # hidden size of the fused shared expert
    layer_period: int = 1         # MoE every `period` layers ...
    layer_offset: int = 0         # ... starting at `offset`
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01

    def is_moe_layer(self, idx: int) -> bool:
        return idx % self.layer_period == self.layer_offset % self.layer_period


@dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0              # 0 -> ceil(d_model / 16)


@dataclass(frozen=True)
class RwkvConfig:
    head_dim: int = 64
    lora_rank_decay: int = 64
    lora_rank_mix: int = 32
    gate_rank: int = 0            # 0 -> full projection for the gate


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: Literal["dense", "moe", "ssm", "hybrid", "audio", "vlm"]
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                    # 0 -> d_model // n_heads
    mlp_type: Literal["swiglu", "squared_relu", "gelu"] = "swiglu"
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    norm_type: Literal["rmsnorm", "layernorm"] = "rmsnorm"
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # per-layer mixer kinds; () -> ("attn",) * n_layers
    layer_kinds: tuple[str, ...] = ()
    moe: MoEConfig | None = None
    mamba: MambaConfig | None = None
    rwkv: RwkvConfig | None = None
    # encoder-decoder (whisper-style): encoder layers are bidirectional attn
    encdec: bool = False
    n_encoder_layers: int = 0
    decoder_len: int = 448               # training target length for enc-dec
    # modality frontend: "tokens" | "stub_frames" | "stub_patches"
    frontend: str = "tokens"
    n_patches: int = 1024                # VLM stub: patch embeddings per sample
    # positions: "rope" | "sinusoidal" | "none"
    positions: str = "rope"
    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    # implementation switches
    attn_impl: Literal["auto", "xla", "pallas"] = "auto"
    logit_chunk: int = 256               # chunked vocab-parallel xent
    # source tag [citation; verification tier] from the assignment
    source: str = ""

    # -- derived ------------------------------------------------------------
    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if not self.layer_kinds:
            object.__setattr__(self, "layer_kinds", ("attn",) * self.n_layers)
        if len(self.layer_kinds) != self.n_layers:
            raise ValueError(
                f"{self.name}: layer_kinds has {len(self.layer_kinds)} entries "
                f"for {self.n_layers} layers"
            )
        if self.family in ("ssm",) and "attn" in self.layer_kinds:
            raise ValueError(f"{self.name}: ssm family must be attention-free")

    @property
    def is_attention_free(self) -> bool:
        return "attn" not in self.layer_kinds

    @property
    def supports_long_context(self) -> bool:
        """True if decode state does not grow quadratically-costly with
        context — SSM / linear-attention / hybrid families."""
        n_attn = sum(1 for k in self.layer_kinds if k == "attn")
        return n_attn == 0 or (self.family == "hybrid")

    @property
    def group_pattern(self) -> tuple[str, ...]:
        """Smallest repeating block of layer kinds (scan group)."""
        n = self.n_layers
        kinds = self.layer_kinds
        for size in range(1, n + 1):
            if n % size:
                continue
            if all(kinds[i] == kinds[i % size] for i in range(n)):
                # MoE interleave must also repeat with this period
                if self.moe and size % self.moe.layer_period:
                    continue
                return kinds[:size]
        return kinds

    @property
    def n_groups(self) -> int:
        return self.n_layers // len(self.group_pattern)

    def moe_layer_mask(self) -> tuple[bool, ...]:
        if self.moe is None:
            return (False,) * self.n_layers
        return tuple(self.moe.is_moe_layer(i) for i in range(self.n_layers))

    # -- parameter counting (used by roofline MODEL_FLOPS) --------------------
    def param_count(self) -> int:
        return sum(c for _, c in self.param_breakdown())

    def active_param_count(self) -> int:
        """Params touched per token (MoE counts top_k + shared experts)."""
        total = 0
        for name, c in self.param_breakdown():
            if name.startswith("moe_experts"):
                assert self.moe is not None
                total += c * self.moe.top_k // self.moe.n_experts
            else:
                total += c
        return total

    def param_breakdown(self) -> list[tuple[str, int]]:
        d, hd = self.d_model, self.head_dim
        out: list[tuple[str, int]] = [("embed", self.vocab_size * d)]
        if not self.tie_embeddings:
            out.append(("lm_head", d * self.vocab_size))
        moe_mask = self.moe_layer_mask()
        n_dec = self.n_layers
        for i in range(n_dec):
            kind = self.layer_kinds[i]
            if kind == "attn":
                qkv = d * (self.n_heads + 2 * self.n_kv_heads) * hd
                if self.qkv_bias:
                    qkv += (self.n_heads + 2 * self.n_kv_heads) * hd
                out.append((f"attn[{i}]", qkv + self.n_heads * hd * d))
            elif kind == "mamba":
                m = self.mamba or MambaConfig()
                d_in = m.expand * d
                dt_rank = m.dt_rank or -(-d // 16)
                c = (d * 2 * d_in              # in_proj (x and gate)
                     + m.d_conv * d_in          # depthwise conv
                     + d_in * (dt_rank + 2 * m.d_state)   # x_proj
                     + dt_rank * d_in + d_in    # dt_proj (+bias)
                     + d_in * m.d_state         # A_log
                     + d_in                     # D
                     + d_in * d)                # out_proj
                out.append((f"mamba[{i}]", c))
            elif kind == "rwkv":
                r = self.rwkv or RwkvConfig()
                c = (4 * d * d                  # r, k, v, output
                     + d * d                    # gate
                     + 5 * (d * r.lora_rank_mix + r.lora_rank_mix * d)
                     + d * r.lora_rank_decay + r.lora_rank_decay * d
                     + 8 * d)                   # mixes, decay bias, bonus u, ln
                out.append((f"rwkv_tmix[{i}]", c))
            else:
                raise ValueError(f"unknown layer kind {kind}")
            # channel path
            if kind == "rwkv":
                out.append((f"rwkv_cmix[{i}]", 2 * d * self.d_ff + d * d + 2 * d))
            elif moe_mask[i]:
                assert self.moe is not None
                w_per_ff = 3 if self.mlp_type == "swiglu" else 2
                out.append((f"moe_experts[{i}]",
                            self.moe.n_experts * w_per_ff * d * self.moe.d_expert))
                out.append((f"moe_router[{i}]", d * self.moe.n_experts))
                if self.moe.n_shared:
                    out.append((f"moe_shared[{i}]", w_per_ff * d * self.moe.d_shared))
            else:
                w_per_ff = 3 if self.mlp_type == "swiglu" else 2
                out.append((f"mlp[{i}]", w_per_ff * d * self.d_ff))
            out.append((f"norms[{i}]", 2 * d))
        if self.encdec:
            for i in range(self.n_encoder_layers):
                qkv = d * (self.n_heads + 2 * self.n_kv_heads) * hd
                out.append((f"enc_attn[{i}]", qkv + self.n_heads * hd * d))
                w_per_ff = 3 if self.mlp_type == "swiglu" else 2
                out.append((f"enc_mlp[{i}]", w_per_ff * d * self.d_ff))
                out.append((f"enc_norms[{i}]", 2 * d))
            # decoder cross-attention (one per decoder layer)
            for i in range(n_dec):
                qkv = d * (self.n_heads + 2 * self.n_kv_heads) * hd
                out.append((f"cross_attn[{i}]", qkv + self.n_heads * hd * d))
                out.append((f"cross_norm[{i}]", d))
        out.append(("final_norm", d))
        return out

    def smoke(self) -> "ArchConfig":
        """Reduced same-family config for CPU smoke tests."""
        scale: dict = dict(
            n_layers=min(self.n_layers, 2 * len(self.group_pattern)),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            head_dim=32,
            d_ff=256,
            vocab_size=512,
            logit_chunk=64,
            n_patches=8,
        )
        nl = scale["n_layers"]
        if self.layer_kinds and len(set(self.layer_kinds)) > 1:
            scale["layer_kinds"] = self.layer_kinds[:nl]
        elif self.layer_kinds:
            scale["layer_kinds"] = (self.layer_kinds[0],) * nl
        if self.moe is not None:
            scale["moe"] = replace(
                self.moe, n_experts=min(self.moe.n_experts, 8),
                top_k=min(self.moe.top_k, 2), d_expert=64,
                d_shared=128 if self.moe.n_shared else 0,
            )
        if self.mamba is not None:
            scale["mamba"] = replace(self.mamba, d_state=8, dt_rank=16)
        if self.encdec:
            scale["n_encoder_layers"] = min(self.n_encoder_layers, 2)
            scale["decoder_len"] = 16
        return replace(self, name=self.name + "-smoke", **scale)
