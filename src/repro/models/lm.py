"""Decoder-only language model: embed -> scan(layer groups) -> norm -> loss.

* scan-over-layers with stacked group parameters keeps the HLO one group
  body + a loop regardless of depth (96-layer models compile in seconds);
* optional ``jax.checkpoint`` (remat) around the scanned group body;
* the loss is a chunked, vocab-parallel softmax cross-entropy that never
  materialises the full (B, T, V) logits tensor;
* the VLM frontend ("stub_patches") prepends precomputed patch embeddings
  (the assignment specifies modality frontends as stubs) and masks them
  out of the loss.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from ..dist.api import constrain
from .blocks import (apply_group, decode_group, init_group, init_group_state,
                     prefill_group)
from .config import ArchConfig
from .layers import apply_norm, embed_tokens, init_embed, init_norm

Params = dict[str, Any]


def _remat_policy(remat: bool | str):
    if remat == "save_dots":
        return jax.checkpoint_policies.save_only_these_names(
            "mixer_out", "channel_out", "mlp_hidden", "qkv_out")
    return jax.checkpoint_policies.nothing_saveable


def chunked_xent(h: jax.Array, head_w: jax.Array, targets: jax.Array,
                 mask: jax.Array, cfg: ArchConfig) -> jax.Array:
    """Mean cross-entropy over masked positions, chunked along T.

    h: (B, T, D); head_w: (D, V); targets/mask: (B, T).
    """
    b, t, d = h.shape
    c = min(cfg.logit_chunk, t)
    while t % c:
        c -= 1
    n_chunks = t // c
    dtc = jnp.dtype(cfg.compute_dtype)

    def chunk(carry, idx):
        loss_sum, count = carry
        hs = jax.lax.dynamic_slice_in_dim(h, idx * c, c, axis=1)
        ts = jax.lax.dynamic_slice_in_dim(targets, idx * c, c, axis=1)
        ms = jax.lax.dynamic_slice_in_dim(mask, idx * c, c, axis=1)
        logits = (hs.astype(dtc) @ head_w.astype(dtc)).astype(jnp.float32)
        logits = constrain(logits, "batch", None, "vocab")
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, ts[..., None], axis=-1)[..., 0]
        loss_sum = loss_sum + jnp.sum((lse - ll) * ms)
        count = count + ms.sum()
        return (loss_sum, count), None

    (loss_sum, count), _ = jax.lax.scan(
        chunk, (jnp.float32(0.0), jnp.float32(0.0)), jnp.arange(n_chunks))
    return loss_sum / jnp.maximum(count, 1.0)


@dataclass(frozen=True)
class LM:
    cfg: ArchConfig

    # -- init -----------------------------------------------------------------
    def init(self, key) -> Params:
        cfg = self.cfg
        k_emb, k_layers, k_norm = jax.random.split(key, 3)
        group_keys = jax.random.split(k_layers, cfg.n_groups)
        layers = jax.vmap(lambda k: init_group(k, cfg))(group_keys)
        return {
            "embed": init_embed(k_emb, cfg),
            "layers": layers,
            "final_norm": init_norm(cfg),
        }

    # -- forward --------------------------------------------------------------
    def backbone(self, params: Params, x: jax.Array, positions: jax.Array,
                 remat: bool | str = False) -> tuple[jax.Array, jax.Array]:
        cfg = self.cfg

        def body(carry, group_params):
            h, aux = carry
            h, a = apply_group(group_params, h, cfg, positions)
            return (h, aux + a), None

        if remat:
            body = jax.checkpoint(body, policy=_remat_policy(remat))
        (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0.0)),
                                   params["layers"])
        x = apply_norm(params["final_norm"], x, cfg)
        return x, aux

    def embed_inputs(self, params: Params, batch: dict
                     ) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
        """Returns (x, positions, targets, loss_mask)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        x = embed_tokens(params["embed"], tokens, cfg)
        targets = batch["labels"]
        mask = batch.get("loss_mask")
        if mask is None:
            mask = jnp.ones(targets.shape, jnp.float32)
        if cfg.frontend == "stub_patches":
            patches = batch["patch_embeds"].astype(x.dtype)
            x = jnp.concatenate([patches, x], axis=1)
            pad = jnp.zeros(patches.shape[:2], targets.dtype)
            targets = jnp.concatenate([pad, targets], axis=1)
            mask = jnp.concatenate([jnp.zeros(patches.shape[:2], mask.dtype),
                                    mask], axis=1)
        positions = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])
        return x, positions, targets, mask

    def loss(self, params: Params, batch: dict, *,
             remat: bool | str = False) -> tuple[jax.Array, dict]:
        cfg = self.cfg
        x, positions, targets, mask = self.embed_inputs(params, batch)
        h, aux = self.backbone(params, x, positions, remat=remat)
        head_w = (params["embed"]["tokens"].T if cfg.tie_embeddings
                  else params["embed"]["lm_head"])
        xent = chunked_xent(h, head_w, targets, mask, cfg)
        aux_w = cfg.moe.router_aux_weight if cfg.moe else 0.0
        total = xent + aux_w * aux / max(cfg.n_layers, 1)
        return total, {"xent": xent, "aux": aux}

    # -- prefill ---------------------------------------------------------------
    def prefill(self, params: Params, tokens: jax.Array, *, max_len: int = 0,
                patch_embeds: jax.Array | None = None
                ) -> tuple[jax.Array, Params]:
        """Process a full prompt; returns (last-position logits, decode state).

        Attention KV caches are padded to ``max_len`` capacity (defaults to
        the prompt length) and sharded per the installed rules
        ("prefill_kv_seq" maps the cache sequence dim).
        """
        cfg = self.cfg
        batch = {"tokens": tokens, "labels": jnp.zeros_like(tokens)}
        if patch_embeds is not None:
            batch["patch_embeds"] = patch_embeds
        x, positions, _, _ = self.embed_inputs(params, batch)
        s = x.shape[1]
        max_len = max(max_len, s)

        def body(h, group_params):
            h, state = prefill_group(group_params, h, cfg, positions)
            return h, state

        x, states = jax.lax.scan(body, x, params["layers"])
        x = apply_norm(params["final_norm"], x, cfg)

        # pad attention kv caches (G, B, S, KV, hd) -> (G, B, max_len, KV, hd)
        def pad_kv(tree):
            def visit(d):
                out = {}
                for k, v in d.items():
                    if isinstance(v, dict):
                        out[k] = visit(v)
                    else:
                        out[k] = v
                if set(out) == {"k", "v"}:
                    pad = max_len - out["k"].shape[2]
                    if pad > 0:
                        out = {kk: jnp.pad(vv, ((0, 0), (0, 0), (0, pad),
                                                (0, 0), (0, 0)))
                               for kk, vv in out.items()}
                    out = {kk: constrain(vv, None, "batch", "kv_seq",
                                         "kv_heads", None)
                           for kk, vv in out.items()}
                return out

            return visit(tree)

        states = pad_kv(states)
        head_w = (params["embed"]["tokens"].T if cfg.tie_embeddings
                  else params["embed"]["lm_head"])
        dtc = jnp.dtype(cfg.compute_dtype)
        last = x[:, -1:]
        logits = (last.astype(dtc) @ head_w.astype(dtc)).astype(jnp.float32)
        return constrain(logits, "batch", None, "vocab"), states

    # -- decode ----------------------------------------------------------------
    def init_decode_state(self, batch: int, max_len: int) -> Params:
        cfg = self.cfg

        def one(_):
            return init_group_state(cfg, batch, max_len)

        # stack per-group states along a leading axis to scan over
        return jax.vmap(one)(jnp.arange(cfg.n_groups))

    def decode_step(self, params: Params, state: Params, tokens: jax.Array,
                    pos: jax.Array) -> tuple[jax.Array, Params]:
        """tokens: (B, 1) -> (logits (B, 1, V), new_state)."""
        cfg = self.cfg
        x = embed_tokens(params["embed"], tokens, cfg)

        def body(h, scanned):
            group_params, group_state = scanned
            h, new_state = decode_group(group_params, h, group_state, cfg, pos)
            return h, new_state

        x, new_state = jax.lax.scan(body, x, (params["layers"], state))
        x = apply_norm(params["final_norm"], x, cfg)
        head_w = (params["embed"]["tokens"].T if cfg.tie_embeddings
                  else params["embed"]["lm_head"])
        dtc = jnp.dtype(cfg.compute_dtype)
        logits = (x.astype(dtc) @ head_w.astype(dtc)).astype(jnp.float32)
        logits = constrain(logits, "batch", None, "vocab")
        return logits, new_state
