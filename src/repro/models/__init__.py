"""Model zoo: functional JAX implementations of the assigned architectures."""

from .config import ArchConfig, MambaConfig, MoEConfig, RwkvConfig
from .encdec import EncDec
from .lm import LM

__all__ = ["ArchConfig", "MambaConfig", "MoEConfig", "RwkvConfig",
           "EncDec", "LM", "build_model"]


def build_model(cfg: ArchConfig):
    return EncDec(cfg) if cfg.encdec else LM(cfg)
