"""Mamba-1 selective-state-space mixer (Jamba's SSM layers).

Training runs the selective scan as a ``lax.scan`` over time with an
fp32 (B, d_inner, d_state) carry — the XLA reference the dry-run lowers.
The Pallas kernel (``repro.kernels.mamba_scan``) fuses the same recurrence
into VMEM for the TPU target and is validated against ``ref.py`` which
mirrors this math.  Decode keeps a (conv window, ssm state) pair per layer
and advances one token in O(d_inner * d_state).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from ..dist.api import constrain
from .config import ArchConfig, MambaConfig
from .layers import chunked_scan, dense_init

Params = dict[str, Any]


def _dims(cfg: ArchConfig) -> tuple[int, int, int, int]:
    m = cfg.mamba or MambaConfig()
    d_in = m.expand * cfg.d_model
    dt_rank = m.dt_rank or -(-cfg.d_model // 16)
    return d_in, m.d_state, m.d_conv, dt_rank


def init_mamba(key, cfg: ArchConfig) -> Params:
    d = cfg.d_model
    d_in, d_state, d_conv, dt_rank = _dims(cfg)
    dt = cfg.param_dtype
    ks = jax.random.split(key, 6)
    # dt bias initialised so softplus(dt_bias) spans [1e-3, 1e-1] (mamba init)
    u = jax.random.uniform(ks[0], (d_in,))
    dt_init = jnp.exp(u * (jnp.log(0.1) - jnp.log(1e-3)) + jnp.log(1e-3))
    dt_bias = dt_init + jnp.log(-jnp.expm1(-dt_init))  # inverse softplus
    return {
        "in_proj": dense_init(ks[1], (d, 2 * d_in), dt),
        "conv_w": (jax.random.normal(ks[2], (d_conv, d_in)) * d_conv ** -0.5
                   ).astype(dt),
        "conv_b": jnp.zeros((d_in,), dt),
        "x_proj": dense_init(ks[3], (d_in, dt_rank + 2 * d_state), dt),
        "dt_proj": dense_init(ks[4], (dt_rank, d_in), dt),
        "dt_bias": dt_bias.astype(dt),
        "A_log": jnp.log(jnp.arange(1, d_state + 1, dtype=jnp.float32)
                         )[None, :].repeat(d_in, 0).astype(jnp.float32),
        "D": jnp.ones((d_in,), jnp.float32),
        "out_proj": dense_init(ks[5], (d_in, d), dt),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 prefix: jax.Array | None = None) -> jax.Array:
    """Depthwise causal conv along time. x: (B,T,C), w: (K,C)."""
    k = w.shape[0]
    if prefix is None:
        prefix = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([prefix, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(k))
    return out + b


def _ssm_inputs(p: Params, xc: jax.Array, cfg: ArchConfig):
    d_in, d_state, _, dt_rank = _dims(cfg)
    dtc = jnp.dtype(cfg.compute_dtype)
    dbc = xc.astype(dtc) @ p["x_proj"].astype(dtc)
    dt_r, b_ssm, c_ssm = jnp.split(
        dbc.astype(jnp.float32), [dt_rank, dt_rank + d_state], axis=-1)
    delta = jax.nn.softplus(
        dt_r @ p["dt_proj"].astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["A_log"])                       # (d_in, d_state)
    return delta, a, b_ssm, c_ssm


def apply_mamba(p: Params, x: jax.Array, cfg: ArchConfig,
                return_state: bool = False):
    """Full-sequence training path. x: (B, T, D)."""
    dtc = jnp.dtype(cfg.compute_dtype)
    b, t, d = x.shape
    xz = x.astype(dtc) @ p["in_proj"].astype(dtc)
    xs, z = jnp.split(xz, 2, axis=-1)
    xs = constrain(xs, "batch", None, "mamba_ff")
    xc = jax.nn.silu(_causal_conv(xs, p["conv_w"].astype(dtc),
                                  p["conv_b"].astype(dtc)))
    delta, a, b_ssm, c_ssm = _ssm_inputs(p, xc, cfg)
    xf = xc.astype(jnp.float32)

    if cfg.attn_impl == "pallas":
        from ..kernels.mamba_scan import ops as ms_ops
        # tuned=None: cached best launch params when kernel tuning is
        # enabled (repro.tune.kernels.configure), defaults otherwise.
        # The op carries a Pallas custom_vjp, so jax.grad through this
        # path runs tuned forward AND backward kernels (the backward
        # resolves its own "mamba_scan_bwd" launch parameters).
        y, h_final = ms_ops.selective_scan(
            xf, delta, a, b_ssm, c_ssm, p["D"], tuned=None)
        y = y.astype(dtc) * jax.nn.silu(z)
        out = y @ p["out_proj"].astype(dtc)
        out = constrain(out, "batch", None, None)
        if return_state:
            d_conv = p["conv_w"].shape[0]
            return out, {"conv": xs[:, -(d_conv - 1):], "ssm": h_final}
        return out

    # The (B,T,d_in,d_state) discretised tensors are never materialised:
    # each step builds its own slice, and the chunked scan bounds backward
    # residual memory (see layers.chunked_scan).
    def step(h, inputs):
        delta_t, b_t, c_t, x_t = inputs            # (B,dI),(B,dS),(B,dS),(B,dI)
        da_t = jnp.exp(delta_t[..., None] * a)     # (B, d_in, d_state)
        h = da_t * h + (delta_t * x_t)[..., None] * b_t[:, None, :]
        y = jnp.einsum("bds,bs->bd", h, c_t)
        return h, y

    h0 = jnp.zeros((b, xs.shape[-1], a.shape[-1]), jnp.float32)
    h_final, ys = chunked_scan(
        step, h0,
        (delta.swapaxes(0, 1), b_ssm.swapaxes(0, 1),
         c_ssm.swapaxes(0, 1), xf.swapaxes(0, 1)),
        chunk=64)
    y = ys.swapaxes(0, 1) + xf * p["D"]
    y = (y.astype(dtc) * jax.nn.silu(z))
    out = y @ p["out_proj"].astype(dtc)
    out = constrain(out, "batch", "seq", None)
    if return_state:
        d_conv = p["conv_w"].shape[0]
        return out, {"conv": xs[:, -(d_conv - 1):], "ssm": h_final}
    return out


# -- decode -------------------------------------------------------------------

def init_mamba_state(cfg: ArchConfig, batch: int) -> Params:
    d_in, d_state, d_conv, _ = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, d_conv - 1, d_in), jnp.dtype(cfg.compute_dtype)),
        "ssm": jnp.zeros((batch, d_in, d_state), jnp.float32),
    }


def decode_mamba(p: Params, x: jax.Array, state: Params, cfg: ArchConfig
                 ) -> tuple[jax.Array, Params]:
    """One-token decode. x: (B, 1, D)."""
    dtc = jnp.dtype(cfg.compute_dtype)
    xz = x.astype(dtc) @ p["in_proj"].astype(dtc)
    xs, z = jnp.split(xz, 2, axis=-1)              # (B,1,d_in)
    xc = jax.nn.silu(_causal_conv(xs, p["conv_w"].astype(dtc),
                                  p["conv_b"].astype(dtc),
                                  prefix=state["conv"]))
    new_conv = jnp.concatenate([state["conv"], xs], axis=1)[:, 1:]
    delta, a, b_ssm, c_ssm = _ssm_inputs(p, xc, cfg)
    xf = xc.astype(jnp.float32)
    da = jnp.exp(delta[:, 0, :, None] * a)
    h = da * state["ssm"] + (delta[:, 0, :, None] * b_ssm[:, 0, None, :]
                             * xf[:, 0, :, None])
    y = jnp.einsum("bds,bs->bd", h, c_ssm[:, 0]) + xf[:, 0] * p["D"]
    y = (y[:, None].astype(dtc) * jax.nn.silu(z))
    out = y @ p["out_proj"].astype(dtc)
    return constrain(out, "batch", None, None), {"conv": new_conv, "ssm": h}
