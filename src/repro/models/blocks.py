"""Layer assembly: (norm -> mixer -> residual) + (norm -> channel -> residual).

Mixer kinds: "attn" (GQA), "mamba" (selective SSM), "rwkv" (RWKV-6 time
mix).  The channel path is an MLP, an MoE layer (per the arch's interleave
mask), or the RWKV channel mix.  Heterogeneous stacks (Jamba) group layers
into the smallest repeating pattern; ``init_group``/``apply_group`` handle
one pattern instance and the LM scans over stacked groups.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name

from .attention import (decode_attention, full_attention, init_attention,
                        init_kv_cache)
from .config import ArchConfig
from .layers import apply_mlp, apply_norm, init_mlp, init_norm
from .mamba import apply_mamba, decode_mamba, init_mamba, init_mamba_state
from .moe import apply_moe, init_moe
from .rwkv6 import (apply_rwkv_cmix, apply_rwkv_tmix, init_rwkv_cmix,
                    init_rwkv_state, init_rwkv_tmix)

Params = dict[str, Any]


def init_layer(key, cfg: ArchConfig, kind: str, is_moe: bool) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p: Params = {"norm1": init_norm(cfg), "norm2": init_norm(cfg)}
    if kind == "attn":
        p["mixer"] = init_attention(k1, cfg)
    elif kind == "mamba":
        p["mixer"] = init_mamba(k1, cfg)
    elif kind == "rwkv":
        p["mixer"] = init_rwkv_tmix(k1, cfg)
    else:
        raise ValueError(kind)
    if kind == "rwkv":
        p["channel"] = init_rwkv_cmix(k2, cfg)
    elif is_moe:
        p["channel"] = init_moe(k3, cfg)
    else:
        p["channel"] = init_mlp(k4, cfg)
    return p


def apply_layer(p: Params, x: jax.Array, cfg: ArchConfig, kind: str,
                is_moe: bool, positions: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Training path. Returns (x, aux_loss)."""
    aux = jnp.float32(0.0)
    h = apply_norm(p["norm1"], x, cfg)
    if kind == "attn":
        mixed = full_attention(p["mixer"], h, cfg, positions=positions,
                               causal=True)
    elif kind == "mamba":
        mixed = apply_mamba(p["mixer"], h, cfg)
    else:
        mixed, _ = apply_rwkv_tmix(p["mixer"], h, cfg)
    x = x + checkpoint_name(mixed, "mixer_out")
    h = apply_norm(p["norm2"], x, cfg)
    if kind == "rwkv":
        ch, _ = apply_rwkv_cmix(p["channel"], h, cfg)
    elif is_moe:
        ch, aux = apply_moe(p["channel"], h, cfg)
    else:
        ch = apply_mlp(p["channel"], h, cfg)
    return x + checkpoint_name(ch, "channel_out"), aux


def init_layer_state(cfg: ArchConfig, kind: str, batch: int,
                     max_len: int) -> Params:
    if kind == "attn":
        return init_kv_cache(cfg, batch, max_len)
    if kind == "mamba":
        return init_mamba_state(cfg, batch)
    return init_rwkv_state(cfg, batch)


def prefill_layer(p: Params, x: jax.Array, cfg: ArchConfig, kind: str,
                  is_moe: bool, positions: jax.Array
                  ) -> tuple[jax.Array, Params]:
    """Full-sequence forward that also emits the layer's decode state."""
    h = apply_norm(p["norm1"], x, cfg)
    if kind == "attn":
        mixed, kv = full_attention(p["mixer"], h, cfg, positions=positions,
                                   causal=True, return_kv=True)
        state: Params = kv
    elif kind == "mamba":
        mixed, state = apply_mamba(p["mixer"], h, cfg, return_state=True)
    else:
        mixed, state = apply_rwkv_tmix(p["mixer"], h, cfg, return_state=True)
    x = x + mixed
    h = apply_norm(p["norm2"], x, cfg)
    if kind == "rwkv":
        ch, cstate = apply_rwkv_cmix(p["channel"], h, cfg, return_state=True)
        state = {**state, **cstate}
    elif is_moe:
        ch, _ = apply_moe(p["channel"], h, cfg)
    else:
        ch = apply_mlp(p["channel"], h, cfg)
    return x + ch, state


def decode_layer(p: Params, x: jax.Array, state: Params, cfg: ArchConfig,
                 kind: str, is_moe: bool, pos: jax.Array
                 ) -> tuple[jax.Array, Params]:
    """Single-token decode path. x: (B, 1, D)."""
    h = apply_norm(p["norm1"], x, cfg)
    if kind == "attn":
        mixed, state = decode_attention(p["mixer"], h, state, cfg, pos=pos)
    elif kind == "mamba":
        mixed, state = decode_mamba(p["mixer"], h, state, cfg)
    else:
        mixed, tstate = apply_rwkv_tmix(p["mixer"], h, cfg, state=state)
        state = {**state, **tstate}
    x = x + mixed
    h = apply_norm(p["norm2"], x, cfg)
    if kind == "rwkv":
        ch, cstate = apply_rwkv_cmix(p["channel"], h, cfg, state=state)
        state = {**state, **cstate}
    elif is_moe:
        ch, _ = apply_moe(p["channel"], h, cfg)
    else:
        ch = apply_mlp(p["channel"], h, cfg)
    return x + ch, state


# -- groups (smallest repeating pattern; the LM scans over these) -------------

def group_slots(cfg: ArchConfig) -> list[tuple[str, str, bool]]:
    """[(slot_name, kind, is_moe)] for one group instance."""
    pattern = cfg.group_pattern
    moe_mask = cfg.moe_layer_mask()[: len(pattern)]
    return [(f"slot{i}", kind, moe_mask[i])
            for i, kind in enumerate(pattern)]


def init_group(key, cfg: ArchConfig) -> Params:
    slots = group_slots(cfg)
    keys = jax.random.split(key, len(slots))
    return {name: init_layer(k, cfg, kind, is_moe)
            for (name, kind, is_moe), k in zip(slots, keys)}


def apply_group(p: Params, x: jax.Array, cfg: ArchConfig,
                positions: jax.Array) -> tuple[jax.Array, jax.Array]:
    aux = jnp.float32(0.0)
    for name, kind, is_moe in group_slots(cfg):
        x, a = apply_layer(p[name], x, cfg, kind, is_moe, positions)
        aux = aux + a
    return x, aux


def prefill_group(p: Params, x: jax.Array, cfg: ArchConfig,
                  positions: jax.Array) -> tuple[jax.Array, Params]:
    states: Params = {}
    for name, kind, is_moe in group_slots(cfg):
        x, s = prefill_layer(p[name], x, cfg, kind, is_moe, positions)
        states[name] = s
    return x, states


def init_group_state(cfg: ArchConfig, batch: int, max_len: int) -> Params:
    return {name: init_layer_state(cfg, kind, batch, max_len)
            for name, kind, _ in group_slots(cfg)}


def decode_group(p: Params, x: jax.Array, state: Params, cfg: ArchConfig,
                 pos: jax.Array) -> tuple[jax.Array, Params]:
    new_state: Params = {}
    for name, kind, is_moe in group_slots(cfg):
        x, s = decode_layer(p[name], x, state[name], cfg, kind, is_moe, pos)
        new_state[name] = s
    return x, new_state
