"""JAX version compatibility shims for the SPMD surface.

The distribution subsystem targets the modern JAX mesh API
(``jax.make_mesh(..., axis_types=...)``, ``jax.set_mesh``,
``jax.shard_map``) but must also run on older releases (this container
ships 0.4.x) where those spell ``jax.make_mesh(shape, names)``,
``with mesh:`` and ``jax.experimental.shard_map.shard_map``.  Everything
that touches meshes or shard_map goes through the three helpers below so
the rest of the codebase is version-agnostic:

  * ``make_mesh(shape, axes)``   — mesh with Auto axis types when supported
  * ``set_mesh(mesh)``           — context manager installing ``mesh`` as
                                   the ambient mesh
  * ``shard_map(f, mesh, in_specs=..., out_specs=...)`` — per-shard SPMD
                                   mapping (replication checking disabled:
                                   the dist collectives combine with psum,
                                   which older checkers reject)
"""

from __future__ import annotations

import contextlib
from typing import Any, Sequence

import jax

try:  # modern API (jax >= 0.6)
    from jax.sharding import AxisType as _AxisType
except ImportError:  # pragma: no cover - depends on container jax
    _AxisType = None


def make_mesh(shape: Sequence[int], axes: Sequence[str]):
    """``jax.make_mesh`` with Auto axis types where the API supports them."""
    if _AxisType is not None:
        return jax.make_mesh(tuple(shape), tuple(axes),
                             axis_types=(_AxisType.Auto,) * len(axes))
    return jax.make_mesh(tuple(shape), tuple(axes))


@contextlib.contextmanager
def set_mesh(mesh):
    """Install ``mesh`` as the ambient mesh (``jax.set_mesh`` fallback)."""
    if hasattr(jax, "set_mesh"):
        with jax.set_mesh(mesh):
            yield mesh
    else:  # legacy global mesh context manager
        with mesh:
            yield mesh


def cost_analysis(compiled) -> dict:
    """``compiled.cost_analysis()`` as a flat dict (older JAX wraps the
    per-program properties in a one-element list)."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca)


def shard_map(f, mesh, *, in_specs: Any, out_specs: Any):
    """Version-agnostic ``shard_map`` (replication checking off)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False)
