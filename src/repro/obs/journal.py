"""Append-only structured decision journal (JSONL).

The trace answers *when* things ran; the journal answers *why the run
unfolded the way it did*: every semantic decision the runtime, guard,
and tuning stack makes is one JSON object with a monotone sequence
number and a clock timestamp.  Replaying a fault-harness run on a
``VirtualClock`` yields the same journal every time, so causal
assertions ("the demotion preceded the re-dispatch preceded the guard
trip") are exact tests, not log-scraping heuristics.

Event catalog (``EVENT_KINDS``; ``docs/observability.md`` documents the
fields of each):

  * ``rebalance_adopted`` / ``rebalance_debounced`` — the scheduler's
    plan cache adopted a new row split / suppressed a one-step flicker;
  * ``group_demoted`` / ``group_restored`` — elastic membership changes
    (with the failure reason on demotion);
  * ``chunks_redispatched`` — orphaned rows of a failed group completed
    on the survivors;
  * ``killswitch_armed`` / ``killswitch_tripped`` /
    ``killswitch_rearmed`` / ``guard_membership_change`` — the serve
    guard's state machine;
  * ``tuning_start`` / ``tuning_stop`` — one ``TuningSession.run``, with
    ``n_measured`` vs ``space_size`` (the paper's ~5% accounting);
  * ``store_hit`` / ``store_miss`` — the persistent tuning cache;
  * ``surrogate_refit`` — the online feedback loop folded live
    observations into the BDTR pair;
  * ``request_admitted`` / ``request_shed`` / ``request_retired`` /
    ``request_retried`` — the request-level serving layer
    (``repro.serve``): one event per admission decision, per shed
    (with the policy reason), per completed retirement (with the
    queue-delay/service decomposition) and per post-failure retry;
  * ``request_replayed`` / ``wal_recovered`` / ``snapshot_saved`` /
    ``store_quarantined`` — the crash-durability layer
    (``runtime.checkpoint``): one event per request rebuilt from the
    write-ahead log after a restart (with its requeue/shed
    disposition), one summary per WAL recovery, one per periodic
    soft-state snapshot, and one per corrupt durable file moved aside;
  * ``log`` — a structured-logger line routed into the journal sink.
"""

from __future__ import annotations

import json
import threading
from pathlib import Path
from typing import IO

__all__ = ["EVENT_KINDS", "Journal", "load_journal", "validate_events"]

EVENT_KINDS = frozenset({
    "rebalance_adopted", "rebalance_debounced",
    "group_demoted", "group_restored", "chunks_redispatched",
    "killswitch_armed", "killswitch_tripped", "killswitch_rearmed",
    "guard_membership_change",
    "tuning_start", "tuning_stop", "store_hit", "store_miss",
    "surrogate_refit",
    "request_admitted", "request_shed", "request_retired",
    "request_retried",
    "request_replayed", "wal_recovered", "snapshot_saved",
    "store_quarantined",
    "log",
})


class Journal:
    """Thread-safe append-only event list with an optional live sink."""

    def __init__(self, *, clock=None, sink: IO[str] | None = None,
                 flush_every: int = 1):
        """``clock`` is anything with ``now() -> float`` seconds (share
        the scheduler's ``VirtualClock`` for deterministic timestamps);
        ``sink`` is an optional open text stream that receives each
        event as one JSON line the moment it is recorded — with the
        default ``flush_every=1`` each line is flushed as written, so a
        crash loses nothing already journaled (larger values batch the
        flushes for hot paths); :meth:`save` writes the full JSONL
        afterwards either way, byte-identical to the streamed lines."""
        if flush_every < 1:
            raise ValueError("flush_every must be >= 1")
        self.clock = clock
        self.sink = sink
        self.flush_every = int(flush_every)
        self.events: list[dict] = []
        self._lock = threading.Lock()

    def now(self) -> float:
        if self.clock is not None:
            return self.clock.now()
        import time
        return time.perf_counter()

    def event(self, kind: str, **fields) -> dict:
        """Record one event; returns the record (already sequenced)."""
        if kind not in EVENT_KINDS:
            raise ValueError(f"unknown journal event kind {kind!r}; add it "
                             "to repro.obs.journal.EVENT_KINDS (and the "
                             "docs/observability.md catalog) first")
        with self._lock:
            rec = {"seq": len(self.events), "ts": round(self.now(), 9),
                   "kind": kind, **fields}
            self.events.append(rec)
            if self.sink is not None:
                self.sink.write(json.dumps(rec, default=str) + "\n")
                if len(self.events) % self.flush_every == 0:
                    self.sink.flush()
        return rec

    def by_kind(self, kind: str) -> list[dict]:
        with self._lock:
            return [e for e in self.events if e["kind"] == kind]

    def kinds(self) -> dict[str, int]:
        out: dict[str, int] = {}
        with self._lock:
            for e in self.events:
                out[e["kind"]] = out.get(e["kind"], 0) + 1
        return out

    def __len__(self) -> int:
        return len(self.events)

    def save(self, path) -> Path:
        """Write the journal as JSONL (one event object per line)."""
        out = Path(path)
        out.parent.mkdir(parents=True, exist_ok=True)
        with self._lock:
            lines = [json.dumps(e, default=str) for e in self.events]
        out.write_text("\n".join(lines) + ("\n" if lines else ""))
        return out


def load_journal(path) -> list[dict]:
    """Parse a JSONL journal back into event records."""
    out = []
    for line in Path(path).read_text().splitlines():
        line = line.strip()
        if line:
            out.append(json.loads(line))
    return out


def validate_events(events: list[dict],
                    known_kinds: frozenset[str] = EVENT_KINDS) -> list[str]:
    """Schema errors of a journal event list (empty list = valid).

    Every event must carry ``seq`` (dense, starting at 0), a numeric
    ``ts``, and a ``kind`` from the catalog.  ``python -m repro.obs``
    runs this against the checked-in ``docs/obs_schema.json`` in CI.
    """
    errors = []
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            errors.append(f"event {i}: not an object")
            continue
        for k in ("seq", "ts", "kind"):
            if k not in ev:
                errors.append(f"event {i}: missing key {k!r}")
        if not isinstance(ev.get("ts", 0), (int, float)):
            errors.append(f"event {i}: ts must be a number")
        if ev.get("seq") != i:
            errors.append(f"event {i}: seq {ev.get('seq')!r} is not dense")
        kind = ev.get("kind")
        if kind is not None and kind not in known_kinds:
            errors.append(f"event {i}: unknown kind {kind!r}")
    return errors
