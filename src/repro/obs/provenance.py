"""Run provenance: who/what/where produced a result artifact.

Every ``BENCH_*.json`` and ``obs_summary.json`` should answer "which
commit, which jax, which devices, when" without forensic work —
otherwise the bench trajectory across PRs compares apples to unknowns.
:func:`build_meta` collects the answer cheaply and degrades gracefully
(missing git, no devices yet) so it can run anywhere from CI to a
laptop without adding dependencies.

The wall date is deliberately **not** read from the system clock by
default: benches must stay reproducible byte-for-byte on re-runs.  CI
passes it explicitly (``--date`` flags / ``BENCH_DATE`` env var).
"""

from __future__ import annotations

import os
import subprocess
from pathlib import Path

__all__ = ["build_meta", "git_sha"]

_REPO_ROOT = Path(__file__).resolve().parents[3]


def git_sha(root: Path | None = None) -> str | None:
    """The current commit SHA, or None outside a git checkout.

    CI environments expose it as an env var (``GITHUB_SHA``) even on
    shallow/detached checkouts, so that wins over asking git.
    """
    for var in ("GITHUB_SHA", "GIT_SHA", "CI_COMMIT_SHA"):
        sha = os.environ.get(var)
        if sha:
            return sha
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"], cwd=root or _REPO_ROOT,
            capture_output=True, text=True, timeout=10)
        return out.stdout.strip() or None if out.returncode == 0 else None
    except (OSError, subprocess.SubprocessError):
        return None


def _device_topology() -> list[list] | None:
    """(platform, kind, count) summary — None when jax will not init."""
    try:
        from ..runtime.store import device_topology
        return device_topology()
    except Exception:       # noqa: BLE001 — provenance must never crash a run
        return None


def build_meta(date: str | None = None, *, devices: bool = True) -> dict:
    """The ``meta`` block stamped into result artifacts.

    ``date`` is the CI-supplied wall date (falls back to the
    ``BENCH_DATE`` env var, then None — never the system clock, see
    module docstring).  ``devices=False`` skips the jax device query
    for callers that must not initialize a backend.
    """
    import jax

    return {
        "git_sha": git_sha(),
        "jax": jax.__version__,
        # default_backend() initializes the platform — only touch it when
        # the caller allows the device query at all
        "backend": jax.default_backend() if devices else None,
        "devices": _device_topology() if devices else None,
        "date": date or os.environ.get("BENCH_DATE"),
    }
