"""repro.obs — structured tracing, metrics, and the decision journal.

One :class:`Observer` bundles the three recording surfaces on a shared
clock and is threaded (default-off) through the runtime, guard, and
tuning constructors:

  * :class:`~repro.obs.trace.Tracer` — Chrome-trace spans of *when*
    things ran (dispatch/drain lanes per group, tuning sessions);
  * :class:`~repro.obs.metrics.MetricsRegistry` — counters, gauges, and
    fixed-bucket latency histograms of *how much / how fast*;
  * :class:`~repro.obs.journal.Journal` — the append-only record of
    *why*: every semantic decision (rebalance adopted, group demoted,
    kill switch tripped, store hit, ...) in causal order.

Instrumented call sites hold ``self._obs = as_observer(observer)`` and
guard every recording block with ``if self._obs is not None`` — a
disabled or absent observer costs nothing on the hot path (no calls, no
allocation; ``tests/test_obs.py`` pins this with tracemalloc).

Pass the same ``runtime.simulate.VirtualClock`` that drives a
fault-harness run and all three surfaces stamp deterministic simulated
timestamps: the same ``FaultPlan`` reproduces the same trace and
journal, which is what makes the CI fault drill an exact check.
"""

from __future__ import annotations

from .journal import EVENT_KINDS, Journal, load_journal, validate_events
from .log import LEVELS, StructuredLogger, configure, get_logger
from .metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                      default_latency_buckets)
from .provenance import build_meta, git_sha
from .report import render, summarize, write_summary
from .trace import Tracer, load_trace, validate_trace

__all__ = [
    "EVENT_KINDS", "Journal", "load_journal", "validate_events",
    "LEVELS", "StructuredLogger", "configure", "get_logger",
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "default_latency_buckets",
    "build_meta", "git_sha",
    "render", "summarize", "write_summary",
    "Tracer", "load_trace", "validate_trace",
    "Observer", "as_observer",
]


class Observer:
    """Tracer + metrics + journal on one clock.

    ``enabled=False`` builds the same object but :func:`as_observer`
    resolves it to None, which is how call sites keep their disabled
    path allocation-free; the sub-objects still exist so tests can
    assert they stayed empty.
    """

    def __init__(self, *, enabled: bool = True, clock=None, pid: int = 0):
        self.enabled = bool(enabled)
        self.clock = clock
        self.tracer = Tracer(clock=clock, pid=pid)
        self.metrics = MetricsRegistry(enabled=self.enabled)
        self.journal = Journal(clock=clock)

    def now(self) -> float:
        return self.tracer.now()

    # report.py conveniences, so launch scripts write artifacts in one
    # call each without importing the submodules
    def save_trace(self, path):
        return self.tracer.save(path)

    def save_journal(self, path):
        return self.journal.save(path)

    def write_summary(self, path, *, extra: dict | None = None,
                      date: str | None = None) -> dict:
        return write_summary(self, path, extra=extra, date=date)

    def render(self) -> str:
        return render(summarize(self, events=False))


def as_observer(obs) -> Observer | None:
    """Normalize a constructor's ``observer=`` argument.

    Returns the observer when it is present *and* enabled, else None —
    so instrumented code needs exactly one check (``is not None``) and
    a disabled observer is indistinguishable from no observer.
    """
    if obs is None or not getattr(obs, "enabled", True):
        return None
    return obs
