"""Structured logger for the launch scripts and tuning CLI narration.

``launch/serve.py``, ``launch/train.py`` and the ``repro.tune``
selfcheck used to narrate with bare ``print(...)``; this logger keeps
their CLI output **byte-compatible by default** (the default format is
the message verbatim, level INFO, stdout) while adding two things
prints cannot do:

  * level filtering — ``configure(level="warning")`` or
    ``REPRO_LOG_LEVEL=warning`` silences the per-step narration without
    touching call sites;
  * a journal sink — ``configure(journal=observer.journal)`` mirrors
    every emitted line into the run's decision journal as a ``log``
    event (same JSONL stream as the semantic events), so the narration
    and the decisions land in one causally ordered record.

Usage::

    from repro.obs import get_logger
    log = get_logger("repro.serve")
    log.info(f"stream: {n} batches", batches=n)    # fields -> journal only
"""

from __future__ import annotations

import os
import sys
from typing import IO

__all__ = ["LEVELS", "StructuredLogger", "configure", "get_logger"]

LEVELS = {"debug": 10, "info": 20, "warning": 30, "error": 40}

# process-wide defaults; configure() updates these AND every logger
# already handed out, so launch scripts may configure at any point
_config: dict = {
    "level": os.environ.get("REPRO_LOG_LEVEL", "info").lower(),
    "journal": None,
    "stream": None,
}


class StructuredLogger:
    """Level-filtered message printer with an optional journal mirror."""

    def __init__(self, name: str, *, level: str | None = None,
                 stream: IO[str] | None = None, journal=None):
        self.name = name
        self.level = LEVELS[(level or _config["level"])]
        self.stream = stream if stream is not None else _config["stream"]
        self.journal = journal if journal is not None else _config["journal"]

    def log(self, level: str, msg: str, **fields) -> None:
        """Print ``msg`` verbatim when ``level`` passes the filter, and
        mirror it (with the structured ``fields``) into the journal.
        The journal sees every emitted line, filtered the same way."""
        n = LEVELS.get(level, LEVELS["info"])
        if n < self.level:
            return
        print(msg, file=self.stream or sys.stdout, flush=True)
        if self.journal is not None:
            self.journal.event("log", level=level, logger=self.name,
                               msg=msg, **fields)

    def debug(self, msg: str, **fields) -> None:
        self.log("debug", msg, **fields)

    def info(self, msg: str, **fields) -> None:
        self.log("info", msg, **fields)

    def warning(self, msg: str, **fields) -> None:
        self.log("warning", msg, **fields)

    def error(self, msg: str, **fields) -> None:
        self.log("error", msg, **fields)


_loggers: dict[str, StructuredLogger] = {}


def get_logger(name: str) -> StructuredLogger:
    """The process-wide logger registered under ``name`` (created on
    first use with the current global configuration)."""
    lg = _loggers.get(name)
    if lg is None:
        lg = _loggers[name] = StructuredLogger(name)
    return lg


def configure(*, level: str | None = None, journal=None,
              stream: IO[str] | None = None) -> None:
    """Reconfigure every registered (and future) logger in place.

    ``level`` filters (``debug``/``info``/``warning``/``error``);
    ``journal`` mirrors emitted lines into a
    :class:`~repro.obs.journal.Journal`; ``stream`` redirects the
    printed output (tests).  Pass ``journal=False`` / ``stream=False``
    to detach an earlier sink."""
    if level is not None:
        if level not in LEVELS:
            raise ValueError(f"unknown log level {level!r}; expected one "
                             f"of {sorted(LEVELS)}")
        _config["level"] = level
    if journal is not None:
        _config["journal"] = None if journal is False else journal
    if stream is not None:
        _config["stream"] = None if stream is False else stream
    for lg in _loggers.values():
        if level is not None:
            lg.level = LEVELS[level]
        if journal is not None:
            lg.journal = _config["journal"]
        if stream is not None:
            lg.stream = _config["stream"]
