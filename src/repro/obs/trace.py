"""Lightweight span tracer producing Chrome-trace-format JSON.

One :class:`Tracer` records one run as a flat list of Chrome
``chrome://tracing`` / Perfetto events (the "Trace Event Format"):
``ph="X"`` complete spans with microsecond timestamps, ``ph="i"``
instants, and ``ph="M"`` metadata rows naming the lanes.  Load the
saved file directly in ``chrome://tracing`` or https://ui.perfetto.dev.

Three recording surfaces, matching how the runtime is structured:

  * :meth:`Tracer.span` — a context manager for straight-line code
    (tuning sessions, surrogate refits);
  * :meth:`Tracer.begin` / :meth:`Tracer.end` — explicit tokens for the
    threaded drain paths of ``ChunkedScheduler``, where a span opens in
    the dispatch loop and closes in a drain worker;
  * :meth:`Tracer.complete` — one-shot emission with explicit
    timestamps, for call sites that already carry exact instants (the
    scheduler's per-chunk completion times, ``SimReadyAt.ready_at``).

The clock is pluggable exactly like ``ChunkedScheduler``'s: pass the
same ``runtime.simulate.VirtualClock`` that drives a fault-harness run
and the trace timestamps are deterministic simulated instants — the
same ``FaultPlan`` yields the same span timeline, bit for bit (modulo
event append order across drain threads; sort by ``ts`` to compare).

Lanes: ``tid`` is a small stable integer chosen by the caller (the
scheduler uses the group index, never an OS thread id), so traces are
comparable across runs and machines.
"""

from __future__ import annotations

import json
import threading
import time
from contextlib import contextmanager
from pathlib import Path
from typing import Any, Mapping

__all__ = ["Tracer", "load_trace", "validate_trace"]

_US = 1e6     # Chrome trace timestamps are microseconds


class Tracer:
    """Append-only Chrome-trace event recorder (thread-safe)."""

    def __init__(self, *, clock=None, pid: int = 0):
        """``clock`` is anything with ``now() -> float`` seconds (e.g. a
        ``VirtualClock``); the wall clock (``time.perf_counter``) when
        omitted.  ``pid`` groups every event under one process row."""
        self.clock = clock
        self.pid = pid
        self.events: list[dict] = []
        self._lock = threading.Lock()
        self._token = 0
        self._open: dict[int, tuple] = {}

    def now(self) -> float:
        return self.clock.now() if self.clock is not None \
            else time.perf_counter()

    def _emit(self, ev: dict) -> None:
        with self._lock:
            self.events.append(ev)

    # -- emission ------------------------------------------------------------
    def complete(self, name: str, ts_s: float, dur_s: float, *,
                 cat: str = "span", tid: int = 0,
                 args: Mapping[str, Any] | None = None) -> None:
        """One finished span with explicit start/duration in seconds."""
        ev = {"name": name, "cat": cat, "ph": "X", "pid": self.pid,
              "tid": int(tid), "ts": round(ts_s * _US, 3),
              "dur": round(max(dur_s, 0.0) * _US, 3)}
        if args:
            ev["args"] = dict(args)
        self._emit(ev)

    def instant(self, name: str, *, ts_s: float | None = None,
                cat: str = "event", tid: int = 0,
                args: Mapping[str, Any] | None = None) -> None:
        """A zero-duration marker (``ph="i"``, thread scope)."""
        ev = {"name": name, "cat": cat, "ph": "i", "s": "t",
              "pid": self.pid, "tid": int(tid),
              "ts": round((self.now() if ts_s is None else ts_s) * _US, 3)}
        if args:
            ev["args"] = dict(args)
        self._emit(ev)

    def begin(self, name: str, *, cat: str = "span", tid: int = 0,
              ts_s: float | None = None,
              args: Mapping[str, Any] | None = None) -> int:
        """Open a span; returns a token for :meth:`end`.

        Token-based rather than stack-based so the span can be closed
        from a different thread than the one that opened it (the
        scheduler's drain workers)."""
        ts = self.now() if ts_s is None else ts_s
        with self._lock:
            self._token += 1
            token = self._token
            self._open[token] = (name, cat, int(tid), ts,
                                 dict(args) if args else None)
        return token

    def end(self, token: int, *, ts_s: float | None = None,
            args: Mapping[str, Any] | None = None) -> None:
        """Close a span opened by :meth:`begin` (unknown tokens no-op)."""
        ts = self.now() if ts_s is None else ts_s
        with self._lock:
            opened = self._open.pop(token, None)
        if opened is None:
            return
        name, cat, tid, t0, a0 = opened
        merged = dict(a0 or {})
        if args:
            merged.update(args)
        self.complete(name, t0, ts - t0, cat=cat, tid=tid,
                      args=merged or None)

    @contextmanager
    def span(self, name: str, *, cat: str = "span", tid: int = 0,
             args: Mapping[str, Any] | None = None):
        """``with tracer.span("tune.saml"): ...`` for straight-line code."""
        token = self.begin(name, cat=cat, tid=tid, args=args)
        try:
            yield
        finally:
            self.end(token)

    def thread_name(self, tid: int, name: str) -> None:
        """Label lane ``tid`` (shown as the row name in the viewer)."""
        self._emit({"name": "thread_name", "ph": "M", "pid": self.pid,
                    "tid": int(tid), "ts": 0, "args": {"name": name}})

    # -- output --------------------------------------------------------------
    def __len__(self) -> int:
        return len(self.events)

    def to_json(self) -> dict:
        with self._lock:
            events = list(self.events)
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def save(self, path) -> Path:
        """Write a ``chrome://tracing``-loadable JSON file."""
        out = Path(path)
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(json.dumps(self.to_json(), indent=1) + "\n")
        return out


def load_trace(path) -> list[dict]:
    """The ``traceEvents`` list of a saved trace file."""
    doc = json.loads(Path(path).read_text())
    if isinstance(doc, list):           # bare-array variant is also legal
        return doc
    return list(doc.get("traceEvents", []))


_PH_REQUIRED = {
    "X": ("name", "cat", "ph", "pid", "tid", "ts", "dur"),
    "i": ("name", "cat", "ph", "pid", "tid", "ts"),
    "M": ("name", "ph", "pid", "tid"),
}


def validate_trace(events: list[dict]) -> list[str]:
    """Structural errors of a trace event list (empty list = valid).

    Checks the subset of the Trace Event Format this tracer emits:
    known phases, the per-phase required keys, numeric non-negative
    timestamps/durations.  ``python -m repro.obs`` runs this against the
    checked-in schema (``docs/obs_schema.json``) in CI.
    """
    errors = []
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            errors.append(f"event {i}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in _PH_REQUIRED:
            errors.append(f"event {i}: unknown phase {ph!r}")
            continue
        for k in _PH_REQUIRED[ph]:
            if k not in ev:
                errors.append(f"event {i} ({ev.get('name')!r}): "
                              f"missing key {k!r}")
        for k in ("ts", "dur"):
            if k in ev and (not isinstance(ev[k], (int, float))
                            or ev[k] < 0):
                errors.append(f"event {i} ({ev.get('name')!r}): "
                              f"{k} must be a non-negative number")
        if "args" in ev and not isinstance(ev["args"], dict):
            errors.append(f"event {i}: args must be an object")
    return errors
