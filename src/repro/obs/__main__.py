"""Validate saved obs artifacts against the checked-in schema.

CI's obs-smoke job runs a scripted fault drill through
``launch/serve.py --stream --fault-plan ... --trace-out --journal-out``
and then calls::

    python -m repro.obs --trace trace.json --journal journal.jsonl \
        --schema docs/obs_schema.json \
        --require group_demoted,chunks_redispatched,killswitch_tripped

which checks (a) both files parse, (b) every event satisfies the
structural schema, (c) the journal's event kinds all appear in the
schema catalog (so the checked-in file cannot drift silently from
``EVENT_KINDS``), and (d) the ``--require`` kinds each occur at least
once and their *first* occurrences are in the given order — the causal
assertion "the demotion preceded the re-dispatch preceded the guard
trip" as an exit code.

``--wal wal.jsonl`` additionally validates a write-ahead request log
(``runtime.checkpoint``): every record parses with a matching CRC and a
dense LSN (a torn tail is an error here — the engine truncates it on
reopen, so a *post-recovery* WAL must be clean), no request retires
twice, and with ``--wal-complete`` every admitted request has a
terminal retire record — the recover-smoke job's "no request lost, none
double-retired" assertion as an exit code.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from .journal import EVENT_KINDS, load_journal, validate_events
from .trace import load_trace, validate_trace


def _load_schema(path: str | None) -> dict:
    if path is None:
        return {}
    return json.loads(Path(path).read_text())


def check_required_order(events: list[dict], kinds: list[str]) -> list[str]:
    """Errors when any kind is absent or first occurrences are out of order."""
    errors = []
    first = {}
    for ev in events:
        k = ev.get("kind")
        if k in kinds and k not in first:
            first[k] = ev.get("seq", len(first))
    prev = None
    for k in kinds:
        if k not in first:
            errors.append(f"required journal event {k!r} never occurred")
            continue
        if prev is not None and first[k] < first[prev]:
            errors.append(f"causal order violated: first {k!r} (seq "
                          f"{first[k]}) precedes first {prev!r} "
                          f"(seq {first[prev]})")
        prev = k
    return errors


def check_wal(path: str, *, complete: bool = False) -> tuple[list[str], dict]:
    """(errors, stats) of a write-ahead request log.

    Structural: every line parses, CRCs match, LSNs are dense (the
    reader stops at the first bad line, so a surviving torn tail shows
    up as ``torn``).  Semantic: at most one valid retire per request id;
    with ``complete=True`` every admitted id must also retire — the
    crash-drill accounting invariant.
    """
    from ..runtime.checkpoint import read_wal
    errors: list[str] = []
    records, torn = read_wal(path)
    if torn is not None:
        errors.append(f"torn tail at line {torn['line']} "
                      f"({torn['reason']}); run the engine once with "
                      "--resume to truncate it")
    admits: set[int] = set()
    retired: dict[int, int] = {}
    for rec in records:
        kind = rec.get("kind")
        if kind == "admit":
            admits.add(rec["rid"])
        elif kind == "retire":
            rid = rec["rid"]
            if rid in retired:
                errors.append(f"request {rid} retired twice "
                              f"(lsn {retired[rid]} and {rec['lsn']})")
            else:
                retired[rid] = rec["lsn"]
    ghost = set(retired) - admits
    if ghost:
        errors.append(f"retired but never admitted: {sorted(ghost)[:8]}")
    if complete:
        lost = admits - set(retired)
        if lost:
            errors.append(f"admitted but never retired (lost): "
                          f"{sorted(lost)[:8]} "
                          f"({len(lost)}/{len(admits)})")
    stats = {"records": len(records), "admitted": len(admits),
             "retired": len(retired), "torn": torn is not None}
    return errors, stats


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="validate trace/journal artifacts against the schema")
    ap.add_argument("--trace", help="Chrome-trace JSON file to validate")
    ap.add_argument("--journal", help="decision-journal JSONL file to validate")
    ap.add_argument("--schema", default=None,
                    help="checked-in schema (docs/obs_schema.json)")
    ap.add_argument("--require", default=None,
                    help="comma-separated journal kinds that must occur, "
                         "first occurrences in this causal order")
    ap.add_argument("--wal", help="write-ahead request log (JSONL) to "
                    "validate: CRCs, dense LSNs, no double retire")
    ap.add_argument("--wal-complete", action="store_true",
                    help="with --wal: every admitted request must have "
                    "a terminal retire record (post-recovery accounting)")
    args = ap.parse_args(argv)
    if not args.trace and not args.journal and not args.wal:
        ap.error("nothing to validate: pass --trace, --journal "
                 "and/or --wal")

    schema = _load_schema(args.schema)
    errors: list[str] = []

    if args.trace:
        events = load_trace(args.trace)
        errors += [f"trace: {e}" for e in validate_trace(events)]
        want_phases = schema.get("trace", {}).get("phases")
        if want_phases:
            seen = {e.get("ph") for e in events if isinstance(e, dict)}
            extra = seen - set(want_phases)
            if extra:
                errors.append(f"trace: phases {sorted(extra)} not in schema")
        print(f"[obs] trace   {args.trace}: {len(events)} events")

    if args.journal:
        events = load_journal(args.journal)
        known = frozenset(schema.get("journal", {}).get("kinds") or EVENT_KINDS)
        # the checked-in catalog and the code catalog must agree exactly
        if schema.get("journal", {}).get("kinds") is not None \
                and known != EVENT_KINDS:
            errors.append(
                "journal: schema kinds differ from EVENT_KINDS "
                f"(schema-only: {sorted(known - EVENT_KINDS)}, "
                f"code-only: {sorted(EVENT_KINDS - known)})")
        errors += [f"journal: {e}" for e in validate_events(events, known)]
        if args.require:
            kinds = [k.strip() for k in args.require.split(",") if k.strip()]
            errors += [f"journal: {e}"
                       for e in check_required_order(events, kinds)]
        by_kind: dict[str, int] = {}
        for ev in events:
            by_kind[ev.get("kind", "?")] = by_kind.get(ev.get("kind", "?"), 0) + 1
        summary = "  ".join(f"{k}×{n}" for k, n in sorted(by_kind.items()))
        print(f"[obs] journal {args.journal}: {len(events)} events  {summary}")

    if args.wal:
        wal_errors, stats = check_wal(args.wal, complete=args.wal_complete)
        errors += [f"wal: {e}" for e in wal_errors]
        print(f"[obs] wal     {args.wal}: {stats['records']} records  "
              f"{stats['admitted']} admitted  {stats['retired']} retired")

    if errors:
        for e in errors:
            print(f"[obs] ERROR {e}", file=sys.stderr)
        return 1
    print("[obs] OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
