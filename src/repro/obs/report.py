"""Render an observed run into a human summary + ``obs_summary.json``.

One :class:`~repro.obs.Observer` accumulates three views of a run —
trace spans, metric handles, journal events.  This module folds them
into a single machine-readable summary (written as
``obs_summary.json`` by ``launch/serve.py --metrics-out``) and a short
text rendering for the terminal:

  * every counter and gauge verbatim;
  * every histogram as count / mean / p50 / p95 / p99 (the latency-
    percentile accounting the serving front end needs);
  * journal event counts by kind, plus the full ordered event list
    (the summary is self-contained: a CI artifact reader needs no
    second file to see what decisions the run took);
  * trace size (the spans themselves stay in the trace file);
  * a provenance ``meta`` block (:mod:`repro.obs.provenance`).
"""

from __future__ import annotations

import json
from pathlib import Path

from .provenance import build_meta

__all__ = ["render", "summarize", "write_summary"]


def summarize(observer, *, extra: dict | None = None,
              date: str | None = None, events: bool = True) -> dict:
    """JSON-ready summary of everything the observer accumulated."""
    out = {
        "meta": build_meta(date),
        "metrics": observer.metrics.to_dict(),
        "journal": {
            "n_events": len(observer.journal),
            "by_kind": observer.journal.kinds(),
        },
        "trace": {"n_events": len(observer.tracer)},
    }
    if events:
        out["journal"]["events"] = list(observer.journal.events)
    if extra:
        out.update(extra)
    return out


def render(summary: dict) -> str:
    """Terminal rendering of a :func:`summarize` dict."""
    lines = ["== obs summary =="]
    meta = summary.get("meta", {})
    sha = (meta.get("git_sha") or "?")[:12]
    lines.append(f"commit {sha}  jax {meta.get('jax', '?')}  "
                 f"backend {meta.get('backend', '?')}")
    m = summary.get("metrics", {})
    for name, v in m.get("counters", {}).items():
        lines.append(f"counter   {name} = {v}")
    for name, v in m.get("gauges", {}).items():
        lines.append(f"gauge     {name} = {v}")
    for name, h in m.get("histograms", {}).items():
        if h.get("count"):
            lines.append(
                f"histogram {name}: n={h['count']} mean={h['mean']:.6f} "
                f"p50={h['p50']:.6f} p95={h['p95']:.6f} p99={h['p99']:.6f}")
        else:
            lines.append(f"histogram {name}: empty")
    by_kind = summary.get("journal", {}).get("by_kind", {})
    if by_kind:
        kinds = "  ".join(f"{k}×{n}" for k, n in sorted(by_kind.items()))
        lines.append(f"journal   {kinds}")
    lines.append(f"trace     {summary.get('trace', {}).get('n_events', 0)} "
                 "events")
    return "\n".join(lines)


def write_summary(observer, path, *, extra: dict | None = None,
                  date: str | None = None) -> dict:
    """Write ``obs_summary.json``; returns the summary dict."""
    summary = summarize(observer, extra=extra, date=date)
    out = Path(path)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(summary, indent=1, default=str) + "\n")
    return summary
