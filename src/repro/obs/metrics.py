"""Process-local metrics: counters, gauges, fixed-bucket histograms.

A :class:`MetricsRegistry` hands out named handles; hot paths hold the
handle (one attribute load + add per event), never a dict lookup.  A
**disabled** registry hands out shared no-op singletons instead — the
handle API is identical, the cost is one no-op method call, and nothing
accumulates — so instrumented code needs no ``if enabled`` branches of
its own (the scheduler still guards its whole instrumentation block
behind the observer, which makes the disabled path literally
allocation-free).

Histograms use fixed geometric buckets (default: 1 µs to 100 s, four
per decade — the latency range of everything this repo times, from a
kernel launch to a serving step) and support percentile extraction by
linear interpolation inside the owning bucket: the error of ``p50`` /
``p95`` / ``p99`` is bounded by the bucket width (~78% ratio steps at
four buckets per decade), which is the right resolution for SLO
accounting without keeping samples.
"""

from __future__ import annotations

import math
from bisect import bisect_left
from typing import Sequence

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "default_latency_buckets"]


def default_latency_buckets() -> tuple[float, ...]:
    """Geometric bucket upper bounds: 1e-6 .. 1e2 s, 4 per decade."""
    return tuple(10.0 ** (-6 + i / 4) for i in range(4 * 8 + 1))


class Counter:
    """Monotonic counter."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n: int | float = 1) -> None:
        self.value += n


class Gauge:
    """Last-set value."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = None

    def set(self, v: float) -> None:
        self.value = v


class Histogram:
    """Fixed-bucket histogram with percentile extraction.

    ``buckets`` are ascending upper bounds; observations above the last
    bound land in an overflow bucket.  ``min``/``max``/``sum``/``count``
    are tracked exactly, so means are exact and percentile estimates
    are clamped to the observed range.
    """

    __slots__ = ("name", "bounds", "counts", "count", "sum", "min", "max")

    def __init__(self, name: str, buckets: Sequence[float] | None = None):
        self.name = name
        self.bounds = tuple(buckets) if buckets is not None \
            else default_latency_buckets()
        if list(self.bounds) != sorted(self.bounds) or len(self.bounds) < 1:
            raise ValueError("histogram buckets must be ascending")
        self.counts = [0] * (len(self.bounds) + 1)    # + overflow
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, v: float) -> None:
        self.counts[bisect_left(self.bounds, v)] += 1
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    def percentile(self, q: float) -> float | None:
        """Estimated ``q``-quantile (``q`` in [0, 1]); None when empty.

        Linear interpolation inside the bucket holding the target rank
        (numpy's ``linear`` method applied to bucket-censored data);
        the estimate is clamped to the exact observed min/max, so
        single-bucket histograms still answer sensibly.
        """
        if self.count == 0:
            return None
        if not 0.0 <= q <= 1.0:
            raise ValueError("quantile must be in [0, 1]")
        target = q * (self.count - 1) + 1        # 1-based fractional rank
        cum = 0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            if cum + c >= target:
                lo = self.bounds[i - 1] if i > 0 else self.min
                hi = self.bounds[i] if i < len(self.bounds) else self.max
                frac = (target - cum) / c
                est = lo + (hi - lo) * frac
                return float(min(max(est, self.min), self.max))
            cum += c
        return float(self.max)

    def summary(self) -> dict:
        out = {"count": self.count, "sum": round(self.sum, 9)}
        if self.count:
            out.update(
                min=self.min, max=self.max, mean=self.sum / self.count,
                p50=self.percentile(0.50), p95=self.percentile(0.95),
                p99=self.percentile(0.99))
        return out


class _NoopCounter:
    __slots__ = ()

    def inc(self, n: int | float = 1) -> None:
        pass


class _NoopGauge:
    __slots__ = ()

    def set(self, v: float) -> None:
        pass


class _NoopHistogram:
    __slots__ = ()

    def observe(self, v: float) -> None:
        pass

    def percentile(self, q: float) -> None:
        return None

    def summary(self) -> dict:
        return {"count": 0}


_NOOP_COUNTER = _NoopCounter()
_NOOP_GAUGE = _NoopGauge()
_NOOP_HISTOGRAM = _NoopHistogram()


class MetricsRegistry:
    """Named counters/gauges/histograms; disabled = shared no-op handles."""

    def __init__(self, enabled: bool = True):
        self.enabled = bool(enabled)
        self.counters: dict[str, Counter] = {}
        self.gauges: dict[str, Gauge] = {}
        self.histograms: dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        if not self.enabled:
            return _NOOP_COUNTER
        c = self.counters.get(name)
        if c is None:
            c = self.counters[name] = Counter(name)
        return c

    def gauge(self, name: str) -> Gauge:
        if not self.enabled:
            return _NOOP_GAUGE
        g = self.gauges.get(name)
        if g is None:
            g = self.gauges[name] = Gauge(name)
        return g

    def histogram(self, name: str,
                  buckets: Sequence[float] | None = None) -> Histogram:
        if not self.enabled:
            return _NOOP_HISTOGRAM
        h = self.histograms.get(name)
        if h is None:
            h = self.histograms[name] = Histogram(name, buckets)
        return h

    def to_dict(self) -> dict:
        """JSON-ready snapshot (histograms as percentile summaries)."""
        return {
            "counters": {k: c.value for k, c in sorted(self.counters.items())},
            "gauges": {k: g.value for k, g in sorted(self.gauges.items())},
            "histograms": {k: h.summary()
                           for k, h in sorted(self.histograms.items())},
        }
