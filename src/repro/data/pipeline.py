"""Deterministic, resumable, host-sharded synthetic data pipeline.

Every batch is a pure function of ``(seed, step, process slice)``: resuming
from a checkpoint at step k reproduces the exact token stream without any
persisted cursor beyond the step counter — the property the fault-
tolerance tests assert (bitwise-identical restart).

The stream has learnable structure (an affine token chain with noise) so
end-to-end training demonstrably reduces loss; pure-uniform tokens would
make the e2e example meaningless.

Multi-host: each process materialises only its ``[lo, hi)`` row slice of
the global batch (``process_index/process_count`` or explicit overrides) —
the layout jax.make_array_from_process_local_data expects.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    structure: float = 0.8      # P(next token follows the affine chain)
    frontend: str = "tokens"    # mirror of ArchConfig.frontend
    d_model: int = 0            # for stub frontends
    n_patches: int = 0
    decoder_len: int = 0


class SyntheticPipeline:
    def __init__(self, cfg: DataConfig, process_index: int = 0,
                 process_count: int = 1):
        self.cfg = cfg
        if cfg.global_batch % process_count:
            raise ValueError("global_batch must divide across processes")
        per = cfg.global_batch // process_count
        self.lo = process_index * per
        self.hi = self.lo + per

    # -- pure batch functions -------------------------------------------------
    def batch_at(self, step: int) -> dict:
        cfg = self.cfg
        rows = self.hi - self.lo
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, self.lo]))
        v = cfg.vocab_size
        a = 6364136223846793005 % v or 1
        seq_len = cfg.seq_len if cfg.frontend != "stub_frames" \
            else cfg.decoder_len
        toks = np.empty((rows, seq_len + 1), np.int64)
        toks[:, 0] = rng.integers(0, v, rows)
        noise = rng.random((rows, seq_len)) > cfg.structure
        rand = rng.integers(0, v, (rows, seq_len))
        for t in range(seq_len):
            chain = (toks[:, t] * a + 12345) % v
            toks[:, t + 1] = np.where(noise[:, t], rand[:, t], chain)
        batch = {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }
        if cfg.frontend == "stub_patches":
            batch["patch_embeds"] = rng.standard_normal(
                (rows, cfg.n_patches, cfg.d_model), np.float32) * 0.02
        if cfg.frontend == "stub_frames":
            batch["frame_embeds"] = rng.standard_normal(
                (rows, cfg.seq_len, cfg.d_model), np.float32) * 0.02
        return batch

    def iterate(self, start_step: int = 0) -> Iterator[tuple[int, dict]]:
        step = start_step
        while True:
            yield step, self.batch_at(step)
            step += 1
