import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any other import (jax locks the device
count at first init).  512 placeholder CPU devices back the production
meshes: 16x16 (single pod) and 2x16x16 (two pods).

For each applicable cell this script:
  1. builds the step function (train_step / prefill_step / serve_step)
     with the default sharding policy,
  2. ``.lower().compile()`` against ShapeDtypeStruct inputs (no allocation),
  3. records ``memory_analysis()`` (per-device bytes -> proves it fits),
     ``cost_analysis()`` (raw XLA flops/bytes; NOTE: scan bodies counted
     once — see repro.roofline for trip-count-corrected terms),
  4. runs the collective census over the partitioned HLO,
  5. appends the record to ``results/dryrun.json`` incrementally.

Usage:
  python -m repro.launch.dryrun --arch qwen2.5-3b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both]
"""

import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402
from pathlib import Path  # noqa: E402

import jax           # noqa: E402

from .. import configs                     # noqa: E402
from ..compat import cost_analysis         # noqa: E402
from ..roofline.hlo import collective_census  # noqa: E402
from . import policies, shapes, steps      # noqa: E402
from .mesh import make_production_mesh, set_mesh  # noqa: E402

RESULTS = Path(__file__).resolve().parents[3] / "results"


def build_bundle(arch_name: str, cell: shapes.ShapeCell, mesh,
                 scfg=None) -> steps.StepBundle:
    cfg = policies.arch_for_cell(configs.get(arch_name), cell)
    scfg = scfg or policies.default_sharding(cfg, cell)
    if cell.kind == "train":
        batch = shapes.batch_specs_for(cfg, cell)
        return steps.make_train_step(cfg, scfg, mesh,
                                     policies.default_opt(cfg), batch)
    if cell.kind == "prefill":
        batch = shapes.batch_specs_for(cfg, cell)
        return steps.make_prefill_step(cfg, scfg, mesh, batch,
                                       max_len=cell.seq_len)
    return steps.make_serve_step(cfg, scfg, mesh, cell.global_batch,
                                 cell.seq_len)


def run_cell(arch_name: str, cell: shapes.ShapeCell, mesh_name: str,
             scfg=None, keep_hlo: bool = False) -> dict:
    mesh = make_production_mesh(multi_pod=mesh_name == "multi")
    rec: dict = {"arch": arch_name, "cell": cell.name, "mesh": mesh_name,
                 "n_devices": mesh.devices.size}
    t0 = time.time()
    try:
        with set_mesh(mesh):
            bundle = build_bundle(arch_name, cell, mesh, scfg)
            lowered = bundle.lower()
            t_lower = time.time()
            compiled = lowered.compile()
            t_compile = time.time()
            ma = compiled.memory_analysis()
            ca = cost_analysis(compiled)
            txt = compiled.as_text()
            census = collective_census(txt)
            rec.update({
                "ok": True,
                "lower_s": round(t_lower - t0, 1),
                "compile_s": round(t_compile - t_lower, 1),
                "memory": {
                    "argument_bytes": ma.argument_size_in_bytes,
                    "output_bytes": ma.output_size_in_bytes,
                    "temp_bytes": ma.temp_size_in_bytes,
                    "alias_bytes": ma.alias_size_in_bytes,
                    "peak_per_device_gb": round(
                        (ma.argument_size_in_bytes + ma.output_size_in_bytes
                         + ma.temp_size_in_bytes
                         - ma.alias_size_in_bytes) / 2**30, 3),
                },
                "cost_analysis": {
                    "flops": ca.get("flops", 0.0),
                    "bytes_accessed": ca.get("bytes accessed", 0.0),
                },
                "collectives": census,
            })
            if keep_hlo:
                rec["hlo_path"] = str(RESULTS / "hlo" /
                                      f"{arch_name}_{cell.name}_{mesh_name}.txt")
                Path(rec["hlo_path"]).parent.mkdir(parents=True, exist_ok=True)
                Path(rec["hlo_path"]).write_text(txt)
    except Exception as e:  # noqa: BLE001 — a failing cell is a bug report
        rec.update({"ok": False, "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-2000:]})
    return rec


def append_result(rec: dict, path: Path) -> None:
    path.parent.mkdir(parents=True, exist_ok=True)
    existing = []
    if path.exists():
        existing = json.loads(path.read_text())
    existing = [r for r in existing
                if not (r["arch"] == rec["arch"] and r["cell"] == rec["cell"]
                        and r["mesh"] == rec["mesh"])]
    existing.append(rec)
    path.write_text(json.dumps(existing, indent=1))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=configs.ARCH_NAMES)
    ap.add_argument("--shape", default=None, choices=list(shapes.SHAPE_CELLS))
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--keep-hlo", action="store_true")
    ap.add_argument("--out", default=str(RESULTS / "dryrun.json"))
    args = ap.parse_args()

    archs = list(configs.ARCH_NAMES) if (args.all or not args.arch) \
        else [args.arch]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    out = Path(args.out)

    n_ok = n_fail = n_skip = 0
    for arch_name in archs:
        cfg = configs.get(arch_name)
        for cell in shapes.SHAPE_CELLS.values():
            if args.shape and cell.name != args.shape:
                continue
            ok, reason = shapes.applicable(cfg, cell)
            if not ok:
                print(f"SKIP  {arch_name} x {cell.name}: {reason}")
                n_skip += 1
                continue
            for mesh_name in meshes:
                rec = run_cell(arch_name, cell, mesh_name,
                               keep_hlo=args.keep_hlo)
                append_result(rec, out)
                if rec["ok"]:
                    n_ok += 1
                    print(f"OK    {arch_name} x {cell.name} x {mesh_name}: "
                          f"lower {rec['lower_s']}s compile {rec['compile_s']}s "
                          f"peak/dev {rec['memory']['peak_per_device_gb']} GiB "
                          f"flops {rec['cost_analysis']['flops']:.3e}")
                else:
                    n_fail += 1
                    print(f"FAIL  {arch_name} x {cell.name} x {mesh_name}: "
                          f"{rec['error']}")
    print(f"\ndone: {n_ok} ok, {n_fail} failed, {n_skip} skipped "
          f"-> {out}")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
