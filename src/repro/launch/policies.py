"""Default per-(arch x cell) distribution policies.

These are the *paper-faithful baseline* configurations: sensible static
choices an engineer would write down before running the autotuner.  The
sharding tuner (repro.core.sharding_tuner) then searches around them; the
EXPERIMENTS.md §Perf log records baseline vs tuned.
"""

from __future__ import annotations

import dataclasses

from ..dist.sharding import ShardingConfig
from ..models.config import ArchConfig
from ..optim.adamw import AdamWConfig
from .shapes import ShapeCell

# param_dtype: bf16 for >30B (training at that scale is mixed-precision);
# int8 moments only where fp32 Adam cannot fit 16 GB/chip (340B @ 256).
_BIG = 30e9
_HUGE = 150e9


def arch_for_cell(cfg: ArchConfig, cell: ShapeCell) -> ArchConfig:
    n = cfg.param_count()
    upd: dict = {}
    if n > _BIG:
        upd["param_dtype"] = "bfloat16"
    if cell.kind != "train":
        upd["param_dtype"] = "bfloat16"     # serving always bf16 weights
    return dataclasses.replace(cfg, **upd) if upd else cfg


def default_opt(cfg: ArchConfig) -> AdamWConfig:
    return AdamWConfig(
        learning_rate=3e-4,
        moments_dtype="int8" if cfg.param_count() > _HUGE else "float32",
    )


def default_microbatches(cfg: ArchConfig, cell: ShapeCell) -> int:
    if cell.kind != "train":
        return 1
    if cfg.d_model >= 16384:
        return 8
    if cfg.d_model >= 8192:
        return 4
    return 1


def default_sharding(cfg: ArchConfig, cell: ShapeCell,
                     multi_pod: bool = False) -> ShardingConfig:
    kv = "heads"
    if cell.kind in ("decode", "prefill"):
        if cell.global_batch == 1:
            kv = "seq"
        elif cfg.n_kv_heads < 16:
            kv = "batch_seq"
    # Inference keeps fsdp axes on params too: 2D weight sharding (D over
    # data, F over model) so a 340B bf16 model fits 256 chips at serve —
    # the per-layer partial-sum all-reduce over `data` is tiny at decode.
    return ShardingConfig(
        data_axes=("data",),
        model_axes=("model",),
        fsdp_axes=("data",),
        expert_axes=("model",),
        kv_shard=kv,
        seq_parallel=cell.kind == "train",
        microbatches=default_microbatches(cfg, cell),
        remat=cell.kind == "train",
        moments_dtype=default_opt(cfg).moments_dtype,
    )
