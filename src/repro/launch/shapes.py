"""Assigned input-shape cells and ShapeDtypeStruct input specs.

Four cells per architecture (where applicable):

  train_4k      seq 4,096   global_batch 256   -> train_step
  prefill_32k   seq 32,768  global_batch 32    -> serve prefill
  decode_32k    seq 32,768  global_batch 128   -> serve_step (1 new token,
                                                  KV cache of seq_len)
  long_500k     seq 524,288 global_batch 1     -> serve_step; only for
                                                  sub-quadratic families
                                                  (rwkv6, jamba)

``input_specs`` produces weak-type-correct ShapeDtypeStruct stand-ins for
every model input — shardable, no device allocation — exactly what
``jax.jit(...).lower(**specs)`` wants.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from ..models.config import ArchConfig

__all__ = ["ShapeCell", "SHAPE_CELLS", "applicable", "batch_specs_for",
           "all_cells"]


@dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str                 # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPE_CELLS: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524_288, 1),
}

# Whisper decode cells: fixed-length precomputed encoder state.
WHISPER_CROSS_LEN = 1024


def applicable(cfg: ArchConfig, cell: ShapeCell) -> tuple[bool, str]:
    """(is_applicable, reason-if-not). Skips follow DESIGN.md §4."""
    if cell.name == "long_500k" and not cfg.supports_long_context:
        return False, "full quadratic attention: 500k decode cache skipped"
    return True, ""


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs_for(cfg: ArchConfig, cell: ShapeCell) -> dict:
    """ShapeDtypeStructs for the data batch of one cell."""
    b, s = cell.global_batch, cell.seq_len
    if cell.kind == "train":
        if cfg.encdec:
            return {
                "frame_embeds": _sds((b, s, cfg.d_model), jnp.bfloat16),
                "tokens": _sds((b, cfg.decoder_len), jnp.int32),
                "labels": _sds((b, cfg.decoder_len), jnp.int32),
            }
        out = {"tokens": _sds((b, s), jnp.int32),
               "labels": _sds((b, s), jnp.int32)}
        if cfg.frontend == "stub_patches":
            out["patch_embeds"] = _sds((b, cfg.n_patches, cfg.d_model),
                                       jnp.bfloat16)
        return out
    if cell.kind == "prefill":
        if cfg.encdec:
            return {"frame_embeds": _sds((b, s, cfg.d_model), jnp.bfloat16)}
        out = {"tokens": _sds((b, s), jnp.int32)}
        if cfg.frontend == "stub_patches":
            out["patch_embeds"] = _sds((b, cfg.n_patches, cfg.d_model),
                                       jnp.bfloat16)
        return out
    # decode: one new token; the KV cache (capacity seq_len) is state
    return {"tokens": _sds((b, 1), jnp.int32),
            "pos": _sds((), jnp.int32)}


def all_cells(cfg: ArchConfig) -> list[ShapeCell]:
    return [c for c in SHAPE_CELLS.values() if applicable(cfg, c)[0]]
