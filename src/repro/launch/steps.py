"""Jitted step builders: train_step, prefill_step, serve_step.

Each builder binds (model, sharding config, mesh) and returns the jitted
function plus the in/out sharding trees the dry-run and drivers need.
Sharding constraints inside the model are baked at trace time via
``use_rules``, so all tracing/lowering must go through these wrappers.

train_step = microbatched grad accumulation (lax.scan, fp32 accumulator)
-> global-norm clip -> AdamW (optionally int8 moments) -> donated state.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..dist import sharding as shd
from ..dist.api import use_rules
from ..dist.compression import (CompressionConfig, compress_with_feedback,
                                init_error_state)
from ..models import build_model
from ..models.config import ArchConfig
from ..optim.adamw import AdamWConfig, apply_updates, global_norm, init_opt_state

Params = Any


@dataclass
class StepBundle:
    """A jitted step with its sharding trees and shape specs."""
    fn: Callable
    in_shardings: tuple
    out_shardings: Any
    in_specs: tuple           # ShapeDtypeStruct trees for .lower()
    donate_argnums: tuple = ()
    rules: Any = None

    def lower(self):
        jitted = jax.jit(self.fn, in_shardings=self.in_shardings,
                         out_shardings=self.out_shardings,
                         donate_argnums=self.donate_argnums)
        with use_rules(self.rules):
            return jitted.lower(*self.in_specs)

    def jit(self):
        return jax.jit(self.fn, in_shardings=self.in_shardings,
                       out_shardings=self.out_shardings,
                       donate_argnums=self.donate_argnums)


def _named(mesh: Mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def state_shapes(cfg: ArchConfig, opt_cfg: AdamWConfig) -> dict:
    """ShapeDtypeStruct tree for {params, opt, step} without allocation."""
    model = build_model(cfg)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    opt = jax.eval_shape(functools.partial(init_opt_state, cfg=opt_cfg),
                         params)
    return {"params": params, "opt": opt,
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def make_train_step(cfg: ArchConfig, scfg: shd.ShardingConfig, mesh: Mesh,
                    opt_cfg: AdamWConfig, batch_shapes: dict) -> StepBundle:
    model = build_model(cfg)
    rules = scfg.rules(mesh)
    n_micro = scfg.microbatches

    def train_step(state, batch):
        params = state["params"]

        remat_arg = (scfg.remat_policy if (scfg.remat and
                     scfg.remat_policy != "full") else scfg.remat)

        def loss_fn(p, mb):
            loss, metrics = model.loss(p, mb, remat=remat_arg)
            return loss, metrics

        if n_micro > 1:
            micro = jax.tree.map(
                lambda a: a.reshape(n_micro, a.shape[0] // n_micro,
                                    *a.shape[1:]), batch)

            def accum(carry, mb):
                gsum, lsum = carry
                (loss, metrics), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, mb)
                gsum = jax.tree.map(
                    lambda s, x: s + x.astype(jnp.float32), gsum, g)
                return (gsum, lsum + loss), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            (gsum, lsum), _ = jax.lax.scan(accum, (g0, jnp.float32(0.0)),
                                           micro)
            grads = jax.tree.map(lambda g: g / n_micro, gsum)
            loss = lsum / n_micro
        else:
            (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch)

        new_state_extra = {}
        if scfg.grad_compression != "none":
            ccfg = CompressionConfig(scheme=scfg.grad_compression)
            grads, new_err = compress_with_feedback(grads, state["err"],
                                                    ccfg)
            new_state_extra["err"] = new_err
        new_params, new_opt = apply_updates(params, grads, state["opt"],
                                            opt_cfg)
        metrics = {"loss": loss, "gnorm": global_norm(grads),
                   "step": state["step"] + 1}
        return ({"params": new_params, "opt": new_opt,
                 "step": state["step"] + 1, **new_state_extra}, metrics)

    st_shapes = state_shapes(cfg, opt_cfg)
    state_spec = {
        "params": shd.param_specs(st_shapes["params"], mesh, scfg),
        "opt": shd.opt_specs(st_shapes["opt"], st_shapes["params"], mesh,
                             scfg),
        "step": P(),
    }
    if scfg.grad_compression != "none":
        st_shapes["err"] = jax.eval_shape(init_error_state,
                                          st_shapes["params"])
        state_spec["err"] = shd.param_specs(st_shapes["params"], mesh, scfg)
    batch_spec = shd.batch_specs(batch_shapes, mesh, scfg)
    metrics_spec = {"loss": P(), "gnorm": P(), "step": P()}
    return StepBundle(
        fn=train_step,
        in_shardings=(_named(mesh, state_spec), _named(mesh, batch_spec)),
        out_shardings=(_named(mesh, state_spec), _named(mesh, metrics_spec)),
        in_specs=(st_shapes, batch_shapes),
        donate_argnums=(0,),
        rules=rules,
    )


def make_prefill_step(cfg: ArchConfig, scfg: shd.ShardingConfig, mesh: Mesh,
                      batch_shapes: dict, max_len: int = 0) -> StepBundle:
    model = build_model(cfg)
    rules = scfg.rules(mesh)

    if cfg.encdec:
        def prefill_step(params, batch):
            b = batch["frame_embeds"].shape[0]
            state = model.init_decode_state(b, max(max_len, cfg.decoder_len),
                                            cross_len=batch[
                                                "frame_embeds"].shape[1])
            return model.prefill_cross(params, state, batch["frame_embeds"])
    else:
        def prefill_step(params, batch):
            return model.prefill(params, batch["tokens"], max_len=max_len,
                                 patch_embeds=batch.get("patch_embeds"))

    params_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    params_spec = shd.param_specs(params_shapes, mesh, scfg)
    batch_spec = shd.batch_specs(batch_shapes, mesh, scfg)
    with use_rules(rules):
        out_shapes = jax.eval_shape(prefill_step, params_shapes, batch_shapes)

    def out_spec_of(shapes):
        if cfg.encdec:
            return shd.cache_specs(shapes, mesh, scfg)
        logits_spec = P(tuple(scfg.batch_axes(mesh)), None, None)
        return (logits_spec, shd.cache_specs(shapes[1], mesh, scfg))

    out_spec = out_spec_of(out_shapes)
    return StepBundle(
        fn=prefill_step,
        in_shardings=(_named(mesh, params_spec), _named(mesh, batch_spec)),
        out_shardings=_named(mesh, out_spec),
        in_specs=(params_shapes, batch_shapes),
        rules=rules,
    )


def make_serve_step(cfg: ArchConfig, scfg: shd.ShardingConfig, mesh: Mesh,
                    batch: int, max_len: int) -> StepBundle:
    """Single-token decode with a KV cache of capacity ``max_len``."""
    model = build_model(cfg)
    rules = scfg.rules(mesh)

    def serve_step(params, state, tokens, pos):
        logits, new_state = model.decode_step(params, state, tokens, pos)
        return logits, new_state

    params_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    kw = {"cross_len": 1024} if cfg.encdec else {}
    state_shapes_ = jax.eval_shape(
        functools.partial(model.init_decode_state, batch, max_len, **kw))
    params_spec = shd.param_specs(params_shapes, mesh, scfg)
    cache_spec = shd.cache_specs(state_shapes_, mesh, scfg)
    batch_axes = tuple(scfg.batch_axes(mesh))
    tok_spec = P(batch_axes if scfg.kv_shard != "seq" else None, None)
    logits_spec = P(batch_axes if scfg.kv_shard != "seq" else None, None, None)
    tok_shape = jax.ShapeDtypeStruct((batch, 1), jnp.int32)
    pos_shape = jax.ShapeDtypeStruct((), jnp.int32)
    return StepBundle(
        fn=serve_step,
        in_shardings=(_named(mesh, params_spec), _named(mesh, cache_spec),
                      NamedSharding(mesh, tok_spec), NamedSharding(mesh, P())),
        out_shardings=(NamedSharding(mesh, logits_spec),
                       _named(mesh, cache_spec)),
        in_specs=(params_shapes, state_shapes_, tok_shape, pos_shape),
        donate_argnums=(1,),
        rules=rules,
    )
