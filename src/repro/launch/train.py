"""Training driver: data pipeline -> jitted train_step -> checkpoint/restart.

Works at every scale knob: the e2e example trains a ~100M model on this
container's CPU devices; the same driver with ``--dryrun-mesh`` lowers
against the production mesh.  Fault tolerance: checkpoints every
``ckpt_every`` steps (async, atomic), auto-resumes from the latest
complete checkpoint, and the data pipeline regenerates its stream from the
step counter (bitwise-identical restart, tested).

    PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b --smoke \
        --steps 50 --batch 8 --seq-len 128
"""

from __future__ import annotations

import argparse
import time
from pathlib import Path

import jax
import numpy as np

from .. import configs
from ..ckpt.manager import CheckpointManager
from ..data.pipeline import DataConfig, SyntheticPipeline
from ..dist.api import use_rules
from ..dist.sharding import ShardingConfig
from ..models import build_model
from ..obs import get_logger
from ..optim.adamw import AdamWConfig, init_opt_state
from ..optim.schedule import warmup_cosine
from . import shapes, steps
from .mesh import make_host_mesh, set_mesh

log = get_logger("repro.train")


def make_data_cfg(cfg, batch: int, seq_len: int, seed: int = 0) -> DataConfig:
    return DataConfig(
        vocab_size=cfg.vocab_size, seq_len=seq_len, global_batch=batch,
        seed=seed, frontend=cfg.frontend, d_model=cfg.d_model,
        n_patches=cfg.n_patches, decoder_len=cfg.decoder_len)


def train_loop(cfg, *, steps_total: int, batch: int, seq_len: int,
               ckpt_dir: str | Path | None = None, ckpt_every: int = 50,
               scfg: ShardingConfig | None = None,
               opt_cfg: AdamWConfig | None = None,
               mesh=None, log_every: int = 10, seed: int = 0,
               fail_at_step: int | None = None) -> dict:
    """Returns {"losses": [...], "resumed_from": step|None, ...}."""
    mesh = mesh or make_host_mesh()
    scfg = scfg or ShardingConfig(
        data_axes=mesh.axis_names[:1], model_axes=(), fsdp_axes=(),
        microbatches=1, remat=False)
    opt_cfg = opt_cfg or AdamWConfig(
        learning_rate=warmup_cosine(3e-4, 20, steps_total))
    model = build_model(cfg)
    data = SyntheticPipeline(make_data_cfg(cfg, batch, seq_len, seed))
    cell = shapes.ShapeCell("custom", "train", seq_len, batch)
    batch_shapes = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), data.batch_at(0))

    with set_mesh(mesh):
        bundle = steps.make_train_step(cfg, scfg, mesh, opt_cfg, batch_shapes)
        step_fn = bundle.jit()

        mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
        start_step = 0
        resumed_from = None
        restored = False
        if mgr and mgr.latest_step() is not None:
            try:
                with use_rules(bundle.rules):
                    start_step, state, extra = mgr.restore(
                        shardings=bundle.in_shardings[0])
                resumed_from = start_step
                restored = True
            except Exception as e:  # noqa: BLE001 — incompatible checkpoint
                log.warning(f"WARNING: checkpoint in {ckpt_dir} is "
                            f"incompatible with this model "
                            f"({type(e).__name__}); starting fresh",
                            ckpt_dir=str(ckpt_dir), error=type(e).__name__)
        if not restored:
            with use_rules(bundle.rules):
                params = jax.jit(
                    model.init,
                    out_shardings=bundle.in_shardings[0]["params"],
                )(jax.random.PRNGKey(seed))
                opt = jax.jit(
                    lambda p: init_opt_state(p, opt_cfg),
                    out_shardings=bundle.in_shardings[0]["opt"],
                )(params)
            state = {"params": params, "opt": opt,
                     "step": jax.numpy.zeros((), jax.numpy.int32)}
            if scfg.grad_compression != "none":
                from ..dist.compression import init_error_state
                state["err"] = jax.jit(
                    init_error_state,
                    out_shardings=bundle.in_shardings[0]["params"],
                )(params)

        losses: list[float] = []
        t0 = time.time()
        try:
            with use_rules(bundle.rules):
                for step, host_batch in data.iterate(start_step):
                    if step >= steps_total:
                        break
                    if fail_at_step is not None and step == fail_at_step:
                        raise RuntimeError(
                            f"injected failure at step {step}")
                    dev_batch = jax.tree.map(
                        lambda a, s: jax.device_put(a, s), host_batch,
                        bundle.in_shardings[1])
                    state, metrics = step_fn(state, dev_batch)
                    loss = float(metrics["loss"])
                    losses.append(loss)
                    if log_every and step % log_every == 0:
                        dt = time.time() - t0
                        log.info(f"step {step:5d}  loss {loss:7.4f}  "
                                 f"gnorm {float(metrics['gnorm']):7.3f}  "
                                 f"{dt:6.1f}s",
                                 step=step, loss=loss,
                                 gnorm=float(metrics["gnorm"]))
                    if mgr and ckpt_every and (step + 1) % ckpt_every == 0:
                        mgr.save(step + 1, state, extra={"loss": loss})
        except BaseException:
            # flush in-flight async saves so a supervised restart
            # (dist.fault.run_with_restarts) sees every completed
            # checkpoint — otherwise resume races the writer thread
            if mgr:
                mgr.wait()
            raise
        if mgr:
            mgr.save(steps_total, state, extra={"final": True})
            mgr.wait()
    return {"losses": losses, "resumed_from": resumed_from,
            "final_loss": losses[-1] if losses else None, "state": state}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b", choices=configs.ARCH_NAMES)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = configs.get(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    out = train_loop(cfg, steps_total=args.steps, batch=args.batch,
                     seq_len=args.seq_len, ckpt_dir=args.ckpt_dir,
                     ckpt_every=args.ckpt_every, seed=args.seed)
    log.info(f"final loss: {out['final_loss']:.4f} "
             f"(first: {out['losses'][0]:.4f})",
             final_loss=out["final_loss"], first_loss=out["losses"][0])


if __name__ == "__main__":
    main()
