import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Perf-iteration driver: evaluate one (arch x cell) under config overrides.

Each invocation is one hypothesis->measure cycle of the §Perf loop:
lower+compile on the production mesh, trip-weighted collective census,
analytic ledger -> roofline terms, plus a per-kind collective breakdown
so the dominant term can be attributed.

    PYTHONPATH=src python -m repro.launch.perf --arch jamba-v0.1-52b \
        --shape train_4k [--set microbatches=4 remat=False ...] \
        [--mesh-shape 64x4]
"""

import argparse      # noqa: E402
import dataclasses   # noqa: E402
import json          # noqa: E402
import time          # noqa: E402

import jax           # noqa: E402

from .. import configs                  # noqa: E402
from ..roofline import analysis         # noqa: E402
from ..roofline.hlo import collective_census  # noqa: E402
from . import policies, shapes, steps   # noqa: E402
from .mesh import make_production_mesh  # noqa: E402


def parse_override(kv: str):
    k, v = kv.split("=", 1)
    if v in ("True", "False"):
        v = v == "True"
    elif v.isdigit():
        v = int(v)
    elif "," in v:
        v = tuple(x for x in v.split(",") if x)
    return k, v


def evaluate(arch: str, shape: str, scfg_overrides: dict,
             arch_overrides: dict, mesh_shape=(16, 16),
             mesh_axes=("data", "model")) -> dict:
    cell = shapes.SHAPE_CELLS[shape]
    cfg = policies.arch_for_cell(configs.get(arch), cell)
    if arch_overrides:
        cfg = dataclasses.replace(cfg, **arch_overrides)
    scfg = policies.default_sharding(cfg, cell)
    if scfg_overrides:
        scfg = dataclasses.replace(scfg, **scfg_overrides)
    mesh = make_production_mesh(shape=mesh_shape, axes=mesh_axes)
    n_chips = mesh.devices.size
    t0 = time.time()
    with jax.set_mesh(mesh):
        if cell.kind == "train":
            bundle = steps.make_train_step(cfg, scfg, mesh,
                                           policies.default_opt(cfg),
                                           shapes.batch_specs_for(cfg, cell))
        elif cell.kind == "prefill":
            bundle = steps.make_prefill_step(cfg, scfg, mesh,
                                             shapes.batch_specs_for(cfg, cell),
                                             max_len=cell.seq_len)
        else:
            bundle = steps.make_serve_step(cfg, scfg, mesh,
                                           cell.global_batch, cell.seq_len)
        compiled = bundle.lower().compile()
        txt = compiled.as_text()
        census = collective_census(txt)
        ma = compiled.memory_analysis()
    ledger = analysis.analytic_cost(cfg, cell, scfg, n_chips=n_chips)
    terms = analysis.roofline_terms(
        ledger, census["transfer_bytes_per_step"], n_chips)
    peak = (ma.argument_size_in_bytes + ma.output_size_in_bytes
            + ma.temp_size_in_bytes - ma.alias_size_in_bytes) / 2**30
    return {
        "arch": arch, "cell": shape, "mesh": "x".join(map(str, mesh_shape)),
        "overrides": {**scfg_overrides, **arch_overrides},
        "compile_s": round(time.time() - t0, 1),
        "peak_gb": round(peak, 2),
        **{k: (round(v, 5) if isinstance(v, float) else v)
           for k, v in terms.items()},
        "collective_breakdown_gb": {
            k: round(v["transfer_bytes"] / 2**30, 3)
            for k, v in census["weighted"].items()
            if v["transfer_bytes"]},
        "ledger_detail_top": dict(sorted(
            ((k, f"{v['flops']:.3g}F/{v['hbm']/2**30:.2f}GiB")
             for k, v in ledger.detail.items()),
            key=lambda kv: kv[0])),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.ARCH_NAMES)
    ap.add_argument("--shape", required=True, choices=list(shapes.SHAPE_CELLS))
    ap.add_argument("--set", nargs="*", default=[],
                    help="ShardingConfig overrides k=v")
    ap.add_argument("--arch-set", nargs="*", default=[],
                    help="ArchConfig overrides k=v")
    ap.add_argument("--mesh-shape", default="16x16")
    args = ap.parse_args()
    scfg_over = dict(parse_override(kv) for kv in args.set)
    arch_over = dict(parse_override(kv) for kv in args.arch_set)
    mesh_shape = tuple(int(x) for x in args.mesh_shape.split("x"))
    axes = ("data", "model") if len(mesh_shape) == 2 \
        else ("pod", "data", "model")
    rec = evaluate(args.arch, args.shape, scfg_over, arch_over,
                   mesh_shape, axes)
    print(json.dumps(rec, indent=1, default=str))


if __name__ == "__main__":
    main()
