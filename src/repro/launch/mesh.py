"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state.  Single-pod: 256 chips as
(data=16, model=16).  Multi-pod: 2 pods x 256 chips as
(pod=2, data=16, model=16) — the pod axis is the DCN-connected dimension.

Mesh creation and the ambient-mesh context go through ``repro.compat`` so
the same code runs on old and new JAX mesh APIs; ``set_mesh`` is
re-exported here for the drivers.
"""

from __future__ import annotations

import jax

from ..compat import make_mesh, set_mesh  # noqa: F401 — re-exported

__all__ = ["make_production_mesh", "make_host_mesh", "set_mesh"]


def make_production_mesh(*, multi_pod: bool = False,
                         shape: tuple[int, ...] | None = None,
                         axes: tuple[str, ...] | None = None):
    if shape is None:
        shape = (2, 16, 16) if multi_pod else (16, 16)
    if axes is None:
        axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(n_devices: int | None = None,
                   axes: tuple[str, ...] = ("data",)):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = n_devices or len(jax.devices())
    return make_mesh((n,), axes)
