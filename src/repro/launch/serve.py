"""Serving driver: batched prefill -> decode loop with KV caches.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --smoke \
        --batch 4 --prompt-len 32 --gen 16

``--stream`` switches to the online runtime: request batches flow
through ``repro.runtime.StreamingPipeline``, each batch chunk-scheduled
across device groups (``--slow N`` reserves the last N devices as a
second group), and the EWMA controller adapts the split per request mix.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from .. import configs
from ..core.hetero import DeviceGroup
from ..dist.api import use_rules
from ..dist.sharding import ShardingConfig
from ..models import build_model
from .mesh import make_host_mesh, set_mesh
from . import steps


def serve_session(cfg, *, batch: int, prompt_len: int, gen: int,
                  scfg: ShardingConfig | None = None, mesh=None,
                  seed: int = 0, greedy: bool = True) -> dict:
    """Prefill a random prompt batch, then decode ``gen`` tokens."""
    mesh = mesh or make_host_mesh()
    scfg = scfg or ShardingConfig(
        data_axes=mesh.axis_names[:1], model_axes=(), fsdp_axes=(),
        kv_shard="none", remat=False)
    model = build_model(cfg)
    max_len = prompt_len + gen
    rng = np.random.default_rng(seed)

    with set_mesh(mesh), use_rules(scfg.rules(mesh)):
        params = jax.jit(model.init)(jax.random.PRNGKey(seed))
        tokens = jnp.asarray(rng.integers(
            0, cfg.vocab_size, (batch, prompt_len)), jnp.int32)

        t0 = time.time()
        if cfg.encdec:
            frames = jnp.asarray(rng.standard_normal(
                (batch, prompt_len, cfg.d_model)), jnp.float32) * 0.02
            state = model.init_decode_state(batch, max_len,
                                            cross_len=prompt_len)
            state = jax.jit(model.prefill_cross)(params, state, frames)
            start_pos = 0
            last_tok = jnp.zeros((batch, 1), jnp.int32)
        else:
            logits, state = jax.jit(
                lambda p, t: model.prefill(p, t, max_len=max_len)
            )(params, tokens)
            start_pos = prompt_len
            last_tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        t_prefill = time.time() - t0

        decode = jax.jit(model.decode_step, donate_argnums=(1,))
        out_tokens = [last_tok]
        t0 = time.time()
        key = jax.random.PRNGKey(seed)
        for i in range(gen - 1):
            pos = jnp.int32(start_pos + i)
            logits, state = decode(params, state, last_tok, pos)
            if greedy:
                last_tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            else:
                key, k = jax.random.split(key)
                last_tok = jax.random.categorical(
                    k, logits[:, -1])[:, None].astype(jnp.int32)
            out_tokens.append(last_tok)
        generated = jnp.concatenate(out_tokens, axis=1)
        generated.block_until_ready()
        t_decode = time.time() - t0

    return {
        "generated": np.asarray(generated),
        "prefill_s": t_prefill,
        "decode_s": t_decode,
        "tokens_per_s": batch * (gen - 1) / max(t_decode, 1e-9),
    }


def serve_stream(cfg, *, groups: list[DeviceGroup], n_batches: int = 4,
                 batch: int = 8, prompt_len: int = 16, gen: int = 8,
                 seed: int = 0, chunks_per_group: int = 2,
                 row_quantum: int = 2, controller=None) -> dict:
    """Adaptive serving: chunk-schedule request batches across groups.

    Each group holds its own (replicated) copy of the params and runs
    full prefill+decode for the request rows it is handed; the
    ``StreamingPipeline``'s EWMA controller moves rows between groups as
    measured per-chunk times come in, so the split tracks the live
    request mix and relative group speed.  Decoder-only models.
    ``row_quantum`` coarsens chunk sizes (prefill/decode re-jit per
    distinct chunk shape, so coarse quanta keep the compiled-shape set
    small while the split drifts).
    """
    from ..runtime import StreamingPipeline

    if cfg.encdec:
        raise ValueError("serve_stream supports decoder-only models")
    n_devices = sum(len(g.devices) for g in groups)
    if batch < n_devices:
        raise ValueError(
            f"--batch {batch} is smaller than one request per device "
            f"({n_devices}); raise --batch or use fewer devices/groups")
    model = build_model(cfg)
    max_len = prompt_len + gen

    def step_builder(group: DeviceGroup):
        mesh = group.mesh()
        scfg = ShardingConfig(data_axes=mesh.axis_names[:1], model_axes=(),
                              fsdp_axes=(), kv_shard="none", remat=False)
        rules = scfg.rules(mesh)
        with set_mesh(mesh), use_rules(rules):
            params = jax.jit(model.init)(jax.random.PRNGKey(seed))
        params = jax.device_put(params, NamedSharding(mesh, P()))
        prefill = jax.jit(lambda p, t: model.prefill(p, t, max_len=max_len))
        decode = jax.jit(model.decode_step, donate_argnums=(1,))

        def fn(chunk):
            with set_mesh(mesh), use_rules(rules):
                logits, state = prefill(params, chunk["tokens"])
                last = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
                outs = [last]
                for i in range(gen - 1):
                    logits, state = decode(params, state, last,
                                           jnp.int32(prompt_len + i))
                    last = jnp.argmax(logits[:, -1:],
                                      axis=-1).astype(jnp.int32)
                    outs.append(last)
                return jnp.concatenate(outs, axis=1)
        return fn

    pipeline = StreamingPipeline(step_builder, groups,
                                 chunks_per_group=chunks_per_group,
                                 row_quantum=row_quantum,
                                 controller=controller)
    rng = np.random.default_rng(seed)
    batches = [{"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (batch, prompt_len)), jnp.int32)}
        for _ in range(n_batches)]
    records = pipeline.run(batches)
    summary = pipeline.summary()
    summary["tokens_per_s_mean"] = summary["rows_per_s_mean"] * gen
    return {"records": records, "summary": summary}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b", choices=configs.ARCH_NAMES)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--stream", action="store_true",
                    help="adaptive chunk-scheduled serving (repro.runtime)")
    ap.add_argument("--stream-batches", type=int, default=4)
    ap.add_argument("--slow", type=int, default=0,
                    help="reserve the last N devices as a second group")
    args = ap.parse_args()
    cfg = configs.get(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    if args.stream:
        # the scheduler needs >= 1 request row per device: on small
        # --batch runs use only as many devices as there are rows
        devs = jax.devices()[:max(args.batch, 1)]
        if 0 < args.slow < len(devs):
            groups = [DeviceGroup("fast", devs[:-args.slow]),
                      DeviceGroup("slow", devs[-args.slow:])]
        else:
            groups = [DeviceGroup("all", devs)]
        out = serve_stream(cfg, groups=groups, n_batches=args.stream_batches,
                           batch=args.batch, prompt_len=args.prompt_len,
                           gen=args.gen)
        s = out["summary"]
        print(f"stream: {s['batches']} batches  "
              f"{s['tokens_per_s_mean']:.1f} tok/s  "
              f"shares {s['shares_final']}")
        return
    out = serve_session(cfg, batch=args.batch, prompt_len=args.prompt_len,
                        gen=args.gen)
    print(f"prefill {out['prefill_s']:.2f}s  decode {out['decode_s']:.2f}s  "
          f"{out['tokens_per_s']:.1f} tok/s")
    print("sample tokens:", out["generated"][0, :12])


if __name__ == "__main__":
    main()
