"""Serving driver: batched prefill -> decode loop with KV caches.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --smoke \
        --batch 4 --prompt-len 32 --gen 16

``--stream`` switches to the online runtime: request batches flow
through ``repro.runtime.StreamingPipeline``, each batch chunk-scheduled
across device groups (``--slow N`` reserves the last N devices as a
second group), and the EWMA controller adapts the split per request mix.

``--tuned-kernels STORE`` enables the kernel-autotuning fast path: the
Pallas kernels resolve their cached best launch parameters (tuned via
``repro.tune.kernels`` / ``benchmarks/bench_kernels.py``) per traced
shape, with zero measurements at serve time.

Observability (``repro.obs``): ``--trace-out`` / ``--journal-out`` /
``--metrics-out`` record a ``--stream`` run — a Chrome-loadable span
trace, the decision journal (JSONL), and an ``obs_summary.json``.
``--fault-plan "kill:0@3,slow:1@9:4"`` replays a scripted failure drill
against the simulated serial-device groups on a ``VirtualClock`` (no
model build, deterministic timestamps) — the CI obs-smoke job validates
its artifacts against ``docs/obs_schema.json``.

``--serve-requests N`` switches to the request-level serving engine
(``repro.serve``): N requests from a deterministic arrival source flow
through SLO-aware admission and the continuous batcher into the
chunked scheduler, with per-request completion records.  With
``--sim-serve`` or ``--fault-plan`` the engine runs the deterministic
sim rig (``VirtualClock``, no model build — the CI serve-smoke drill);
otherwise real prefill+decode serves each formed batch.
``--tune-batcher`` tunes the batcher knobs through ``TuningSession``
(persisted in ``--batcher-store``) before serving; ``docs/serving.md``
documents the policies.

Crash durability (``runtime.checkpoint``; sim rig only): ``--wal PATH``
appends every admit/retire/step to a write-ahead request log and
``--snapshot PATH`` checkpoints the engine's soft state; after a crash
(scripted via ``--fault-plan 'crash:0@N'``, raising by default or a
real ``SIGKILL`` with ``--crash-sigkill``) the same command plus
``--resume`` replays unretired requests and finishes the run with every
admitted request accounted — the CI recover-smoke drill;
``docs/resilience.md`` documents the protocol.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from .. import configs
from ..core.hetero import DeviceGroup
from ..dist.api import use_rules
from ..dist.sharding import ShardingConfig
from ..models import build_model
from ..obs import get_logger
from .mesh import make_host_mesh, set_mesh
from . import steps

log = get_logger("repro.serve")


def serve_session(cfg, *, batch: int, prompt_len: int, gen: int,
                  scfg: ShardingConfig | None = None, mesh=None,
                  seed: int = 0, greedy: bool = True) -> dict:
    """Prefill a random prompt batch, then decode ``gen`` tokens."""
    mesh = mesh or make_host_mesh()
    scfg = scfg or ShardingConfig(
        data_axes=mesh.axis_names[:1], model_axes=(), fsdp_axes=(),
        kv_shard="none", remat=False)
    model = build_model(cfg)
    max_len = prompt_len + gen
    rng = np.random.default_rng(seed)

    with set_mesh(mesh), use_rules(scfg.rules(mesh)):
        params = jax.jit(model.init)(jax.random.PRNGKey(seed))
        tokens = jnp.asarray(rng.integers(
            0, cfg.vocab_size, (batch, prompt_len)), jnp.int32)

        t0 = time.time()
        if cfg.encdec:
            frames = jnp.asarray(rng.standard_normal(
                (batch, prompt_len, cfg.d_model)), jnp.float32) * 0.02
            state = model.init_decode_state(batch, max_len,
                                            cross_len=prompt_len)
            state = jax.jit(model.prefill_cross)(params, state, frames)
            start_pos = 0
            last_tok = jnp.zeros((batch, 1), jnp.int32)
        else:
            logits, state = jax.jit(
                lambda p, t: model.prefill(p, t, max_len=max_len)
            )(params, tokens)
            start_pos = prompt_len
            last_tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        t_prefill = time.time() - t0

        decode = jax.jit(model.decode_step, donate_argnums=(1,))
        out_tokens = [last_tok]
        t0 = time.time()
        key = jax.random.PRNGKey(seed)
        for i in range(gen - 1):
            pos = jnp.int32(start_pos + i)
            logits, state = decode(params, state, last_tok, pos)
            if greedy:
                last_tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            else:
                key, k = jax.random.split(key)
                last_tok = jax.random.categorical(
                    k, logits[:, -1])[:, None].astype(jnp.int32)
            out_tokens.append(last_tok)
        generated = jnp.concatenate(out_tokens, axis=1)
        generated.block_until_ready()
        t_decode = time.time() - t0

    return {
        "generated": np.asarray(generated),
        "prefill_s": t_prefill,
        "decode_s": t_decode,
        "tokens_per_s": batch * (gen - 1) / max(t_decode, 1e-9),
    }


def _stream_step_builder(model, *, prompt_len: int, gen: int, seed: int):
    """Per-group prefill+decode step factory shared by ``serve_stream``
    and the split tuner (same jitted functions, same chunk contract)."""
    max_len = prompt_len + gen

    def step_builder(group: DeviceGroup):
        mesh = group.mesh()
        scfg = ShardingConfig(data_axes=mesh.axis_names[:1], model_axes=(),
                              fsdp_axes=(), kv_shard="none", remat=False)
        rules = scfg.rules(mesh)
        with set_mesh(mesh), use_rules(rules):
            params = jax.jit(model.init)(jax.random.PRNGKey(seed))
        params = jax.device_put(params, NamedSharding(mesh, P()))
        prefill = jax.jit(lambda p, t: model.prefill(p, t, max_len=max_len))
        decode = jax.jit(model.decode_step, donate_argnums=(1,))

        def fn(chunk):
            with set_mesh(mesh), use_rules(rules):
                logits, state = prefill(params, chunk["tokens"])
                last = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
                outs = [last]
                for i in range(gen - 1):
                    logits, state = decode(params, state, last,
                                           jnp.int32(prompt_len + i))
                    last = jnp.argmax(logits[:, -1:],
                                      axis=-1).astype(jnp.int32)
                    outs.append(last)
                return jnp.concatenate(outs, axis=1)
        return fn

    return step_builder


def _memoize_per_group(step_builder):
    """Cache the per-group step closures (params init + jitted
    prefill/decode) so a builder shared between ``tune_stream_split``
    and ``serve_stream`` compiles each group's functions exactly once."""
    cache: dict[int, object] = {}

    def memoized(group: DeviceGroup):
        key = id(group)
        if key not in cache:
            cache[key] = step_builder(group)
        return cache[key]
    return memoized


def tune_stream_split(cfg, *, groups: list[DeviceGroup], batch: int = 8,
                      prompt_len: int = 16, gen: int = 8, seed: int = 0,
                      strategy: str = "sam", iterations: int = 10,
                      store=None, chunks_per_group: int = 2,
                      row_quantum: int = 2, model=None, step_builder=None):
    """Offline-tune the initial two-group split through ``repro.tune``.

    The paper's loop at serve time: the config space is the fraction of
    each request batch handed to the first group, one measurement is a
    chunk-scheduled dispatch (rebalance off) of a representative batch,
    and any registered strategy searches it.  ``store`` caches the tuned
    split per (batch shape x group topology) workload signature, so a
    serving session on a known workload starts at the tuned split with
    zero extra measurements.  Returns shares for the controller.
    """
    from ..core.space import ConfigSpace, Param
    from ..runtime import ChunkedScheduler, EwmaController
    from ..tune import TuningSession

    if len(groups) != 2:
        raise ValueError("tune_stream_split needs exactly two device groups")
    if step_builder is None:
        model = model if model is not None else build_model(cfg)
        step_builder = _stream_step_builder(model, prompt_len=prompt_len,
                                            gen=gen, seed=seed)
    rng = np.random.default_rng(seed)
    sample = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (batch, prompt_len)), jnp.int32)}
    controller = EwmaController(2)
    sched = ChunkedScheduler(
        step_builder, groups, controller=controller,
        chunks_per_group=chunks_per_group, row_quantum=row_quantum)
    space = ConfigSpace([Param("fraction", tuple(range(10, 100, 10)))])

    def measure(cfg_point):
        f = cfg_point["fraction"] / 100.0
        controller.shares = np.asarray([f, 1.0 - f])
        rec = sched.step(sample, rebalance=False)
        return {"time": rec["t_step"], "t_host": rec["t_group"][0],
                "t_device": rec["t_group"][1]}

    workload = None
    if store is not None:
        workload = {"batch": (batch, prompt_len, gen), "arch": cfg.name,
                    "groups": [(g.name, len(g.devices), g.work_multiplier)
                               for g in groups]}
    session = TuningSession(space, evaluator=measure, store=store,
                            workload=workload)
    result = session.run(strategy, iterations=iterations, seed=seed)
    f = result.best_config["fraction"] / 100.0
    return np.asarray([f, 1.0 - f]), result


def serve_stream(cfg, *, groups: list[DeviceGroup], n_batches: int = 4,
                 batch: int = 8, prompt_len: int = 16, gen: int = 8,
                 seed: int = 0, chunks_per_group: int = 2,
                 row_quantum: int = 2, controller=None,
                 initial_shares=None, model=None,
                 step_builder=None, guard=None, observer=None,
                 clock=None, injector=None) -> dict:
    """Adaptive serving: chunk-schedule request batches across groups.

    Each group holds its own (replicated) copy of the params and runs
    full prefill+decode for the request rows it is handed; the
    ``StreamingPipeline``'s EWMA controller moves rows between groups as
    measured per-chunk times come in, so the split tracks the live
    request mix and relative group speed.  Decoder-only models.
    ``row_quantum`` coarsens chunk sizes (prefill/decode re-jit per
    distinct chunk shape, so coarse quanta keep the compiled-shape set
    small while the split drifts).  ``initial_shares`` (e.g. from
    ``tune_stream_split``) starts the controller at a tuned split
    instead of uniform.  ``guard`` (``True`` or a preconfigured
    ``repro.runtime.ServeGuard``) adds the kill-switch guardrail: if the
    online trajectory regresses, the split pins to the last known-good
    static configuration until a cool-down probe passes
    (``docs/resilience.md``).

    ``observer`` (``repro.obs.Observer``) records the run; ``clock``
    passes through to the scheduler (share it with the observer and a
    sim ``step_builder`` for deterministic traces); ``injector`` (a
    ``repro.runtime.FaultInjector``) is ticked once per batch and
    attached so recover events restore membership — the fault-drill
    surface behind ``--fault-plan``.
    """
    from ..runtime import EwmaController, StreamingPipeline

    if cfg.encdec:
        raise ValueError("serve_stream supports decoder-only models")
    n_devices = sum(len(g.devices) for g in groups)
    if batch < n_devices:
        raise ValueError(
            f"--batch {batch} is smaller than one request per device "
            f"({n_devices}); raise --batch or use fewer devices/groups")
    if step_builder is None:
        model = model if model is not None else build_model(cfg)
        step_builder = _stream_step_builder(model, prompt_len=prompt_len,
                                            gen=gen, seed=seed)
    if controller is None and initial_shares is not None:
        controller = EwmaController(len(groups),
                                    shares=np.asarray(initial_shares))

    pipeline = StreamingPipeline(
        step_builder, groups, chunks_per_group=chunks_per_group,
        row_quantum=row_quantum, controller=controller, guard=guard,
        clock=clock, observer=observer)
    rng = np.random.default_rng(seed)
    batches = [{"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (batch, prompt_len)), jnp.int32)}
        for _ in range(n_batches)]
    if injector is not None:
        # route recover events through the membership surface, and feed
        # the scripted plan one scheduler step at a time
        injector.attach(pipeline.guard if pipeline.guard is not None
                        else pipeline.scheduler)
        records = []
        for b in batches:
            injector.tick()
            records.extend(pipeline.run([b]))
    else:
        records = pipeline.run(batches)
    summary = pipeline.summary()
    summary["tokens_per_s_mean"] = summary["rows_per_s_mean"] * gen
    return {"records": records, "summary": summary}


def serve_requests(cfg, *, groups: list[DeviceGroup], n_requests: int,
                   rate_rps: float, prompt_len: int, gen: int,
                   seed: int = 0, batcher_config=None, guard: bool = False,
                   observer=None, row_quantum: int = 1,
                   model=None, step_builder=None) -> dict:
    """Request-level serving on real devices: the ``repro.serve`` engine
    over a prefill+decode step builder.

    Every request asks for rows of one ``(prompt_len, gen)`` shape (the
    arrival process, priorities and SLOs come from the source's default
    mix); the continuous batcher re-forms a scheduler batch per step
    from whatever is queued, and the chunked scheduler splits each batch
    across ``groups``.  Arrival waits are real ``time.sleep`` — for the
    deterministic virtual-clock rig use ``repro.serve.make_sim_engine``
    (the ``--sim-serve`` / ``--fault-plan`` path).
    """
    from ..runtime import ChunkedScheduler, ServeGuard
    from ..serve import (AdmissionController, BatcherConfig,
                         ContinuousBatcher, RequestSource, ServeEngine,
                         SloPolicy)

    if step_builder is None:
        model = model if model is not None else build_model(cfg)
        step_builder = _memoize_per_group(_stream_step_builder(
            model, prompt_len=prompt_len, gen=gen, seed=seed))
    # anchor arrivals on the engine's wall clock (the sim rig's
    # VirtualClock starts at 0; perf_counter does not)
    source = RequestSource(n_requests=n_requests, rate_rps=rate_rps,
                           seed=seed, shapes=((prompt_len, gen),),
                           rows_choices=(1, 2, 4),
                           start=time.perf_counter())
    rng = np.random.default_rng(seed)

    def payload_fn(shape, rows):
        return {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (rows, shape[0])), jnp.int32)}

    scheduler = ChunkedScheduler(step_builder, groups,
                                 row_quantum=max(row_quantum, 1),
                                 observer=observer)
    target = ServeGuard(scheduler) if guard else scheduler
    bcfg = batcher_config or BatcherConfig()
    engine = ServeEngine(
        target, source=source,
        admission=AdmissionController(
            SloPolicy(max_queue_rows=bcfg.queue_depth_rows)),
        batcher=ContinuousBatcher(bcfg),
        payload_fn=payload_fn, observer=observer)
    summary = engine.run()
    summary["tokens_per_s"] = summary.get("goodput_rows_per_s", 0.0) * gen
    return {"summary": summary,
            "records": [r.record() for r in engine.done]}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b", choices=configs.ARCH_NAMES)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--stream", action="store_true",
                    help="adaptive chunk-scheduled serving (repro.runtime)")
    ap.add_argument("--stream-batches", type=int, default=4)
    ap.add_argument("--slow", type=int, default=0,
                    help="reserve the last N devices as a second group")
    ap.add_argument("--tune-split", action="store_true",
                    help="tune the initial two-group split offline "
                    "(repro.tune session) before streaming")
    ap.add_argument("--tune-store", default=None,
                    help="TuningStore JSON path caching tuned splits "
                    "per workload signature")
    ap.add_argument("--tune-strategy", default="sam",
                    help="registered strategy for --tune-split "
                    "(see repro.tune.list_strategies())")
    ap.add_argument("--guard", action="store_true",
                    help="kill-switch guardrail: pin the last known-good "
                    "static split when the online controller regresses "
                    "(docs/resilience.md)")
    ap.add_argument("--guard-threshold", type=float, default=1.5,
                    help="trip when step time exceeds this multiple of "
                    "the rolling baseline")
    ap.add_argument("--guard-patience", type=int, default=5,
                    help="consecutive regressing steps before tripping")
    ap.add_argument("--attn-impl", default=None,
                    choices=["auto", "xla", "pallas"],
                    help="override the arch's mixer implementation "
                    "(pallas = the repro.kernels suite; interpret mode "
                    "on CPU)")
    ap.add_argument("--tuned-kernels", default=None, metavar="STORE",
                    help="kernel tuning store (JSON from "
                    "repro.tune.kernels.tune_kernel / bench_kernels.py); "
                    "Pallas kernels resolve their cached best launch "
                    "params for each traced shape, defaults on a miss")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a chrome://tracing span trace of the "
                    "--stream run (repro.obs)")
    ap.add_argument("--journal-out", default=None, metavar="PATH",
                    help="write the decision journal (JSONL) of the "
                    "--stream run")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write obs_summary.json (counters, latency "
                    "percentiles, journal digest, provenance meta)")
    ap.add_argument("--log-level", default=None,
                    choices=["debug", "info", "warning", "error"],
                    help="filter the structured log (default info; also "
                    "REPRO_LOG_LEVEL)")
    ap.add_argument("--fault-plan", default=None, metavar="SPEC",
                    help="scripted failure drill for --stream, e.g. "
                    "'kill:0@3,slow:1@9:4' — runs against simulated "
                    "serial groups on a virtual clock (no model build); "
                    "see repro.runtime.parse_fault_plan")
    ap.add_argument("--sim-devices", type=int, default=8,
                    help="device count of the simulated groups under "
                    "--fault-plan")
    ap.add_argument("--serve-requests", type=int, default=None, metavar="N",
                    help="request-level serving (repro.serve): N requests "
                    "from a deterministic arrival source through admission "
                    "-> continuous batching -> the chunked scheduler")
    ap.add_argument("--request-rate", type=float, default=200.0,
                    help="offered load for --serve-requests (requests/s)")
    ap.add_argument("--serve-seed", type=int, default=0,
                    help="seed of the request arrival source")
    ap.add_argument("--sim-serve", action="store_true",
                    help="run --serve-requests on the deterministic sim "
                    "rig (VirtualClock, no model build) even without a "
                    "--fault-plan")
    ap.add_argument("--tune-batcher", action="store_true",
                    help="tune the continuous-batcher knobs through a "
                    "TuningSession (sim-rig evaluations) before serving")
    ap.add_argument("--batcher-store", default=None, metavar="PATH",
                    help="TuningStore JSON caching tuned batcher configs "
                    "per workload signature")
    ap.add_argument("--wal", default=None, metavar="PATH",
                    help="write-ahead request log for --serve-requests "
                    "(sim rig): every admit/retire/step is appended "
                    "before the engine proceeds, so a crashed run can "
                    "restart with --resume (docs/resilience.md)")
    ap.add_argument("--snapshot", default=None, metavar="PATH",
                    help="periodic checksummed snapshot of the engine's "
                    "soft state (controller shares, kill-switch, service "
                    "estimator) next to the --wal")
    ap.add_argument("--resume", action="store_true",
                    help="recover from --wal (and --snapshot if given) "
                    "before serving: unretired admitted requests replay "
                    "through admission, the clock and fault plan fast-"
                    "forward to the crash point")
    ap.add_argument("--crash-sigkill", action="store_true",
                    help="scripted crash faults (--fault-plan 'crash:0@N') "
                    "kill the process with SIGKILL instead of raising — "
                    "the real-process recovery drill")
    args = ap.parse_args()
    from ..obs import Observer, configure
    if args.log_level:
        configure(level=args.log_level)
    cfg = configs.get(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    if args.attn_impl:
        from dataclasses import replace
        cfg = replace(cfg, attn_impl=args.attn_impl)
    if args.tuned_kernels:
        # every kernel op called with tuned=None (the models' default)
        # now resolves through this store at trace time — serving runs
        # the tuned launch parameters with zero extra measurements
        from ..tune import kernels as ktune
        ktune.configure(args.tuned_kernels)
    if args.serve_requests:
        from ..serve import BatcherConfig, make_sim_engine, tune_batcher
        observer = None
        journal_sink = None
        if args.trace_out or args.journal_out or args.metrics_out:
            observer = Observer()
            if args.journal_out:
                # stream every event as it happens (line-buffered +
                # per-event flush): a SIGKILL mid-run still leaves the
                # journal on disk up to the last decision.  save_journal
                # rewrites the same bytes at clean exit.
                from pathlib import Path
                Path(args.journal_out).parent.mkdir(parents=True,
                                                    exist_ok=True)
                journal_sink = open(args.journal_out, "w", buffering=1)
                observer.journal.sink = journal_sink
            configure(journal=observer.journal)
        sim = bool(args.fault_plan or args.sim_serve)
        if (args.wal or args.resume) and not sim:
            ap.error("--wal/--resume need the sim rig "
                     "(--sim-serve or --fault-plan)")
        if args.resume and not args.wal:
            ap.error("--resume needs --wal")
        bcfg = None
        if args.tune_batcher:
            # tune on the sim rig (cheap, deterministic); the store
            # re-serves a known workload with zero new measurements
            from ..runtime import TuningStore
            store = TuningStore(args.batcher_store) \
                if args.batcher_store else None
            workload = {"n_requests": args.serve_requests,
                        "rate_rps": args.request_rate,
                        "seed": args.serve_seed}

            def evaluate(cand):
                eng = make_sim_engine(n_requests=args.serve_requests,
                                      rate_rps=args.request_rate,
                                      seed=args.serve_seed,
                                      batcher_config=cand)
                s = eng.run()
                return {"time": s.get("e2e_p95", 10.0)
                        + 0.1 * s["shed_rate"],
                        "shed_rate": s["shed_rate"]}

            bcfg, tuned = tune_batcher(evaluate, store=store,
                                       workload=workload,
                                       observer=observer)
            log.info(f"tuned batcher: {bcfg} "
                     f"({tuned.n_experiments} measurements, "
                     f"{100 * tuned.experiments_fraction:.1f}% of space"
                     f"{', cached' if tuned.from_cache else ''})")
        if sim:
            from ..runtime.checkpoint import SimulatedCrash
            from ..runtime.simulate import parse_fault_plan
            plan = parse_fault_plan(args.fault_plan) \
                if args.fault_plan else None
            engine = make_sim_engine(
                n_requests=args.serve_requests,
                rate_rps=args.request_rate,
                seed=args.serve_seed, fault_plan=plan,
                guard=args.guard or bool(plan),
                batcher_config=bcfg, observer=observer,
                wal=args.wal, snapshot=args.snapshot,
                resume=args.resume,
                crash_mode="sigkill" if args.crash_sigkill else "raise")
            try:
                s = engine.run()
            except SimulatedCrash as exc:
                # scripted crash drill (crash_mode="raise"): the WAL and
                # streamed journal are already durable — flush what we
                # have and exit with the drill's sentinel code so CI can
                # assert the crash actually fired before the restart
                log.warning(f"simulated crash: {exc}",
                            steps=engine.steps)
                if engine.wal is not None:
                    engine.wal.sync()
                if journal_sink is not None:
                    journal_sink.close()
                raise SystemExit(17)
        else:
            devs = jax.devices()[:max(args.batch, 1)]
            if 0 < args.slow < len(devs):
                groups = [DeviceGroup("fast", devs[:-args.slow]),
                          DeviceGroup("slow", devs[-args.slow:])]
            else:
                groups = [DeviceGroup("all", devs)]
            out = serve_requests(
                cfg, groups=groups, n_requests=args.serve_requests,
                rate_rps=args.request_rate, prompt_len=args.prompt_len,
                gen=args.gen, seed=args.serve_seed, batcher_config=bcfg,
                guard=args.guard, observer=observer)
            s = out["summary"]
        replayed = (f"  {s['replayed']} replayed"
                    if s.get("replayed") else "")
        log.info(f"serve: {s['completed']}/{s['requests']} completed  "
                 f"{s['shed']} shed {s['shed_reasons']}  "
                 f"{s['retries']} retries{replayed}  "
                 f"e2e p99 {s.get('e2e_p99', float('nan')):.4f}s")
        if observer is not None:
            if args.trace_out:
                path = observer.save_trace(args.trace_out)
                log.info(f"trace: {path} ({len(observer.tracer)} events)")
            if args.journal_out:
                # close the stream first; save() rewrites the identical
                # bytes (plus anything the sink never saw on a non-crash
                # path — there is none with flush_every=1)
                if journal_sink is not None:
                    journal_sink.close()
                    observer.journal.sink = None
                path = observer.save_journal(args.journal_out)
                log.info(f"journal: {path} "
                         f"({len(observer.journal)} events)")
            if args.metrics_out:
                observer.write_summary(args.metrics_out,
                                       extra={"serve": s})
                log.info(f"metrics: {args.metrics_out}")
        return
    if args.stream:
        clock = injector = observer = None
        if args.fault_plan:
            if args.tune_split:
                ap.error("--fault-plan is a simulated drill; it cannot "
                         "combine with --tune-split")
            from ..runtime.simulate import (FakeDevice, FaultInjector,
                                            VirtualClock,
                                            make_serial_sim_builder,
                                            parse_fault_plan)
            # the drill runs against simulated serial groups on a
            # virtual clock: no model, no compile, and every timestamp
            # in the trace/journal is a deterministic simulated instant
            clock = VirtualClock()
            devs = [FakeDevice()
                    for _ in range(min(args.sim_devices,
                                       max(args.batch, 1)))]
        else:
            # the scheduler needs >= 1 request row per device: on small
            # --batch runs use only as many devices as there are rows
            devs = jax.devices()[:max(args.batch, 1)]
        if 0 < args.slow < len(devs):
            groups = [DeviceGroup("fast", devs[:-args.slow]),
                      DeviceGroup("slow", devs[-args.slow:])]
        else:
            groups = [DeviceGroup("all", devs)]
        if args.trace_out or args.journal_out or args.metrics_out:
            observer = Observer(clock=clock)
            # mirror every narrated line into the decision journal, so
            # the narration and the decisions land on one sequence
            configure(journal=observer.journal)
        initial_shares = None
        if args.fault_plan:
            injector = FaultInjector(parse_fault_plan(args.fault_plan),
                                     groups)
            builder = make_serial_sim_builder(1e-3, clock=clock,
                                              injector=injector)
        else:
            # one memoized builder: the split tuner and the serving
            # pipeline share per-group params init + jitted
            # prefill/decode
            builder = _memoize_per_group(_stream_step_builder(
                build_model(cfg), prompt_len=args.prompt_len, gen=args.gen,
                seed=0))
        if args.tune_split:
            if len(groups) != 2:
                ap.error("--tune-split needs two groups (pass --slow N)")
            initial_shares, tuned = tune_stream_split(
                cfg, groups=groups, batch=args.batch,
                prompt_len=args.prompt_len, gen=args.gen,
                strategy=args.tune_strategy, store=args.tune_store,
                step_builder=builder)
            log.info(f"tuned split: {initial_shares.round(2)} "
                     f"({tuned.strategy}, {tuned.n_experiments} measurements"
                     f"{', cached' if tuned.from_cache else ''})")
        guard = None
        if args.guard:
            from ..runtime import KillSwitch, ServeGuard
            # last known-good fallback: the tuned split when we have one
            # (tuner-measured, the strongest prior); otherwise the guard
            # snapshots the best online split it observes
            guard = ServeGuard(
                None, switch=KillSwitch(threshold=args.guard_threshold,
                                        patience=args.guard_patience),
                fallback=initial_shares)
        out = serve_stream(cfg, groups=groups, n_batches=args.stream_batches,
                           batch=args.batch, prompt_len=args.prompt_len,
                           gen=args.gen, initial_shares=initial_shares,
                           step_builder=builder, guard=guard,
                           observer=observer, clock=clock,
                           injector=injector)
        s = out["summary"]
        guarded = f"  guard trips {s['guard_trips']}" if args.guard else ""
        log.info(f"stream: {s['batches']} batches  "
                 f"{s['tokens_per_s_mean']:.1f} tok/s  "
                 f"shares {s['shares_final']}{guarded}")
        if observer is not None:
            if args.trace_out:
                path = observer.save_trace(args.trace_out)
                log.info(f"trace: {path} ({len(observer.tracer)} events)")
            if args.journal_out:
                path = observer.save_journal(args.journal_out)
                log.info(f"journal: {path} "
                         f"({len(observer.journal)} events)")
            if args.metrics_out:
                observer.write_summary(args.metrics_out,
                                       extra={"stream": s})
                log.info(f"metrics: {args.metrics_out}")
        return
    out = serve_session(cfg, batch=args.batch, prompt_len=args.prompt_len,
                        gen=args.gen)
    log.info(f"prefill {out['prefill_s']:.2f}s  "
             f"decode {out['decode_s']:.2f}s  "
             f"{out['tokens_per_s']:.1f} tok/s")
    log.info(f"sample tokens: {out['generated'][0, :12]}")


if __name__ == "__main__":
    main()
