"""The unified tuning result record.

``TuneResult`` supersedes the seed's ``TuneReport`` (``core.autotuner``
keeps ``TuneReport`` as an alias so persisted caches and existing callers
keep working).  One dataclass serves every strategy in the registry and
every objective: the paper's effort accounting (experiments vs
predictions vs one-time training cost) is unchanged, and multi-objective
runs additionally carry the scored metrics of the winning configuration
and — for enumerating strategies under a ``Pareto`` objective — the
non-dominated front.
"""

from __future__ import annotations

from dataclasses import dataclass, field

__all__ = ["TuneResult"]


@dataclass
class TuneResult:
    strategy: str
    best_config: dict
    best_energy_search: float      # score the search itself saw (pred or meas)
    best_energy_measured: float    # ground-truth (noise-free) score
    n_experiments: int             # measurements performed during the search
    n_predictions: int             # surrogate queries during the search
    n_training_experiments: int    # one-time surrogate training measurements
    space_size: int
    # {iteration: (measured score of best-so-far config, config)}
    checkpoints: dict[int, tuple[float, dict]] = field(default_factory=dict)
    # True when the result was served from a persistent tuning cache
    # (repro.runtime.store) — the counters above then describe the effort
    # of the *original* recorded search, and this tune ran 0 experiments.
    from_cache: bool = False
    # key of the objective the search minimised ("time" is the paper's
    # E = max(T_host, T_device))
    objective: str = "time"
    # ground-truth metric columns of the winning config (e.g. {"time": ...,
    # "energy": ...}) when the evaluator exposes them
    best_metrics: dict = field(default_factory=dict)
    # [[component scores...], config] rows of the non-dominated set, filled
    # by enumerating strategies under a Pareto objective
    pareto_front: list = field(default_factory=list)
    # deduplicated *real executions* behind the search: ``n_experiments``
    # counts oracle calls (repeats of a config served from the oracle's
    # memo included), ``n_measured`` counts distinct configs actually
    # timed on hardware when the oracle exposes that accounting (e.g.
    # ``KernelTimer``); equal to ``n_experiments`` otherwise.  This is
    # the numerator of the paper's ~5%-of-space budget claim.
    n_measured: int = 0

    # ``best_score_*`` are the objective-neutral names for new-API callers;
    # the stored field names keep the paper's "energy" wording (and the
    # on-disk cache format) stable.
    @property
    def best_score_search(self) -> float:
        return self.best_energy_search

    @property
    def best_score_measured(self) -> float:
        return self.best_energy_measured

    @property
    def experiments_fraction(self) -> float:
        """Search experiments as a fraction of the enumeration count.

        A degenerate/empty space (``space_size <= 0`` — e.g. a manually
        constructed or deserialized result) yields 0.0 rather than a
        division error or a nonsensical ratio.
        """
        if self.space_size <= 0:
            return 0.0
        return self.n_experiments / self.space_size
