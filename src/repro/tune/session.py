"""``TuningSession`` — one entry point for every tuning scenario.

The paper's loop (combinatorial search + ML evaluation) used to be
implemented four times with four incompatible surfaces (``Autotuner``,
``HeterogeneousRunner.tune_fraction_sa``, ``ShardingTuner``, the online
feedback loop).  A session binds the decoupled pieces once —

    session = TuningSession(
        space=paper_space(),
        evaluator=platform.evaluator(gb),      # cfg -> metrics record
        objective=Weighted(Time(), Energy(), scales=(1.0, 300.0)),
        surrogate=pair,                        # enables eml / saml
        budget=1000,                           # default iterations/samples
        store="tune_cache.json",               # persistent result cache
        online=loop,                           # live-observation feedback
    )
    result = session.run("saml", engine="vectorized")

— and ``run(strategy)`` dispatches through the strategy registry
(``repro.tune.strategy``), returning a unified :class:`TuneResult`.

Wiring notes:

  * ``evaluator`` accepts a plain scalar oracle (the seed shape,
    ``cfg -> seconds``), a metrics oracle (``cfg -> {"time": ...,
    "energy": ...}``) or a :class:`~repro.tune.objective.MetricsEvaluator`;
    ``evaluator_batch`` is the optional column-oriented fast path.
  * ``surrogate`` is a ``SurrogatePair`` (scored through the objective's
    surrogate hooks) or any plain ``cfg -> score`` callable (scored
    verbatim — e.g. the sharding tuner's single fitted BDTR).
  * ``store`` caches results keyed by (space, workload, strategy,
    objective); a hit returns with zero new measurements.
  * ``warm_start`` seeds local-search strategies with a configuration
    (or a previous ``TuneResult``'s best config).
  * ``online`` hooks an ``OnlineSurrogateLoop``: pending live
    observations are folded in (``refit``) before the search, and every
    measurement taken during the search whose metrics carry per-side
    times (``t_host`` / ``t_device``) is observed back into the loop.
  * ``ledger`` hooks a :class:`~repro.runtime.checkpoint.MeasurementLedger`:
    every real measurement is appended to its write-ahead log before the
    search proceeds, so a crash mid-tune loses nothing — rerunning the
    same seeded session replays the measured prefix from the ledger
    (zero re-measurement) and only spends budget on the tail.
"""

from __future__ import annotations

from typing import Any, Callable, Mapping

import numpy as np

from ..core.space import ConfigSpace
from .objective import MetricsEvaluator, Objective, Time, as_metrics_evaluator
from .result import TuneResult
from .strategy import SearchContext, StrategyOutcome, get_strategy

__all__ = ["TuningSession"]


class TuningSession:
    """Builder binding space x evaluator x objective x strategy options."""

    def __init__(
        self,
        space: ConfigSpace,
        *,
        evaluator: Any = None,
        evaluator_batch: Any = None,
        objective: Objective | None = None,
        strategy: str | None = None,
        surrogate: Any = None,
        n_training_experiments: int = 0,
        budget: int | None = None,
        store: Any = None,
        warm_start: Any = None,
        workload: Mapping[str, Any] | None = None,
        online: Any = None,
        truth: Callable[[Mapping[str, Any]], Any] | None = None,
        seed: int | None = None,
        observer: Any = None,
        ledger: Any = None,
    ):
        self.space = space
        self.ledger = ledger
        if ledger is not None and evaluator is not None:
            # Wrap the raw scalar/metrics oracle before MetricsEvaluator
            # normalization so ledger hits and misses share one shape.
            evaluator = ledger.wrap(evaluator)
        self.evaluator = as_metrics_evaluator(evaluator, evaluator_batch)
        self.objective = objective if objective is not None else Time()
        self.strategy = strategy
        self.online = online
        if surrogate is None and online is not None:
            surrogate = online.surrogate
        self.surrogate = surrogate
        self.n_training_experiments = n_training_experiments
        self.budget = budget
        self.store = self._as_store(store)
        self.workload = workload
        self.truth = truth
        self.seed = seed
        if warm_start is not None and hasattr(warm_start, "best_config"):
            warm_start = warm_start.best_config
        if warm_start is not None:
            space.validate(warm_start)
            warm_start = dict(warm_start)
        self.warm_start = warm_start
        from ..obs import as_observer
        self._obs = as_observer(observer)

    @staticmethod
    def _as_store(store):
        if store is None or hasattr(store, "lookup"):
            return store
        # deferred import: tune must stay importable without runtime
        from ..runtime.store import TuningStore
        return TuningStore(store)

    # -- oracle composition --------------------------------------------------
    def _measure(self) -> Callable | None:
        """cfg -> objective score of one real measurement (+ online feed)."""
        ev = self.evaluator
        if ev is None:
            return None
        objective, online = self.objective, self.online

        def scored(cfg):
            m = ev.metrics(cfg)
            if online is not None:
                th, td = m.get("t_host"), m.get("t_device")
                if th is not None or td is not None:
                    # a zero per-side time is the E = max(...) collapse
                    # (that side did no work), not a measurement
                    online.observe(cfg, th or None, td or None,
                                   auto_refit=False)
            return float(objective(m))
        return scored

    def _observe_batch(self, columns, metrics) -> None:
        """Feed a column batch of measurements into the online loop."""
        th = metrics.get("t_host")
        td = metrics.get("t_device")
        if th is None and td is None:
            return
        names = list(columns)
        n = len(next(iter(metrics.values())))
        for i in range(n):
            cfg = {k: columns[k][i] for k in names}
            h = float(th[i]) if th is not None else 0.0
            d = float(td[i]) if td is not None else 0.0
            # a zero per-side time is the E = max(...) collapse
            self.online.observe(cfg, h or None, d or None, auto_refit=False)

    def _metrics_batch(self) -> Callable | None:
        """Column batch -> metric columns, observing into the online loop."""
        ev = self.evaluator
        if ev is None or not ev.has_batch:
            return None
        if self.online is None:
            return ev.metrics_batch

        def observed(columns):
            m = ev.metrics_batch(columns)
            self._observe_batch(columns, m)
            return m
        return observed

    def _measure_batch(self) -> Callable | None:
        metrics_batch = self._metrics_batch()
        if metrics_batch is None:
            return None
        objective = self.objective

        def scored(columns):
            return np.asarray(objective.batch(metrics_batch(columns)),
                              dtype=np.float64)
        return scored

    def _surrogate_oracles(self):
        """(predict, predict_batch, predict_jax_builder) for the context."""
        sur = self.surrogate
        if sur is None:
            return None, None, None
        if callable(sur) and not hasattr(sur, "predict_energy"):
            # a plain cfg -> score predictor (already objective-scored)
            return sur, None, None
        obj = self.objective
        try:
            predict = obj.surrogate_scalar(sur)
        except NotImplementedError:
            # the objective cannot score pair predictions (e.g. Energy):
            # surrogate strategies will raise their canonical "needs a
            # surrogate" error; measurement strategies are unaffected
            return None, None, None
        try:
            predict_batch = obj.surrogate_batch(sur)
        except NotImplementedError:
            predict_batch = None
        try:
            jax_builder = (obj.surrogate_jax_builder(sur)
                           if sur.energy_fn_jax_builder is not None else None)
        except NotImplementedError:
            jax_builder = None
        return predict, predict_batch, jax_builder

    def _truth_metrics(self, cfg) -> tuple[float, dict]:
        """(ground-truth score, metrics record) of one configuration.

        Falls back evaluator -> surrogate when no explicit ``truth`` is
        given, mirroring the legacy ``truth = truth or measure`` default.
        """
        if self.truth is not None:
            out = self.truth(cfg)
            if isinstance(out, Mapping):
                m = {str(k): float(v) for k, v in out.items()}
                return float(self.objective(m)), m
            return float(out), {}
        if self.evaluator is not None:
            m = self.evaluator.metrics(cfg)
            return float(self.objective(m)), m
        predict, _, _ = self._surrogate_oracles()
        if predict is not None:
            return float(predict(cfg)), {}
        raise ValueError("session has neither evaluator, truth nor "
                         "surrogate to score the winning config")

    def _context(self) -> SearchContext:
        predict, predict_batch, jax_builder = self._surrogate_oracles()
        metrics_batch = self._metrics_batch()
        return SearchContext(
            space=self.space,
            measure=self._measure(),
            measure_batch=self._measure_batch(),
            predict=predict,
            predict_batch=predict_batch,
            predict_jax_builder=jax_builder,
            metrics_batch=metrics_batch,
            objective=self.objective,
            warm_start=self.warm_start,
            budget=self.budget,
        )

    # -- the run -------------------------------------------------------------
    def _store_key(self, strategy: str) -> str:
        key = strategy.upper()
        if self.objective.key != "time":
            key += "|" + self.objective.key
        return key

    def run(self, strategy: str | None = None, **opts) -> TuneResult:
        """Search and return the unified result.

        ``strategy`` defaults to the one given at construction; ``opts``
        are forwarded to the registered strategy function (``iterations=``,
        ``seed=``, ``engine=``, ``checkpoints=``, ...).
        """
        name = (strategy or self.strategy or "").lower()
        if not name:
            raise ValueError("no strategy: pass run('sam') or "
                             "TuningSession(strategy='sam')")
        info = get_strategy(name)
        if self._obs is not None:
            self._obs.journal.event("tuning_start", strategy=name,
                                    objective=self.objective.key,
                                    space_size=self.space.size())
        if self.store is not None:
            hit = self.store.lookup(self.space, self.workload,
                                    self._store_key(name))
            if self._obs is not None:
                self._obs.metrics.counter(
                    "tune.store_hits" if hit is not None
                    else "tune.store_misses").inc()
                self._obs.journal.event(
                    "store_hit" if hit is not None else "store_miss",
                    strategy=name, key=self._store_key(name))
            if hit is not None:
                if self._obs is not None:
                    self._obs.journal.event(
                        "tuning_stop", strategy=name, from_cache=True,
                        n_experiments=hit.n_experiments,
                        n_measured=hit.n_measured,
                        space_size=hit.space_size)
                return hit
        if self.online is not None:
            # fold pending live observations into the surrogate first, so
            # the search starts from live data (respects refit_every)
            self.online.refit()
        if self.seed is not None:
            opts.setdefault("seed", self.seed)
        if self._obs is not None:
            with self._obs.tracer.span(f"tune.{name}",
                                       args={"objective":
                                             self.objective.key}):
                outcome = info.fn(self._context(), **opts)
        else:
            outcome = info.fn(self._context(), **opts)
        result = self._finalize(name, info, outcome)
        if self.store is not None:
            self.store.record(self.space, self.workload,
                              self._store_key(name), result)
        if self._obs is not None:
            # the paper's effort accounting in one event: how many real
            # measurements bought the winner, out of how large a space
            self._obs.journal.event(
                "tuning_stop", strategy=name, from_cache=False,
                n_experiments=result.n_experiments,
                n_predictions=result.n_predictions,
                n_measured=result.n_measured,
                space_size=result.space_size,
                experiments_fraction=round(result.experiments_fraction, 6),
                best_score=round(result.best_energy_measured, 9))
        return result

    def _finalize(self, name: str, info, outcome: StrategyOutcome
                  ) -> TuneResult:
        # For fair comparison the paper evaluates suggested configs with
        # *measured* values (Sec. IV-C) — re-score checkpoints, then the
        # winner, with ground truth (same call order as the legacy report).
        measured_cp = {
            it: (self._truth_metrics(c)[0], dict(c))
            for it, (_, c) in outcome.checkpoints.items()
        }
        best_measured, best_metrics = self._truth_metrics(outcome.best_config)
        # deduplicated real-execution count, when the oracle keeps it
        # (KernelTimer does); oracle calls otherwise
        raw = getattr(self.evaluator, "raw", None)
        n_measured = getattr(raw, "n_measured", None)
        if n_measured is None:
            n_measured = outcome.n_experiments
        return TuneResult(
            strategy=name.upper(),
            best_config=dict(outcome.best_config),
            best_energy_search=float(outcome.best_score),
            best_energy_measured=best_measured,
            n_experiments=outcome.n_experiments,
            n_predictions=outcome.n_predictions,
            n_training_experiments=(self.n_training_experiments
                                    if info.uses_surrogate else 0),
            space_size=self.space.size(),
            checkpoints=measured_cp,
            objective=self.objective.key,
            best_metrics=best_metrics,
            pareto_front=outcome.pareto_front,
            n_measured=int(n_measured),
        )
