"""Declarative, composable tuning objectives.

The paper minimises one scalar — E = max(T_host, T_device) (Eq. 2).  The
follow-up work (Memeti & Pllana, arXiv:2106.01441) extends the identical
search framework to energy-aware multi-objective tuning; this module is
that decoupling: an :class:`Objective` maps a **metrics record** (one
measured/simulated row, e.g. ``{"time": 1.84, "energy": 512.0}``) to the
scalar score the search minimises, and combinators build compound
objectives out of atomic ones.

  * :class:`Time`    — ``metrics["time"]``; the paper's objective.
  * :class:`Energy`  — ``metrics["energy"]`` (joules); the platform model
    provides the column (``EmilPlatformModel.metrics``).
  * :class:`Weighted` — normalised weighted sum of sub-objectives.
  * :class:`Pareto`  — Chebyshev scalarisation (max of normalised
    components) for the search loop, plus non-dominated-front extraction
    for enumerating strategies.

Objectives score *measurements* generically; scoring a **surrogate**
requires the objective to know how predictions compose (the paper's
``SurrogatePair`` predicts per-side times, so only time-like objectives
have a surrogate form).  ``Time`` implements the surrogate hooks; other
objectives raise with a pointer at the measurement-based strategies.

``MetricsEvaluator`` is the evaluator half of the contract: it adapts
whatever the caller has — a scalar oracle, a metrics-dict oracle, a
batched column oracle — into the uniform interface the strategies
consume.
"""

from __future__ import annotations

from typing import Any, Callable, Mapping, Sequence

import numpy as np

__all__ = ["Objective", "Time", "Energy", "Metric", "Weighted", "Pareto",
           "MetricsEvaluator", "as_metrics_evaluator", "pareto_front"]


class Objective:
    """Maps one metrics record to the scalar score being minimised."""

    #: cache-key / display name; folded into ``TuningStore`` keys so
    #: differently-scored searches never collide.
    key: str = "objective"
    #: metric columns this objective reads.
    requires: tuple[str, ...] = ()

    def __call__(self, metrics: Mapping[str, float]) -> float:
        raise NotImplementedError

    def batch(self, metrics: Mapping[str, np.ndarray]) -> np.ndarray:
        """Vectorised score over column-oriented metric arrays.

        The default lifts ``__call__`` over rows; atomic objectives
        override with pure array ops.
        """
        names = list(metrics)
        rows = zip(*(np.asarray(metrics[n]) for n in names))
        return np.asarray([self(dict(zip(names, r))) for r in rows])

    def components(self) -> tuple["Objective", ...]:
        """Atomic sub-objectives (self for atomic objectives)."""
        return (self,)

    # -- surrogate forms ----------------------------------------------------
    def _no_surrogate(self) -> "NotImplementedError":
        return NotImplementedError(
            f"objective {self.key!r} has no surrogate form; use a "
            "measurement-based strategy (em / sam / random / hillclimb) or "
            "an objective that can score predictions (Time)")

    def surrogate_scalar(self, pair) -> Callable[[Mapping[str, Any]], float]:
        """cfg -> predicted score, from a ``SurrogatePair``."""
        raise self._no_surrogate()

    def surrogate_batch(self, pair) -> Callable[[Mapping[str, np.ndarray]],
                                                np.ndarray]:
        """column batch -> predicted scores, from a ``SurrogatePair``."""
        raise self._no_surrogate()

    def surrogate_jax_builder(self, pair):
        """space -> jitted feature-matrix score fn (vectorized SA engine)."""
        raise self._no_surrogate()

    def __repr__(self) -> str:
        return f"{type(self).__name__}({self.key!r})"


class Metric(Objective):
    """Minimise one named metric column verbatim."""

    def __init__(self, name: str):
        self.key = name
        self.requires = (name,)
        self._name = name

    def __call__(self, metrics: Mapping[str, float]) -> float:
        return float(metrics[self._name])

    def batch(self, metrics: Mapping[str, np.ndarray]) -> np.ndarray:
        return np.asarray(metrics[self._name], dtype=np.float64)


class Time(Metric):
    """The paper's objective: execution time E = max(T_host, T_device)."""

    def __init__(self):
        super().__init__("time")

    # The SurrogatePair predicts per-side times, so Time is exactly the
    # pair's own energy composition — these delegate to the proven paths.
    def surrogate_scalar(self, pair):
        return pair.predict_energy

    def surrogate_batch(self, pair):
        return pair.predict_energy_batch

    def surrogate_jax_builder(self, pair):
        if pair.energy_fn_jax_builder is None:
            raise ValueError(
                "vectorized search needs a surrogate with an "
                "energy_fn_jax_builder (see fit_emil_surrogates)")
        return pair.energy_fn_jax_builder


class Energy(Metric):
    """Energy-to-solution in joules (``metrics['energy']``)."""

    def __init__(self):
        super().__init__("energy")


def _as_pairs(objectives, weights) -> list[tuple[Objective, float]]:
    objectives = tuple(objectives)
    if weights is None:
        weights = (1.0,) * len(objectives)
    if len(weights) != len(objectives):
        raise ValueError("need one weight per objective")
    return [(o, float(w)) for o, w in zip(objectives, weights)]


class Weighted(Objective):
    """Weighted sum of sub-objectives: ``sum(w_i * o_i(m) / scale_i)``.

    ``scales`` normalises components with different units (seconds vs
    joules) onto comparable magnitudes; defaults to 1.0 each.

        Weighted(Time(), Energy(), weights=(1.0, 0.5), scales=(1.0, 300.0))
    """

    def __init__(self, *objectives: Objective,
                 weights: Sequence[float] | None = None,
                 scales: Sequence[float] | None = None):
        if not objectives:
            raise ValueError("Weighted needs at least one objective")
        self._parts = _as_pairs(objectives, weights)
        scales = scales if scales is not None else (1.0,) * len(objectives)
        if len(scales) != len(objectives):
            raise ValueError("need one scale per objective")
        self._scales = [float(s) for s in scales]
        if any(s <= 0 for s in self._scales):
            raise ValueError("scales must be positive")
        self.requires = tuple(dict.fromkeys(
            k for o, _ in self._parts for k in o.requires))
        self.key = "weighted(" + ",".join(
            f"{o.key}*{w:g}" for o, w in self._parts) + ")"

    def components(self) -> tuple[Objective, ...]:
        return tuple(o for o, _ in self._parts)

    def __call__(self, metrics: Mapping[str, float]) -> float:
        return float(sum(w * o(metrics) / s for (o, w), s in
                         zip(self._parts, self._scales)))

    def batch(self, metrics: Mapping[str, np.ndarray]) -> np.ndarray:
        out = 0.0
        for (o, w), s in zip(self._parts, self._scales):
            out = out + (w / s) * o.batch(metrics)
        return np.asarray(out, dtype=np.float64)


class Pareto(Objective):
    """Multi-objective front.  Searches minimise the Chebyshev
    scalarisation ``max_i(w_i * o_i(m) / scale_i)``; enumerating
    strategies (em / eml batched) additionally report the non-dominated
    set of the whole space in ``TuneResult.pareto_front``.
    """

    def __init__(self, *objectives: Objective,
                 weights: Sequence[float] | None = None,
                 scales: Sequence[float] | None = None):
        if len(objectives) < 2:
            raise ValueError("Pareto needs at least two objectives")
        self._parts = _as_pairs(objectives, weights)
        scales = scales if scales is not None else (1.0,) * len(objectives)
        self._scales = [float(s) for s in scales]
        if any(s <= 0 for s in self._scales):
            raise ValueError("scales must be positive")
        self.requires = tuple(dict.fromkeys(
            k for o, _ in self._parts for k in o.requires))
        self.key = "pareto(" + ",".join(o.key for o, _ in self._parts) + ")"

    def components(self) -> tuple[Objective, ...]:
        return tuple(o for o, _ in self._parts)

    def __call__(self, metrics: Mapping[str, float]) -> float:
        return float(max(w * o(metrics) / s for (o, w), s in
                         zip(self._parts, self._scales)))

    def batch(self, metrics: Mapping[str, np.ndarray]) -> np.ndarray:
        cols = [(w / s) * o.batch(metrics) for (o, w), s in
                zip(self._parts, self._scales)]
        return np.max(np.stack(cols), axis=0)

    def component_batch(self, metrics: Mapping[str, np.ndarray]
                        ) -> np.ndarray:
        """Raw (unweighted) component columns, shape (n, n_objectives)."""
        return np.stack([o.batch(metrics) for o, _ in self._parts], axis=1)


def pareto_front(points: np.ndarray) -> np.ndarray:
    """Indices of the non-dominated rows of ``points`` (minimisation).

    A row dominates another when it is <= everywhere and < somewhere.
    O(n^2) pairwise filter — fronts here come from enumerated spaces of
    at most a few tens of thousands of rows.
    """
    pts = np.asarray(points, dtype=np.float64)
    n = len(pts)
    keep = np.ones(n, dtype=bool)
    for i in range(n):
        if not keep[i]:
            continue
        dominated = (np.all(pts[i] <= pts, axis=1)
                     & np.any(pts[i] < pts, axis=1))
        dominated[i] = False
        keep &= ~dominated
    return np.flatnonzero(keep)


# ---------------------------------------------------------------------------
# The evaluator half: anything -> metrics records.
# ---------------------------------------------------------------------------

class MetricsEvaluator:
    """Adapts a measurement oracle to the metrics-record interface.

    ``scalar`` maps one config to either a plain float (interpreted as
    ``{"time": value}`` — the seed's oracle shape) or a metrics mapping.
    ``batch`` (optional) maps column-oriented config batches to either a
    score array or a mapping of metric columns.
    """

    def __init__(self, scalar: Callable[[Mapping[str, Any]], Any],
                 batch: Callable[[Mapping[str, np.ndarray]], Any] | None
                 = None):
        self._scalar = scalar
        self._batch = batch

    @property
    def has_batch(self) -> bool:
        return self._batch is not None

    @property
    def raw(self):
        """The underlying scalar oracle (e.g. a ``KernelTimer``), so the
        session can read accounting it keeps — ``n_measured`` is the
        deduplicated real-execution count behind the ~5% budget."""
        return self._scalar

    def metrics(self, cfg: Mapping[str, Any]) -> dict[str, float]:
        out = self._scalar(cfg)
        if isinstance(out, Mapping):
            return {str(k): float(v) for k, v in out.items()}
        return {"time": float(out)}

    def metrics_batch(self, columns: Mapping[str, np.ndarray]
                      ) -> dict[str, np.ndarray]:
        if self._batch is None:
            raise ValueError("evaluator has no batch oracle")
        out = self._batch(columns)
        if isinstance(out, Mapping):
            return {str(k): np.asarray(v, dtype=np.float64)
                    for k, v in out.items()}
        return {"time": np.asarray(out, dtype=np.float64)}


def as_metrics_evaluator(obj: Any,
                         batch: Any = None) -> MetricsEvaluator | None:
    """Coerce ``obj`` into a :class:`MetricsEvaluator` (None passes through)."""
    if obj is None and batch is None:
        return None
    if isinstance(obj, MetricsEvaluator):
        return obj
    if obj is None:
        raise ValueError("evaluator_batch given without a scalar evaluator")
    if not callable(obj):
        raise TypeError(f"evaluator must be callable, got {type(obj).__name__}")
    return MetricsEvaluator(obj, batch)
