"""Registry-completeness selfcheck: smoke-tune every registered strategy.

    PYTHONPATH=src python -m repro.tune

Runs each strategy in ``list_strategies()`` end-to-end on a tiny
two-parameter space with a deterministic analytic evaluator (plus a
fitted surrogate pair for the ML strategies) and fails loudly if any
registered strategy cannot complete a search.  CI runs this so a
strategy added to the registry without a working implementation is
caught immediately.
"""

from __future__ import annotations

import sys

import numpy as np

from ..obs import get_logger

log = get_logger("repro.tune")


def selfcheck(verbose: bool = True) -> list[str]:
    """Smoke-tune every registered strategy; returns the checked names."""
    from ..core import (BoostedTreesRegressor, ConfigSpace, Param,
                        SurrogatePair)
    from . import Time, TuningSession, get_strategy, list_strategies

    space = ConfigSpace([
        Param("threads", (1, 2, 4, 8)),
        Param("host_fraction", tuple(range(0, 101, 10))),
    ])

    def truth(cfg):
        f = cfg["host_fraction"] / 100.0
        return f * 8.0 / cfg["threads"] + (1.0 - f) * 1.2

    def feats(cfg):
        return np.asarray([float(cfg["threads"]),
                           float(cfg["host_fraction"])])

    grid = space.index_grid()
    cols = space.enumerate_columns(grid)
    X = np.column_stack([np.asarray(cols["threads"], float),
                         np.asarray(cols["host_fraction"], float)])
    f = X[:, 1] / 100.0
    yh = f * 8.0 / X[:, 0]
    yd = (1.0 - f) * 1.2
    pair = SurrogatePair(
        host=BoostedTreesRegressor(n_estimators=20, max_depth=3,
                                   tree_method="hist").fit(X, yh),
        device=BoostedTreesRegressor(n_estimators=20, max_depth=3,
                                     tree_method="hist").fit(X, yd),
        host_features=feats, device_features=feats)

    session = TuningSession(
        space, evaluator=truth, objective=Time(), surrogate=pair,
        budget=60, seed=0)
    checked = []
    for name in list_strategies():
        opts = {}
        if get_strategy(name).uses_surrogate and name == "saml":
            opts["engine"] = "scalar"
        result = session.run(name, **opts)
        assert result.strategy == name.upper(), result
        assert set(result.best_config) == set(space.names), result
        assert np.isfinite(result.best_energy_measured), result
        assert (result.n_experiments + result.n_predictions) > 0, result
        assert result.space_size == space.size(), result
        assert 0 <= result.n_measured <= max(result.n_experiments, 1), result
        if verbose:
            # the paper's effort accounting: measured configs as a
            # fraction of the enumeration count (~5% in Sec. IV-C)
            log.info(
                f"[selfcheck] {name:<10s} best={result.best_config} "
                f"score={result.best_energy_measured:.4f} "
                f"(exp={result.n_experiments} pred={result.n_predictions} "
                f"measured={result.n_measured}/{result.space_size} "
                f"= {100 * result.experiments_fraction:.1f}%)")
        checked.append(name)
    return checked


def main() -> int:
    names = selfcheck()
    if len(names) < 6:
        print(f"[selfcheck] FAIL: only {len(names)} strategies registered "
              f"({names}); expected >= 6", file=sys.stderr)
        return 1
    log.info(f"[selfcheck] OK: {len(names)} strategies "
             f"({', '.join(names)})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
