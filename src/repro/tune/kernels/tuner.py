"""``tune_kernel`` — the paper's loop applied to kernel launch parameters.

For surrogate strategies (``saml`` — the default — and ``eml``) the flow
mirrors the paper end to end:

  1. measure a small seeded training sample of *valid* configs (the
     hardcoded default plus random valid draws; the sample is sized to
     keep total measurements within ``budget_fraction`` — 5% — of the
     space, matching the headline result);
  2. fit a BDTR surrogate on (encoded config -> seconds);
  3. hand the surrogate to a :class:`~repro.tune.session.TuningSession`
     and search with the requested registry strategy (predictions are
     free; invalid configs predict ``inf`` so the search cannot leave
     the launchable region);
  4. the session re-measures the winner with ground truth (free when the
     winner was in the training sample — measurements deduplicate).

Measurement-only strategies (``sam``/``random``/``hillclimb``/``em``)
skip 1–2 and drive the timer directly.  Results persist through the
session's :class:`~repro.runtime.store.TuningStore` keyed by (kernel,
shape signature, dtype, device topology): repeating a tune of the same
workload — or resolving it through a kernel's ``tuned=`` path — performs
zero new measurements.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Mapping

import numpy as np

from ...core.bdtr import BoostedTreesRegressor
from ..session import TuningSession
from ..strategy import get_strategy
from .evaluate import KernelTimer
from .registry import get_kernel, kernel_workload

__all__ = ["KernelTuneOutcome", "tune_kernel"]


@dataclass
class KernelTuneOutcome:
    """A tuned kernel: the session result plus measurement accounting."""

    kernel: str
    shape: dict
    dtype: str
    result: Any                   # TuneResult (from_cache=True on a hit)
    default_config: dict
    space_size: int
    n_measured: int               # actual kernel executions this tune
    timer: KernelTimer            # reusable oracle (measurements dedup)

    @property
    def best_config(self) -> dict:
        return self.result.best_config

    @property
    def measured_fraction(self) -> float:
        return self.n_measured / self.space_size if self.space_size else 0.0

    def default_time(self) -> float:
        """Seconds at the hardcoded defaults (measures once, then cached)."""
        return self.timer(self.default_config)

    def best_time(self) -> float:
        return float(self.result.best_energy_measured)


def _axis_corner(space, spec, meta, base, pick):
    """Greedily move each ordinal parameter to the ``pick``-most valid
    candidate (holding the rest) — the standard design-of-experiments
    anchors that give the surrogate the slope of every axis."""
    cfg = dict(base)
    for p in space.params:
        if not p.ordinal:
            continue
        for v in sorted(p.values, reverse=(pick == "max")):
            cand = dict(cfg, **{p.name: v})
            if spec.validate(cand, meta) is None:
                cfg = cand
                break
    return cfg


def _training_sample(space, spec, meta, default_cfg, n_train, seed):
    """Seeded design: default + per-axis extreme corners + random valid
    draws, deduplicated (an experiment is never measured twice)."""
    rng = np.random.default_rng(seed)
    anchors = [default_cfg,
               _axis_corner(space, spec, meta, default_cfg, "max"),
               _axis_corner(space, spec, meta, default_cfg, "min")]
    cfgs, seen = [], set()
    for cand in anchors:
        key = tuple(sorted(cand.items()))
        if key not in seen and spec.validate(cand, meta) is None:
            seen.add(key)
            cfgs.append(cand)
    attempts = 0
    while len(cfgs) < n_train and attempts < 200 * n_train:
        attempts += 1
        cand = space.random(rng)
        key = tuple(sorted(cand.items()))
        if key in seen or spec.validate(cand, meta) is not None:
            continue
        seen.add(key)
        cfgs.append(cand)
    return cfgs[:n_train]


def tune_kernel(name: str, shape: Mapping[str, Any] | None = None, *,
                dtype: Any = None, strategy: str = "saml",
                store: Any = None, iterations: int = 300, seed: int = 0,
                n_train: int | None = None, budget_fraction: float = 0.05,
                repeats: int = 3, interpret: bool | None = None,
                smoke: bool = False, observer: Any = None,
                **opts) -> KernelTuneOutcome:
    """Tune one kernel's launch parameters for one (shape, dtype).

    ``shape`` overrides entries of the spec's default (or, with
    ``smoke=True``, CI-sized) shape.  ``store`` (a ``TuningStore`` or a
    path) makes the result persistent — a repeated tune is a cache hit
    with zero measurements.  Any registered session strategy works;
    surrogate strategies train on at most ``budget_fraction`` of the
    space.  Extra ``opts`` go to the strategy (``engine=``, ...).
    """
    spec = get_kernel(name)
    if dtype is None:
        dtype = spec.dtype          # match the ops layer's resolution key
    meta = dict(spec.smoke_shape if smoke else spec.default_shape,
                **(shape or {}))
    space = spec.space(meta)
    timer = KernelTimer(spec, meta, dtype, interpret=interpret,
                        repeats=repeats, seed=seed, observer=observer)
    workload = kernel_workload(name, meta, dtype)
    default_cfg = spec.default_config(space, meta)
    tstore = TuningSession._as_store(store)
    info = get_strategy(strategy)

    surrogate = None
    n_train_used = 0
    warm = dict(default_cfg)
    cached = (tstore.lookup(space, workload, strategy.upper())
              if tstore is not None else None)
    if cached is None and info.uses_surrogate:
        if n_train is None:
            n_train = max(4, int(budget_fraction * space.size()) - 1)
        cfgs = _training_sample(space, spec, meta, default_cfg, n_train, seed)
        times = np.asarray([timer(c) for c in cfgs])
        ok = np.isfinite(times)
        if ok.sum() < 2:
            raise ValueError(f"kernel {name!r}: too few valid training "
                             f"measurements ({int(ok.sum())}) to fit a "
                             "surrogate; use a measurement strategy")
        X = space.encode_many([c for c, k in zip(cfgs, ok) if k])
        model = BoostedTreesRegressor(
            n_estimators=60, learning_rate=0.1, max_depth=3,
            min_samples_leaf=1, tree_method="hist").fit(X, times[ok])
        n_train_used = timer.n_measured

        def surrogate(cfg):
            # validity is free — keep the search inside the launchable
            # region without spending measurements on invalid configs
            if spec.validate(cfg, meta) is not None:
                return float("inf")
            return float(model.predict(space.encode(cfg)[None, :])[0])

        best_i = int(np.argmin(np.where(ok, times, np.inf)))
        warm = dict(cfgs[best_i])

    session = TuningSession(
        space, evaluator=timer, surrogate=surrogate,
        n_training_experiments=n_train_used, warm_start=warm,
        workload=workload, store=tstore, seed=seed, observer=observer)
    result = session.run(strategy, iterations=iterations, **opts)
    return KernelTuneOutcome(
        kernel=name, shape=dict(meta), dtype=workload["dtype"],
        result=result, default_config=default_cfg,
        space_size=space.size(), n_measured=timer.n_measured, timer=timer)
