"""Timed-execution evaluator with a numerical-parity gate.

The paper evaluates a candidate system configuration by running the
experiment; here an experiment is one jitted kernel launch at a
candidate's launch parameters.  :class:`KernelTimer` is the measurement
oracle a :class:`~repro.tune.session.TuningSession` consumes:

  * **validity first** — configs that cannot launch (non-dividing
    blocks, VMEM overflow, incompatible chunking) score ``inf`` without
    running anything, so the search never crashes on them and they cost
    zero experiments;
  * **parity second** — the candidate's output must match the kernel's
    ``ref.py`` oracle within the spec's tolerance, else ``inf`` (a fast
    config that computes the wrong thing must never win);
  * **then time** — best-of-``repeats`` wall time of the jitted call
    (first call compiles/warms, subsequent calls are timed with
    ``block_until_ready``).

Measurements are deduplicated per config (the paper's effort
accounting: re-measuring a recorded experiment is free), and
``n_measured`` counts actual kernel executions — the number the bench
compares against the space size for the <=5% headline claim.
"""

from __future__ import annotations

import time
from typing import Any, Mapping

import numpy as np

import jax

from .registry import KernelSpec

__all__ = ["KernelTimer", "VMEM_BUDGET_BYTES"]

# Per-core VMEM on current TPUs is ~16 MiB; leave headroom for Mosaic's
# double buffering of in/out blocks (the estimate below already folds a
# 2x pipelining factor in, so the budget is the raw capacity).
VMEM_BUDGET_BYTES = 16 * 1024 * 1024


def _block(out) -> None:
    for leaf in jax.tree.leaves(out):
        blocker = getattr(leaf, "block_until_ready", None)
        if blocker is not None:
            blocker()


class KernelTimer:
    """Measurement oracle: ``cfg -> seconds`` (``inf`` = invalid/diverged).

    One timer holds one (kernel, shape, dtype) worth of inputs and the
    precomputed reference output; every distinct config is measured at
    most once.
    """

    def __init__(self, spec: KernelSpec, meta: Mapping[str, Any], dtype: Any,
                 *, interpret: bool | None = None, repeats: int = 3,
                 seed: int = 0, observer=None):
        self.spec = spec
        self.meta = dict(meta)
        self.dtype = dtype
        if interpret is None:
            interpret = jax.default_backend() == "cpu"
        self.interpret = bool(interpret)
        self.repeats = max(int(repeats), 1)
        self.inputs = spec.make_inputs(self.meta, dtype,
                                       np.random.default_rng(seed))
        self.atol, self.rtol = spec.atol, spec.rtol
        if jax.numpy.dtype(dtype).itemsize < 4:      # bf16/f16/int8 inputs
            self.atol = max(self.atol, 2e-2)
            self.rtol = max(self.rtol, 2e-2)
        self._expected = None
        self._cache: dict[tuple, float] = {}
        self.n_measured = 0          # actual kernel executions (deduplicated)
        self.rejected: dict[tuple, str] = {}   # cfg key -> invalidity reason
        from ...obs import as_observer
        self._obs = as_observer(observer)
        if self._obs is not None:
            m = self._obs.metrics
            self._m_measured = m.counter(f"kernel.{spec.name}.measured")
            self._m_rejected = m.counter(f"kernel.{spec.name}.rejected")
            self._m_cached = m.counter(f"kernel.{spec.name}.cache_hits")
            self._h_time = m.histogram(f"kernel.{spec.name}.t_best_s")

    def _key(self, cfg: Mapping[str, Any]) -> tuple:
        return tuple(sorted((str(k), cfg[k]) for k in cfg))

    @property
    def expected(self):
        if self._expected is None:
            self._expected = self.spec.ref(self.inputs)
        return self._expected

    def _parity_ok(self, out) -> bool:
        got = jax.tree.leaves(out)
        want = jax.tree.leaves(self.expected)
        if len(got) != len(want):
            return False
        for g, w in zip(got, want):
            if not np.allclose(np.asarray(g, np.float32),
                               np.asarray(w, np.float32),
                               atol=self.atol, rtol=self.rtol):
                return False
        return True

    def __call__(self, cfg: Mapping[str, Any]) -> float:
        key = self._key(cfg)
        if key in self._cache:
            if self._obs is not None:
                self._m_cached.inc()
            return self._cache[key]
        reason = self.spec.validate(cfg, self.meta)
        if reason is not None:
            self.rejected[key] = reason
            self._cache[key] = float("inf")
            if self._obs is not None:
                self._m_rejected.inc()
            return float("inf")
        if self._obs is not None:
            with self._obs.tracer.span(f"measure.{self.spec.name}",
                                       cat="tune", args=dict(cfg)):
                score = self._guarded_measure(cfg, key)
        else:
            score = self._guarded_measure(cfg, key)
        self._cache[key] = score
        if self._obs is not None:
            if np.isfinite(score):
                self._m_measured.inc()
                self._h_time.observe(score)
            else:
                self._m_rejected.inc()
        return score

    def _guarded_measure(self, cfg: Mapping[str, Any], key: tuple) -> float:
        try:
            return self._measure(dict(cfg))
        except Exception as exc:            # launch failure = invalid config
            self.rejected[key] = f"launch failed: {type(exc).__name__}"
            return float("inf")

    def _measure(self, cfg: dict) -> float:
        spec, interpret = self.spec, self.interpret
        fn = jax.jit(lambda args: spec.run(cfg, args, interpret))
        out = fn(self.inputs)               # compile + warm
        _block(out)
        if not self._parity_ok(out):
            self.rejected[self._key(cfg)] = "parity vs ref.py failed"
            return float("inf")
        times = []
        for _ in range(self.repeats):
            t0 = time.perf_counter()
            _block(fn(self.inputs))
            times.append(time.perf_counter() - t0)
        self.n_measured += 1
        return float(min(times))
