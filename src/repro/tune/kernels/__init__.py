"""repro.tune.kernels — autotuning for the Pallas kernel suite.

Closes the loop between the paper's tuning stack (``repro.tune``
sessions, BDTR surrogate, ``TuningStore``) and the repo's hottest code:
each kernel's launch parameters (block sizes, chunk lengths, grid
semantics) are a :class:`~repro.core.space.ConfigSpace`, candidates are
evaluated by a timed-execution oracle that gates on numerical parity
against the kernel's ``ref.py`` (invalid configs score ``inf`` instead
of crashing the search), and the session strategies — ``saml`` by
default — keep measured experiments to <=5% of each space.

Three surfaces:

  * :func:`tune_kernel` — search one (kernel, shape, dtype) and persist
    the winner in a ``TuningStore``;
  * :func:`configure` / :func:`resolve_config` — the serving side: once
    a store is configured, every kernel op called with ``tuned=True``
    (or ``tuned=None`` after ``configure(..., enabled=True)``) resolves
    its cached best config at trace time with zero measurements,
    falling back to the hardcoded defaults on a miss;
  * :func:`register_kernel` — add a new kernel's space (see
    ``docs/kernels.md``).

Usage::

    from repro.tune import kernels as ktune

    out = ktune.tune_kernel("flash_attention", store="kernels.json")
    ktune.configure("kernels.json")          # enable the tuned path
    # ... flash_attention(q, k, v) now runs the tuned launch params
"""

from __future__ import annotations

import os
from typing import Any, Mapping

from .evaluate import KernelTimer, VMEM_BUDGET_BYTES
from .registry import (KernelSpec, get_kernel, kernel_workload, list_kernels,
                       register_kernel)
from .tuner import KernelTuneOutcome, tune_kernel
from . import specs as _specs  # noqa: F401  (registers the five kernels)

__all__ = [
    "KernelSpec", "KernelTimer", "KernelTuneOutcome", "VMEM_BUDGET_BYTES",
    "configure", "disable", "get_kernel", "kernel_workload", "list_kernels",
    "register_kernel", "resolve_config", "tune_kernel", "tuning_enabled",
]

# Global tuned-path state: the store serving ``resolve_config`` plus the
# enable flag consulted by ops called with ``tuned=None``.  The resolve
# cache memoizes per (kernel, shape, dtype, backend) so repeated traces
# do not re-read the store.
_state: dict = {"store": None, "enabled": False, "cache": {}}


def configure(store: Any = None, *, enabled: bool = True) -> None:
    """Install the kernel tuning store (path or ``TuningStore``).

    ``enabled=True`` switches every kernel op's default (``tuned=None``)
    to tuned resolution; ``enabled=False`` installs the store for
    explicit ``tuned=True`` calls only.
    """
    if isinstance(store, (str, os.PathLike)):
        from ...runtime.store import TuningStore
        store = TuningStore(store)
    _state.update(store=store, enabled=bool(enabled), cache={})


def disable() -> None:
    """Drop the tuned-path store and flag (ops fall back to defaults)."""
    _state.update(store=None, enabled=False, cache={})


def tuning_enabled() -> bool:
    return bool(_state["enabled"]) and _state["store"] is not None


def resolve_config(kernel: str, meta: Mapping[str, Any], dtype: Any) -> dict:
    """Cached best launch params for (kernel, shape, dtype, backend).

    Pure lookup — zero measurements.  Returns ``{}`` when no store is
    configured, the kernel is unregistered, or the store has no entry
    for this workload signature (the caller keeps its defaults).

    The store key already hashes the space fingerprint (param names,
    domains, ordinality), so editing a kernel's :class:`ConfigSpace` in
    ``specs.py`` invalidates every record tuned against the old space —
    a stale winner can never be served to a redefined kernel.  As a
    second line of defense (hand-edited stores, renamed launch params),
    a resolved config must still be a valid point of the *current*
    space for this shape, else it is dropped and the defaults win.
    """
    store = _state["store"]
    if store is None:
        return {}
    import jax.numpy as jnp

    key = (kernel,
           tuple(sorted((str(k), v) for k, v in meta.items())),
           str(jnp.dtype(dtype)))
    cache = _state["cache"]
    if key not in cache:
        try:
            spec = get_kernel(kernel)
        except ValueError:
            cache[key] = {}
        else:
            space = spec.space(meta)
            rec = store.best_record(space, kernel_workload(kernel, meta,
                                                           dtype))
            cfg = dict(rec.best_config) if rec is not None else {}
            if cfg:
                try:
                    space.validate(cfg)
                    stale = spec.validate(cfg, meta)
                except (KeyError, ValueError):
                    cfg = {}
                else:
                    if stale is not None:
                        cfg = {}
            cache[key] = cfg
    return cache[key]
