"""Launch-parameter spaces for the Pallas kernel suite (fwd and bwd).

Candidate values are shape-independent power-of-two ladders — the same
space structure the paper tunes over (Table I lists raw combinations;
invalid rows are never measured).  Validity is checked per shape:
blocks must divide their extent, chunked passes must nest, and the
per-cell VMEM footprint must fit the ~16 MiB budget (pipelined
input/output blocks count twice for double buffering; scratch is
allocated once).  ``dims`` is the grid-layout variant: whether the
non-carry grid dimensions are declared ``"parallel"`` (Mosaic may
reorder/parallelize) or ``"arbitrary"`` (strict loop nest).

The scan kernels (``mamba_scan``, ``rwkv6_wkv``) expose a ``lanes``
parameter selecting between the serial per-token grid program
(``lanes=0`` — the hardcoded default, so the bench baseline stays the
serial-scan default) and the chunked parallel-scan formulation
(``lanes >= 2`` chunks scanned per grid cell; see each ``kernel.py``).
Their backward passes are registered as separate ``*_bwd`` spaces over
the same shape metas, so the ``tuned=`` path resolves forward and
backward launch parameters independently for one workload family.

Every spec's ``run`` drives the kernel directly with explicit launch
parameters (never through the ``tuned=`` resolution path), and ``ref``
is the kernel's ``ref.py`` oracle (for ``*_bwd`` specs: ``jax.vjp`` of
that oracle with the same cotangents).
"""

from __future__ import annotations

from typing import Any, Mapping

import numpy as np

import jax
import jax.numpy as jnp

from ...core.space import ConfigSpace, Param
from .evaluate import VMEM_BUDGET_BYTES
from .registry import KernelSpec, register_kernel

__all__ = ["BLOCKS", "CHUNKS", "DIMS", "LANES", "SPLITS"]

BLOCKS = (8, 16, 32, 64, 128, 256, 512, 1024)
CHUNKS = (8, 16, 32, 64, 128, 256, 512, 1024)
TEXT_CHUNKS = (256, 512, 1024, 2048, 4096, 8192, 16384, 32768, 65536, 131072)
DIMS = ("parallel", "arbitrary")
LANES = (0, 4, 8, 16)          # 0 = serial grid program (the default)
SPLITS = (1, 2, 4, 8)


def _f32(n: int) -> int:
    return 4 * int(n)


def _divides(extent: int, block: int, name: str) -> str | None:
    if block > extent:
        return f"{name}={block} exceeds extent {extent}"
    if extent % block:
        return f"{name}={block} does not divide {extent}"
    return None


def _vmem(block_bytes: int, scratch_bytes: int = 0) -> str | None:
    """Per-cell VMEM estimate.

    Pipelined input/output blocks are double buffered (2x); scratch
    buffers are allocated once for the whole grid, so counting them
    twice would wrongly reject large-scratch chunked configurations.
    """
    total = 2 * block_bytes + scratch_bytes
    if total > VMEM_BUDGET_BYTES:
        return f"VMEM overflow: ~{total >> 20} MiB per grid cell"
    return None


# -- flash attention ------------------------------------------------------------

def _fa_space(meta: Mapping[str, Any]) -> ConfigSpace:
    return ConfigSpace([
        Param("block_q", BLOCKS),
        Param("block_k", BLOCKS),
        Param("dims", DIMS, ordinal=False),
    ])


def _fa_validate(cfg, meta) -> str | None:
    bq, bk, hd = cfg["block_q"], cfg["block_k"], meta["hd"]
    return (_divides(meta["tq"], bq, "block_q")
            or _divides(meta["tk"], bk, "block_k")
            or _vmem(_f32(2 * bq * hd + 2 * bk * hd + 3 * bq + bq * hd)))


def _fa_inputs(meta, dtype, rng):
    shp = [(meta["bh"], meta["tq"], meta["hd"]),
           (meta["bh"], meta["tk"], meta["hd"])]
    return tuple(jnp.asarray(rng.standard_normal(s), dtype)
                 for s in (shp[0], shp[1], shp[1]))


def _fa_run(cfg, inputs, interpret):
    from ...kernels.flash_attention.kernel import flash_attention_fwd

    q, k, v = inputs
    o, _ = flash_attention_fwd(q, k, v, causal=True,
                               block_q=cfg["block_q"],
                               block_k=cfg["block_k"], dims=cfg["dims"],
                               interpret=interpret)
    return o


def _fa_ref(inputs):
    from ...kernels.flash_attention.ref import attention_ref

    q, k, v = inputs
    return attention_ref(q[:, :, None], k[:, :, None], v[:, :, None],
                         causal=True)[:, :, 0]


register_kernel(KernelSpec(
    name="flash_attention",
    defaults={"block_q": 128, "block_k": 128, "dims": "parallel"},
    space_fn=_fa_space, validate_fn=_fa_validate,
    make_inputs=_fa_inputs, run=_fa_run, ref=_fa_ref,
    default_shape={"bh": 4, "tq": 512, "tk": 512, "hd": 64, "causal": True},
    smoke_shape={"bh": 2, "tq": 128, "tk": 128, "hd": 32, "causal": True},
    atol=2e-4, rtol=2e-4,
))


# -- decode attention -----------------------------------------------------------

def _da_space(meta: Mapping[str, Any]) -> ConfigSpace:
    return ConfigSpace([
        Param("block_s", (64, 128, 256, 512, 1024, 2048, 4096, 8192)),
        Param("splits", SPLITS),
        Param("dims", DIMS, ordinal=False),
    ])


def _da_validate(cfg, meta) -> str | None:
    bs, sp = cfg["block_s"], cfg["splits"]
    hd, rep = meta["hd"], meta["rep"]
    err = _divides(meta["s"], sp, "splits")
    if err:
        return err
    return (_divides(meta["s"] // sp, bs, "block_s")
            or _vmem(_f32(2 * bs * hd + 2 * rep * hd + 2 * rep),
                     _f32(rep * hd + 2 * rep)))


def _da_inputs(meta, dtype, rng):
    b, kv, rep, hd, s = (meta[k] for k in ("b", "kv", "rep", "hd", "s"))
    q = jnp.asarray(rng.standard_normal((b, kv, rep, hd)), dtype)
    k = jnp.asarray(rng.standard_normal((b, s, kv, hd)), dtype)
    v = jnp.asarray(rng.standard_normal((b, s, kv, hd)), dtype)
    return q, k, v, jnp.asarray([s], jnp.int32)


def _da_run(cfg, inputs, interpret):
    from ...kernels.decode_attention.kernel import decode_attention_kernel

    q, k, v, length = inputs
    return decode_attention_kernel(q, k, v, length, block_s=cfg["block_s"],
                                   splits=cfg["splits"], dims=cfg["dims"],
                                   interpret=interpret)


def _da_ref(inputs):
    from ...kernels.decode_attention.ref import decode_attention_ref

    q, k, v, length = inputs
    b, kv, rep, hd = q.shape
    out = decode_attention_ref(q.reshape(b, kv * rep, hd), k, v,
                               length=length[0])
    return out.reshape(b, kv, rep, hd)


register_kernel(KernelSpec(
    name="decode_attention",
    defaults={"block_s": 512, "splits": 1, "dims": "parallel"},
    space_fn=_da_space, validate_fn=_da_validate,
    make_inputs=_da_inputs, run=_da_run, ref=_da_ref,
    default_shape={"b": 2, "kv": 2, "rep": 4, "hd": 64, "s": 4096},
    smoke_shape={"b": 1, "kv": 2, "rep": 4, "hd": 32, "s": 512},
    atol=2e-4, rtol=2e-4,
))


# -- mamba selective scan -------------------------------------------------------

def _ms_space(meta: Mapping[str, Any]) -> ConfigSpace:
    return ConfigSpace([
        Param("block_d", BLOCKS),
        Param("chunk", CHUNKS),
        Param("lanes", LANES),
        Param("unroll", (1, 4)),
        Param("dims", DIMS, ordinal=False),
    ])


def _ms_validate(cfg, meta) -> str | None:
    bd, chunk, lanes = cfg["block_d"], cfg["chunk"], cfg["lanes"]
    t, s = meta["t"], meta["s"]
    err = (_divides(meta["di"], bd, "block_d")
           or _divides(t, chunk, "chunk"))
    if err:
        return err
    if lanes == 0:           # serial grid program
        return _vmem(_f32(3 * chunk * bd + 4 * bd * s + 2 * chunk * s + bd),
                     _f32(bd * s))
    span = chunk * lanes
    if t % span:
        return f"span chunk*lanes={span} does not divide t={t}"
    # the chunked cell stores per-token (P, Hl) scans for every lane
    return _vmem(_f32(3 * span * bd + 4 * bd * s + 2 * span * s + bd),
                 _f32((2 * lanes * chunk + 1) * bd * s))


def _ms_inputs(meta, dtype, rng):
    bt, t, di, s = (meta[k] for k in ("bt", "t", "di", "s"))
    f32 = jnp.float32
    x = jnp.asarray(rng.standard_normal((bt, t, di)), f32)
    delta = jnp.asarray(np.abs(rng.standard_normal((bt, t, di))) * 0.1, f32)
    a = jnp.asarray(-(np.abs(rng.standard_normal((di, s))) + 0.5), f32)
    b = jnp.asarray(rng.standard_normal((bt, t, s)), f32)
    c = jnp.asarray(rng.standard_normal((bt, t, s)), f32)
    d = jnp.asarray(rng.standard_normal(di), f32)
    h0 = jnp.zeros((bt, di, s), f32)
    return x, delta, a, b, c, d, h0


def _ms_run(cfg, inputs, interpret):
    from ...kernels.mamba_scan.kernel import selective_scan_kernel

    return selective_scan_kernel(*inputs, block_d=cfg["block_d"],
                                 chunk=cfg["chunk"], lanes=cfg["lanes"],
                                 unroll=cfg["unroll"], dims=cfg["dims"],
                                 interpret=interpret)


def _ms_ref(inputs):
    from ...kernels.mamba_scan.ref import selective_scan_ref

    return selective_scan_ref(*inputs)


register_kernel(KernelSpec(
    name="mamba_scan",
    defaults={"block_d": 256, "chunk": 64, "lanes": 0, "unroll": 1,
              "dims": "parallel"},
    space_fn=_ms_space, validate_fn=_ms_validate,
    make_inputs=_ms_inputs, run=_ms_run, ref=_ms_ref,
    default_shape={"bt": 2, "t": 512, "di": 512, "s": 8},
    smoke_shape={"bt": 1, "t": 64, "di": 64, "s": 4},
    atol=2e-4, rtol=2e-3,
))


# -- mamba selective scan: backward ---------------------------------------------

def _msb_space(meta: Mapping[str, Any]) -> ConfigSpace:
    return ConfigSpace([
        Param("block_d", BLOCKS),
        Param("chunk", CHUNKS),
        Param("dims", DIMS, ordinal=False),
    ])


def _msb_validate(cfg, meta) -> str | None:
    bd, chunk, s = cfg["block_d"], cfg["chunk"], meta["s"]
    # the reverse cell re-traces the span forward under jax.vjp; the
    # stacked per-token residuals (decay products + states) dominate
    return (_divides(meta["di"], bd, "block_d")
            or _divides(meta["t"], chunk, "chunk")
            or _vmem(_f32(7 * chunk * bd + 6 * chunk * s + 4 * bd * s
                          + 2 * bd),
                     _f32(3 * chunk * bd * s + bd * s)))


def _msb_inputs(meta, dtype, rng):
    inputs = _ms_inputs(meta, dtype, rng)
    bt, t, di, s = (meta[k] for k in ("bt", "t", "di", "s"))
    dy = jnp.asarray(rng.standard_normal((bt, t, di)), jnp.float32)
    dh = jnp.asarray(rng.standard_normal((bt, di, s)), jnp.float32)
    return inputs + (dy, dh)


def _msb_run(cfg, inputs, interpret):
    from ...kernels.mamba_scan.kernel import selective_scan_bwd

    return selective_scan_bwd(*inputs, block_d=cfg["block_d"],
                              chunk=cfg["chunk"], dims=cfg["dims"],
                              interpret=interpret)


def _msb_ref(inputs):
    from ...kernels.mamba_scan.ref import selective_scan_ref

    *primals, dy, dh = inputs
    _, vjp = jax.vjp(lambda *args: selective_scan_ref(*args), *primals)
    return vjp((dy, dh))


register_kernel(KernelSpec(
    name="mamba_scan_bwd",
    defaults={"block_d": 256, "chunk": 64, "dims": "parallel"},
    space_fn=_msb_space, validate_fn=_msb_validate,
    make_inputs=_msb_inputs, run=_msb_run, ref=_msb_ref,
    default_shape={"bt": 2, "t": 512, "di": 512, "s": 8},
    smoke_shape={"bt": 1, "t": 64, "di": 64, "s": 4},
    atol=2e-4, rtol=2e-3,
))


# -- rwkv6 wkv ------------------------------------------------------------------

def _wkv_space(meta: Mapping[str, Any]) -> ConfigSpace:
    return ConfigSpace([
        Param("chunk", CHUNKS),
        Param("lanes", (0, 2, 4, 8)),
        Param("block_h", (1, 2, 4)),
        Param("dims", DIMS, ordinal=False),
    ])


def _wkv_validate(cfg, meta) -> str | None:
    chunk, lanes, bh = cfg["chunk"], cfg["lanes"], cfg["block_h"]
    t, hd = meta["t"], meta["hd"]
    err = (_divides(t, chunk, "chunk")
           or _divides(meta["h"], bh, "block_h"))
    if err:
        return err
    if lanes == 0:           # serial grid program
        return _vmem(_f32(5 * chunk * bh * hd + bh * hd),
                     _f32(3 * bh * hd * hd))
    span = chunk * lanes
    if t % span:
        return f"span chunk*lanes={span} does not divide t={t}"
    if chunk > 64:
        # the matrix form computes k * exp(-cumsum(log w)); past ~64
        # tokens the inverse decay product can overflow f32 (the
        # tuner's parity gate also rejects any config that diverges)
        return f"chunk={chunk} exceeds matrix-form stability cap 64"
    # intra-chunk scores (chunk x chunk) per lane plus chunk temporaries
    return _vmem(_f32(5 * span * bh * hd + bh * hd),
                 _f32(lanes * bh * (chunk * chunk + 6 * chunk * hd)
                      + 3 * bh * hd * hd))


def _wkv_inputs(meta, dtype, rng):
    b, t, h, hd = (meta[k] for k in ("b", "t", "h", "hd"))
    f32 = jnp.float32
    r, k, v = (jnp.asarray(rng.standard_normal((b, t, h, hd)) * 0.5, f32)
               for _ in range(3))
    w = jnp.asarray(1.0 / (1.0 + np.exp(-(rng.standard_normal(
        (b, t, h, hd)) + 2))), f32)
    u = jnp.asarray(rng.standard_normal((h, hd)) * 0.1, f32)
    s0 = jnp.zeros((b, h, hd, hd), f32)
    return r, k, v, w, u, s0


def _wkv_run(cfg, inputs, interpret):
    from ...kernels.rwkv6_wkv.kernel import wkv6_kernel

    return wkv6_kernel(*inputs, chunk=cfg["chunk"], lanes=cfg["lanes"],
                       block_h=cfg["block_h"], dims=cfg["dims"],
                       interpret=interpret)


def _wkv_ref(inputs):
    from ...kernels.rwkv6_wkv.ref import wkv6_ref

    r, k, v, w, u, s0 = inputs
    return wkv6_ref(r, k, v, w, u, s0)


register_kernel(KernelSpec(
    name="rwkv6_wkv",
    defaults={"chunk": 64, "lanes": 0, "block_h": 1, "dims": "parallel"},
    space_fn=_wkv_space, validate_fn=_wkv_validate,
    make_inputs=_wkv_inputs, run=_wkv_run, ref=_wkv_ref,
    default_shape={"b": 2, "t": 512, "h": 2, "hd": 48},
    smoke_shape={"b": 1, "t": 64, "h": 1, "hd": 16},
    atol=2e-4, rtol=2e-3,
))


# -- rwkv6 wkv: backward --------------------------------------------------------

def _wkvb_space(meta: Mapping[str, Any]) -> ConfigSpace:
    return ConfigSpace([
        Param("chunk", CHUNKS),
        Param("block_h", (1, 2, 4, 8)),
        Param("dims", DIMS, ordinal=False),
    ])


def _wkvb_validate(cfg, meta) -> str | None:
    chunk, bh, hd = cfg["chunk"], cfg["block_h"], meta["hd"]
    # reverse-cell residuals: per-token kv outer products + state stack
    return (_divides(meta["t"], chunk, "chunk")
            or _divides(meta["h"], bh, "block_h")
            or _vmem(_f32(10 * chunk * bh * hd + 2 * bh * hd
                          + 3 * bh * hd * hd),
                     _f32(2 * chunk * bh * hd * hd)))


def _wkvb_inputs(meta, dtype, rng):
    inputs = _wkv_inputs(meta, dtype, rng)
    b, t, h, hd = (meta[k] for k in ("b", "t", "h", "hd"))
    dy = jnp.asarray(rng.standard_normal((b, t, h, hd)), jnp.float32)
    ds = jnp.asarray(rng.standard_normal((b, h, hd, hd)), jnp.float32)
    return inputs + (dy, ds)


def _wkvb_run(cfg, inputs, interpret):
    from ...kernels.rwkv6_wkv.kernel import wkv6_bwd

    return wkv6_bwd(*inputs, chunk=cfg["chunk"], block_h=cfg["block_h"],
                    dims=cfg["dims"], interpret=interpret)


def _wkvb_ref(inputs):
    from ...kernels.rwkv6_wkv.ref import wkv6_ref

    *primals, dy, ds = inputs
    _, vjp = jax.vjp(lambda *args: wkv6_ref(*args), *primals)
    return vjp((dy, ds))


register_kernel(KernelSpec(
    name="rwkv6_wkv_bwd",
    defaults={"chunk": 64, "block_h": 1, "dims": "parallel"},
    space_fn=_wkvb_space, validate_fn=_wkvb_validate,
    make_inputs=_wkvb_inputs, run=_wkvb_run, ref=_wkvb_ref,
    default_shape={"b": 2, "t": 512, "h": 2, "hd": 48},
    smoke_shape={"b": 1, "t": 64, "h": 1, "hd": 16},
    atol=2e-4, rtol=2e-3,
))


# -- DNA automaton --------------------------------------------------------------

def _dna_space(meta: Mapping[str, Any]) -> ConfigSpace:
    return ConfigSpace([
        Param("map_chunk", TEXT_CHUNKS),
        Param("count_chunk", TEXT_CHUNKS),
        Param("dims", DIMS, ordinal=False),
    ])


def _dna_validate(cfg, meta) -> str | None:
    mc, cc, t = cfg["map_chunk"], cfg["count_chunk"], meta["t"]
    err = _divides(t, mc, "map_chunk") or _divides(t, cc, "count_chunk")
    if err:
        return err
    if cc % mc:
        return (f"count_chunk={cc} is not a multiple of map_chunk={mc} "
                "(count start states live at map-chunk boundaries)")
    return None


def _dna_inputs(meta, dtype, rng):
    from ...kernels.dna_automaton.ops import build_motif_dfa

    table, accept = build_motif_dfa(meta.get("motif", "ACGTAC"))
    text = rng.integers(0, 4, meta["t"]).astype(np.uint8)
    return (jnp.asarray(text), jnp.asarray(table, jnp.int32),
            jnp.asarray(accept))


def _dna_run(cfg, inputs, interpret):
    from ...kernels.dna_automaton.ops import fa_match

    text, table, accept = inputs
    return fa_match(text, table, accept, map_chunk=cfg["map_chunk"],
                    count_chunk=cfg["count_chunk"], dims=cfg["dims"],
                    tuned=False, interpret=interpret)


def _dna_ref(inputs):
    from ...kernels.dna_automaton.ref import fa_match_ref

    text, table, accept = inputs
    return fa_match_ref(text, table, accept)[0]


register_kernel(KernelSpec(
    name="dna_automaton",
    defaults={"map_chunk": 2048, "count_chunk": 2048, "dims": "parallel"},
    space_fn=_dna_space, validate_fn=_dna_validate,
    make_inputs=_dna_inputs, run=_dna_run, ref=_dna_ref,
    default_shape={"t": 131072, "s": 7},
    smoke_shape={"t": 4096, "s": 7},
    dtype="uint8",
    atol=0.0, rtol=0.0,
))
