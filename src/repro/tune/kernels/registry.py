"""Kernel registry: one :class:`KernelSpec` per Pallas kernel.

A spec bundles everything the tuner needs to treat a kernel's launch
parameters as a paper-style combinatorial space:

  * ``space_fn(meta)``   — the launch-parameter :class:`ConfigSpace` for
    a concrete shape ``meta`` (candidate values include invalid ones —
    non-dividing blocks, VMEM overflows — which the evaluator scores
    ``inf`` without measuring);
  * ``validate_fn(cfg, meta)`` — ``None`` when the config can launch,
    else a short reason string (free: no kernel run happens);
  * ``make_inputs(meta, dtype, rng)`` — random inputs for the shape;
  * ``run(cfg, inputs, interpret)`` — execute the kernel at a candidate;
  * ``ref(inputs)``      — the ``ref.py`` oracle the candidate's output
    must match before its time counts.

Registering a new kernel space is one :func:`register_kernel` call; see
``specs.py`` for the five built-in kernels and ``docs/kernels.md`` for a
walkthrough.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Mapping

import numpy as np

from ...core.space import ConfigSpace

__all__ = ["KernelSpec", "register_kernel", "get_kernel", "list_kernels",
           "kernel_workload"]


@dataclass(frozen=True)
class KernelSpec:
    name: str
    defaults: Mapping[str, Any]           # the ops.py hardcoded launch params
    space_fn: Callable[[Mapping[str, Any]], ConfigSpace]
    validate_fn: Callable[[Mapping[str, Any], Mapping[str, Any]], str | None]
    make_inputs: Callable[[Mapping[str, Any], Any, np.random.Generator], tuple]
    run: Callable[[Mapping[str, Any], tuple, bool], Any]
    ref: Callable[[tuple], Any]
    default_shape: Mapping[str, Any]      # bench/tune shape (full run)
    smoke_shape: Mapping[str, Any]        # CI-sized shape (tiny spaces OK)
    dtype: str = "float32"                # the ops layer's resolution dtype
    atol: float = 2e-4
    rtol: float = 2e-4

    def space(self, meta: Mapping[str, Any]) -> ConfigSpace:
        return self.space_fn(meta)

    def validate(self, cfg: Mapping[str, Any],
                 meta: Mapping[str, Any]) -> str | None:
        return self.validate_fn(cfg, meta)

    def default_config(self, space: ConfigSpace,
                       meta: Mapping[str, Any] | None = None) -> dict:
        """The hardcoded launch parameters as a point of ``space``.

        When ``meta`` is given and the raw defaults are invalid for that
        shape (e.g. a 256-wide block on a 64-wide extent), returns the
        nearest valid config instead — mirroring the clamping the ops
        layer applies to its hardcoded defaults at launch.
        """
        cfg = {p.name: self.defaults[p.name] for p in space.params}
        space.validate(cfg)
        if meta is None or self.validate(cfg, meta) is None:
            return cfg
        didx = space.to_indices(cfg)
        best, best_d = None, None
        for row in space.index_grid():
            cand = space.from_indices(row)
            if self.validate(cand, meta) is not None:
                continue
            d = int(np.abs(np.asarray(row) - didx).sum())
            if best is None or d < best_d:
                best, best_d = cand, d
        if best is None:
            raise ValueError(f"kernel {self.name!r} has no valid config "
                             f"for shape {dict(meta)!r}")
        return best


_REGISTRY: dict[str, KernelSpec] = {}


def register_kernel(spec: KernelSpec) -> KernelSpec:
    _REGISTRY[spec.name] = spec
    return spec


def get_kernel(name: str) -> KernelSpec:
    spec = _REGISTRY.get(name)
    if spec is None:
        raise ValueError(f"unknown kernel {name!r}; registered: "
                         f"{', '.join(list_kernels())}")
    return spec


def list_kernels() -> list[str]:
    """Sorted names of every registered tunable kernel."""
    return sorted(_REGISTRY)


def kernel_workload(name: str, meta: Mapping[str, Any], dtype: Any) -> dict:
    """The tuning-store workload payload: kernel + shape signature + dtype.

    Together with the store's device-topology component this keys cached
    results by (kernel name, shape signature, dtype, backend/device
    kind) — the resolution key of the ``tuned=`` fast path.
    """
    import jax.numpy as jnp

    return {"kernel": name,
            "shape": {str(k): meta[k] for k in sorted(meta, key=str)},
            "dtype": str(jnp.dtype(dtype))}
