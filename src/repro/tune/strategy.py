"""Pluggable search-strategy registry.

Every way of searching a ``ConfigSpace`` is one registered function with
the uniform signature ``fn(ctx: SearchContext, **opts) -> StrategyOutcome``.
The paper's four methods (``em``, ``eml``, ``sam``, ``saml``) are the
seed engines lifted out of the old ``Autotuner`` methods verbatim — same
oracles, same RNG streams, same effort accounting — so a
``TuningSession`` run reproduces the legacy results bit-for-bit on a
fixed seed.  ``random`` and ``hillclimb`` are implemented purely against
the new interface; a new search method is one decorated function:

    from repro.tune import register_strategy, StrategyOutcome

    @register_strategy("greedy2", description="two random restarts")
    def greedy2(ctx, *, seed=0, **_):
        ...
        return StrategyOutcome(best_cfg, best_score, n_experiments=n)

and is then discoverable via ``list_strategies()`` and runnable through
``TuningSession(...).run("greedy2")``.

``SearchContext`` is the decoupled (objective x evaluator x surrogate)
bundle the session prepares: ``measure``/``measure_batch`` score real
measurements under the session's objective, ``predict``/``predict_batch``
score surrogate predictions, and ``predict_jax_builder`` powers the
vectorized SA engine.  A strategy uses whichever oracles it needs and
reports its effort through the outcome counters.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Mapping, Sequence

import numpy as np

from ..core.evaluators import MeasurementEvaluator
from ..core.sa import SASchedule, simulated_annealing, vectorized_sa
from ..core.space import ConfigSpace

__all__ = ["SearchContext", "StrategyOutcome", "StrategyInfo",
           "register_strategy", "get_strategy", "list_strategies"]


@dataclass
class SearchContext:
    """Everything a strategy may consume, pre-composed by the session."""

    space: ConfigSpace
    # objective-scored oracles; None when the session lacks that capability
    measure: Callable[[Mapping[str, Any]], float] | None = None
    measure_batch: Callable[[Mapping[str, np.ndarray]], np.ndarray] | \
        None = None
    predict: Callable[[Mapping[str, Any]], float] | None = None
    predict_batch: Callable[[Mapping[str, np.ndarray]], np.ndarray] | \
        None = None
    # space -> jitted (n, feature_dim) -> (n,) score fn (vectorized SA)
    predict_jax_builder: Callable[[ConfigSpace], Callable] | None = None
    # component metric columns for a column batch (Pareto front extraction)
    metrics_batch: Callable[[Mapping[str, np.ndarray]],
                            dict[str, np.ndarray]] | None = None
    objective: Any = None
    # initial configuration for local-search strategies
    warm_start: dict | None = None
    # default evaluation budget (iterations / samples) when the caller
    # does not pass one explicitly
    budget: int | None = None

    def require_measure(self, name: str):
        if self.measure is None:
            raise ValueError(f"strategy {name!r} needs a measurement "
                             "evaluator (pass evaluator= to the session)")
        return self.measure

    def require_predict(self, name: str):
        if self.predict is None:
            raise ValueError(f"strategy {name!r} needs a trained surrogate "
                             "(pass surrogate= to the session)")
        return self.predict


@dataclass
class StrategyOutcome:
    """What a strategy returns; the session turns it into a TuneResult."""

    best_config: dict
    best_score: float
    n_experiments: int = 0
    n_predictions: int = 0
    # {iteration: (search score of best-so-far, config)} — the session
    # re-scores checkpoints with ground truth, like the paper (Sec. IV-C)
    checkpoints: dict[int, tuple[float, dict]] = field(default_factory=dict)
    # [[component scores...], config] rows (enumerating Pareto runs)
    pareto_front: list = field(default_factory=list)


@dataclass(frozen=True)
class StrategyInfo:
    name: str
    fn: Callable[..., StrategyOutcome]
    uses_surrogate: bool
    description: str


_REGISTRY: dict[str, StrategyInfo] = {}


def register_strategy(name: str, *, uses_surrogate: bool = False,
                      description: str = ""):
    """Decorator: add ``fn(ctx, **opts) -> StrategyOutcome`` to the registry.

    ``uses_surrogate`` marks strategies whose effort accounting should
    charge the one-time surrogate training experiments (the paper charges
    them to EML/SAML, not to the measurement-only methods).
    """
    key = name.lower()

    def deco(fn):
        doc = (fn.__doc__ or "").strip()
        desc = description or (doc.splitlines()[0] if doc else "")
        _REGISTRY[key] = StrategyInfo(key, fn, uses_surrogate, desc)
        return fn
    return deco


def get_strategy(name: str) -> StrategyInfo:
    info = _REGISTRY.get(name.lower())
    if info is None:
        raise ValueError(f"unknown strategy {name!r}; registered: "
                         f"{', '.join(list_strategies())}")
    return info


def list_strategies() -> list[str]:
    """Sorted names of every registered strategy."""
    return sorted(_REGISTRY)


# ---------------------------------------------------------------------------
# Counting wrappers (prediction-side analogue of MeasurementEvaluator).
# ---------------------------------------------------------------------------

class _PredictCounter:
    """Counts surrogate queries one-per-config, like LearnedEvaluator."""

    def __init__(self, fn):
        self._fn = fn
        self.n_predictions = 0

    def __call__(self, cfg):
        self.n_predictions += 1
        return float(self._fn(cfg))


class _BatchPredictCounter:
    def __init__(self, fn):
        self._fn = fn
        self.n_predictions = 0

    def __call__(self, columns):
        out = np.asarray(self._fn(columns))
        self.n_predictions += len(out)
        return out


def _front_from_metrics(ctx: SearchContext, metrics, grid) -> list:
    """Non-dominated rows of an enumerated space under a Pareto objective."""
    from .objective import pareto_front
    comps = ctx.objective.component_batch(metrics)
    idx = pareto_front(comps)
    return [[[float(v) for v in comps[i]],
             ctx.space.from_indices(grid[i])] for i in idx]


# ---------------------------------------------------------------------------
# The paper's four strategies (seed engines, lifted verbatim).
# ---------------------------------------------------------------------------

@register_strategy("em", description="enumeration + measurements "
                   "(optimal, very high effort)")
def _em(ctx: SearchContext, *, engine: str = "auto", **_) -> StrategyOutcome:
    space = ctx.space
    if engine == "auto":
        engine = "batched" if ctx.measure_batch is not None else "scalar"
    if engine == "batched":
        if ctx.measure_batch is None:
            raise ValueError("batched EM needs a batch evaluator "
                             "(measure_batch= / evaluator_batch=)")
        grid = space.index_grid()
        columns = space.enumerate_columns(grid)
        front: list = []
        if (ctx.metrics_batch is not None
                and hasattr(ctx.objective, "component_batch")):
            # Pareto: ONE full-space measurement pass feeds both the
            # scalarised scores and the front — re-running the oracle
            # would double-spend experiments and desync noise draws
            metrics = ctx.metrics_batch(columns)
            scores = np.asarray(ctx.objective.batch(metrics))
            front = _front_from_metrics(ctx, metrics, grid)
        else:
            scores = np.asarray(ctx.measure_batch(columns))
        k = int(np.argmin(scores))        # first minimum, like the loop
        best_cfg = space.from_indices(grid[k])
        # enumeration visits each distinct config exactly once, so the
        # deduplicated experiment count equals the space size
        return StrategyOutcome(
            best_cfg, float(scores[k]), n_experiments=space.size(),
            pareto_front=front)
    if engine != "scalar":
        raise ValueError(f"unknown EM engine {engine!r}")
    ev = MeasurementEvaluator(ctx.require_measure("em"), space)
    best_cfg, best_e = None, float("inf")
    for cfg in space.enumerate():
        e = ev(cfg)
        if e < best_e:
            best_cfg, best_e = cfg, e
    return StrategyOutcome(best_cfg, best_e, n_experiments=ev.n_experiments)


@register_strategy("eml", uses_surrogate=True,
                   description="enumeration + machine learning "
                   "(near-optimal, high effort)")
def _eml(ctx: SearchContext, *, engine: str = "batched",
         **_) -> StrategyOutcome:
    space = ctx.space
    if engine == "batched":
        if ctx.predict_batch is None:
            ctx.require_predict("eml")    # raises the canonical message
            raise ValueError("batched EML needs a batch-capable surrogate")
        ev = _BatchPredictCounter(ctx.predict_batch)
        grid = space.index_grid()
        scores = np.asarray(ev(space.enumerate_columns(grid)))
        k = int(np.argmin(scores))        # first minimum, like the loop
        return StrategyOutcome(space.from_indices(grid[k]), float(scores[k]),
                               n_predictions=ev.n_predictions)
    if engine != "scalar":
        raise ValueError(f"unknown EML engine {engine!r}")
    ev = _PredictCounter(ctx.require_predict("eml"))
    best_cfg, best_e = None, float("inf")
    for cfg in space.enumerate():
        e = ev(cfg)
        if e < best_e:
            best_cfg, best_e = cfg, e
    return StrategyOutcome(best_cfg, best_e, n_predictions=ev.n_predictions)


@register_strategy("sam", description="simulated annealing + measurements "
                   "(near-optimal, medium effort)")
def _sam(ctx: SearchContext, *, iterations: int | None = None, seed: int = 0,
         checkpoints: Sequence[int] = (), **_) -> StrategyOutcome:
    iterations = iterations if iterations is not None else ctx.budget or 1000
    ev = MeasurementEvaluator(ctx.require_measure("sam"), ctx.space)
    res = simulated_annealing(
        ctx.space, ev, seed=seed, initial=ctx.warm_start,
        schedule=SASchedule.for_iterations(iterations),
        max_iterations=iterations, checkpoint_at=checkpoints,
    )
    return StrategyOutcome(res.best_config, res.best_energy,
                           n_experiments=ev.n_experiments,
                           checkpoints=res.checkpoints)


@register_strategy("saml", uses_surrogate=True,
                   description="simulated annealing + machine learning "
                   "— the paper's headline method")
def _saml(ctx: SearchContext, *, iterations: int | None = None, seed: int = 0,
          checkpoints: Sequence[int] = (), engine: str = "scalar",
          n_chains: int = 32, **_) -> StrategyOutcome:
    iterations = iterations if iterations is not None else ctx.budget or 1000
    if engine == "vectorized":
        if ctx.predict_jax_builder is None:
            raise ValueError(
                "vectorized SAML needs a surrogate with an "
                "energy_fn_jax_builder (see fit_emil_surrogates)")
        energy_fn = ctx.predict_jax_builder(ctx.space)
        res = vectorized_sa(
            ctx.space, energy_fn, n_chains=n_chains,
            n_iterations=iterations,
            schedule=SASchedule.for_iterations(iterations),
            seed=seed, checkpoint_at=checkpoints,
        )
        # every chain step is one surrogate query — same accounting unit
        # as the scalar engine (predictions, not experiments)
        return StrategyOutcome(res.best_config, res.best_energy,
                               n_predictions=res.n_evaluations,
                               checkpoints=res.checkpoints)
    if engine != "scalar":
        raise ValueError(f"unknown SAML engine {engine!r}")
    ev = _PredictCounter(ctx.require_predict("saml"))
    res = simulated_annealing(
        ctx.space, ev, seed=seed, initial=ctx.warm_start,
        schedule=SASchedule.for_iterations(iterations),
        max_iterations=iterations, checkpoint_at=checkpoints,
    )
    return StrategyOutcome(res.best_config, res.best_energy,
                           n_predictions=ev.n_predictions,
                           checkpoints=res.checkpoints)


# ---------------------------------------------------------------------------
# New strategies, written purely against the SearchContext interface.
# ---------------------------------------------------------------------------

def _search_oracle(ctx: SearchContext, name: str):
    """(score_fn, counts_as_experiments) — prefer real measurements, fall
    back to the surrogate so these strategies also work surrogate-only."""
    if ctx.measure is not None:
        return MeasurementEvaluator(ctx.measure, ctx.space), True
    if ctx.predict is not None:
        return _PredictCounter(ctx.predict), False
    raise ValueError(f"strategy {name!r} needs an evaluator or a surrogate")


def _counts(ev, measured: bool) -> dict:
    n = ev.n_experiments if measured else ev.n_predictions
    return {"n_experiments": n if measured else 0,
            "n_predictions": 0 if measured else n}


@register_strategy("random", description="uniform random sampling "
                   "(baseline; budgeted)")
def _random(ctx: SearchContext, *, samples: int | None = None,
            iterations: int | None = None, seed: int = 0,
            checkpoints: Sequence[int] = (), **_) -> StrategyOutcome:
    """Sample ``samples`` uniform configs, keep the best.

    A ``warm_start`` (when the session provides one) is evaluated as the
    first sample, so the search result is never worse than the caller's
    known-good configuration — and never ``None`` even if every random
    draw scores ``inf`` (e.g. invalid kernel launch configs).
    """
    n = samples or iterations or ctx.budget or 100
    ev, measured = _search_oracle(ctx, "random")
    rng = np.random.default_rng(seed)
    cps: dict[int, tuple[float, dict]] = {}
    checkpoint_set = set(int(c) for c in checkpoints)
    best, best_e = None, float("inf")
    for it in range(1, n + 1):
        if it == 1 and ctx.warm_start is not None:
            cfg = dict(ctx.warm_start)
        else:
            cfg = ctx.space.random(rng)
        e = ev(cfg)
        if best is None or e < best_e:
            best, best_e = dict(cfg), e
        if it in checkpoint_set:
            cps[it] = (best_e, dict(best))
    return StrategyOutcome(best, best_e, checkpoints=cps,
                           **_counts(ev, measured))


@register_strategy("hillclimb", description="greedy local search with "
                   "random restarts (budgeted)")
def _hillclimb(ctx: SearchContext, *, iterations: int | None = None,
               seed: int = 0, checkpoints: Sequence[int] = (),
               patience: int = 12, **_) -> StrategyOutcome:
    """First-improvement hill climbing over ``space.neighbor`` moves;
    after ``patience`` consecutive non-improving proposals the walk
    restarts from a fresh random configuration (budget permitting)."""
    n = iterations if iterations is not None else ctx.budget or 200
    ev, measured = _search_oracle(ctx, "hillclimb")
    rng = np.random.default_rng(seed)
    cps: dict[int, tuple[float, dict]] = {}
    checkpoint_set = set(int(c) for c in checkpoints)

    cur = dict(ctx.warm_start) if ctx.warm_start else ctx.space.random(rng)
    ctx.space.validate(cur)
    cur_e = ev(cur)
    best, best_e = dict(cur), cur_e
    stuck = 0
    for it in range(1, n + 1):
        restart = stuck >= patience
        cand = ctx.space.random(rng) if restart \
            else ctx.space.neighbor(cur, rng)
        e = ev(cand)
        if restart or e < cur_e:
            # a restart moves the walk to the fresh point even when it
            # scores worse — descending from the new basin is the point;
            # the global best below is unaffected
            cur, cur_e = dict(cand), e
            stuck = 0
        else:
            stuck += 1
        if e < best_e:
            best, best_e = dict(cand), e
        if it in checkpoint_set:
            cps[it] = (best_e, dict(best))
    return StrategyOutcome(best, best_e, checkpoints=cps,
                           **_counts(ev, measured))
