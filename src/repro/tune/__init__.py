"""repro.tune — the unified tuning facade.

One session API for every tuning scenario in the repo (offline EMIL
search, online fraction tuning, pod-scale sharding configs, live
surrogate feedback), decoupled into three pluggable pieces:

  objective  — what to minimise (``Time``, ``Energy``, ``Weighted``,
               ``Pareto``); see ``objective.py``.
  strategy   — how to search (``em``/``eml``/``sam``/``saml``/``random``/
               ``hillclimb`` + ``@register_strategy`` for new ones);
               see ``strategy.py``.
  evaluator  — where scores come from (scalar oracle, metrics oracle,
               batched columns, surrogate pair); see ``objective.py``.

``TuningSession`` binds them and ``run()`` returns a ``TuneResult``
(usage guide: ``docs/tune.md``).  The legacy surfaces (``Autotuner``,
``HeterogeneousRunner.tune_fraction_sa``) are deprecated shims routing
through this package.
"""

from .objective import (Energy, Metric, MetricsEvaluator, Objective, Pareto,
                        Time, Weighted, as_metrics_evaluator, pareto_front)
from .result import TuneResult
from .session import TuningSession
from .strategy import (SearchContext, StrategyOutcome, get_strategy,
                       list_strategies, register_strategy)

__all__ = [
    "Objective", "Metric", "Time", "Energy", "Weighted", "Pareto",
    "MetricsEvaluator", "as_metrics_evaluator", "pareto_front",
    "TuneResult", "TuningSession",
    "SearchContext", "StrategyOutcome",
    "register_strategy", "get_strategy", "list_strategies",
]
