"""repro: the ICPPW'16 work-distribution autotuner as a TPU-pod framework."""

__version__ = "1.0.0"
