"""Phi-4-mini 3.8B — dense, RoPE + SwiGLU, GQA kv=8, 200k vocab.

[arXiv:2412.08905; hf]  32L d_model=3072 24H d_ff=8192 vocab=200064.
"""
from ..models.config import ArchConfig


def full() -> ArchConfig:
    return ArchConfig(
        name="phi4-mini-3.8b",
        family="dense",
        n_layers=32,
        d_model=3072,
        n_heads=24,
        n_kv_heads=8,
        d_ff=8192,
        vocab_size=200064,
        mlp_type="swiglu",
        tie_embeddings=True,
        source="[arXiv:2412.08905; hf]",
    )
