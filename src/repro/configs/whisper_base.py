"""Whisper-base — encoder-decoder audio backbone; conv frontend STUB.

[arXiv:2212.04356; unverified]  6L enc + 6L dec, d_model=512 8H
d_ff=2048 vocab=51865, LayerNorm + GELU.  input_specs feeds precomputed
frame embeddings.
"""
from ..models.config import ArchConfig


def full() -> ArchConfig:
    return ArchConfig(
        name="whisper-base",
        family="audio",
        n_layers=6,
        d_model=512,
        n_heads=8,
        n_kv_heads=8,
        d_ff=2048,
        vocab_size=51865,
        mlp_type="gelu",
        norm_type="layernorm",
        encdec=True,
        n_encoder_layers=6,
        decoder_len=448,
        frontend="stub_frames",
        positions="sinusoidal",
        tie_embeddings=True,
        source="[arXiv:2212.04356; unverified]",
    )
