"""InternVL2-76B — InternViT frontend (STUB) + LLaMA-70B-shape backbone.

[arXiv:2404.16821; unverified]  80L d_model=8192 64H (GQA kv=8)
d_ff=28672 vocab=128256.  The ViT is a stub: input_specs feeds
precomputed patch embeddings.
"""
from ..models.config import ArchConfig


def full() -> ArchConfig:
    return ArchConfig(
        name="internvl2-76b",
        family="vlm",
        n_layers=80,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=28672,
        vocab_size=128256,
        mlp_type="swiglu",
        rope_theta=500_000.0,
        frontend="stub_patches",
        n_patches=1024,
        source="[arXiv:2404.16821; unverified]",
    )
