"""RWKV-6 "Finch" 1.6B — attention-free, data-dependent decay.

[arXiv:2404.05892; unverified]  24L d_model=2048 d_ff=7168 vocab=65536.
"""
from ..models.config import ArchConfig, RwkvConfig


def full() -> ArchConfig:
    return ArchConfig(
        name="rwkv6-1.6b",
        family="ssm",
        n_layers=24,
        d_model=2048,
        n_heads=32,          # wkv heads = d_model / 64
        n_kv_heads=32,
        head_dim=64,
        d_ff=7168,
        vocab_size=65536,
        layer_kinds=("rwkv",) * 24,
        rwkv=RwkvConfig(head_dim=64),
        positions="none",
        source="[arXiv:2404.05892; unverified]",
    )
