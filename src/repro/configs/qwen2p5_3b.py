"""Qwen2.5-3B — dense, GQA kv=2, QKV bias.

[hf:Qwen/Qwen2.5-0.5B; hf]  36L d_model=2048 16H d_ff=11008 vocab=151936.
"""
from ..models.config import ArchConfig


def full() -> ArchConfig:
    return ArchConfig(
        name="qwen2.5-3b",
        family="dense",
        n_layers=36,
        d_model=2048,
        n_heads=16,
        n_kv_heads=2,
        d_ff=11008,
        vocab_size=151936,
        mlp_type="swiglu",
        qkv_bias=True,
        rope_theta=1_000_000.0,
        tie_embeddings=True,
        source="[hf:Qwen/Qwen2.5-0.5B; hf]",
    )
