"""Nemotron-4-340B — dense, GQA kv=8, squared-ReLU MLP.

[arXiv:2402.16819; unverified]  96L d_model=18432 96H d_ff=73728
vocab=256000, head_dim=192.
"""
from ..models.config import ArchConfig


def full() -> ArchConfig:
    return ArchConfig(
        name="nemotron-4-340b",
        family="dense",
        n_layers=96,
        d_model=18432,
        n_heads=96,
        n_kv_heads=8,
        head_dim=192,
        d_ff=73728,
        vocab_size=256000,
        mlp_type="squared_relu",
        source="[arXiv:2402.16819; unverified]",
    )
