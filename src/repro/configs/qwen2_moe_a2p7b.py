"""Qwen2-MoE A2.7B — 60 routed experts top-4 + 4 shared experts.

[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]  24L d_model=2048 16H (kv=16)
moe d_ff=1408, shared expert d_ff=5632, vocab=151936.
"""
from ..models.config import ArchConfig, MoEConfig


def full() -> ArchConfig:
    return ArchConfig(
        name="qwen2-moe-a2.7b",
        family="moe",
        n_layers=24,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=1408,
        vocab_size=151936,
        mlp_type="swiglu",
        qkv_bias=True,
        moe=MoEConfig(n_experts=60, top_k=4, d_expert=1408,
                      n_shared=4, d_shared=5632),
        source="[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]",
    )
