"""Phi-3-mini 3.8B — dense, RoPE + SwiGLU, kv=32 (MHA).

[arXiv:2404.14219; unverified]  32L d_model=3072 32H d_ff=8192 vocab=32064.
"""
from ..models.config import ArchConfig


def full() -> ArchConfig:
    return ArchConfig(
        name="phi3-mini-3.8b",
        family="dense",
        n_layers=32,
        d_model=3072,
        n_heads=32,
        n_kv_heads=32,
        d_ff=8192,
        vocab_size=32064,
        mlp_type="swiglu",
        source="[arXiv:2404.14219; unverified]",
    )
