"""Jamba-v0.1 52B — hybrid Mamba + attention (1:7), MoE 16e top-2.

[arXiv:2403.19887; hf]  32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536; attention at layer index 4 of each 8-layer block
(attn_layer_period=8, offset=4); MoE every other layer (period=2,
offset=1); mamba d_state=16 d_conv=4 expand=2, dt_rank=256.

No positional embeddings (the Mamba layers carry position information).
"""
from ..models.config import ArchConfig, MambaConfig, MoEConfig

_KINDS = tuple("attn" if i % 8 == 4 else "mamba" for i in range(32))


def full() -> ArchConfig:
    return ArchConfig(
        name="jamba-v0.1-52b",
        family="hybrid",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=14336,
        vocab_size=65536,
        mlp_type="swiglu",
        layer_kinds=_KINDS,
        mamba=MambaConfig(d_state=16, d_conv=4, expand=2, dt_rank=256),
        moe=MoEConfig(n_experts=16, top_k=2, d_expert=14336,
                      layer_period=2, layer_offset=1),
        positions="none",
        source="[arXiv:2403.19887; hf]",
    )
