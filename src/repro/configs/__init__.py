"""Architecture registry: one module per assigned architecture.

``get(name)`` returns the full-scale ArchConfig; ``get(name).smoke()``
returns the reduced same-family config used by CPU smoke tests.
"""

from __future__ import annotations

from ..models.config import ArchConfig

from . import (internvl2_76b, jamba_v0p1_52b, nemotron4_340b, phi3_mini_3p8b,
               phi3p5_moe_42b, phi4_mini_3p8b, qwen2_moe_a2p7b, qwen2p5_3b,
               rwkv6_1p6b, whisper_base)

_MODULES = {
    "rwkv6-1.6b": rwkv6_1p6b,
    "internvl2-76b": internvl2_76b,
    "nemotron-4-340b": nemotron4_340b,
    "phi4-mini-3.8b": phi4_mini_3p8b,
    "phi3-mini-3.8b": phi3_mini_3p8b,
    "qwen2.5-3b": qwen2p5_3b,
    "qwen2-moe-a2.7b": qwen2_moe_a2p7b,
    "phi3.5-moe-42b-a6.6b": phi3p5_moe_42b,
    "jamba-v0.1-52b": jamba_v0p1_52b,
    "whisper-base": whisper_base,
}

ARCH_NAMES = tuple(_MODULES)


def get(name: str) -> ArchConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; available: {ARCH_NAMES}")
    return _MODULES[name].full()


def all_archs() -> dict[str, ArchConfig]:
    return {name: get(name) for name in ARCH_NAMES}
