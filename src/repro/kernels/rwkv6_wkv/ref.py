"""Pure-jnp oracle for the RWKV-6 wkv recurrence (sequential scan)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv6_ref(r, k, v, w, u, s0=None):
    """r,k,v,w: (B, T, H, hd) fp32 (w = multiplicative decay in (0,1));
    u: (H, hd).  Returns (y (B,T,H,hd), s_T (B,H,hd,hd))."""
    b, t, h, hd = r.shape
    if s0 is None:
        s0 = jnp.zeros((b, h, hd, hd), jnp.float32)

    def step(s, inp):
        r_t, k_t, v_t, w_t = inp
        kv = k_t[..., :, None] * v_t[..., None, :]
        y = jnp.einsum("bhk,bhkv->bhv", r_t, s + u[..., :, None] * kv)
        s = w_t[..., :, None] * s + kv
        return s, y

    s, ys = jax.lax.scan(step, s0, (r.swapaxes(0, 1), k.swapaxes(0, 1),
                                    v.swapaxes(0, 1), w.swapaxes(0, 1)))
    return ys.swapaxes(0, 1), s
