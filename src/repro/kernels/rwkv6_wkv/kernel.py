"""RWKV-6 wkv recurrence as a chunked Pallas TPU kernel.

Grid (B, H, T/L): the (hd x hd) per-head state lives in VMEM scratch and
is carried across the innermost (time-chunk) grid dimension; each cell
loads an (L, hd) block of r/k/v/w and steps through its L tokens with a
``fori_loop``.  Keeping the state resident in VMEM is the entire point —
the HBM traffic is exactly one read of r/k/v/w and one write of y
(the CUDA wkv kernel's shared-memory strategy, translated to the TPU
memory hierarchy).

State is read out per chunk into the ``s_out`` block so callers can both
resume (decode) and checkpoint the recurrence at chunk boundaries
(matching the chunked-remat training layout in models/rwkv6.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .. import grid_compiler_params, largest_aligned_divisor


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, s_out_ref,
            s_ref, *, chunk, n_chunks):
    jc = pl.program_id(2)

    @pl.when(jc == 0)
    def _init():
        s_ref[...] = s0_ref[0, 0]

    u = u_ref[0]                                   # (hd,)

    def step(t, _):
        r_t = r_ref[0, t, 0]                       # (hd,)
        k_t = k_ref[0, t, 0]
        v_t = v_ref[0, t, 0]
        w_t = w_ref[0, t, 0]
        s = s_ref[...]                             # (hd, hd) key x value
        kv = k_t[:, None] * v_t[None, :]
        y = ((s + u[:, None] * kv) * r_t[:, None]).sum(axis=0)
        y_ref[0, t, 0] = y.astype(y_ref.dtype)
        s_ref[...] = w_t[:, None] * s + kv
        return ()

    jax.lax.fori_loop(0, chunk, step, ())

    @pl.when(jc == n_chunks - 1)
    def _final():
        s_out_ref[0, 0] = s_ref[...]


def wkv6_kernel(r, k, v, w, u, s0, *, chunk: int = 64,
                dims: str = "parallel", interpret: bool = False):
    """r,k,v,w: (B, T, H, hd) f32; u: (H, hd); s0: (B, H, hd, hd).

    Returns (y (B,T,H,hd) f32, s_T (B,H,hd,hd) f32).
    """
    b, t, h, hd = r.shape
    chunk = largest_aligned_divisor(t, chunk)
    n_chunks = t // chunk
    kernel = functools.partial(_kernel, chunk=chunk, n_chunks=n_chunks)
    seq_spec = pl.BlockSpec((1, chunk, 1, hd), lambda b_, h_, j: (b_, j, h_, 0))
    return pl.pallas_call(
        kernel,
        grid=(b, h, n_chunks),
        in_specs=[
            seq_spec, seq_spec, seq_spec, seq_spec,
            pl.BlockSpec((1, hd), lambda b_, h_, j: (h_, 0)),
            pl.BlockSpec((1, 1, hd, hd), lambda b_, h_, j: (b_, h_, 0, 0)),
        ],
        out_specs=[
            seq_spec,
            pl.BlockSpec((1, 1, hd, hd), lambda b_, h_, j: (b_, h_, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, t, h, hd), jnp.float32),
            jax.ShapeDtypeStruct((b, h, hd, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        compiler_params=grid_compiler_params(dims, 2, 1),
        interpret=interpret,
    )(r, k, v, w, u, s0)
