"""RWKV-6 wkv recurrence as chunked Pallas TPU kernels.

Forward — two grid programs behind one entry point, both keeping the
(hd x hd) per-head state resident in VMEM scratch carried across the
innermost (time) grid dimension (the CUDA wkv kernel's shared-memory
strategy translated to the TPU memory hierarchy — HBM traffic is one
read of r/k/v/w and one write of y):

  * **serial** (``lanes=0``): grid (B, H/bh, T/L); each cell loads an
    (L, bh, hd) block of r/k/v/w and steps through its L tokens with a
    ``fori_loop``, ``block_h`` heads vectorised per cell.
  * **chunked matrix form** (``lanes>=2``): each cell owns
    ``lanes * chunk`` tokens.  With ``g = cumsum(log w)`` inside a
    chunk, the intra-chunk contribution is a masked (chunk x chunk)
    score GEMM between ``r * exp(g_excl)`` and ``k * exp(-g)``, the
    cross-chunk contribution is one GEMM against the chunk-entry state,
    and per-chunk summaries (total decay ``exp(g_last)``, local state
    from safe ratios ``exp(g_last - g) <= 1``) thread the carried state
    through a Python-unrolled ``lanes``-step combine.  No token loop at
    all — the sequential depth per cell is ``lanes``, and the work is
    MXU-shaped.  ``exp(-g)`` bounds chunk length: ``validate`` caps
    matrix-form chunks at 64 and the tuner's parity gate rejects any
    configuration that overflows on the tuning inputs (trained RWKV
    decays sit near 1; adversarially small ``w`` should stay on the
    serial path).

Backward (``wkv6_bwd``) is recompute-based: a spans pre-pass re-derives
the state at every span boundary, then a reverse grid sweep calls
``jax.vjp`` on the pure local recurrence of each span (loop form —
decays are only ever multiplied, so it is unconditionally stable) with
the incoming output/state cotangents; per-cell partials for the shared
``u`` are summed by the caller and the span-entry cotangent becomes the
carried adjoint.  Residual memory is O(inputs).

State is read out per cell into ``s_out`` so callers can both resume
(decode) and checkpoint the recurrence (matching the chunked-remat
training layout in models/rwkv6.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .. import grid_compiler_params, largest_aligned_divisor


def _serial_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref,
                   s_out_ref, s_ref, *, chunk, n_chunks):
    jc = pl.program_id(2)

    @pl.when(jc == 0)
    def _init():
        s_ref[...] = s0_ref[0]

    u = u_ref[...]                                 # (bh, hd)

    def step(t, _):
        r_t = r_ref[0, t]                          # (bh, hd)
        k_t = k_ref[0, t]
        v_t = v_ref[0, t]
        w_t = w_ref[0, t]
        s = s_ref[...]                             # (bh, hd, hd) key x value
        kv = k_t[..., :, None] * v_t[..., None, :]
        y = ((s + u[..., :, None] * kv) * r_t[..., :, None]).sum(axis=-2)
        y_ref[0, t] = y.astype(y_ref.dtype)
        s_ref[...] = w_t[..., :, None] * s + kv
        return ()

    jax.lax.fori_loop(0, chunk, step, ())

    @pl.when(jc == n_chunks - 1)
    def _final():
        s_out_ref[0] = s_ref[...]


def _chunked_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref,
                    s_out_ref, s_scr, *, lanes, chunk, block_h, n_spans):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        s_scr[...] = s0_ref[0]

    hd = u_ref.shape[1]
    u = u_ref[...]                                   # (bh, hd)
    rs = r_ref[0].reshape(lanes, chunk, block_h, hd)
    ks = k_ref[0].reshape(lanes, chunk, block_h, hd)
    vs = v_ref[0].reshape(lanes, chunk, block_h, hd)
    ws = w_ref[0].reshape(lanes, chunk, block_h, hd)

    logw = jnp.log(ws)
    tril = jnp.tril(jnp.ones((chunk, chunk), jnp.float32))
    g = jnp.einsum("ti,libd->ltbd", tril, logw)      # inclusive cumsum
    g_excl = g - logw
    aa = rs * jnp.exp(g_excl)                        # (lanes, L, bh, hd)
    bb = ks * jnp.exp(-g)
    scores = jnp.einsum("ltbd,libd->lbti", aa, bb)
    mask = jnp.tril(jnp.ones((chunk, chunk), jnp.float32), -1)
    y_intra = jnp.einsum("lbti,libj->ltbj", scores * mask, vs)
    bonus = (rs * u * ks).sum(-1)[..., None] * vs
    # per-chunk summaries: total decay + local state via safe ratios <= 1
    g_last = g[:, -1:]                               # (lanes, 1, bh, hd)
    cc = ks * jnp.exp(g_last - g)
    s_loc = jnp.einsum("libd,libj->lbdj", cc, vs)    # (lanes, bh, hd, hd)
    d_tot = jnp.exp(g_last[:, 0])                    # (lanes, bh, hd)

    s = s_scr[...]
    starts = []
    for l in range(lanes):
        starts.append(s)
        s = d_tot[l][..., :, None] * s + s_loc[l]
    s_scr[...] = s
    s_start = jnp.stack(starts, 0)                   # (lanes, bh, hd, hd)

    @pl.when(j == n_spans - 1)
    def _final():
        s_out_ref[0] = s

    y_inter = jnp.einsum("ltbd,lbdj->ltbj", aa, s_start)
    y = y_intra + y_inter + bonus
    y_ref[0] = y.reshape(lanes * chunk, block_h, hd)


def _clamp_chunking(t: int, chunk: int, lanes: int) -> tuple[int, int]:
    chunk = largest_aligned_divisor(t, chunk)
    if lanes >= 2:
        lanes = largest_aligned_divisor(t // chunk, lanes)
    return chunk, (lanes if lanes >= 2 else 0)


def wkv6_kernel(r, k, v, w, u, s0, *, chunk: int = 64, lanes: int = 0,
                block_h: int = 1, dims: str = "parallel",
                interpret: bool = False):
    """r,k,v,w: (B, T, H, hd) f32; u: (H, hd); s0: (B, H, hd, hd).

    Returns (y (B,T,H,hd) f32, s_T (B,H,hd,hd) f32).  ``lanes=0`` runs
    the serial per-token scan; ``lanes>=2`` the matrix-form chunked
    formulation (``lanes`` chunks of ``chunk`` tokens per grid cell).
    """
    b, t, h, hd = r.shape
    block_h = largest_aligned_divisor(h, block_h)
    chunk, lanes = _clamp_chunking(t, chunk, lanes)
    span = chunk * lanes if lanes else chunk
    n_spans = t // span
    seq_spec = pl.BlockSpec((1, span, block_h, hd),
                            lambda b_, h_, j: (b_, j, h_, 0))
    sspec = pl.BlockSpec((1, block_h, hd, hd),
                         lambda b_, h_, j: (b_, h_, 0, 0))
    if lanes:
        kernel = functools.partial(_chunked_kernel, lanes=lanes, chunk=chunk,
                                   block_h=block_h, n_spans=n_spans)
    else:
        kernel = functools.partial(_serial_kernel, chunk=chunk,
                                   n_chunks=n_spans)
    return pl.pallas_call(
        kernel,
        grid=(b, h // block_h, n_spans),
        in_specs=[
            seq_spec, seq_spec, seq_spec, seq_spec,
            pl.BlockSpec((block_h, hd), lambda b_, h_, j: (h_, 0)),
            sspec,
        ],
        out_specs=[seq_spec, sspec],
        out_shape=[
            jax.ShapeDtypeStruct((b, t, h, hd), jnp.float32),
            jax.ShapeDtypeStruct((b, h, hd, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_h, hd, hd), jnp.float32)],
        compiler_params=grid_compiler_params(dims, 2, 1),
        interpret=interpret,
    )(r, k, v, w, u, s0)


# -- backward: spans pre-pass + reverse vjp sweep -------------------------------

def _spans_kernel(k_ref, v_ref, w_ref, s0_ref, ss_ref, s_scr, *, span):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        s_scr[...] = s0_ref[0]

    ss_ref[0, 0] = s_scr[...]                     # state entering this span

    def step(t, _):
        k_t = k_ref[0, t]
        v_t = v_ref[0, t]
        w_t = w_ref[0, t]
        kv = k_t[..., :, None] * v_t[..., None, :]
        s_scr[...] = w_t[..., :, None] * s_scr[...] + kv
        return ()

    jax.lax.fori_loop(0, span, step, ())


def _local_wkv(r, k, v, w, u, s_in):
    """Pure forward over one span from its entry state — the function the
    backward cell differentiates (recompute-in-backward).  Loop form:
    decays are only multiplied, never inverted, so it is stable for any
    ``w`` in (0, 1)."""
    def step(s, inp):
        r_t, k_t, v_t, w_t = inp
        kv = k_t[..., :, None] * v_t[..., None, :]
        y = ((s + u[..., :, None] * kv) * r_t[..., :, None]).sum(axis=-2)
        return w_t[..., :, None] * s + kv, y

    s_out, y = jax.lax.scan(step, s_in, (r, k, v, w))
    return y, s_out


def _wkv_bwd_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, ss_ref, dy_ref,
                    dsT_ref, dr_ref, dk_ref, dv_ref, dw_ref, du_ref,
                    ds0_ref, g_scr, *, n_spans):
    jr = pl.program_id(2)                         # 0 = last span (reversed)

    @pl.when(jr == 0)
    def _init():
        g_scr[...] = dsT_ref[0]

    _, vjp = jax.vjp(_local_wkv, r_ref[0], k_ref[0], v_ref[0], w_ref[0],
                     u_ref[...], ss_ref[0, 0])
    dr, dk, dv, dw, du_p, ds_in = vjp((dy_ref[0], g_scr[...]))
    dr_ref[0] = dr
    dk_ref[0] = dk
    dv_ref[0] = dv
    dw_ref[0] = dw
    du_ref[0, 0] = du_p                           # per-cell partial: summed
    g_scr[...] = ds_in                            # by the caller

    @pl.when(jr == n_spans - 1)
    def _final():
        ds0_ref[0] = ds_in


def wkv6_bwd(r, k, v, w, u, s0, dy, dsT, *, chunk: int = 64,
             block_h: int = 1, dims: str = "parallel",
             interpret: bool = False):
    """Pallas backward pass: grads of (y, s_T) cotangents (dy, dsT) w.r.t.
    every forward operand.  Returns (dr, dk, dv, dw, du, ds0)."""
    b, t, h, hd = r.shape
    block_h = largest_aligned_divisor(h, block_h)
    chunk = largest_aligned_divisor(t, chunk)
    n_spans = t // chunk
    seq = pl.BlockSpec((1, chunk, block_h, hd),
                       lambda b_, h_, j: (b_, j, h_, 0))
    sspec = pl.BlockSpec((1, block_h, hd, hd),
                         lambda b_, h_, j: (b_, h_, 0, 0))
    uspec = pl.BlockSpec((block_h, hd), lambda b_, h_, j: (h_, 0))

    spans = pl.pallas_call(
        functools.partial(_spans_kernel, span=chunk),
        grid=(b, h // block_h, n_spans),
        in_specs=[seq, seq, seq, sspec],
        out_specs=pl.BlockSpec((1, 1, block_h, hd, hd),
                               lambda b_, h_, j: (b_, j, h_, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n_spans, h, hd, hd), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_h, hd, hd), jnp.float32)],
        compiler_params=grid_compiler_params(dims, 2, 1),
        interpret=interpret,
    )(k, v, w, s0)

    seq_r = pl.BlockSpec((1, chunk, block_h, hd),
                         lambda b_, h_, j: (b_, n_spans - 1 - j, h_, 0))
    out = pl.pallas_call(
        functools.partial(_wkv_bwd_kernel, n_spans=n_spans),
        grid=(b, h // block_h, n_spans),
        in_specs=[
            seq_r, seq_r, seq_r, seq_r, uspec,
            pl.BlockSpec((1, 1, block_h, hd, hd),
                         lambda b_, h_, j: (b_, n_spans - 1 - j, h_, 0, 0)),
            seq_r, sspec,
        ],
        out_specs=[
            seq_r, seq_r, seq_r, seq_r,
            pl.BlockSpec((1, 1, block_h, hd),
                         lambda b_, h_, j: (b_, n_spans - 1 - j, h_, 0)),
            sspec,
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, t, h, hd), jnp.float32),
            jax.ShapeDtypeStruct((b, t, h, hd), jnp.float32),
            jax.ShapeDtypeStruct((b, t, h, hd), jnp.float32),
            jax.ShapeDtypeStruct((b, t, h, hd), jnp.float32),
            jax.ShapeDtypeStruct((b, n_spans, h, hd), jnp.float32),
            jax.ShapeDtypeStruct((b, h, hd, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_h, hd, hd), jnp.float32)],
        compiler_params=grid_compiler_params(dims, 2, 1),
        interpret=interpret,
    )(r, k, v, w, u, spans, dy, dsT)
    dr, dk, dv, dw, du_p, ds0 = out
    return dr, dk, dv, dw, du_p.sum(axis=(0, 1)), ds0
