"""Jit'd wrapper for the wkv6 kernel, differentiable via custom_vjp.

Forward runs the Pallas kernel (state resident in VMEM).  Backward
recomputes through the reference recurrence with ``jax.vjp`` — state
recurrences keep O(T) residuals otherwise; recompute-in-backward is the
standard training strategy for linear-attention kernels (upstream code
additionally chunk-remats, bounding the recompute window).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import wkv6_kernel
from .ref import wkv6_ref


@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7))
def _wkv(r, k, v, w, u, s0, chunk, interpret):
    return wkv6_kernel(r, k, v, w, u, s0, chunk=chunk, interpret=interpret)


def _wkv_fwd(r, k, v, w, u, s0, chunk, interpret):
    out = wkv6_kernel(r, k, v, w, u, s0, chunk=chunk, interpret=interpret)
    return out, (r, k, v, w, u, s0)


def _wkv_bwd(chunk, interpret, res, cts):
    r, k, v, w, u, s0 = res
    _, vjp = jax.vjp(lambda *a: wkv6_ref(*a), r, k, v, w, u, s0)
    return vjp(cts)


_wkv.defvjp(_wkv_fwd, _wkv_bwd)


def wkv6(r, k, v, w, u, s0=None, *, chunk: int = 64,
         interpret: bool | None = None):
    """r,k,v,w: (B,T,H,hd) f32; u: (H,hd). Returns (y, s_T). Differentiable."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    b, t, h, hd = r.shape
    if s0 is None:
        s0 = jnp.zeros((b, h, hd, hd), jnp.float32)
    return _wkv(r.astype(jnp.float32), k.astype(jnp.float32),
                v.astype(jnp.float32), w.astype(jnp.float32),
                u.astype(jnp.float32), s0, chunk, interpret)
