"""Jit'd wrapper for the wkv6 kernel, differentiable via custom_vjp.

Forward runs the Pallas kernel (state resident in VMEM).  Backward
recomputes through the reference recurrence with ``jax.vjp`` — state
recurrences keep O(T) residuals otherwise; recompute-in-backward is the
standard training strategy for linear-attention kernels (upstream code
additionally chunk-remats, bounding the recompute window).

Launch parameters (``chunk``/``dims``) resolve defaults < tuned store
(``tuned=``, see ``repro.tune.kernels``) < explicit overrides.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .. import resolve_launch_params
from .kernel import wkv6_kernel
from .ref import wkv6_ref

DEFAULTS = {"chunk": 64, "dims": "parallel"}


@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7, 8))
def _wkv(r, k, v, w, u, s0, chunk, dims, interpret):
    return wkv6_kernel(r, k, v, w, u, s0, chunk=chunk, dims=dims,
                       interpret=interpret)


def _wkv_fwd(r, k, v, w, u, s0, chunk, dims, interpret):
    out = wkv6_kernel(r, k, v, w, u, s0, chunk=chunk, dims=dims,
                      interpret=interpret)
    return out, (r, k, v, w, u, s0)


def _wkv_bwd(chunk, dims, interpret, res, cts):
    r, k, v, w, u, s0 = res
    _, vjp = jax.vjp(lambda *a: wkv6_ref(*a), r, k, v, w, u, s0)
    return vjp(cts)


_wkv.defvjp(_wkv_fwd, _wkv_bwd)


def wkv6(r, k, v, w, u, s0=None, *, chunk: int | None = None,
         dims: str | None = None, tuned: bool | None = None,
         interpret: bool | None = None):
    """r,k,v,w: (B,T,H,hd) f32; u: (H,hd). Returns (y, s_T). Differentiable.

    ``tuned=True`` resolves the cached best launch parameters for this
    (shape, dtype, backend) at trace time; ``tuned=None`` does so only
    when tuning was enabled globally (``repro.tune.kernels.configure``).
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    b, t, h, hd = r.shape
    meta = {"b": b, "t": t, "h": h, "hd": hd}
    p = resolve_launch_params(
        "rwkv6_wkv", meta, jnp.float32, defaults=DEFAULTS,
        overrides={"chunk": chunk, "dims": dims}, tuned=tuned)
    if s0 is None:
        s0 = jnp.zeros((b, h, hd, hd), jnp.float32)
    return _wkv(r.astype(jnp.float32), k.astype(jnp.float32),
                v.astype(jnp.float32), w.astype(jnp.float32),
                u.astype(jnp.float32), s0, p["chunk"], p["dims"], interpret)
