"""Jit'd wrapper for the wkv6 kernel, differentiable via custom_vjp.

Forward and backward are *separately tunable* Pallas launches: the
forward resolves ``rwkv6_wkv`` launch parameters
(``chunk``/``lanes``/``block_h``/``dims``), the backward resolves
``rwkv6_wkv_bwd`` (``chunk``/``block_h``/``dims``) for the same shape —
both as defaults < tuned store (``tuned=``, see ``repro.tune.kernels``)
< explicit overrides, at trace time.  The backward recomputes
span-boundary states and runs a reverse Pallas sweep (state recurrences
keep O(T) residuals otherwise; recompute-in-backward is the standard
training strategy for linear-attention kernels), so ``jax.grad``
through ``models/rwkv6.py`` stays on tuned kernels end to end.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .. import resolve_launch_params
from .kernel import wkv6_bwd, wkv6_kernel

DEFAULTS = {"chunk": 64, "lanes": 0, "block_h": 1, "dims": "parallel"}
BWD_DEFAULTS = {"chunk": 64, "block_h": 1, "dims": "parallel"}


@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7, 8))
def _wkv(r, k, v, w, u, s0, fwd_params, bwd_params, interpret):
    return wkv6_kernel(r, k, v, w, u, s0, **dict(fwd_params),
                       interpret=interpret)


def _wkv_fwd(r, k, v, w, u, s0, fwd_params, bwd_params, interpret):
    out = wkv6_kernel(r, k, v, w, u, s0, **dict(fwd_params),
                      interpret=interpret)
    return out, (r, k, v, w, u, s0)


def _wkv_bwd(fwd_params, bwd_params, interpret, res, cts):
    r, k, v, w, u, s0 = res
    dy, dsT = cts
    return wkv6_bwd(r, k, v, w, u, s0, dy, dsT, **dict(bwd_params),
                    interpret=interpret)


_wkv.defvjp(_wkv_fwd, _wkv_bwd)


def wkv6(r, k, v, w, u, s0=None, *, chunk: int | None = None,
         lanes: int | None = None, block_h: int | None = None,
         dims: str | None = None, tuned: bool | None = None,
         interpret: bool | None = None):
    """r,k,v,w: (B,T,H,hd) f32; u: (H,hd). Returns (y, s_T). Differentiable.

    ``tuned=True`` resolves the cached best launch parameters — forward
    and backward independently — for this (shape, dtype, backend) at
    trace time; ``tuned=None`` does so only when tuning was enabled
    globally (``repro.tune.kernels.configure``).
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    b, t, h, hd = r.shape
    meta = {"b": b, "t": t, "h": h, "hd": hd}
    p = resolve_launch_params(
        "rwkv6_wkv", meta, jnp.float32, defaults=DEFAULTS,
        overrides={"chunk": chunk, "lanes": lanes, "block_h": block_h,
                   "dims": dims},
        tuned=tuned)
    pb = resolve_launch_params(
        "rwkv6_wkv_bwd", meta, jnp.float32, defaults=BWD_DEFAULTS,
        tuned=tuned)
    if s0 is None:
        s0 = jnp.zeros((b, h, hd, hd), jnp.float32)
    return _wkv(r.astype(jnp.float32), k.astype(jnp.float32),
                v.astype(jnp.float32), w.astype(jnp.float32),
                u.astype(jnp.float32), s0, tuple(sorted(p.items())),
                tuple(sorted(pb.items())), interpret)
