"""Pure-jnp oracle for the Mamba-1 selective scan."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def selective_scan_ref(x, delta, a, b, c, d, h0=None):
    """x, delta: (B, T, dI); a: (dI, S); b, c: (B, T, S); d: (dI,).

    h_t = exp(delta_t * A) h_{t-1} + (delta_t * x_t) B_t
    y_t = C_t . h_t + D * x_t
    Returns (y (B,T,dI) f32, h_T (B,dI,S) f32).
    """
    bt, t, di = x.shape
    s = a.shape[1]
    if h0 is None:
        h0 = jnp.zeros((bt, di, s), jnp.float32)

    def step(h, inp):
        x_t, d_t, b_t, c_t = inp
        da = jnp.exp(d_t[..., None] * a)
        h = da * h + (d_t * x_t)[..., None] * b_t[:, None, :]
        y = jnp.einsum("bds,bs->bd", h, c_t)
        return h, y

    h, ys = jax.lax.scan(step, h0, (x.swapaxes(0, 1), delta.swapaxes(0, 1),
                                    b.swapaxes(0, 1), c.swapaxes(0, 1)))
    return ys.swapaxes(0, 1) + x * d, h
