"""Mamba-1 selective scan as a fused Pallas TPU kernel.

Grid (B, dI/bd, T/L): the (bd, S) state is VMEM scratch carried across
the innermost time-chunk dimension; each cell loads (L, bd) blocks of
x/delta and (L, S) blocks of B/C and steps its L tokens sequentially.
This is the CUDA selective-scan kernel's strategy mapped onto the TPU
memory hierarchy: discretised tensors (exp(delta A) etc.) are
rematerialised per timestep in VREGs and never touch HBM — the kernel's
HBM traffic is exactly one read of x/delta/B/C and one write of y.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .. import grid_compiler_params, largest_aligned_divisor


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, h0_ref,
            y_ref, h_out_ref, h_ref, *, chunk, n_chunks):
    jc = pl.program_id(2)

    @pl.when(jc == 0)
    def _init():
        h_ref[...] = h0_ref[0]

    a = a_ref[...]                                # (bd, S)
    d = d_ref[...]                                # (bd,)

    def step(t, _):
        x_t = x_ref[0, t]                         # (bd,)
        dt_t = dt_ref[0, t]                       # (bd,)
        b_t = b_ref[0, t]                         # (S,)
        c_t = c_ref[0, t]                         # (S,)
        da = jnp.exp(dt_t[:, None] * a)           # (bd, S)
        h = da * h_ref[...] + (dt_t * x_t)[:, None] * b_t[None, :]
        h_ref[...] = h
        y_ref[0, t] = (h * c_t[None, :]).sum(axis=1) + d * x_t
        return ()

    jax.lax.fori_loop(0, chunk, step, ())

    @pl.when(jc == n_chunks - 1)
    def _final():
        h_out_ref[0] = h_ref[...]


def selective_scan_kernel(x, delta, a, b, c, d, h0, *, block_d: int = 256,
                          chunk: int = 64, dims: str = "parallel",
                          interpret: bool = False):
    """x/delta: (B,T,dI) f32; a: (dI,S); b/c: (B,T,S); d: (dI,);
    h0: (B,dI,S).  Returns (y (B,T,dI) f32, h_T (B,dI,S) f32)."""
    bt, t, di = x.shape
    s = a.shape[1]
    block_d = largest_aligned_divisor(di, block_d, align=8)
    chunk = largest_aligned_divisor(t, chunk)
    n_chunks = t // chunk
    kernel = functools.partial(_kernel, chunk=chunk, n_chunks=n_chunks)
    xspec = pl.BlockSpec((1, chunk, block_d), lambda b_, i, j: (b_, j, i))
    sspec = pl.BlockSpec((1, chunk, s), lambda b_, i, j: (b_, j, 0))
    return pl.pallas_call(
        kernel,
        grid=(bt, di // block_d, n_chunks),
        in_specs=[
            xspec, xspec,
            pl.BlockSpec((block_d, s), lambda b_, i, j: (i, 0)),
            sspec, sspec,
            pl.BlockSpec((block_d,), lambda b_, i, j: (i,)),
            pl.BlockSpec((1, block_d, s), lambda b_, i, j: (b_, i, 0)),
        ],
        out_specs=[
            xspec,
            pl.BlockSpec((1, block_d, s), lambda b_, i, j: (b_, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bt, t, di), jnp.float32),
            jax.ShapeDtypeStruct((bt, di, s), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_d, s), jnp.float32)],
        compiler_params=grid_compiler_params(dims, 2, 1),
        interpret=interpret,
    )(x, delta, a, b, c, d, h0)
