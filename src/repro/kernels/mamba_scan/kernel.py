"""Mamba-1 selective scan as fused Pallas TPU kernels.

Forward — two grid programs behind one entry point:

  * **serial** (``lanes=0``): grid (B, dI/bd, T/L); the (bd, S) state is
    VMEM scratch carried across the innermost time-chunk dimension and
    each cell steps its L tokens sequentially.  This is the CUDA
    selective-scan kernel's strategy mapped onto the TPU memory
    hierarchy: discretised tensors (exp(delta A) etc.) are
    rematerialised per timestep in VREGs and never touch HBM.
  * **chunked** (``lanes>=2``): each cell owns a *span* of
    ``lanes * chunk`` tokens split into ``lanes`` chunks scanned in
    lockstep — the per-token loop runs ``chunk`` iterations with a
    ``(lanes, bd, S)`` carry, storing each token's running decay
    product and zero-state local scan in VMEM.  A Python-unrolled
    ``lanes``-step combine then threads the carried span-entry state
    through the chunk summaries (decay product, local state), and one
    vectorised fixup ``H = H_local + P * h_chunk_start`` + output
    contraction finishes all span tokens at once.  Identical math, but
    the sequential depth per cell drops from ``span`` to
    ``chunk + lanes`` — on backends where the serial loop is
    per-iteration-overhead bound this is the win the tuner finds.

Backward (``selective_scan_bwd``) is recompute-based: a light spans
pre-pass re-derives the state at every span boundary, then a reverse
grid sweep (span index map ``n-1-j``) calls ``jax.vjp`` on the pure
local forward of each span with the incoming output/state cotangents —
the input cotangents land in per-cell partial outputs (summed by the
caller for the reduced operands a/b/c/d) and the span-entry cotangent
becomes the carried adjoint for the previous span.  Residual memory is
O(inputs): nothing from the forward pass is saved but the inputs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .. import grid_compiler_params, largest_aligned_divisor


def _serial_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, h0_ref,
                   y_ref, h_out_ref, h_ref, *, chunk, n_chunks):
    jc = pl.program_id(2)

    @pl.when(jc == 0)
    def _init():
        h_ref[...] = h0_ref[0]

    a = a_ref[...]                                # (bd, S)
    d = d_ref[...]                                # (bd,)

    def step(t, _):
        x_t = x_ref[0, t]                         # (bd,)
        dt_t = dt_ref[0, t]                       # (bd,)
        b_t = b_ref[0, t]                         # (S,)
        c_t = c_ref[0, t]                         # (S,)
        da = jnp.exp(dt_t[:, None] * a)           # (bd, S)
        h = da * h_ref[...] + (dt_t * x_t)[:, None] * b_t[None, :]
        h_ref[...] = h
        y_ref[0, t] = (h * c_t[None, :]).sum(axis=1) + d * x_t
        return ()

    jax.lax.fori_loop(0, chunk, step, ())

    @pl.when(jc == n_chunks - 1)
    def _final():
        h_out_ref[0] = h_ref[...]


def _chunked_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, h0_ref,
                    y_ref, h_out_ref, h_scr, p_scr, hl_scr,
                    *, lanes, chunk, unroll, n_spans):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        h_scr[...] = h0_ref[0]

    a = a_ref[...]                                     # (bd, S)
    bd, s = a.shape
    xs = x_ref[0].reshape(lanes, chunk, bd)
    dts = dt_ref[0].reshape(lanes, chunk, bd)
    bs = b_ref[0].reshape(lanes, chunk, s)
    cs = c_ref[0].reshape(lanes, chunk, s)

    # all `lanes` chunks scan their tokens in lockstep; P is the running
    # in-chunk decay product, Hl the scan from a zero entry state
    def body(tk, carry):
        p, hl = carry                                  # (lanes, bd, S)
        dt_t = dts[:, tk]                              # (lanes, bd)
        da = jnp.exp(dt_t[..., None] * a[None])        # (lanes, bd, S)
        u = (dt_t * xs[:, tk])[..., None] * bs[:, tk, None, :]
        hl = da * hl + u
        p = p * da
        p_scr[:, tk] = p
        hl_scr[:, tk] = hl
        return p, hl

    zeros = jnp.zeros((lanes, bd, s), jnp.float32)
    p, hl = jax.lax.fori_loop(0, chunk, body, (jnp.ones_like(zeros), zeros),
                              unroll=unroll)

    # thread the carried span-entry state through the chunk summaries
    h = h_scr[...]
    starts = []
    for l in range(lanes):
        starts.append(h)
        h = p[l] * h + hl[l]
    h_scr[...] = h
    hs = jnp.stack(starts, 0)                          # (lanes, bd, S)

    @pl.when(j == n_spans - 1)
    def _final():
        h_out_ref[0] = h

    # fixup every span token at once: h_t = Hl_t + P_t * h_chunk_start
    big = hl_scr[...] + p_scr[...] * hs[:, None]
    y = (big * cs[:, :, None, :]).sum(-1) + d_ref[...] * xs
    y_ref[0] = y.reshape(lanes * chunk, bd)


def _clamp_chunking(t: int, chunk: int, lanes: int) -> tuple[int, int]:
    """Clamp (chunk, lanes) so ``chunk * lanes`` divides ``t``; lanes < 2
    collapses to the serial path (the ``lanes=0`` sentinel)."""
    chunk = largest_aligned_divisor(t, chunk)
    if lanes >= 2:
        lanes = largest_aligned_divisor(t // chunk, lanes)
    return chunk, (lanes if lanes >= 2 else 0)


def selective_scan_kernel(x, delta, a, b, c, d, h0, *, block_d: int = 256,
                          chunk: int = 64, lanes: int = 0, unroll: int = 1,
                          dims: str = "parallel", interpret: bool = False):
    """x/delta: (B,T,dI) f32; a: (dI,S); b/c: (B,T,S); d: (dI,);
    h0: (B,dI,S).  Returns (y (B,T,dI) f32, h_T (B,dI,S) f32).

    ``lanes=0`` runs the serial per-token scan; ``lanes>=2`` runs the
    chunked formulation with ``lanes`` chunks of ``chunk`` tokens per
    grid cell (clamped to divide T).
    """
    bt, t, di = x.shape
    s = a.shape[1]
    block_d = largest_aligned_divisor(di, block_d, align=8)
    chunk, lanes = _clamp_chunking(t, chunk, lanes)
    span = chunk * lanes if lanes else chunk
    n_spans = t // span
    xspec = pl.BlockSpec((1, span, block_d), lambda b_, i, j: (b_, j, i))
    sspec = pl.BlockSpec((1, span, s), lambda b_, i, j: (b_, j, 0))
    hspec = pl.BlockSpec((1, block_d, s), lambda b_, i, j: (b_, i, 0))
    if lanes:
        kernel = functools.partial(_chunked_kernel, lanes=lanes, chunk=chunk,
                                   unroll=max(int(unroll), 1),
                                   n_spans=n_spans)
        scratch = [pltpu.VMEM((block_d, s), jnp.float32),
                   pltpu.VMEM((lanes, chunk, block_d, s), jnp.float32),
                   pltpu.VMEM((lanes, chunk, block_d, s), jnp.float32)]
    else:
        kernel = functools.partial(_serial_kernel, chunk=chunk,
                                   n_chunks=n_spans)
        scratch = [pltpu.VMEM((block_d, s), jnp.float32)]
    return pl.pallas_call(
        kernel,
        grid=(bt, di // block_d, n_spans),
        in_specs=[
            xspec, xspec,
            pl.BlockSpec((block_d, s), lambda b_, i, j: (i, 0)),
            sspec, sspec,
            pl.BlockSpec((block_d,), lambda b_, i, j: (i,)),
            hspec,
        ],
        out_specs=[xspec, hspec],
        out_shape=[
            jax.ShapeDtypeStruct((bt, t, di), jnp.float32),
            jax.ShapeDtypeStruct((bt, di, s), jnp.float32),
        ],
        scratch_shapes=scratch,
        compiler_params=grid_compiler_params(dims, 2, 1),
        interpret=interpret,
    )(x, delta, a, b, c, d, h0)


# -- backward: spans pre-pass + reverse vjp sweep -------------------------------

def _spans_kernel(x_ref, dt_ref, a_ref, b_ref, h0_ref, hs_ref, h_scr,
                  *, span):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        h_scr[...] = h0_ref[0]

    hs_ref[0, 0] = h_scr[...]                     # state entering this span
    a = a_ref[...]

    def step(t, _):
        dt_t = dt_ref[0, t]
        da = jnp.exp(dt_t[:, None] * a)
        h_scr[...] = (da * h_scr[...]
                      + (dt_t * x_ref[0, t])[:, None] * b_ref[0, t][None, :])
        return ()

    jax.lax.fori_loop(0, span, step, ())


def _local_scan(x, dt, a, b, c, d, h_in):
    """Pure forward over one span from its entry state — the function the
    backward cell differentiates (recompute-in-backward)."""
    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp
        da = jnp.exp(dt_t[:, None] * a)
        h = da * h + (dt_t * x_t)[:, None] * b_t[None, :]
        y = (h * c_t[None, :]).sum(axis=1) + d * x_t
        return h, y

    h_out, y = jax.lax.scan(step, h_in, (x, dt, b, c))
    return y, h_out


def _scan_bwd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, hs_ref,
                     dy_ref, dhT_ref, dx_ref, ddt_ref, da_ref, db_ref,
                     dc_ref, dd_ref, dh0_ref, g_scr, *, n_spans):
    jr = pl.program_id(2)                         # 0 = last span (reversed)

    @pl.when(jr == 0)
    def _init():
        g_scr[...] = dhT_ref[0]

    _, vjp = jax.vjp(_local_scan, x_ref[0], dt_ref[0], a_ref[...],
                     b_ref[0], c_ref[0], d_ref[...], hs_ref[0, 0])
    dx, ddt, da_p, db_p, dc_p, dd_p, dh_in = vjp((dy_ref[0], g_scr[...]))
    dx_ref[0] = dx
    ddt_ref[0] = ddt
    da_ref[0, 0] = da_p                           # per-cell partials: the
    db_ref[0, 0] = db_p                           # reduced operands are
    dc_ref[0, 0] = dc_p                           # summed by the caller
    dd_ref[0, 0] = dd_p
    g_scr[...] = dh_in

    @pl.when(jr == n_spans - 1)
    def _final():
        dh0_ref[0] = dh_in


def selective_scan_bwd(x, delta, a, b, c, d, h0, dy, dhT, *,
                       block_d: int = 256, chunk: int = 64,
                       dims: str = "parallel", interpret: bool = False):
    """Pallas backward pass: grads of (y, h_T) cotangents (dy, dhT) w.r.t.
    every forward operand.  Returns (dx, ddelta, da, db, dc, dd, dh0)."""
    bt, t, di = x.shape
    s = a.shape[1]
    block_d = largest_aligned_divisor(di, block_d, align=8)
    chunk = largest_aligned_divisor(t, chunk)
    n_spans = t // chunk
    n_db = di // block_d
    aspec = pl.BlockSpec((block_d, s), lambda b_, i, j: (i, 0))
    dspec = pl.BlockSpec((block_d,), lambda b_, i, j: (i,))

    spans = pl.pallas_call(
        functools.partial(_spans_kernel, span=chunk),
        grid=(bt, n_db, n_spans),
        in_specs=[
            pl.BlockSpec((1, chunk, block_d), lambda b_, i, j: (b_, j, i)),
            pl.BlockSpec((1, chunk, block_d), lambda b_, i, j: (b_, j, i)),
            aspec,
            pl.BlockSpec((1, chunk, s), lambda b_, i, j: (b_, j, 0)),
            pl.BlockSpec((1, block_d, s), lambda b_, i, j: (b_, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_d, s),
                               lambda b_, i, j: (b_, j, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bt, n_spans, di, s), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_d, s), jnp.float32)],
        compiler_params=grid_compiler_params(dims, 2, 1),
        interpret=interpret,
    )(x, delta, a, b, h0)

    rev = lambda b_, i, j: (b_, n_spans - 1 - j, i)          # noqa: E731
    xspec_r = pl.BlockSpec((1, chunk, block_d), rev)
    sspec_r = pl.BlockSpec((1, chunk, s),
                           lambda b_, i, j: (b_, n_spans - 1 - j, 0))
    out = pl.pallas_call(
        functools.partial(_scan_bwd_kernel, n_spans=n_spans),
        grid=(bt, n_db, n_spans),
        in_specs=[
            xspec_r, xspec_r, aspec, sspec_r, sspec_r, dspec,
            pl.BlockSpec((1, 1, block_d, s),
                         lambda b_, i, j: (b_, n_spans - 1 - j, i, 0)),
            xspec_r,
            pl.BlockSpec((1, block_d, s), lambda b_, i, j: (b_, i, 0)),
        ],
        out_specs=[
            xspec_r, xspec_r,
            pl.BlockSpec((1, 1, block_d, s),
                         lambda b_, i, j: (b_, n_spans - 1 - j, i, 0)),
            pl.BlockSpec((1, 1, chunk, s),
                         lambda b_, i, j: (i, b_, n_spans - 1 - j, 0)),
            pl.BlockSpec((1, 1, chunk, s),
                         lambda b_, i, j: (i, b_, n_spans - 1 - j, 0)),
            pl.BlockSpec((1, 1, block_d),
                         lambda b_, i, j: (b_, n_spans - 1 - j, i)),
            pl.BlockSpec((1, block_d, s), lambda b_, i, j: (b_, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bt, t, di), jnp.float32),
            jax.ShapeDtypeStruct((bt, t, di), jnp.float32),
            jax.ShapeDtypeStruct((bt, n_spans, di, s), jnp.float32),
            jax.ShapeDtypeStruct((n_db, bt, t, s), jnp.float32),
            jax.ShapeDtypeStruct((n_db, bt, t, s), jnp.float32),
            jax.ShapeDtypeStruct((bt, n_spans, di), jnp.float32),
            jax.ShapeDtypeStruct((bt, di, s), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_d, s), jnp.float32)],
        compiler_params=grid_compiler_params(dims, 2, 1),
        interpret=interpret,
    )(x, delta, a, b, c, d, spans, dy, dhT)
    dx, ddt, da_p, db_p, dc_p, dd_p, dh0 = out
    return (dx, ddt, da_p.sum(axis=(0, 1)), db_p.sum(axis=0),
            dc_p.sum(axis=0), dd_p.sum(axis=(0, 1)), dh0)
