"""Jit'd wrapper for the selective-scan kernel (custom_vjp: ref backward)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import selective_scan_kernel
from .ref import selective_scan_ref


@functools.partial(jax.custom_vjp, nondiff_argnums=(7, 8, 9))
def _scan(x, delta, a, b, c, d, h0, block_d, chunk, interpret):
    return selective_scan_kernel(x, delta, a, b, c, d, h0, block_d=block_d,
                                 chunk=chunk, interpret=interpret)


def _scan_fwd(x, delta, a, b, c, d, h0, block_d, chunk, interpret):
    out = selective_scan_kernel(x, delta, a, b, c, d, h0, block_d=block_d,
                                chunk=chunk, interpret=interpret)
    return out, (x, delta, a, b, c, d, h0)


def _scan_bwd(block_d, chunk, interpret, res, cts):
    x, delta, a, b, c, d, h0 = res
    _, vjp = jax.vjp(lambda *args: selective_scan_ref(*args),
                     x, delta, a, b, c, d, h0)
    return vjp(cts)


_scan.defvjp(_scan_fwd, _scan_bwd)


def selective_scan(x, delta, a, b, c, d, h0=None, *, block_d: int = 256,
                   chunk: int = 64, interpret: bool | None = None):
    """Differentiable fused selective scan; see kernel.py for layout."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    bt, t, di = x.shape
    s = a.shape[1]
    if h0 is None:
        h0 = jnp.zeros((bt, di, s), jnp.float32)
    f32 = functools.partial(jnp.asarray, dtype=jnp.float32)
    return _scan(f32(x), f32(delta), f32(a), f32(b), f32(c), f32(d),
                 f32(h0), block_d, chunk, interpret)
