"""Jit'd wrapper for the selective-scan kernel (custom_vjp: Pallas backward).

Forward and backward are *separately tunable* Pallas launches: the
forward resolves ``mamba_scan`` launch parameters
(``block_d``/``chunk``/``lanes``/``unroll``/``dims``), the backward
resolves ``mamba_scan_bwd`` (``block_d``/``chunk``/``dims``) for the
same shape — both as defaults < tuned store (``tuned=``, see
``repro.tune.kernels``) < explicit overrides, at trace time.  The
backward recomputes span-boundary states and runs a reverse Pallas
sweep instead of re-differentiating the reference scan, so
``jax.grad`` through ``models/mamba.py`` stays on tuned kernels end to
end with O(inputs) residual memory.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .. import resolve_launch_params
from .kernel import selective_scan_bwd, selective_scan_kernel

DEFAULTS = {"block_d": 256, "chunk": 64, "lanes": 0, "unroll": 1,
            "dims": "parallel"}
BWD_DEFAULTS = {"block_d": 256, "chunk": 64, "dims": "parallel"}


@functools.partial(jax.custom_vjp, nondiff_argnums=(7, 8, 9))
def _scan(x, delta, a, b, c, d, h0, fwd_params, bwd_params, interpret):
    return selective_scan_kernel(x, delta, a, b, c, d, h0,
                                 **dict(fwd_params), interpret=interpret)


def _scan_fwd(x, delta, a, b, c, d, h0, fwd_params, bwd_params, interpret):
    out = selective_scan_kernel(x, delta, a, b, c, d, h0,
                                **dict(fwd_params), interpret=interpret)
    return out, (x, delta, a, b, c, d, h0)


def _scan_bwd(fwd_params, bwd_params, interpret, res, cts):
    x, delta, a, b, c, d, h0 = res
    dy, dhT = cts
    return selective_scan_bwd(x, delta, a, b, c, d, h0, dy, dhT,
                              **dict(bwd_params), interpret=interpret)


_scan.defvjp(_scan_fwd, _scan_bwd)


def selective_scan(x, delta, a, b, c, d, h0=None, *,
                   block_d: int | None = None, chunk: int | None = None,
                   lanes: int | None = None, unroll: int | None = None,
                   dims: str | None = None, tuned: bool | None = None,
                   interpret: bool | None = None):
    """Differentiable fused selective scan; see kernel.py for layout.

    ``tuned=True`` resolves the cached best launch parameters — forward
    and backward independently — for this (shape, dtype, backend) at
    trace time; ``tuned=None`` does so only when tuning was enabled
    globally (``repro.tune.kernels.configure``).
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    bt, t, di = x.shape
    s = a.shape[1]
    meta = {"bt": bt, "t": t, "di": di, "s": s}
    p = resolve_launch_params(
        "mamba_scan", meta, jnp.float32, defaults=DEFAULTS,
        overrides={"block_d": block_d, "chunk": chunk, "lanes": lanes,
                   "unroll": unroll, "dims": dims},
        tuned=tuned)
    pb = resolve_launch_params(
        "mamba_scan_bwd", meta, jnp.float32, defaults=BWD_DEFAULTS,
        tuned=tuned)
    if h0 is None:
        h0 = jnp.zeros((bt, di, s), jnp.float32)
    f32 = functools.partial(jnp.asarray, dtype=jnp.float32)
    return _scan(f32(x), f32(delta), f32(a), f32(b), f32(c), f32(d),
                 f32(h0), tuple(sorted(p.items())),
                 tuple(sorted(pb.items())), interpret)
