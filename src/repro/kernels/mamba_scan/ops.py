"""Jit'd wrapper for the selective-scan kernel (custom_vjp: ref backward).

Launch parameters (``block_d``/``chunk``/``dims``) resolve defaults <
tuned store (``tuned=``, see ``repro.tune.kernels``) < explicit
overrides.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .. import resolve_launch_params
from .kernel import selective_scan_kernel
from .ref import selective_scan_ref

DEFAULTS = {"block_d": 256, "chunk": 64, "dims": "parallel"}


@functools.partial(jax.custom_vjp, nondiff_argnums=(7, 8, 9, 10))
def _scan(x, delta, a, b, c, d, h0, block_d, chunk, dims, interpret):
    return selective_scan_kernel(x, delta, a, b, c, d, h0, block_d=block_d,
                                 chunk=chunk, dims=dims, interpret=interpret)


def _scan_fwd(x, delta, a, b, c, d, h0, block_d, chunk, dims, interpret):
    out = selective_scan_kernel(x, delta, a, b, c, d, h0, block_d=block_d,
                                chunk=chunk, dims=dims, interpret=interpret)
    return out, (x, delta, a, b, c, d, h0)


def _scan_bwd(block_d, chunk, dims, interpret, res, cts):
    x, delta, a, b, c, d, h0 = res
    _, vjp = jax.vjp(lambda *args: selective_scan_ref(*args),
                     x, delta, a, b, c, d, h0)
    return vjp(cts)


_scan.defvjp(_scan_fwd, _scan_bwd)


def selective_scan(x, delta, a, b, c, d, h0=None, *,
                   block_d: int | None = None, chunk: int | None = None,
                   dims: str | None = None, tuned: bool | None = None,
                   interpret: bool | None = None):
    """Differentiable fused selective scan; see kernel.py for layout.

    ``tuned=True`` resolves the cached best launch parameters for this
    (shape, dtype, backend) at trace time; ``tuned=None`` does so only
    when tuning was enabled globally (``repro.tune.kernels.configure``).
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    bt, t, di = x.shape
    s = a.shape[1]
    meta = {"bt": bt, "t": t, "di": di, "s": s}
    p = resolve_launch_params(
        "mamba_scan", meta, jnp.float32, defaults=DEFAULTS,
        overrides={"block_d": block_d, "chunk": chunk, "dims": dims},
        tuned=tuned)
    if h0 is None:
        h0 = jnp.zeros((bt, di, s), jnp.float32)
    f32 = functools.partial(jnp.asarray, dtype=jnp.float32)
    return _scan(f32(x), f32(delta), f32(a), f32(b), f32(c), f32(d),
                 f32(h0), p["block_d"], p["chunk"], p["dims"], interpret)
