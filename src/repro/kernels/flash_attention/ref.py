"""Pure-jnp oracle for flash attention (numerically exact softmax)."""

from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal: bool = True,
                  q_offset: int = 0) -> jnp.ndarray:
    """q: (B, Tq, H, hd); k, v: (B, Tk, H, hd). Returns (B, Tq, H, hd)."""
    b, tq, h, hd = q.shape
    tk = k.shape[1]
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * hd ** -0.5
    if causal:
        qpos = q_offset + jnp.arange(tq)
        kpos = jnp.arange(tk)
        mask = qpos[:, None] >= kpos[None, :]
        s = jnp.where(mask[None, None], s, NEG_INF)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
