"""Jit'd public wrapper: (B, T, H, hd) API + custom_vjp over the kernels.

``interpret=None`` auto-selects: Pallas interpret mode on CPU (validation),
compiled Mosaic on TPU.  Launch parameters (``block_q``/``block_k``/
``dims``) resolve in three tiers: hardcoded defaults < the tuned-store
best config for this shape/dtype (``tuned=`` — see
``repro.tune.kernels``) < explicit keyword overrides.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .. import resolve_launch_params
from .kernel import flash_attention_bwd, flash_attention_fwd

DEFAULTS = {"block_q": 128, "block_k": 128, "dims": "parallel"}


def _auto_interpret(interpret):
    if interpret is None:
        return jax.default_backend() == "cpu"
    return interpret


def _fold(x):  # (B, T, H, hd) -> (B*H, T, hd)
    b, t, h, hd = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b * h, t, hd)


def _unfold(x, b, h):  # (B*H, T, hd) -> (B, T, H, hd)
    bh, t, hd = x.shape
    return x.reshape(b, h, t, hd).transpose(0, 2, 1, 3)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _flash(q, k, v, causal, q_offset, interpret, block_q, block_k, dims):
    o, _ = flash_attention_fwd(q, k, v, causal=causal, q_offset=q_offset,
                               block_q=block_q, block_k=block_k, dims=dims,
                               interpret=interpret)
    return o


def _flash_fwd(q, k, v, causal, q_offset, interpret, block_q, block_k, dims):
    o, lse = flash_attention_fwd(q, k, v, causal=causal, q_offset=q_offset,
                                 block_q=block_q, block_k=block_k, dims=dims,
                                 interpret=interpret)
    return o, (q, k, v, o, lse)


def _flash_bwd(causal, q_offset, interpret, block_q, block_k, dims, res, do):
    q, k, v, o, lse = res
    dq, dk, dv = flash_attention_bwd(q, k, v, o, lse, do, causal=causal,
                                     q_offset=q_offset, block_q=block_q,
                                     block_k=block_k, dims=dims,
                                     interpret=interpret)
    return dq, dk, dv


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, q_offset: int = 0,
                    block_q: int | None = None, block_k: int | None = None,
                    dims: str | None = None, tuned: bool | None = None,
                    interpret: bool | None = None) -> jax.Array:
    """q/k/v: (B, T, H, hd), kv already head-repeated. Differentiable.

    ``tuned=True`` resolves the cached best launch parameters for this
    (shape, dtype, backend) from the kernel tuning store at trace time
    (zero measurements; defaults on a miss); ``tuned=None`` does so only
    when tuning was enabled globally (``repro.tune.kernels.configure``).
    """
    b, t, h, hd = q.shape
    interp = _auto_interpret(interpret)
    meta = {"bh": b * h, "tq": t, "tk": k.shape[1], "hd": hd,
            "causal": bool(causal)}
    p = resolve_launch_params(
        "flash_attention", meta, q.dtype, defaults=DEFAULTS,
        overrides={"block_q": block_q, "block_k": block_k, "dims": dims},
        tuned=tuned)
    out = _flash(_fold(q), _fold(k), _fold(v), causal, q_offset, interp,
                 p["block_q"], p["block_k"], p["dims"])
    return _unfold(out, b, h)
