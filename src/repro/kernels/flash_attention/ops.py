"""Jit'd public wrapper: (B, T, H, hd) API + custom_vjp over the kernels.

``interpret=None`` auto-selects: Pallas interpret mode on CPU (validation),
compiled Mosaic on TPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import flash_attention_bwd, flash_attention_fwd


def _auto_interpret(interpret):
    if interpret is None:
        return jax.default_backend() == "cpu"
    return interpret


def _fold(x):  # (B, T, H, hd) -> (B*H, T, hd)
    b, t, h, hd = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b * h, t, hd)


def _unfold(x, b, h):  # (B*H, T, hd) -> (B, T, H, hd)
    bh, t, hd = x.shape
    return x.reshape(b, h, t, hd).transpose(0, 2, 1, 3)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _flash(q, k, v, causal, q_offset, interpret):
    o, _ = flash_attention_fwd(q, k, v, causal=causal, q_offset=q_offset,
                               interpret=interpret)
    return o


def _flash_fwd(q, k, v, causal, q_offset, interpret):
    o, lse = flash_attention_fwd(q, k, v, causal=causal, q_offset=q_offset,
                                 interpret=interpret)
    return o, (q, k, v, o, lse)


def _flash_bwd(causal, q_offset, interpret, res, do):
    q, k, v, o, lse = res
    dq, dk, dv = flash_attention_bwd(q, k, v, o, lse, do, causal=causal,
                                     q_offset=q_offset, interpret=interpret)
    return dq, dk, dv


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, q_offset: int = 0,
                    interpret: bool | None = None) -> jax.Array:
    """q/k/v: (B, T, H, hd), kv already head-repeated. Differentiable."""
    b, t, h, hd = q.shape
    interp = _auto_interpret(interpret)
    out = _flash(_fold(q), _fold(k), _fold(v), causal, q_offset, interp)
    return _unfold(out, b, h)
