"""FlashAttention-2 forward + backward Pallas TPU kernels.

Layout: heads are folded into the batch grid dimension; each (bh, q-block)
cell streams k/v blocks through VMEM, carrying the online-softmax state
(acc, running max m, running sum l) in VMEM scratch across the innermost
grid dimension.  MXU-aligned block sizes (multiples of 128 on the lane
dim; hd padded by the caller if needed).

Forward grid:  (B*H, Tq/bq, Tk/bk)    — k innermost, sequential carry
Backward:
  dq grid      (B*H, Tq/bq, Tk/bk)    — recomputes p per block
  dkv grid     (B*H, Tk/bk, Tq/bq)    — q innermost, accumulates dk/dv

The backward uses the saved forward logsumexp (L = m + log l) and
delta = rowsum(do * o), the standard FA-2 decomposition.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .. import grid_compiler_params, largest_aligned_divisor

NEG_INF = -1e30


def _mask(iq, ik, bq, bk, q_offset):
    qpos = q_offset + iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    return qpos >= kpos


# -- forward --------------------------------------------------------------------

def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref,
                acc_ref, m_ref, l_ref, *, scale, causal, q_offset, n_k):
    iq, ik = pl.program_id(1), pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32) * scale          # (bq, hd)
    k = k_ref[0].astype(jnp.float32)                  # (bk, hd)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk)
    if causal:
        s = jnp.where(_mask(iq, ik, q.shape[0], k.shape[0], q_offset),
                      s, NEG_INF)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
    v = v_ref[0].astype(jnp.float32)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot(p, v)
    m_ref[...] = m_new

    @pl.when(ik == n_k - 1)
    def _final():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)
        lse_ref[0] = m_ref[...] + jnp.log(l)


def flash_attention_fwd(q, k, v, *, causal: bool = True, q_offset: int = 0,
                        block_q: int = 128, block_k: int = 128,
                        dims: str = "parallel", interpret: bool = False):
    """q/k/v: (BH, T, hd) with kv already head-repeated. Returns (o, lse)."""
    bh, tq, hd = q.shape
    tk = k.shape[1]
    block_q = largest_aligned_divisor(tq, block_q, align=8)
    block_k = largest_aligned_divisor(tk, block_k, align=8)
    n_q, n_k = tq // block_q, tk // block_k
    scale = hd ** -0.5
    kernel = functools.partial(_fwd_kernel, scale=scale, causal=causal,
                               q_offset=q_offset, n_k=n_k)
    return pl.pallas_call(
        kernel,
        grid=(bh, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_q), lambda b, i, j: (b, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(q.shape, q.dtype),
            jax.ShapeDtypeStruct((bh, tq), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, hd), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        compiler_params=grid_compiler_params(dims, 2, 1),
        interpret=interpret,
    )(q, k, v)


# -- backward ---------------------------------------------------------------------

def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref,
               acc_ref, *, scale, causal, q_offset, n_k):
    iq, ik = pl.program_id(1), pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale
    k = k_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))
    if causal:
        s = jnp.where(_mask(iq, ik, q.shape[0], k.shape[0], q_offset),
                      s, NEG_INF)
    p = jnp.exp(s - lse_ref[0][:, None])                   # (bq, bk)
    do = do_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())))
    ds = p * (dp - delta_ref[0][:, None])
    acc_ref[...] += jax.lax.dot(ds, k) * scale

    @pl.when(ik == n_k - 1)
    def _final():
        dq_ref[0] = acc_ref[...].astype(dq_ref.dtype)


def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                dk_ref, dv_ref, dk_acc, dv_acc, *, scale, causal,
                q_offset, n_q):
    ik, iq = pl.program_id(1), pl.program_id(2)

    @pl.when(iq == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    q = q_ref[0].astype(jnp.float32) * scale
    k = k_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))   # (bq, bk)
    if causal:
        s = jnp.where(_mask(iq, ik, q.shape[0], k.shape[0], q_offset),
                      s, NEG_INF)
    p = jnp.exp(s - lse_ref[0][:, None])
    do = do_ref[0].astype(jnp.float32)
    dv_acc[...] += jax.lax.dot_general(p, do, (((0,), (0,)), ((), ())))
    v = v_ref[0].astype(jnp.float32)
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())))
    ds = p * (dp - delta_ref[0][:, None])
    dk_acc[...] += jax.lax.dot_general(ds, q, (((0,), (0,)), ((), ())))

    @pl.when(iq == n_q - 1)
    def _final():
        dk_ref[0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_acc[...].astype(dv_ref.dtype)


def flash_attention_bwd(q, k, v, o, lse, do, *, causal: bool = True,
                        q_offset: int = 0, block_q: int = 128,
                        block_k: int = 128, dims: str = "parallel",
                        interpret: bool = False):
    bh, tq, hd = q.shape
    tk = k.shape[1]
    block_q = largest_aligned_divisor(tq, block_q, align=8)
    block_k = largest_aligned_divisor(tk, block_k, align=8)
    n_q, n_k = tq // block_q, tk // block_k
    scale = hd ** -0.5
    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1)

    dq = pl.pallas_call(
        functools.partial(_dq_kernel, scale=scale, causal=causal,
                          q_offset=q_offset, n_k=n_k),
        grid=(bh, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_q), lambda b, i, j: (b, i)),
            pl.BlockSpec((1, block_q), lambda b, i, j: (b, i)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[pltpu.VMEM((block_q, hd), jnp.float32)],
        compiler_params=grid_compiler_params(dims, 2, 1),
        interpret=interpret,
    )(q, k, v, do, lse, delta)

    dk, dv = pl.pallas_call(
        functools.partial(_dkv_kernel, scale=scale, causal=causal,
                          q_offset=q_offset, n_q=n_q),
        grid=(bh, n_k, n_q),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, j, i: (b, i, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, j, i: (b, j, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, j, i: (b, j, 0)),
            pl.BlockSpec((1, block_q, hd), lambda b, j, i: (b, i, 0)),
            pl.BlockSpec((1, block_q), lambda b, j, i: (b, i)),
            pl.BlockSpec((1, block_q), lambda b, j, i: (b, i)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_k, hd), lambda b, j, i: (b, j, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, j, i: (b, j, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct(k.shape, k.dtype),
                   jax.ShapeDtypeStruct(v.shape, v.dtype)],
        scratch_shapes=[pltpu.VMEM((block_k, hd), jnp.float32),
                        pltpu.VMEM((block_k, hd), jnp.float32)],
        compiler_params=grid_compiler_params(dims, 2, 1),
        interpret=interpret,
    )(q, k, v, do, lse, delta)
    return dq, dk, dv
