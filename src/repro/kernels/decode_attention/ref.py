"""Pure-jnp oracle for single-token GQA decode attention."""

from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e30


def decode_attention_ref(q, k, v, *, length=None):
    """q: (B, H, hd); k/v: (B, S, KV, hd); length: scalar or None.

    Attends over positions < length (all S if None). Returns (B, H, hd) f32.
    """
    b, h, hd = q.shape
    s_len, kv = k.shape[1], k.shape[2]
    rep = h // kv
    qf = q.astype(jnp.float32).reshape(b, kv, rep, hd) * hd ** -0.5
    s = jnp.einsum("bgrh,bsgh->bgrs", qf, k.astype(jnp.float32))
    if length is not None:
        valid = jnp.arange(s_len) < length
        s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bgrs,bsgh->bgrh", p, v.astype(jnp.float32))
    return out.reshape(b, h, hd)
