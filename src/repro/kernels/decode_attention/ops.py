"""Jit'd wrapper: (B, H, hd) x (B, S, KV, hd) GQA decode attention.

Launch parameters (``block_s``/``dims``) resolve defaults < tuned store
(``tuned=``, see ``repro.tune.kernels``) < explicit overrides.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import resolve_launch_params
from .kernel import decode_attention_kernel

DEFAULTS = {"block_s": 512, "splits": 1, "dims": "parallel"}


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                     length: jax.Array | int | None = None,
                     block_s: int | None = None, splits: int | None = None,
                     dims: str | None = None, tuned: bool | None = None,
                     interpret: bool | None = None) -> jax.Array:
    """q: (B, H, hd); k/v: (B, S, KV, hd). Returns (B, H, hd) fp32.

    ``tuned=True`` resolves the cached best launch parameters for this
    (shape, dtype, backend) at trace time; ``tuned=None`` does so only
    when tuning was enabled globally (``repro.tune.kernels.configure``).
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    b, h, hd = q.shape
    kv = k.shape[2]
    rep = h // kv
    meta = {"b": b, "kv": kv, "rep": rep, "hd": hd, "s": k.shape[1]}
    p = resolve_launch_params(
        "decode_attention", meta, q.dtype, defaults=DEFAULTS,
        overrides={"block_s": block_s, "splits": splits, "dims": dims},
        tuned=tuned)
    if length is None:
        length = k.shape[1]
    length = jnp.asarray(length, jnp.int32).reshape(1)
    qg = q.reshape(b, kv, rep, hd)
    out = decode_attention_kernel(qg, k, v, length, block_s=p["block_s"],
                                  splits=p["splits"], dims=p["dims"],
                                  interpret=interpret)
    return out.reshape(b, h, hd)
