"""Jit'd wrapper: (B, H, hd) x (B, S, KV, hd) GQA decode attention."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import decode_attention_kernel


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                     length: jax.Array | int | None = None,
                     block_s: int = 512,
                     interpret: bool | None = None) -> jax.Array:
    """q: (B, H, hd); k/v: (B, S, KV, hd). Returns (B, H, hd) fp32."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    b, h, hd = q.shape
    kv = k.shape[2]
    rep = h // kv
    if length is None:
        length = k.shape[1]
    length = jnp.asarray(length, jnp.int32).reshape(1)
    qg = q.reshape(b, kv, rep, hd)
    out = decode_attention_kernel(qg, k, v, length, block_s=block_s,
                                  interpret=interpret)
    return out.reshape(b, h, hd)
