"""Flash-decode Pallas TPU kernel: one query token vs a long KV cache.

Grid (B, KV, splits, S/splits/bs): for each (batch, kv-head) the cache
is partitioned into ``splits`` independent segments; each segment
streams its blocks through VMEM, carrying the online-softmax state for
the ``rep = H/KV`` query heads that share this kv head, and emits an
*unnormalised* partial (acc, m, l).  The partials are combined outside
the kernel with one logsumexp rescale — the standard split-KV decode
trick: more segments expose more grid parallelism on a cache too long
for one sequential sweep, at the cost of a (tiny) combine.  The grouped
layout makes the score matmul (rep x hd) @ (hd x bs) — MXU-shaped when
rep is padded to 8 sublanes — and reads each cache block exactly once
(the HBM roofline for decode).  ``splits`` and ``block_s`` are both
tuned (``repro.tune.kernels``).

A ``length`` scalar (SMEM) masks positions >= length, so one compiled
kernel serves any fill level of a fixed-capacity cache.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .. import grid_compiler_params, largest_aligned_divisor

NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, acc_out_ref, m_out_ref, l_out_ref,
            acc_ref, m_ref, l_ref, *, scale, n_s, block_s, seg):
    sp = pl.program_id(2)
    js = pl.program_id(3)

    @pl.when(js == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale       # (rep, hd)
    k = k_ref[0][:, 0].astype(jnp.float32)            # (bs, hd)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (rep, bs)
    pos = (sp * seg + js * block_s
           + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1))
    s = jnp.where(pos < len_ref[0], s, NEG_INF)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
    v = v_ref[0][:, 0].astype(jnp.float32)            # (bs, hd)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot(p, v)
    m_ref[...] = m_new

    @pl.when(js == n_s - 1)
    def _final():
        acc_out_ref[0, 0, 0] = acc_ref[...]
        m_out_ref[0, 0, 0] = m_ref[...]
        l_out_ref[0, 0, 0] = l_ref[...]


def decode_attention_kernel(q, k, v, length, *, block_s: int = 512,
                            splits: int = 1, dims: str = "parallel",
                            interpret: bool = False):
    """q: (B, KV, rep, hd); k/v: (B, S, KV, hd); length: (1,) int32.

    Returns (B, KV, rep, hd) fp32.
    """
    b, kv, rep, hd = q.shape
    s_len = k.shape[1]
    splits = largest_aligned_divisor(s_len, max(int(splits), 1))
    seg = s_len // splits
    block_s = largest_aligned_divisor(seg, block_s, align=8)
    n_s = seg // block_s
    kernel = functools.partial(_kernel, scale=hd ** -0.5, n_s=n_s,
                               block_s=block_s, seg=seg)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,                 # `length` lands in SMEM
        grid=(b, kv, splits, n_s),
        in_specs=[
            pl.BlockSpec((1, 1, rep, hd),
                         lambda b_, g, sp, j, *_: (b_, g, 0, 0)),
            pl.BlockSpec((1, block_s, 1, hd),
                         lambda b_, g, sp, j, *_: (b_, sp * n_s + j, g, 0)),
            pl.BlockSpec((1, block_s, 1, hd),
                         lambda b_, g, sp, j, *_: (b_, sp * n_s + j, g, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, rep, hd),
                         lambda b_, g, sp, j, *_: (b_, sp, g, 0, 0)),
            pl.BlockSpec((1, 1, 1, rep),
                         lambda b_, g, sp, j, *_: (b_, sp, g, 0)),
            pl.BlockSpec((1, 1, 1, rep),
                         lambda b_, g, sp, j, *_: (b_, sp, g, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((rep, hd), jnp.float32),
            pltpu.VMEM((rep,), jnp.float32),
            pltpu.VMEM((rep,), jnp.float32),
        ],
    )
    acc, m, l = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, splits, kv, rep, hd), jnp.float32),
            jax.ShapeDtypeStruct((b, splits, kv, rep), jnp.float32),
            jax.ShapeDtypeStruct((b, splits, kv, rep), jnp.float32),
        ],
        compiler_params=grid_compiler_params(dims, 3, 1),
        interpret=interpret,
    )(length, q, k, v)
    # combine the per-split partials with one logsumexp rescale
    m_tot = m.max(axis=1)                             # (b, kv, rep)
    w = jnp.exp(m - m_tot[:, None])
    l_tot = (l * w).sum(axis=1)
    o = (acc * w[..., None]).sum(axis=1)
    return o / jnp.maximum(l_tot, 1e-30)[..., None]
