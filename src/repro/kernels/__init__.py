"""Pallas TPU kernels for the repo's compute hot spots.

Each kernel lives in its own package: ``kernel.py`` (the Pallas grid
program), ``ops.py`` (the jit'd public wrapper, differentiable where
training needs it) and ``ref.py`` (the pure-jnp oracle the kernel is
validated against).

Launch parameters (block sizes, chunk lengths, grid-dimension
semantics) are tunable: every ``ops.py`` entry point accepts explicit
overrides, and a ``tuned=`` switch that resolves the cached best
configuration for the call's shape/dtype from ``repro.tune.kernels``
(the paper's combinatorial-search loop applied to the kernels
themselves).  This module holds the two pieces shared by all kernels:

  * :func:`largest_aligned_divisor` — clamp a requested block size to a
    valid divisor of the extent (preferring hardware-aligned multiples),
  * :func:`resolve_launch_params` — defaults < tuned cache < explicit
    overrides, with the tuned lookup deferred so the kernels stay
    importable without the tuning stack.
"""

from __future__ import annotations

import sys
from typing import Any, Mapping

__all__ = ["largest_aligned_divisor", "grid_compiler_params",
           "resolve_launch_params"]


def largest_aligned_divisor(n: int, cap: int, align: int = 1) -> int:
    """Largest divisor of ``n`` that is ``<= cap``, preferring multiples
    of ``align`` (sublane/lane tiling) when any exist under the cap.

    Replaces the per-kernel ``while n % block: block -= 1`` linear scans:
    divisors are enumerated in O(sqrt n), and the alignment preference
    keeps clamped blocks on the TPU tile grid (8 sublanes for f32)
    instead of landing on an arbitrary odd divisor.  ``n >= 1`` always
    yields at least 1.
    """
    if n < 1:
        raise ValueError(f"extent must be >= 1, got {n}")
    cap = max(min(cap, n), 1)
    divisors = []
    i = 1
    while i * i <= n:
        if n % i == 0:
            if i <= cap:
                divisors.append(i)
            if n // i <= cap:
                divisors.append(n // i)
        i += 1
    aligned = [d for d in divisors if d % align == 0]
    return max(aligned or divisors)


def grid_compiler_params(dims: str, n_parallel: int, n_carry: int):
    """Mosaic compiler params for a kernel grid: the first ``n_parallel``
    grid dimensions get ``dims`` semantics (``"parallel"`` lets Mosaic
    reorder/parallelize them, ``"arbitrary"`` keeps the nested-loop
    order), and the trailing ``n_carry`` dimensions — those carrying
    VMEM scratch state — are always ``"arbitrary"``.  This is the
    grid-layout variant in each kernel's tuning space; interpret mode
    accepts and ignores it.
    """
    from jax.experimental.pallas import tpu as pltpu  # deferred, like jax

    if dims not in ("parallel", "arbitrary"):
        raise ValueError(f"dims must be 'parallel' or 'arbitrary', "
                         f"got {dims!r}")
    semantics = (dims,) * n_parallel + ("arbitrary",) * n_carry
    return pltpu.TPUCompilerParams(dimension_semantics=semantics)


def resolve_launch_params(kernel: str, meta: Mapping[str, Any], dtype: Any,
                          *, defaults: Mapping[str, Any],
                          overrides: Mapping[str, Any] | None = None,
                          tuned: bool | None = None) -> dict:
    """Launch parameters for one kernel call.

    Precedence: hardcoded ``defaults`` < tuned-store best config <
    caller ``overrides`` (entries that are not ``None``).  ``tuned=None``
    consults the cache only when kernel tuning was enabled globally
    (``repro.tune.kernels.configure``); ``tuned=True`` always consults
    it; ``tuned=False`` never does.  The lookup happens at trace time
    (shapes are static) and performs zero measurements — a store miss
    falls back to the defaults.
    """
    params = dict(defaults)
    # tuned=None can only resolve after repro.tune.kernels.configure()
    # ran, which requires the module to be imported — so when it is not
    # in sys.modules, skip without pulling in the tuning stack at all
    if tuned or (tuned is None and "repro.tune.kernels" in sys.modules):
        from ..tune import kernels as ktune
        if tuned or ktune.tuning_enabled():
            best = ktune.resolve_config(kernel, meta, dtype)
            params.update({k: v for k, v in best.items() if k in params})
    if overrides:
        params.update({k: v for k, v in overrides.items() if v is not None})
    return params
