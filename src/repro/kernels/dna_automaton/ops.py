"""Public API: parallel DFA motif matching + motif-table construction.

``fa_match`` = state-map kernel -> host-side associative compose (an
O(log n_chunks) ``associative_scan`` of S-vectors) -> count kernel.
Composition is ``m_ab = m_b[m_a]`` — tested associative-property via
hypothesis in tests/test_dna_kernel.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .. import largest_aligned_divisor, resolve_launch_params
from .kernel import count_hits_kernel, state_map_kernel

DNA_SYMBOLS = "ACGT"

DEFAULTS = {"map_chunk": 2048, "count_chunk": 2048, "dims": "parallel"}


def build_motif_dfa(motif: str) -> tuple[np.ndarray, np.ndarray]:
    """KMP-style DFA over {A,C,G,T} recognising ``motif`` occurrences.

    Returns (table (S, 4) int32, accept (S,) bool) with S = len(motif)+1;
    the accept state loops via its failure function so overlapping
    occurrences all count.
    """
    m = len(motif)
    sym_of = {c: i for i, c in enumerate(DNA_SYMBOLS)}
    pat = [sym_of[c] for c in motif]
    table = np.zeros((m + 1, 4), np.int32)
    table[0, :] = 0
    if m:
        table[0, pat[0]] = 1
    x = 0
    for j in range(1, m + 1):
        for c in range(4):
            table[j, c] = table[x, c]
        if j < m:
            table[j, pat[j]] = j + 1
            x = table[x, pat[j]]
    accept = np.zeros(m + 1, bool)
    accept[m] = True
    return table, accept


def compose_maps(maps: jax.Array) -> jax.Array:
    """Prefix-compose chunk state maps: out[i] = m_0..i (inclusive)."""
    def combine(a, b):            # a then b
        return jnp.take_along_axis(b, a, axis=-1)

    return jax.lax.associative_scan(combine, maps, axis=0)


def fa_match(text: jax.Array, table: jax.Array, accept: jax.Array, *,
             chunk: int | None = None, map_chunk: int | None = None,
             count_chunk: int | None = None, dims: str | None = None,
             start_state: int = 0, tuned: bool | None = None,
             interpret: bool | None = None) -> jax.Array:
    """Total motif matches in ``text`` ((T,) uint8 symbols). int32 scalar.

    The two passes chunk independently (``map_chunk``/``count_chunk``);
    ``chunk`` sets both at once (legacy knob).  The count pass needs the
    automaton state at its own chunk boundaries, so ``count_chunk`` must
    be a multiple of ``map_chunk`` — otherwise it is clamped down to the
    map granularity.  ``tuned=True`` resolves the cached best launch
    parameters for this (shape, dtype, backend) at trace time;
    ``tuned=None`` does so only when tuning was enabled globally
    (``repro.tune.kernels.configure``).
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    table = jnp.asarray(table, jnp.int32)
    accept = jnp.asarray(accept)
    t = text.shape[0]
    meta = {"t": t, "s": table.shape[0]}
    p = resolve_launch_params(
        "dna_automaton", meta, text.dtype, defaults=DEFAULTS,
        overrides={"map_chunk": map_chunk if map_chunk is not None else chunk,
                   "count_chunk": (count_chunk if count_chunk is not None
                                   else chunk),
                   "dims": dims},
        tuned=tuned)
    mc = largest_aligned_divisor(t, p["map_chunk"])
    cc = largest_aligned_divisor(t, p["count_chunk"])
    if cc % mc:
        cc = mc
    maps = state_map_kernel(text, table, chunk=mc,
                            dims=p["dims"], interpret=interpret)
    prefix = compose_maps(maps)                       # (T/mc, S)
    # start state of count chunk k = automaton state at position k*cc,
    # i.e. the prefix map after map chunk k*(cc/mc) - 1
    rep = cc // mc
    starts = jnp.concatenate([
        jnp.asarray([start_state], jnp.int32),
        prefix[rep - 1::rep, start_state][:t // cc - 1].astype(jnp.int32),
    ])
    counts, _ = count_hits_kernel(text, table, accept, starts,
                                  chunk=cc, dims=p["dims"],
                                  interpret=interpret)
    return counts.sum(dtype=jnp.int32)
