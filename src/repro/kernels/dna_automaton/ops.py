"""Public API: parallel DFA motif matching + motif-table construction.

``fa_match`` = state-map kernel -> host-side associative compose (an
O(log n_chunks) ``associative_scan`` of S-vectors) -> count kernel.
Composition is ``m_ab = m_b[m_a]`` — tested associative-property via
hypothesis in tests/test_dna_kernel.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .kernel import count_hits_kernel, state_map_kernel

DNA_SYMBOLS = "ACGT"


def build_motif_dfa(motif: str) -> tuple[np.ndarray, np.ndarray]:
    """KMP-style DFA over {A,C,G,T} recognising ``motif`` occurrences.

    Returns (table (S, 4) int32, accept (S,) bool) with S = len(motif)+1;
    the accept state loops via its failure function so overlapping
    occurrences all count.
    """
    m = len(motif)
    sym_of = {c: i for i, c in enumerate(DNA_SYMBOLS)}
    pat = [sym_of[c] for c in motif]
    table = np.zeros((m + 1, 4), np.int32)
    table[0, :] = 0
    if m:
        table[0, pat[0]] = 1
    x = 0
    for j in range(1, m + 1):
        for c in range(4):
            table[j, c] = table[x, c]
        if j < m:
            table[j, pat[j]] = j + 1
            x = table[x, pat[j]]
    accept = np.zeros(m + 1, bool)
    accept[m] = True
    return table, accept


def compose_maps(maps: jax.Array) -> jax.Array:
    """Prefix-compose chunk state maps: out[i] = m_0..i (inclusive)."""
    def combine(a, b):            # a then b
        return jnp.take_along_axis(b, a, axis=-1)

    return jax.lax.associative_scan(combine, maps, axis=0)


def fa_match(text: jax.Array, table: jax.Array, accept: jax.Array, *,
             chunk: int = 2048, start_state: int = 0,
             interpret: bool | None = None) -> jax.Array:
    """Total motif matches in ``text`` ((T,) uint8 symbols). int32 scalar."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    table = jnp.asarray(table, jnp.int32)
    accept = jnp.asarray(accept)
    maps = state_map_kernel(text, table, chunk=chunk, interpret=interpret)
    prefix = compose_maps(maps)                       # (n_chunks, S)
    starts = jnp.concatenate([
        jnp.asarray([start_state], jnp.int32),
        prefix[:-1, start_state].astype(jnp.int32),
    ])
    counts, _ = count_hits_kernel(text, table, accept, starts, chunk=chunk,
                                  interpret=interpret)
    return counts.sum(dtype=jnp.int32)
