"""Pure-jnp oracle for finite-automaton DNA motif matching.

The paper's workload (PaREM [24] / refs [11,12]): run a DFA over a DNA
byte stream and count accepting-state visits (motif matches).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def fa_match_ref(text: jnp.ndarray, table: jnp.ndarray,
                 accept: jnp.ndarray, start_state: int = 0):
    """text: (T,) uint8 symbols in [0, n_sym); table: (S, n_sym) int32;
    accept: (S,) bool.  Returns (match_count, final_state)."""
    n_sym = table.shape[1]

    def step(state, sym):
        state = table[state, sym]
        return state, accept[state]

    final, hits = jax.lax.scan(step, jnp.int32(start_state),
                               text.astype(jnp.int32))
    return hits.sum(dtype=jnp.int32), final


def chunk_state_map_ref(chunk: jnp.ndarray, table: jnp.ndarray):
    """End state for EVERY start state after consuming ``chunk``.

    This is the associative element of parallel FA matching: maps compose
    as ``m_ab = m_b[m_a]``.  Returns (S,) int32.
    """
    s = table.shape[0]

    def step(states, sym):
        return table[states, sym], None

    states, _ = jax.lax.scan(step, jnp.arange(s, dtype=jnp.int32),
                             chunk.astype(jnp.int32))
    return states
