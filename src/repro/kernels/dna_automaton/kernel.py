"""Chunk-parallel finite-automaton matching as Pallas TPU kernels.

The paper's application is DFA-based DNA motif search (PaREM).  A DFA is
sequential per symbol, but transition functions COMPOSE: processing a
chunk from every possible start state yields a state-map vector
m: S -> S, and m_ab = m_b[m_a].  That composition is associative — the
classic parallel-FA-matching decomposition, and the reason this workload
is "divisible" in the paper's sense (any chunk boundary works).

Kernel 1 (``state_map``):   grid (n_chunks,) — each cell walks its chunk
    once carrying the full S-vector of states in VREGs (the transition
    table lives in VMEM; S and n_sym are tiny for DNA motifs).
Kernel 2 (``count_hits``):  given each chunk's true start state (from the
    host-side associative compose of the maps), each cell re-walks its
    chunk counting accepting-state visits.

HBM traffic: the text is read exactly twice; table/maps are negligible.
The gather T[state, sym] vectorises over the S lanes (kernel 1) and over
parallel streams (kernel 2).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .. import grid_compiler_params, largest_aligned_divisor


def _state_map_kernel(text_ref, table_ref, map_ref, *, chunk):
    tbl = table_ref[...]                          # (S, n_sym) int32
    s, n_sym = tbl.shape
    flat = tbl.reshape(-1)

    def step(t, states):
        sym = text_ref[t]
        return jnp.take(flat, states * n_sym + sym)

    states0 = jax.lax.broadcasted_iota(jnp.int32, (s,), 0)
    map_ref[0, :] = jax.lax.fori_loop(0, chunk, step, states0)


def state_map_kernel(text, table, *, chunk: int = 2048,
                     dims: str = "parallel", interpret: bool = False):
    """text: (T,) int32; table: (S, n_sym) int32 -> maps (T/chunk, S)."""
    t = text.shape[0]
    chunk = largest_aligned_divisor(t, chunk)
    n_chunks = t // chunk
    s = table.shape[0]
    return pl.pallas_call(
        functools.partial(_state_map_kernel, chunk=chunk),
        grid=(n_chunks,),
        in_specs=[
            pl.BlockSpec((chunk,), lambda i: (i,)),
            pl.BlockSpec(table.shape, lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, s), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_chunks, s), jnp.int32),
        compiler_params=grid_compiler_params(dims, 1, 0),
        interpret=interpret,
    )(text.astype(jnp.int32), table.astype(jnp.int32))


def _count_kernel(text_ref, table_ref, accept_ref, start_ref,
                  count_ref, state_ref, *, chunk):
    tbl = table_ref[...]
    s, n_sym = tbl.shape
    flat = tbl.reshape(-1)
    acc = accept_ref[...]                          # (S,) int32 0/1

    def step(t, carry):
        state, hits = carry
        sym = text_ref[t]
        state = flat[state * n_sym + sym]
        return state, hits + acc[state]

    state0 = start_ref[0]
    state, hits = jax.lax.fori_loop(0, chunk, step,
                                    (state0, jnp.int32(0)))
    count_ref[0] = hits
    state_ref[0] = state


def count_hits_kernel(text, table, accept, starts, *, chunk: int = 2048,
                      dims: str = "parallel", interpret: bool = False):
    """Counts accepting visits per chunk given per-chunk start states."""
    t = text.shape[0]
    chunk = largest_aligned_divisor(t, chunk)
    n_chunks = t // chunk
    return pl.pallas_call(
        functools.partial(_count_kernel, chunk=chunk),
        grid=(n_chunks,),
        in_specs=[
            pl.BlockSpec((chunk,), lambda i: (i,)),
            pl.BlockSpec(table.shape, lambda i: (0, 0)),
            pl.BlockSpec(accept.shape, lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_chunks,), jnp.int32),
            jax.ShapeDtypeStruct((n_chunks,), jnp.int32),
        ],
        compiler_params=grid_compiler_params(dims, 1, 0),
        interpret=interpret,
    )(text.astype(jnp.int32), table.astype(jnp.int32),
      accept.astype(jnp.int32), starts.astype(jnp.int32))
