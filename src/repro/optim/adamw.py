"""AdamW from scratch, with optionally int8 block-quantized moments.

Large-model memory budgeting on 256 chips (EXPERIMENTS.md §Dry-run) needs
the optimizer to cost ~2 bytes/param instead of 8: moments are stored as
int8 with per-block absmax scales and dequantized on the fly inside the
(fully sharded) update.  Quantization blocks run along the LAST parameter
axis (padded to a block multiple) so the quantized state carries exactly
the parameter's sharding spec — no per-step resharding collectives.
fp32 moments remain the default for convergence-sensitive runs.

The update is standard decoupled-weight-decay Adam with global-norm
gradient clipping and bias correction.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

Params = Any

BLOCK = 256


@dataclass(frozen=True)
class AdamWConfig:
    learning_rate: float | Callable[[jax.Array], jax.Array] = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moments_dtype: str = "float32"   # "float32" | "int8"

    def lr_at(self, step: jax.Array) -> jax.Array:
        if callable(self.learning_rate):
            return self.learning_rate(step)
        return jnp.float32(self.learning_rate)


# -- int8 block quantization (last-axis blocks, sharding-aligned) ---------------
#
# First moment m (signed): linear absmax blocks.  Second moment v (>= 0)
# feeds a DIVISION, so linear quantization is catastrophic (small entries
# in a block with one large entry collapse to 0 -> update = m/eps); v is
# quantized LOGARITHMICALLY instead, giving bounded multiplicative error.

def quantize_moment(x: jax.Array, log: bool = False) -> dict:
    last = x.shape[-1] if x.ndim else 1
    xe = x.reshape(x.shape or (1,))
    pad = (-last) % BLOCK
    if pad:
        xe = jnp.pad(xe, [(0, 0)] * (xe.ndim - 1) + [(0, pad)])
    blocks = xe.reshape(*xe.shape[:-1], -1, BLOCK)
    if log:
        # floor must stay in the fp32 NORMAL range: XLA flushes subnormals
        # to zero and log2(0) = -inf poisons the whole block
        l = jnp.log2(jnp.maximum(blocks, 1e-30))
        lmin = l.min(axis=-1)
        lmax = l.max(axis=-1)
        scale = jnp.maximum((lmax - lmin) / 254.0, 1e-9)          # (..., nb)
        q = jnp.round((l - lmin[..., None]) / scale[..., None]) - 127.0
        return {"q": q.reshape(xe.shape).astype(jnp.int8),
                "scale": scale.astype(jnp.float32),
                "minv": lmin.astype(jnp.float32)}
    scale = jnp.max(jnp.abs(blocks), axis=-1) / 127.0             # (..., nb)
    q = jnp.round(blocks / jnp.maximum(scale[..., None], 1e-20))
    return {"q": q.reshape(xe.shape).astype(jnp.int8),
            "scale": scale.astype(jnp.float32)}


def dequantize_moment(d: dict, shape: tuple) -> jax.Array:
    q = d["q"].astype(jnp.float32)
    blocks = q.reshape(*q.shape[:-1], -1, BLOCK)
    if "minv" in d:
        l = d["minv"][..., None] + (blocks + 127.0) * d["scale"][..., None]
        blocks = jnp.exp2(l)
        blocks = jnp.where(l <= -95.0, 0.0, blocks)
    else:
        blocks = blocks * d["scale"][..., None]
    flat = blocks.reshape(q.shape)
    last = shape[-1] if shape else 1
    out = flat[..., :last]
    return out.reshape(shape)


def _moment_zeros(p: jax.Array, dtype: str, log: bool = False):
    if dtype == "int8":
        return quantize_moment(jnp.zeros(p.shape, jnp.float32), log=log)
    return jnp.zeros(p.shape, jnp.float32)


# -- optimizer ------------------------------------------------------------------

def init_opt_state(params: Params, cfg: AdamWConfig) -> dict:
    return {
        "m": jax.tree.map(lambda p: _moment_zeros(p, cfg.moments_dtype),
                          params),
        "v": jax.tree.map(lambda p: _moment_zeros(p, cfg.moments_dtype,
                                                  log=True), params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Params) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def apply_updates(params: Params, grads: Params, state: dict,
                  cfg: AdamWConfig) -> tuple[Params, dict]:
    count = state["count"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12)) \
        if cfg.grad_clip > 0 else jnp.float32(1.0)
    lr = cfg.lr_at(count)
    bc1 = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** count.astype(jnp.float32)
    quant = cfg.moments_dtype == "int8"

    def update_leaf(p, g, m, v):
        g32 = g.astype(jnp.float32) * clip
        m32 = dequantize_moment(m, p.shape) if quant else m
        v32 = dequantize_moment(v, p.shape) if quant else v
        m32 = cfg.b1 * m32 + (1.0 - cfg.b1) * g32
        v32 = cfg.b2 * v32 + (1.0 - cfg.b2) * jnp.square(g32)
        upd = (m32 / bc1) / (jnp.sqrt(v32 / bc2) + cfg.eps)
        decay = cfg.weight_decay if p.ndim >= 2 else 0.0  # no decay on norms
        new_p = p.astype(jnp.float32) - lr * (upd + decay * p.astype(jnp.float32))
        return (new_p.astype(p.dtype),
                quantize_moment(m32) if quant else m32,
                quantize_moment(v32, log=True) if quant else v32)

    p_leaves, tdef = jax.tree.flatten(params)
    g_leaves = tdef.flatten_up_to(grads)
    m_leaves = tdef.flatten_up_to(state["m"])
    v_leaves = tdef.flatten_up_to(state["v"])
    out = [update_leaf(p, g, m, v)
           for p, g, m, v in zip(p_leaves, g_leaves, m_leaves, v_leaves)]
    new_params = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_params, {"m": new_m, "v": new_v, "count": count}
