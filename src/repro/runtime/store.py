"""Persistent tuning cache keyed by workload signature.

Tuned configurations are expensive — the paper's SAML still costs
hundreds of measurements per workload — and the seed threw them away
after every run.  ``TuningStore`` persists ``TuneReport``s to a JSON
file keyed by a **workload signature**: a hash of the config space
(names, values, ordinality), a caller-supplied workload payload (batch
shapes, request mix, anything that changes measured times) and the
device topology.  A repeated workload is served from the cache with
zero new measurements; any change to space, workload or topology
changes the signature and forces a fresh search.

The unified facade consumes this through ``TuningSession(store=...)``
(``repro.tune.session``; entries are keyed per strategy *and* objective)
and the deprecated ``Autotuner`` through its ``warm_start=`` /
``record_to=`` knobs; the online feedback loop (``runtime/feedback.py``)
persists its observation arrays next to the JSON via the NPZ side-car
helpers.  Records round-trip as ``TuneResult`` (``TuneReport`` is its
legacy alias).
"""

from __future__ import annotations

import hashlib
import json
import os
import zipfile
from dataclasses import asdict
from pathlib import Path
from typing import Any, Mapping

import numpy as np

from ..core.autotuner import TuneReport
from ..core.space import ConfigSpace

__all__ = ["TuningStore", "space_fingerprint", "workload_signature"]


def _canon(obj: Any):
    """Canonicalize a workload payload for hashing.

    Semantically identical payloads must hash identically regardless of
    how the caller spelled them: dict keys are stringified and sorted
    (insertion order never matters), tuples and lists normalize to one
    shape, sets/frozensets are ordered, numpy scalars/arrays become
    plain Python.  Anything else falls back to ``repr``.
    """
    if isinstance(obj, Mapping):
        return {str(k): _canon(obj[k]) for k in sorted(obj, key=str)}
    if isinstance(obj, (list, tuple)):
        return [_canon(v) for v in obj]
    if isinstance(obj, (set, frozenset)):
        return sorted((_canon(v) for v in obj), key=repr)
    if isinstance(obj, np.ndarray):
        return [_canon(v) for v in obj.tolist()]
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.floating,)):
        return float(obj)
    if isinstance(obj, (str, int, float, bool)) or obj is None:
        return obj
    return repr(obj)


def _sha(payload: Any) -> str:
    blob = json.dumps(payload, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(blob.encode()).hexdigest()


def space_fingerprint(space: ConfigSpace) -> str:
    """Hash of the space structure: parameter names, domains, ordinality."""
    return _sha([[p.name, _canon(p.values), bool(p.ordinal)]
                 for p in space.params])[:16]


def device_topology() -> list[list]:
    """Summary of the visible JAX devices: (platform, kind, count)."""
    import jax

    counts: dict[tuple, int] = {}
    for d in jax.devices():
        key = (d.platform, getattr(d, "device_kind", ""))
        counts[key] = counts.get(key, 0) + 1
    return [[p, k, n] for (p, k), n in sorted(counts.items())]


def workload_signature(space: ConfigSpace,
                       workload: Mapping[str, Any] | None = None,
                       devices: Any = None) -> str:
    """Cache key: space hash + workload payload + device topology.

    ``devices`` defaults to the live ``jax.devices()`` summary; pass an
    explicit value (any canonicalizable object) to pin the signature in
    tests or across hosts.
    """
    return _sha({
        "space": space_fingerprint(space),
        "workload": _canon(workload),
        "devices": _canon(devices if devices is not None
                          else device_topology()),
    })


def _report_to_json(report: TuneReport) -> dict:
    d = asdict(report)
    d["checkpoints"] = {str(k): [e, cfg]
                        for k, (e, cfg) in report.checkpoints.items()}
    return d


def _report_from_json(d: Mapping[str, Any]) -> TuneReport:
    kw = dict(d)
    kw["checkpoints"] = {int(k): (float(e), dict(cfg))
                         for k, (e, cfg) in d.get("checkpoints", {}).items()}
    kw["from_cache"] = True
    return TuneReport(**kw)


class TuningStore:
    """JSON-backed map: workload signature -> recorded ``TuneReport``s.

    One store file holds many workloads; each entry keeps one report per
    strategy.  ``lookup``/``record`` are what ``Autotuner.tune`` calls;
    ``save_observations``/``load_observations`` persist feedback-loop
    arrays as an NPZ side-car per signature.
    """

    def __init__(self, path: str | os.PathLike, *, devices: Any = None):
        self.path = Path(path)
        self.devices = devices          # pin topology, or None for live
        self._data: dict[str, dict] = {}
        if self.path.exists():
            self._data = self._load_or_quarantine()

    def _load_or_quarantine(self) -> dict:
        """Load the JSON store, surviving corruption.

        A truncated/unparsable file, a non-object payload, or a
        checksummed file whose digest mismatches is moved aside to
        ``<name>.corrupt-<sha8>`` (``runtime.checkpoint.quarantine``)
        with a structured warning, and the store starts fresh — a
        corrupt cache must never take the tuner down with it.  Both
        layouts load: the legacy flat ``{sig: entry}`` and the
        checksummed ``{"checksum", "entries"}`` that :meth:`_flush`
        writes.
        """
        from .checkpoint import quarantine
        try:
            data = json.loads(self.path.read_text())
            if not isinstance(data, dict):
                raise ValueError("store payload is not an object")
            if "entries" in data and "checksum" in data:
                entries = data["entries"]
                if not isinstance(entries, dict):
                    raise ValueError("store entries is not an object")
                if data["checksum"] != _sha(entries):
                    raise ValueError("store checksum mismatch")
                return entries
            return data                         # legacy flat layout
        except (ValueError, UnicodeDecodeError) as exc:
            quarantine(self.path, reason=f"tuning store: {exc}")
            return {}

    # -- keys --------------------------------------------------------------
    def signature(self, space: ConfigSpace,
                  workload: Mapping[str, Any] | None) -> str:
        return workload_signature(space, workload, devices=self.devices)

    # -- report cache -------------------------------------------------------
    def lookup(self, space: ConfigSpace,
               workload: Mapping[str, Any] | None,
               strategy: str) -> TuneReport | None:
        entry = self._data.get(self.signature(space, workload))
        if entry is None or strategy.upper() not in entry.get("reports", {}):
            return None
        return _report_from_json(entry["reports"][strategy.upper()])

    def best_record(self, space: ConfigSpace,
                    workload: Mapping[str, Any] | None) -> TuneReport | None:
        """Best recorded report for a workload across *all* strategies.

        This is the resolution path of the kernel ``tuned=`` fast path
        (``repro.tune.kernels.resolve_config``): whichever strategy
        produced the lowest measured score wins, no matter which one the
        caller tuned with.  Returns ``None`` when the workload has no
        entry (callers fall back to their defaults).
        """
        entry = self._data.get(self.signature(space, workload))
        if entry is None or not entry.get("reports"):
            return None
        best = min(entry["reports"].values(),
                   key=lambda d: float(d.get("best_energy_measured",
                                             float("inf"))))
        return _report_from_json(best)

    def record(self, space: ConfigSpace,
               workload: Mapping[str, Any] | None,
               strategy: str, report: TuneReport) -> str:
        sig = self.signature(space, workload)
        entry = self._data.setdefault(sig, {
            "space": space_fingerprint(space),
            "workload": _canon(workload),
            "reports": {},
        })
        entry["reports"][strategy.upper()] = _report_to_json(report)
        self._flush()
        return sig

    def __len__(self) -> int:
        return len(self._data)

    def _flush(self) -> None:
        # Checksummed envelope: the loader verifies the digest against the
        # entries so a torn write surfaces as quarantine, not silent
        # corruption.  Written atomically (tmp + rename).
        self.path.parent.mkdir(parents=True, exist_ok=True)
        tmp = self.path.with_suffix(self.path.suffix + ".tmp")
        payload = {"checksum": _sha(self._data), "entries": self._data}
        tmp.write_text(json.dumps(payload, indent=1, sort_keys=True))
        os.replace(tmp, self.path)

    # -- observation side-car (NPZ) ----------------------------------------
    def _npz_path(self, sig: str) -> Path:
        return self.path.parent / f"{self.path.stem}-{sig[:16]}.npz"

    def save_observations(self, sig: str, **arrays: np.ndarray) -> Path:
        """Persist feedback-loop arrays (e.g. host_X/host_y/dev_X/dev_y)."""
        out = self._npz_path(sig)
        out.parent.mkdir(parents=True, exist_ok=True)
        np.savez(out, **{k: np.asarray(v) for k, v in arrays.items()})
        return out

    def load_observations(self, sig: str) -> dict[str, np.ndarray] | None:
        p = self._npz_path(sig)
        if not p.exists():
            return None
        try:
            with np.load(p) as z:
                return {k: z[k] for k in z.files}
        except (ValueError, OSError, zipfile.BadZipFile) as exc:
            # A torn NPZ side-car must not take the feedback loop down:
            # quarantine it and report "no observations" (cold start).
            from .checkpoint import quarantine
            quarantine(p, reason=f"observation side-car: {exc}")
            return None
