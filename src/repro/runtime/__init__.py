"""Online work-distribution runtime: the paper's tuner made live.

The offline layer (``repro.core``) finds a near-optimal static work
split with SAML and throws the result away; this package keeps the loop
closed at run time (usage guide: ``docs/runtime.md``):

``scheduler`` — chunked online dispatch.
    :class:`~repro.runtime.scheduler.ChunkedScheduler` splits each batch
    into device-aligned chunks, overlaps dispatch across N
    ``DeviceGroup``s (double-buffered, bounded in-flight depth) and
    rebalances the split from measured per-chunk times via
    :func:`~repro.runtime.scheduler.ewma_rebalance` — the N-group
    generalization of ``proportional_rebalance``.

``feedback`` — online surrogate refits.
    :class:`~repro.runtime.feedback.OnlineSurrogateLoop` appends live
    (config, time) observations and warm-refits the BDTR pair in place
    (``fit_more`` + incremental hist binning), so the next
    ``tune_saml`` searches a surrogate grounded in live data.

``store`` — persistent tuning cache.
    :class:`~repro.runtime.store.TuningStore` keys recorded
    ``TuneResult``s by workload signature (space hash + shapes + device
    topology); ``repro.tune.TuningSession(store=...)`` serves repeated
    workloads with zero new measurements.

``stream`` — streaming pipeline scenario.
    :class:`~repro.runtime.stream.StreamingPipeline` drives a stream of
    batches with overlapped transfer/compute per chunk;
    ``launch/serve.py`` uses it so serving sessions adapt their split
    per request mix.

``guard`` — kill-switch guardrail (``docs/resilience.md``).
    :class:`~repro.runtime.guard.ServeGuard` watches the realized
    step-time trajectory through a :class:`~repro.runtime.guard.KillSwitch`
    and pins the last known-good static split when the online
    controller regresses, re-arming after a cool-down probe.

``simulate`` — deterministic sims, clocks and fault injection.
    :class:`~repro.runtime.simulate.VirtualClock` +
    :class:`~repro.runtime.simulate.FaultPlan` /
    :class:`~repro.runtime.simulate.FaultInjector` script failures
    (kill/slow/transient/recover, plus process-level crash/torn)
    against the serial-device sim or real dispatch, deterministically.

``checkpoint`` — crash durability (``docs/resilience.md``).
    :class:`~repro.runtime.checkpoint.WalWriter` appends a CRC'd
    write-ahead request log that survives ``kill -9`` and truncates
    torn tails on reopen; :func:`~repro.runtime.checkpoint.save_snapshot`
    / :func:`~repro.runtime.checkpoint.load_snapshot` checkpoint soft
    state with checksums (corruption quarantines via
    :func:`~repro.runtime.checkpoint.quarantine` instead of crashing);
    :class:`~repro.runtime.checkpoint.MeasurementLedger` makes tuning
    runs resumable — a crashed search replays its measured prefix from
    the ledger instead of re-spending the budget.
"""

from .checkpoint import (MeasurementLedger, SimulatedCrash, WalWriter,
                         load_snapshot, quarantine, read_wal, save_snapshot)
from .feedback import OnlineSurrogateLoop
from .guard import KillSwitch, ServeGuard, fallback_from_store
from .scheduler import ChunkedScheduler, EwmaController, ewma_rebalance
from .simulate import (FaultInjector, FaultPlan, GroupFailure, VirtualClock,
                       make_serial_sim_builder, parse_fault_plan,
                       sim_skew_groups)
from .store import TuningStore, space_fingerprint, workload_signature
from .stream import StreamingPipeline, dna_stream_builder

__all__ = [
    "ChunkedScheduler", "EwmaController", "ewma_rebalance",
    "KillSwitch", "ServeGuard", "fallback_from_store",
    "MeasurementLedger", "SimulatedCrash", "WalWriter",
    "load_snapshot", "quarantine", "read_wal", "save_snapshot",
    "FaultInjector", "FaultPlan", "GroupFailure", "VirtualClock",
    "make_serial_sim_builder", "parse_fault_plan", "sim_skew_groups",
    "OnlineSurrogateLoop",
    "TuningStore", "space_fingerprint", "workload_signature",
    "StreamingPipeline", "dna_stream_builder",
]
