"""Chunked online work distribution across N device groups.

``HeterogeneousRunner`` (the paper's runtime, ``core/hetero.py``) does
one static split per batch: each group gets its whole share in a single
dispatch, and the split moves only between batches.  This module turns
that into a live scheduler:

  * each incoming batch is split into **chunks** (row slices aligned to
    each group's device count);
  * chunks are dispatched **asynchronously** and interleaved across
    groups, with at most ``inflight`` chunks outstanding per group —
    JAX's async dispatch overlaps chunk k+1's transfer/launch with chunk
    k's compute (double buffering), and the inflight bound keeps live
    buffers constant;
  * per-chunk completion times feed an **EWMA controller**
    (``ewma_rebalance``) that re-splits the next batch — the N-group
    generalization of ``core.hetero.proportional_rebalance``;
  * group membership is **elastic**: ``drop_group``/``restore_group``
    remove and re-admit groups mid-stream (shares re-project onto the
    simplex, plans re-key), and a dispatch that raises or times out
    **demotes** the group automatically, re-dispatching its unfinished
    chunks to the survivors so no batch is ever dropped
    (``docs/resilience.md``).

Chunk inputs are annotated with ``dist.api.constrain_leading`` so that
when mesh rules are installed (see ``docs/dist.md``) each chunk carries
its data-parallel layout into jit.
"""

from __future__ import annotations

import time
from collections import deque
from concurrent.futures import ThreadPoolExecutor
from concurrent.futures import TimeoutError as FutureTimeoutError
from dataclasses import dataclass, field
from typing import Callable, Sequence

import numpy as np

import jax

from ..core.hetero import DeviceGroup, result_ready_time
from ..dist.api import constrain_leading
from ..obs import as_observer

__all__ = ["ChunkedScheduler", "EwmaController", "ewma_rebalance"]


def _slice_spans(spans: Sequence[tuple[int, int]], lo: int,
                 count: int) -> list[tuple[int, int]]:
    """Sub-spans covering rows ``[lo, lo + count)`` of the concatenation
    of ``spans`` (each a ``(batch_row_start, n_rows)`` pair).  Used to
    keep per-row completion attribution exact through the re-dispatch
    path, where orphaned chunks are merged and re-split."""
    out = []
    pos = 0
    for start, n in spans:
        take_lo = max(lo, pos)
        take_hi = min(lo + count, pos + n)
        if take_hi > take_lo:
            out.append((start + take_lo - pos, take_hi - take_lo))
        pos += n
    return out


def _project_simplex_floor(w: np.ndarray, floor: float) -> np.ndarray:
    """Nearest share vector with ``sum == 1`` and every entry ``>= floor``
    (scales the above-floor mass uniformly)."""
    n = len(w)
    free = 1.0 - floor * n
    if free <= 0:
        return np.full(n, 1.0 / n)
    slack = np.maximum(np.asarray(w, dtype=np.float64) - floor, 0.0)
    total = slack.sum()
    if total <= 0:
        return np.full(n, 1.0 / n)
    return floor + slack * (free / total)


def ewma_rebalance(shares: Sequence[float], times: Sequence[float],
                   damping: float = 0.5, min_share: float = 0.01,
                   rows: Sequence[int] | None = None) -> np.ndarray:
    """New work shares from observed per-group times (N groups).

    Rates are ``r_i = rows_i / t_i`` (or ``shares_i / t_i`` when row
    counts are not given); the equal-finish-time target is
    ``r_i / sum(r)``, and the update is the EWMA
    ``(1 - damping) * shares + damping * target`` — for two groups with
    ``rows=None`` this is exactly ``proportional_rebalance``.  Degenerate
    measurements (any ``t_i <= 0``) keep the current shares; the result
    is clamped to ``>= min_share`` per group so no group is ever starved
    permanently.
    """
    shares = _project_simplex_floor(np.asarray(shares, np.float64), min_share)
    times = np.asarray(times, dtype=np.float64)
    if times.shape != shares.shape:
        raise ValueError("times must align with shares")
    if (times <= 0.0).any():
        return shares
    work = shares if rows is None else np.asarray(rows, dtype=np.float64)
    rates = work / times
    target = rates / rates.sum()
    out = (1.0 - damping) * shares + damping * target
    return _project_simplex_floor(out, min_share)


@dataclass
class EwmaController:
    """Stateful wrapper around ``ewma_rebalance`` holding current shares
    and **live membership**: dropped groups hold exactly share 0 and are
    excluded from updates; the surviving shares always form a simplex
    floored at ``min_share``."""

    n_groups: int
    damping: float = 0.5
    min_share: float = 0.01
    shares: np.ndarray = field(default=None)  # type: ignore[assignment]
    live: np.ndarray = field(default=None)    # type: ignore[assignment]
    observer: object = field(default=None, repr=False)

    def __post_init__(self):
        # normalize once: disabled observers become None so every
        # per-update check is a single `is not None`
        self.observer = as_observer(self.observer)
        if self.n_groups < 1:
            raise ValueError("need at least one group")
        if self.live is None:
            self.live = np.ones(self.n_groups, dtype=bool)
        else:
            self.live = np.asarray(self.live, dtype=bool).copy()
            if self.live.shape != (self.n_groups,):
                raise ValueError("live mask must have one entry per group")
            if not self.live.any():
                raise ValueError("at least one group must be live")
        if self.shares is None:
            self.shares = np.where(self.live, 1.0 / self.live.sum(), 0.0)
        self.shares = np.asarray(self.shares, np.float64).copy()
        if len(self.shares) != self.n_groups:
            raise ValueError("shares must have one entry per group")
        self._project()

    def _project(self) -> np.ndarray:
        """Re-project: live shares onto the floored simplex, dead to 0."""
        out = np.zeros(self.n_groups)
        out[self.live] = _project_simplex_floor(
            np.asarray(self.shares, np.float64)[self.live], self.min_share)
        self.shares = out
        return out

    @property
    def n_live(self) -> int:
        return int(self.live.sum())

    def drop(self, i: int) -> np.ndarray:
        """Remove group ``i``: its share goes to exactly 0 and the
        survivors re-project onto the simplex.  Idempotent (demotion can
        race a scripted kill).  The last live group cannot be dropped."""
        if not 0 <= i < self.n_groups:
            raise IndexError(f"group {i} out of range")
        if not self.live[i]:
            return self.shares
        if self.n_live == 1:
            raise RuntimeError("cannot drop the last live group")
        self.live[i] = False
        self.shares[i] = 0.0
        return self._project()

    def restore(self, i: int, share: float | None = None) -> np.ndarray:
        """Re-admit group ``i`` at ``share`` (default ``1 / n_groups``;
        the EWMA pulls it to its rate-proportional share within a few
        steps — even a sliver yields an unbiased rate estimate, since
        rates are rows/time).  Idempotent."""
        if not 0 <= i < self.n_groups:
            raise IndexError(f"group {i} out of range")
        if self.live[i]:
            return self.shares
        if share is None:
            share = 1.0 / self.n_groups
        share = float(min(max(share, self.min_share), 1.0 - self.min_share))
        self.live[i] = True
        self.shares *= (1.0 - share)        # survivors scale down ...
        self.shares[i] = share              # ... to make room
        return self._project()

    def update(self, times: Sequence[float],
               rows: Sequence[int] | None = None) -> np.ndarray:
        """EWMA-rebalance the live groups from observed times (entries
        for dead groups are ignored; their shares stay exactly 0)."""
        times = np.asarray(times, dtype=np.float64)
        if times.shape != (self.n_groups,):
            raise ValueError("times must have one entry per group")
        live = self.live
        if live.all():
            self.shares = ewma_rebalance(self.shares, times, self.damping,
                                         self.min_share, rows=rows)
            self._observe_update()
            return self.shares
        sub_rows = None if rows is None else np.asarray(rows)[live]
        sub = ewma_rebalance(self.shares[live] / self.shares[live].sum(),
                             times[live], self.damping, self.min_share,
                             rows=sub_rows)
        out = np.zeros(self.n_groups)
        out[live] = sub
        self.shares = out
        self._observe_update()
        return self.shares

    def _observe_update(self) -> None:
        if self.observer is None:
            return
        m = self.observer.metrics
        m.counter("controller.updates").inc()
        for i, s in enumerate(self.shares):
            m.gauge(f"controller.share.g{i}").set(round(float(s), 6))

    # -- durability (runtime.checkpoint snapshots) -------------------------
    def state_dict(self) -> dict:
        """JSON-ready recoverable state: shares + live mask."""
        return {"shares": [float(s) for s in self.shares],
                "live": [bool(x) for x in self.live]}

    def load_state(self, state: dict) -> None:
        """Restore a :meth:`state_dict` snapshot (re-projected, so a
        hand-edited or stale snapshot still yields a valid simplex)."""
        live = np.asarray(state["live"], dtype=bool)
        shares = np.asarray(state["shares"], np.float64)
        if live.shape != (self.n_groups,) \
                or shares.shape != (self.n_groups,):
            raise ValueError("snapshot group count mismatch")
        if not live.any():
            raise ValueError("snapshot has no live group")
        self.live = live.copy()
        self.shares = shares.copy()
        self._project()


class ChunkedScheduler:
    """Split each batch into chunks, overlap dispatch across N groups,
    rebalance the split online from measured per-chunk times, and
    survive groups degrading or vanishing mid-stream."""

    def __init__(self, step_builder: Callable[[DeviceGroup], Callable],
                 groups: Sequence[DeviceGroup], *,
                 controller: EwmaController | None = None,
                 chunks_per_group: int = 2, inflight: int = 2,
                 row_quantum: int = 1, clock=None,
                 dispatch_timeout_s: float | None = None,
                 observer=None):
        """``step_builder(group)`` returns ``fn(chunk) -> result`` exactly
        as for ``HeterogeneousRunner`` (results block via
        ``block_until_ready`` leaves).  ``chunks_per_group`` bounds how
        finely each group's share is sliced; ``inflight`` is the per-group
        dispatch depth (2 = double buffering).  ``row_quantum`` coarsens
        chunk-size rounding to multiples of ``quantum * n_devices`` rows:
        jitted step functions recompile per distinct chunk shape, so a
        coarser quantum keeps the shape set small while shares drift.
        Controller-driven steps additionally serve their row/chunk plan
        from a debounced cache (see ``_planned_rows``) so timing noise
        never churns the compiled-shape set.

        ``clock`` (anything with ``now()``, e.g. a shared
        ``runtime.simulate.VirtualClock``) replaces the wall clock for
        deterministic simulated trajectories.  ``dispatch_timeout_s``
        bounds the drain wait per group and step: a group that exceeds
        it is demoted exactly like one whose dispatch raised.

        ``observer`` (a ``repro.obs.Observer``, default off) records
        dispatch/drain spans per group lane, plan-cache hit/miss
        counters, a step-latency histogram, and the semantic decision
        journal (rebalance adopted/debounced, demotion, re-dispatch).
        Share the observer's clock with ``clock`` for deterministic
        traces.  Every instrumentation block is guarded on the resolved
        observer, so a disabled/absent one costs nothing per step."""
        if not groups:
            raise ValueError("need at least one device group")
        if chunks_per_group < 1 or inflight < 1 or row_quantum < 1:
            raise ValueError("chunks_per_group, inflight and row_quantum "
                             "must be >= 1")
        self.groups = list(groups)
        self.controller = controller or EwmaController(len(self.groups))
        if self.controller.n_groups != len(self.groups):
            raise ValueError("controller group count mismatch")
        self.chunks_per_group = chunks_per_group
        self.inflight = inflight
        self.row_quantum = row_quantum
        self.clock = clock
        self.dispatch_timeout_s = dispatch_timeout_s
        self._fns = [step_builder(g) for g in self.groups]
        self._plans: dict[tuple, dict] = {}  # (rows, membership) -> plan
        self.history: list[dict] = []
        self._obs = as_observer(observer)
        if self._obs is not None:
            if self.controller.observer is None:
                self.controller.observer = self._obs
            m = self._obs.metrics
            self._m_plan_hit = m.counter("scheduler.plan_cache_hits")
            self._m_plan_miss = m.counter("scheduler.plan_cache_misses")
            self._m_steps = m.counter("scheduler.steps")
            self._m_rows = m.counter("scheduler.rows_completed")
            self._m_redispatch = m.counter("scheduler.redispatched_rows")
            self._h_step = m.histogram("scheduler.t_step_s")
            # stable lanes: one per group (index, not OS thread id) plus
            # a step lane — traces compare across runs and machines
            for gi, g in enumerate(self.groups):
                self._obs.tracer.thread_name(gi, f"group:{g.name}")
            self._obs.tracer.thread_name(len(self.groups), "scheduler")

    @property
    def shares(self) -> np.ndarray:
        return self.controller.shares

    @property
    def live(self) -> np.ndarray:
        return self.controller.live

    def _now(self) -> float:
        return self.clock.now() if self.clock is not None \
            else time.perf_counter()

    # -- elastic membership ------------------------------------------------
    def drop_group(self, i: int, reason: str = "manual") -> None:
        """Remove group ``i`` from dispatch: its share goes to 0, the
        survivors re-normalize, and the next step plans (under a new
        membership key — never a stale pre-drop plan) without it.
        ``reason`` lands in the decision journal (``group_demoted``)."""
        was_live = bool(self.controller.live[i])
        self.controller.drop(i)
        if self._obs is not None and was_live:
            self._obs.journal.event(
                "group_demoted", group=self.groups[i].name, index=i,
                reason=reason, n_live=self.controller.n_live)
            self._obs.tracer.instant("demote", tid=i,
                                     args={"reason": reason})

    def restore_group(self, i: int, share: float | None = None) -> None:
        """Re-admit group ``i``; the EWMA wins its share back from live
        measurements within a few steps."""
        was_live = bool(self.controller.live[i])
        self.controller.restore(i, share)
        if self._obs is not None and not was_live:
            self._obs.journal.event(
                "group_restored", group=self.groups[i].name, index=i,
                share=round(float(self.controller.shares[i]), 6),
                n_live=self.controller.n_live)
            self._obs.tracer.instant("restore", tid=i)

    def _live_key(self) -> int:
        return int(np.packbits(self.controller.live, bitorder="little")
                   .view(np.uint8)[0]) if self.controller.n_groups <= 8 \
            else hash(tuple(bool(x) for x in self.controller.live))

    # -- planning ----------------------------------------------------------
    def plan_rows(self, n: int) -> list[int]:
        """Per-group row counts for a batch of ``n`` rows.

        Dropped groups get exactly 0 rows.  Every live group gets at
        least one device-aligned sliver; all live groups except the
        largest-share one are rounded to multiples of their device
        count, and the largest-share group absorbs the remainder
        (exactly aligned whenever ``n`` divides by the total live device
        count and groups are equally sized, as in the tests/benchmarks).
        """
        live = self.controller.live
        align = [len(g.devices) for g in self.groups]
        live_align = sum(a for a, l in zip(align, live) if l)
        if n < live_align:
            raise ValueError(f"batch of {n} rows is smaller than one row "
                             f"per live device ({live_align})")
        shares = self.controller.shares
        big = int(np.argmax(shares))          # dead shares are 0: big is live
        rows = [0] * len(self.groups)
        for i, (g, s) in enumerate(zip(align, shares)):
            if i == big or not live[i]:
                continue
            q = g * self.row_quantum            # shape-stable rounding
            rows[i] = max(int(round(n * s / q)) * q, g)
        rest = n - sum(rows)
        while rest < align[big]:
            # reclaim alignment units from the largest other group so the
            # largest-share group is never starved (n >= live aligns
            # guarantees termination: with every other live group at its
            # minimum, rest >= align[big])
            cands = [i for i in range(len(rows))
                     if i != big and rows[i] > align[i]]
            j = max(cands, key=lambda i: rows[i])
            rows[j] -= align[j]
            rest += align[j]
        rows[big] = rest
        return rows

    def _planned_rows(self, n: int, rebalance: bool) -> tuple[list[int], bool]:
        """(row plan for this step, whether a known size's plan changed).

        Recompiles are the dominant cost of chunked dispatch: every new
        row split means new chunk shapes, and on near-equal groups the
        EWMA's response to timing noise would produce a new split almost
        every step — each recompile then poisons the next measurement,
        drifting the shares further (the positive-feedback loop behind
        the old 4x online-vs-static gap in BENCH_runtime.json).  Two
        regimes break it:

          * ``rebalance=False`` — the caller manages the shares (e.g. a
            split tuner sweeping fractions): the freshly computed plan is
            always honored, so measurements reflect the assigned split;
          * ``rebalance=True`` — controller-driven: the cached plan (and
            with it every compiled chunk shape) is reused until the
            freshly computed plan **deviates from it on two consecutive
            steps**.  A single noisy measurement moves the shares once
            and the next clean measurement pulls them back, so one-step
            flicker never recompiles; persistent movement (real skew,
            convergence) lands its new plan one step later.

        Plans are cached per **(batch size, group membership)** — a
        membership change (drop/restore) switches keys, so a post-drop
        batch of a known size can never reuse a stale plan that would
        dispatch rows to a dead group.  ``step`` skips the controller
        update on share-driven replan steps (their measured times
        include compilation of the new shapes and would re-poison the
        shares); a first-seen key does not suppress the update —
        freezing the shares on an all-new-sizes stream would be worse
        than one noisy measurement per size.
        """
        key = (n, self._live_key())
        fresh = self.plan_rows(n)
        plan = self._plans.get(key)
        if plan is not None:
            if fresh == plan["rows"]:
                plan["pending"] = None
                if self._obs is not None:
                    self._m_plan_hit.inc()
                return plan["rows"], False
            if rebalance and plan["pending"] is None:
                plan["pending"] = list(fresh)    # first deviation: debounce
                if self._obs is not None:
                    self._m_plan_hit.inc()
                    self._obs.journal.event(
                        "rebalance_debounced", batch=n,
                        kept=list(plan["rows"]), deviating=list(fresh))
                return plan["rows"], False
        if len(self._plans) >= 64 and key not in self._plans:
            self._plans.pop(next(iter(self._plans)))   # bound the cache
        self._plans[key] = {"rows": list(fresh), "pending": None,
                            "chunks": [self._chunk_sizes(r, len(g.devices))
                                       for r, g in zip(fresh, self.groups)]}
        if self._obs is not None:
            self._m_plan_miss.inc()
            if plan is not None:
                self._obs.journal.event(
                    "rebalance_adopted", batch=n,
                    old=list(plan["rows"]), new=list(fresh))
        # a replan of a known key is share-driven (possibly
        # compile-tainted measurement); a new key is just a new plan
        return self._plans[key]["rows"], plan is not None

    def _chunk_sizes(self, rows: int, align: int) -> list[int]:
        """Split one group's share into up to ``chunks_per_group`` aligned
        chunks (first chunk takes any residual); rounding uses the row
        quantum so chunk shapes stay stable as shares drift.  Zero rows
        (a dropped group) yield no chunks."""
        if rows <= 0:
            return []
        q = align * self.row_quantum
        per = rows // (self.chunks_per_group * q) * q
        if per == 0:
            per = rows // (self.chunks_per_group * align) * align
        if per == 0:
            return [rows]
        sizes = [per] * self.chunks_per_group
        sizes[0] += rows - per * self.chunks_per_group
        return [s for s in sizes if s > 0]

    @staticmethod
    def _block(result) -> None:
        for leaf in jax.tree.leaves(result):
            blocker = getattr(leaf, "block_until_ready", None)
            if blocker is not None:
                blocker()

    @property
    def _drain_pool(self) -> ThreadPoolExecutor:
        # lazy: schedulers built in tests/benches that never step should
        # not spawn threads (an unreferenced scheduler's idle workers
        # also exit on GC via the executor's weakref sentinel)
        pool = getattr(self, "_pool", None)
        if pool is None:
            pool = self._pool = ThreadPoolExecutor(
                max_workers=len(self.groups),
                thread_name_prefix="chunked-drain")
        return pool

    def close(self) -> None:
        """Release the drain worker threads of a long-lived scheduler."""
        pool = getattr(self, "_pool", None)
        if pool is not None:
            pool.shutdown(wait=False)
            self._pool = None

    # -- redispatch after a failure ----------------------------------------
    def _redispatch_split(self, n: int, live_idx: list[int]) -> list[tuple[int, int]]:
        """(group index, rows) assignments for ``n`` orphaned rows across
        the live groups — shares-proportional, device-aligned, no
        min-sliver requirement (zero rows for a group is fine here).
        Falls back to the largest-share group when proportional rounding
        cannot stay aligned; raises if no live group's alignment divides
        the residue (equal-sized groups and ``row_quantum`` planning keep
        this from happening in practice)."""
        shares = self.controller.shares
        align = [len(self.groups[i].devices) for i in live_idx]
        order = sorted(range(len(live_idx)),
                       key=lambda k: -shares[live_idx[k]])
        big = order[0]
        rows = [0] * len(live_idx)
        rest = n
        for k in order[1:]:
            a = align[k]
            r = min(int(n * shares[live_idx[k]]) // a * a, rest)
            rows[k] = r
            rest -= r
        if rest % align[big] == 0:
            rows[big] = rest
        else:
            # push the misaligned residue onto any group that fits it
            for k in order:
                if rest % align[k] == 0:
                    rows[k] += rest
                    rest = 0
                    break
            else:
                raise RuntimeError(
                    f"cannot re-dispatch {rest} orphaned rows: no live "
                    f"group's device count divides them (aligns "
                    f"{align})")
        return [(live_idx[k], r) for k, r in enumerate(rows) if r > 0]

    # -- the online step ---------------------------------------------------
    def step(self, batch: dict, rebalance: bool = True) -> dict:
        """Dispatch one batch; returns the step record (and appends it to
        ``history``).

        A group whose dispatch raises (e.g. ``GroupFailure`` from fault
        injection or a real device error) or whose drain exceeds
        ``dispatch_timeout_s`` is demoted mid-step: its share drops to 0,
        survivors re-normalize, and all of its unconfirmed chunks are
        re-dispatched to the survivors — every row of the batch completes
        on a live group (at-least-once: a chunk whose result was in
        flight when the group died may have run twice).  Failure steps
        never feed the controller (their times are recovery-tainted).
        Raises ``RuntimeError`` if every group fails.
        """
        n = jax.tree.leaves(batch)[0].shape[0]
        rows, plan_changed = self._planned_rows(n, rebalance)
        plan = self._plans[(n, self._live_key())]

        # contiguous per-group row ranges, then per-group chunk slices
        # (sizes come from the plan cache — no recompute per step);
        # each chunk carries its batch-row span so per-row completion
        # instants can be attributed back to the rows (and, one layer
        # up, to the requests) it served
        offsets = np.concatenate([[0], np.cumsum(rows)])
        chunks: list[list[dict]] = []
        chunk_rows: list[list[int]] = []
        chunk_spans: list[list[list[tuple[int, int]]]] = []
        for gi, g in enumerate(self.groups):
            sizes = plan["chunks"][gi]
            lo = int(offsets[gi])
            group_chunks = []
            group_spans = []
            for s in sizes:
                sl = jax.tree.map(lambda x, lo=lo, s=s: x[lo:lo + s], batch)
                group_chunks.append(constrain_leading(sl))
                group_spans.append([(lo, s)])
                lo += s
            chunks.append(group_chunks)
            chunk_rows.append(list(sizes))
            chunk_spans.append(group_spans)

        t0 = self._now()
        n_groups = len(self.groups)
        pending: list[deque] = [deque() for _ in range(n_groups)]
        # per-group clocks start at the group's own first dispatch:
        # measuring every group from the common t0 would bill group k the
        # dispatch latency of groups 0..k-1, and the controller would
        # "rebalance" that constant bias into a real share drift on
        # equal-speed groups (new shapes, recompiles) — group times must
        # estimate device speed, not dispatch order
        t_start = [None] * n_groups
        t_done = [0.0] * n_groups
        t_done_abs = [0.0] * n_groups
        chunk_times: list[list[float]] = [[] for _ in range(n_groups)]
        done_rows = [0] * n_groups        # rows confirmed complete
        done_chunks = [0] * n_groups      # planned chunks confirmed complete
        failures: dict[int, str] = {}
        # absolute completion instant per batch row (the serving layer
        # turns these into per-request latencies); rows of a failed
        # chunk stay NaN until their re-dispatch completes — drain
        # threads write disjoint slices, so no lock is needed
        row_done_at = np.full(n, np.nan)

        def record(gi: int, res, r: int, spans) -> None:
            # emulated results expose their exact completion instant;
            # real arrays are timestamped as their drain returns
            ready = result_ready_time(res)
            now = ready if ready is not None else self._now()
            if self._obs is not None:
                # one span per chunk on the group's lane, back-to-back
                # from the group's first dispatch (timestamps come from
                # the shared clock, so traces are deterministic even
                # though this runs on a drain thread)
                prev = chunk_times[gi][-1] if chunk_times[gi] else 0.0
                self._obs.tracer.complete(
                    "chunk", t_start[gi] + prev,
                    (now - t_start[gi]) - prev, tid=gi, args={"rows": r})
            chunk_times[gi].append(now - t_start[gi])
            t_done[gi] = now - t_start[gi]
            t_done_abs[gi] = max(t_done_abs[gi], now - t0)
            done_rows[gi] += r
            for start, cnt in spans:
                row_done_at[start:start + cnt] = now

        def fail(gi: int, err: BaseException | str) -> None:
            failures[gi] = err if isinstance(err, str) \
                else f"{type(err).__name__}: {err}"
            pending[gi].clear()           # unconfirmed results are orphaned
            if self._obs is not None:
                self._obs.tracer.instant("failure", tid=gi,
                                         args={"error": failures[gi]})

        def drain_one(gi: int) -> bool:
            res, r, planned, spans = pending[gi].popleft()
            try:
                self._block(res)
            except Exception as e:  # noqa: BLE001 — demotion boundary
                fail(gi, e)
                return False
            record(gi, res, r, spans)
            if planned:
                done_chunks[gi] += 1
            return True

        def dispatch(gi: int, chunk, r: int, planned: bool, spans) -> bool:
            if t_start[gi] is None:
                t_start[gi] = self._now()
            if self._obs is not None:
                self._obs.tracer.instant("dispatch", tid=gi,
                                         args={"rows": r})
            try:
                res = self._fns[gi](chunk)
            except Exception as e:  # noqa: BLE001 — demotion boundary
                fail(gi, e)
                return False
            pending[gi].append((res, r, planned, spans))
            return True

        # interleave dispatch round-robin by chunk index so every group
        # starts working immediately; bound the per-group queue depth
        max_chunks = max((len(c) for c in chunks), default=0)
        for ci in range(max_chunks):
            for gi in range(n_groups):
                if gi in failures or ci >= len(chunks[gi]):
                    continue
                if len(pending[gi]) >= self.inflight and not drain_one(gi):
                    continue
                dispatch(gi, chunks[gi][ci], chunk_rows[gi][ci], True,
                         chunk_spans[gi][ci])

        # drain each group in its own worker thread: block_until_ready
        # releases the GIL, so every group's completion is timestamped
        # exactly when it happens (a later-indexed fast group is never
        # measured at a slower group's completion), with zero host-side
        # polling — the old is_ready/sleep loop cost ~ms per step in
        # redundant host syncs
        def drain_group(gi: int) -> None:
            while pending[gi]:
                if not drain_one(gi):
                    return

        futures = {gi: self._drain_pool.submit(drain_group, gi)
                   for gi in range(n_groups)
                   if pending[gi] and gi not in failures}
        for gi, f in futures.items():
            try:
                f.result(timeout=self.dispatch_timeout_s)
            except FutureTimeoutError:
                fail(gi, f"drain timed out after {self.dispatch_timeout_s}s")
                # the worker is still blocked on the dead dispatch — the
                # pool cannot be reused safely, so a fresh one is built
                # lazily on the next step
                pool = getattr(self, "_pool", None)
                if pool is not None:
                    pool.shutdown(wait=False)
                    self._pool = None

        # -- demote failed groups and re-dispatch their orphans ------------
        redispatched = 0
        if failures:
            orphans: list[tuple] = []       # (chunk, rows, spans) triples
            for gi in failures:
                if self.controller.live[gi]:
                    if self.controller.n_live == 1:
                        raise RuntimeError(
                            f"all device groups failed: {failures}")
                    self.drop_group(gi, reason=failures[gi])
                orphans.extend(zip(chunks[gi][done_chunks[gi]:],
                                   chunk_rows[gi][done_chunks[gi]:],
                                   chunk_spans[gi][done_chunks[gi]:]))
            attempts = 0
            while orphans:
                attempts += 1
                if attempts > n_groups:
                    raise RuntimeError(
                        f"re-dispatch kept failing: {failures}")
                merged = jax.tree.map(
                    lambda *xs: np.concatenate([np.asarray(x) for x in xs],
                                               axis=0),
                    *[c for c, _, _ in orphans])
                merged_spans = [sp for _, _, spans in orphans for sp in spans]
                n_orphan = sum(r for _, r, _ in orphans)
                orphans = []
                live_idx = [i for i in range(n_groups)
                            if self.controller.live[i]]
                lo = 0
                retry: list[tuple[int, dict, int, list]] = []
                for gi, r in self._redispatch_split(n_orphan, live_idx):
                    sl = jax.tree.map(
                        lambda x, lo=lo, r=r: x[lo:lo + r], merged)
                    retry.append((gi, constrain_leading(sl), r,
                                  _slice_spans(merged_spans, lo, r)))
                    lo += r
                for gi, chunk, r, spans in retry:
                    if gi in failures and not self.controller.live[gi]:
                        orphans.append((chunk, r, spans))
                        continue
                    if not dispatch(gi, chunk, r, False, spans):
                        self._demote_if_live(gi, failures)
                        orphans.append((chunk, r, spans))
                        continue
                    if not drain_one(gi):
                        self._demote_if_live(gi, failures)
                        orphans.append((chunk, r, spans))
            # rows that completed via re-dispatch rather than the plan
            redispatched = sum(done_rows) - sum(
                sum(chunk_rows[gi][:done_chunks[gi]])
                for gi in range(n_groups))

        times = [max(t, 1e-9) for t in t_done]
        rec = {
            "shares": self.controller.shares.copy(),
            "live": [bool(x) for x in self.controller.live],
            "rows": list(rows),
            "rows_completed": list(done_rows),
            "n_chunks": [len(c) for c in chunks],
            "t_group": times,
            "t_chunks": chunk_times,
            # makespan on the common clock (dispatch latency included);
            # t_group above are per-group durations from each group's
            # own first dispatch (what the controller consumes)
            "t_step": max(max(t, 1e-9) for t in t_done_abs),
            "plan_changed": plan_changed,
            "failures": {self.groups[gi].name: msg
                         for gi, msg in failures.items()},
            "redispatched_rows": int(redispatched),
            # absolute completion instant of every batch row on the
            # step's clock (NaN only for rows the step could not
            # complete, which raises above) — the request-level serving
            # layer (repro.serve) retires per-request latencies from it
            "row_done_at": row_done_at,
        }
        self.history.append(rec)
        if self._obs is not None:
            self._m_steps.inc()
            self._m_rows.inc(int(sum(done_rows)))
            self._h_step.observe(rec["t_step"])
            self._obs.tracer.complete(
                "scheduler.step", t0, rec["t_step"],
                tid=n_groups, args={"rows": n, "plan_changed": plan_changed,
                                    "failures": len(failures)})
            if redispatched:
                self._m_redispatch.inc(int(redispatched))
                self._obs.journal.event(
                    "chunks_redispatched", rows=int(redispatched),
                    from_groups=sorted(rec["failures"]),
                    to_groups=[g.name for g, l in
                               zip(self.groups, self.controller.live) if l])
        if rebalance and not plan_changed and not failures:
            # a plan-change step's times include compiling the new chunk
            # shapes, and a failure step's include recovery re-dispatch —
            # feeding either to the controller would re-poison the shares
            # the moment the stream stabilizes
            self.controller.update(times, rows=rows)
        return rec

    def _demote_if_live(self, gi: int, failures: dict) -> None:
        if self.controller.live[gi]:
            if self.controller.n_live == 1:
                raise RuntimeError(f"all device groups failed: {failures}")
            self.drop_group(gi, reason=failures.get(gi, "redispatch failure"))

    def run(self, batches, rebalance: bool = True) -> list[dict]:
        """Drive a stream of batches; returns the step records."""
        return [self.step(b, rebalance=rebalance) for b in batches]
