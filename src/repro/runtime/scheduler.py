"""Chunked online work distribution across N device groups.

``HeterogeneousRunner`` (the paper's runtime, ``core/hetero.py``) does
one static split per batch: each group gets its whole share in a single
dispatch, and the split moves only between batches.  This module turns
that into a live scheduler:

  * each incoming batch is split into **chunks** (row slices aligned to
    each group's device count);
  * chunks are dispatched **asynchronously** and interleaved across
    groups, with at most ``inflight`` chunks outstanding per group —
    JAX's async dispatch overlaps chunk k+1's transfer/launch with chunk
    k's compute (double buffering), and the inflight bound keeps live
    buffers constant;
  * per-chunk completion times feed an **EWMA controller**
    (``ewma_rebalance``) that re-splits the next batch — the N-group
    generalization of ``core.hetero.proportional_rebalance``.

Chunk inputs are annotated with ``dist.api.constrain_leading`` so that
when mesh rules are installed (see ``docs/dist.md``) each chunk carries
its data-parallel layout into jit.
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Sequence

import numpy as np

import jax

from ..core.hetero import DeviceGroup
from ..dist.api import constrain_leading

__all__ = ["ChunkedScheduler", "EwmaController", "ewma_rebalance"]


def _project_simplex_floor(w: np.ndarray, floor: float) -> np.ndarray:
    """Nearest share vector with ``sum == 1`` and every entry ``>= floor``
    (scales the above-floor mass uniformly)."""
    n = len(w)
    free = 1.0 - floor * n
    if free <= 0:
        return np.full(n, 1.0 / n)
    slack = np.maximum(np.asarray(w, dtype=np.float64) - floor, 0.0)
    total = slack.sum()
    if total <= 0:
        return np.full(n, 1.0 / n)
    return floor + slack * (free / total)


def ewma_rebalance(shares: Sequence[float], times: Sequence[float],
                   damping: float = 0.5, min_share: float = 0.01,
                   rows: Sequence[int] | None = None) -> np.ndarray:
    """New work shares from observed per-group times (N groups).

    Rates are ``r_i = rows_i / t_i`` (or ``shares_i / t_i`` when row
    counts are not given); the equal-finish-time target is
    ``r_i / sum(r)``, and the update is the EWMA
    ``(1 - damping) * shares + damping * target`` — for two groups with
    ``rows=None`` this is exactly ``proportional_rebalance``.  Degenerate
    measurements (any ``t_i <= 0``) keep the current shares; the result
    is clamped to ``>= min_share`` per group so no group is ever starved
    permanently.
    """
    shares = _project_simplex_floor(np.asarray(shares, np.float64), min_share)
    times = np.asarray(times, dtype=np.float64)
    if times.shape != shares.shape:
        raise ValueError("times must align with shares")
    if (times <= 0.0).any():
        return shares
    work = shares if rows is None else np.asarray(rows, dtype=np.float64)
    rates = work / times
    target = rates / rates.sum()
    out = (1.0 - damping) * shares + damping * target
    return _project_simplex_floor(out, min_share)


@dataclass
class EwmaController:
    """Stateful wrapper around ``ewma_rebalance`` holding current shares."""

    n_groups: int
    damping: float = 0.5
    min_share: float = 0.01
    shares: np.ndarray = field(default=None)  # type: ignore[assignment]

    def __post_init__(self):
        if self.n_groups < 1:
            raise ValueError("need at least one group")
        if self.shares is None:
            self.shares = np.full(self.n_groups, 1.0 / self.n_groups)
        self.shares = _project_simplex_floor(
            np.asarray(self.shares, np.float64), self.min_share)
        if len(self.shares) != self.n_groups:
            raise ValueError("shares must have one entry per group")

    def update(self, times: Sequence[float],
               rows: Sequence[int] | None = None) -> np.ndarray:
        self.shares = ewma_rebalance(self.shares, times, self.damping,
                                     self.min_share, rows=rows)
        return self.shares


class ChunkedScheduler:
    """Split each batch into chunks, overlap dispatch across N groups,
    and rebalance the split online from measured per-chunk times."""

    def __init__(self, step_builder: Callable[[DeviceGroup], Callable],
                 groups: Sequence[DeviceGroup], *,
                 controller: EwmaController | None = None,
                 chunks_per_group: int = 2, inflight: int = 2,
                 row_quantum: int = 1):
        """``step_builder(group)`` returns ``fn(chunk) -> result`` exactly
        as for ``HeterogeneousRunner`` (results block via
        ``block_until_ready`` leaves).  ``chunks_per_group`` bounds how
        finely each group's share is sliced; ``inflight`` is the per-group
        dispatch depth (2 = double buffering).  ``row_quantum`` coarsens
        chunk-size rounding to multiples of ``quantum * n_devices`` rows:
        jitted step functions recompile per distinct chunk shape, so a
        coarser quantum keeps the shape set small while shares drift."""
        if not groups:
            raise ValueError("need at least one device group")
        if chunks_per_group < 1 or inflight < 1 or row_quantum < 1:
            raise ValueError("chunks_per_group, inflight and row_quantum "
                             "must be >= 1")
        self.groups = list(groups)
        self.controller = controller or EwmaController(len(self.groups))
        if self.controller.n_groups != len(self.groups):
            raise ValueError("controller group count mismatch")
        self.chunks_per_group = chunks_per_group
        self.inflight = inflight
        self.row_quantum = row_quantum
        self._fns = [step_builder(g) for g in self.groups]
        self.history: list[dict] = []

    @property
    def shares(self) -> np.ndarray:
        return self.controller.shares

    # -- planning ----------------------------------------------------------
    def plan_rows(self, n: int) -> list[int]:
        """Per-group row counts for a batch of ``n`` rows.

        Every group gets at least one device-aligned sliver; all groups
        except the largest-share one are rounded to multiples of their
        device count, and the largest-share group absorbs the remainder
        (exactly aligned whenever ``n`` divides by the total device
        count and groups are equally sized, as in the tests/benchmarks).
        """
        align = [len(g.devices) for g in self.groups]
        if n < sum(align):
            raise ValueError(f"batch of {n} rows is smaller than one row "
                             f"per device ({sum(align)})")
        shares = self.controller.shares
        big = int(np.argmax(shares))
        rows = [0] * len(self.groups)
        for i, (g, s) in enumerate(zip(align, shares)):
            if i == big:
                continue
            q = g * self.row_quantum            # shape-stable rounding
            rows[i] = max(int(round(n * s / q)) * q, g)
        rest = n - sum(rows)
        while rest < align[big]:
            # reclaim alignment units from the largest other group so the
            # largest-share group is never starved (n >= sum(align)
            # guarantees termination: with every other group at its
            # minimum, rest >= align[big])
            cands = [i for i in range(len(rows))
                     if i != big and rows[i] > align[i]]
            j = max(cands, key=lambda i: rows[i])
            rows[j] -= align[j]
            rest += align[j]
        rows[big] = rest
        return rows

    def _chunk_sizes(self, rows: int, align: int) -> list[int]:
        """Split one group's share into up to ``chunks_per_group`` aligned
        chunks (first chunk takes any residual); rounding uses the row
        quantum so chunk shapes stay stable as shares drift."""
        q = align * self.row_quantum
        per = rows // (self.chunks_per_group * q) * q
        if per == 0:
            per = rows // (self.chunks_per_group * align) * align
        if per == 0:
            return [rows]
        sizes = [per] * self.chunks_per_group
        sizes[0] += rows - per * self.chunks_per_group
        return [s for s in sizes if s > 0]

    @staticmethod
    def _block(result) -> None:
        for leaf in jax.tree.leaves(result):
            blocker = getattr(leaf, "block_until_ready", None)
            if blocker is not None:
                blocker()

    @staticmethod
    def _is_ready(result) -> bool | None:
        """True/False when every blockable leaf answers ``is_ready``;
        None when some leaf can only block (duck-typed results)."""
        ready = True
        for leaf in jax.tree.leaves(result):
            probe = getattr(leaf, "is_ready", None)
            if probe is None:
                if getattr(leaf, "block_until_ready", None) is not None:
                    return None
                continue
            if not probe():
                ready = False
        return ready

    # -- the online step ---------------------------------------------------
    def step(self, batch: dict, rebalance: bool = True) -> dict:
        """Dispatch one batch; returns the step record (and appends it to
        ``history``)."""
        n = jax.tree.leaves(batch)[0].shape[0]
        rows = self.plan_rows(n)

        # contiguous per-group row ranges, then per-group chunk slices
        offsets = np.concatenate([[0], np.cumsum(rows)])
        chunks: list[list[dict]] = []
        for gi, g in enumerate(self.groups):
            sizes = self._chunk_sizes(rows[gi], len(g.devices))
            lo = int(offsets[gi])
            group_chunks = []
            for s in sizes:
                sl = jax.tree.map(lambda x, lo=lo, s=s: x[lo:lo + s], batch)
                group_chunks.append(constrain_leading(sl))
                lo += s
            chunks.append(group_chunks)

        t0 = time.perf_counter()
        pending: list[deque] = [deque() for _ in self.groups]
        t_done = [0.0] * len(self.groups)
        chunk_times: list[list[float]] = [[] for _ in self.groups]

        def record(gi: int) -> None:
            t = time.perf_counter() - t0
            chunk_times[gi].append(t)
            t_done[gi] = t

        def drain_one(gi: int) -> None:
            self._block(pending[gi].popleft())
            record(gi)

        def poll_sweep() -> bool:
            """Non-blockingly pop every already-completed head chunk so
            completion timestamps are recorded close to when they happen.
            Returns False when some head result is poll-incapable."""
            pollable = True
            for gi, q in enumerate(pending):
                while q:
                    ready = self._is_ready(q[0])
                    if ready is None:
                        pollable = False
                        break
                    if not ready:
                        break
                    q.popleft()
                    record(gi)
            return pollable

        # interleave dispatch round-robin by chunk index so every group
        # starts working immediately; bound the per-group queue depth
        max_chunks = max(len(c) for c in chunks)
        for ci in range(max_chunks):
            for gi in range(len(self.groups)):
                if ci >= len(chunks[gi]):
                    continue
                if len(pending[gi]) >= self.inflight:
                    drain_one(gi)
                pending[gi].append(self._fns[gi](chunks[gi][ci]))
            poll_sweep()
        # drain by polling so a fast group's finish time is never inflated
        # to a slower group's (blocking group-by-group would timestamp a
        # later-indexed fast group at the slow group's completion); fall
        # back to ordered blocking for results that cannot be polled
        while any(pending):
            if not poll_sweep():
                for gi in range(len(self.groups)):
                    while pending[gi]:
                        drain_one(gi)
                break
            if any(pending):
                time.sleep(2e-5)

        times = [max(t, 1e-9) for t in t_done]
        rec = {
            "shares": self.controller.shares.copy(),
            "rows": list(rows),
            "n_chunks": [len(c) for c in chunks],
            "t_group": times,
            "t_chunks": chunk_times,
            "t_step": max(times),
        }
        self.history.append(rec)
        if rebalance:
            self.controller.update(times, rows=rows)
        return rec

    def run(self, batches, rebalance: bool = True) -> list[dict]:
        """Drive a stream of batches; returns the step records."""
        return [self.step(b, rebalance=rebalance) for b in batches]
