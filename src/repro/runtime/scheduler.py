"""Chunked online work distribution across N device groups.

``HeterogeneousRunner`` (the paper's runtime, ``core/hetero.py``) does
one static split per batch: each group gets its whole share in a single
dispatch, and the split moves only between batches.  This module turns
that into a live scheduler:

  * each incoming batch is split into **chunks** (row slices aligned to
    each group's device count);
  * chunks are dispatched **asynchronously** and interleaved across
    groups, with at most ``inflight`` chunks outstanding per group —
    JAX's async dispatch overlaps chunk k+1's transfer/launch with chunk
    k's compute (double buffering), and the inflight bound keeps live
    buffers constant;
  * per-chunk completion times feed an **EWMA controller**
    (``ewma_rebalance``) that re-splits the next batch — the N-group
    generalization of ``core.hetero.proportional_rebalance``.

Chunk inputs are annotated with ``dist.api.constrain_leading`` so that
when mesh rules are installed (see ``docs/dist.md``) each chunk carries
its data-parallel layout into jit.
"""

from __future__ import annotations

import time
from collections import deque
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Callable, Sequence

import numpy as np

import jax

from ..core.hetero import DeviceGroup
from ..dist.api import constrain_leading

__all__ = ["ChunkedScheduler", "EwmaController", "ewma_rebalance"]


def _project_simplex_floor(w: np.ndarray, floor: float) -> np.ndarray:
    """Nearest share vector with ``sum == 1`` and every entry ``>= floor``
    (scales the above-floor mass uniformly)."""
    n = len(w)
    free = 1.0 - floor * n
    if free <= 0:
        return np.full(n, 1.0 / n)
    slack = np.maximum(np.asarray(w, dtype=np.float64) - floor, 0.0)
    total = slack.sum()
    if total <= 0:
        return np.full(n, 1.0 / n)
    return floor + slack * (free / total)


def ewma_rebalance(shares: Sequence[float], times: Sequence[float],
                   damping: float = 0.5, min_share: float = 0.01,
                   rows: Sequence[int] | None = None) -> np.ndarray:
    """New work shares from observed per-group times (N groups).

    Rates are ``r_i = rows_i / t_i`` (or ``shares_i / t_i`` when row
    counts are not given); the equal-finish-time target is
    ``r_i / sum(r)``, and the update is the EWMA
    ``(1 - damping) * shares + damping * target`` — for two groups with
    ``rows=None`` this is exactly ``proportional_rebalance``.  Degenerate
    measurements (any ``t_i <= 0``) keep the current shares; the result
    is clamped to ``>= min_share`` per group so no group is ever starved
    permanently.
    """
    shares = _project_simplex_floor(np.asarray(shares, np.float64), min_share)
    times = np.asarray(times, dtype=np.float64)
    if times.shape != shares.shape:
        raise ValueError("times must align with shares")
    if (times <= 0.0).any():
        return shares
    work = shares if rows is None else np.asarray(rows, dtype=np.float64)
    rates = work / times
    target = rates / rates.sum()
    out = (1.0 - damping) * shares + damping * target
    return _project_simplex_floor(out, min_share)


@dataclass
class EwmaController:
    """Stateful wrapper around ``ewma_rebalance`` holding current shares."""

    n_groups: int
    damping: float = 0.5
    min_share: float = 0.01
    shares: np.ndarray = field(default=None)  # type: ignore[assignment]

    def __post_init__(self):
        if self.n_groups < 1:
            raise ValueError("need at least one group")
        if self.shares is None:
            self.shares = np.full(self.n_groups, 1.0 / self.n_groups)
        self.shares = _project_simplex_floor(
            np.asarray(self.shares, np.float64), self.min_share)
        if len(self.shares) != self.n_groups:
            raise ValueError("shares must have one entry per group")

    def update(self, times: Sequence[float],
               rows: Sequence[int] | None = None) -> np.ndarray:
        self.shares = ewma_rebalance(self.shares, times, self.damping,
                                     self.min_share, rows=rows)
        return self.shares


class ChunkedScheduler:
    """Split each batch into chunks, overlap dispatch across N groups,
    and rebalance the split online from measured per-chunk times."""

    def __init__(self, step_builder: Callable[[DeviceGroup], Callable],
                 groups: Sequence[DeviceGroup], *,
                 controller: EwmaController | None = None,
                 chunks_per_group: int = 2, inflight: int = 2,
                 row_quantum: int = 1):
        """``step_builder(group)`` returns ``fn(chunk) -> result`` exactly
        as for ``HeterogeneousRunner`` (results block via
        ``block_until_ready`` leaves).  ``chunks_per_group`` bounds how
        finely each group's share is sliced; ``inflight`` is the per-group
        dispatch depth (2 = double buffering).  ``row_quantum`` coarsens
        chunk-size rounding to multiples of ``quantum * n_devices`` rows:
        jitted step functions recompile per distinct chunk shape, so a
        coarser quantum keeps the shape set small while shares drift.
        Controller-driven steps additionally serve their row/chunk plan
        from a debounced cache (see ``_planned_rows``) so timing noise
        never churns the compiled-shape set."""
        if not groups:
            raise ValueError("need at least one device group")
        if chunks_per_group < 1 or inflight < 1 or row_quantum < 1:
            raise ValueError("chunks_per_group, inflight and row_quantum "
                             "must be >= 1")
        self.groups = list(groups)
        self.controller = controller or EwmaController(len(self.groups))
        if self.controller.n_groups != len(self.groups):
            raise ValueError("controller group count mismatch")
        self.chunks_per_group = chunks_per_group
        self.inflight = inflight
        self.row_quantum = row_quantum
        self._fns = [step_builder(g) for g in self.groups]
        self._plans: dict[int, dict] = {}    # batch rows -> row/chunk plan
        self.history: list[dict] = []

    @property
    def shares(self) -> np.ndarray:
        return self.controller.shares

    # -- planning ----------------------------------------------------------
    def plan_rows(self, n: int) -> list[int]:
        """Per-group row counts for a batch of ``n`` rows.

        Every group gets at least one device-aligned sliver; all groups
        except the largest-share one are rounded to multiples of their
        device count, and the largest-share group absorbs the remainder
        (exactly aligned whenever ``n`` divides by the total device
        count and groups are equally sized, as in the tests/benchmarks).
        """
        align = [len(g.devices) for g in self.groups]
        if n < sum(align):
            raise ValueError(f"batch of {n} rows is smaller than one row "
                             f"per device ({sum(align)})")
        shares = self.controller.shares
        big = int(np.argmax(shares))
        rows = [0] * len(self.groups)
        for i, (g, s) in enumerate(zip(align, shares)):
            if i == big:
                continue
            q = g * self.row_quantum            # shape-stable rounding
            rows[i] = max(int(round(n * s / q)) * q, g)
        rest = n - sum(rows)
        while rest < align[big]:
            # reclaim alignment units from the largest other group so the
            # largest-share group is never starved (n >= sum(align)
            # guarantees termination: with every other group at its
            # minimum, rest >= align[big])
            cands = [i for i in range(len(rows))
                     if i != big and rows[i] > align[i]]
            j = max(cands, key=lambda i: rows[i])
            rows[j] -= align[j]
            rest += align[j]
        rows[big] = rest
        return rows

    def _planned_rows(self, n: int, rebalance: bool) -> tuple[list[int], bool]:
        """(row plan for this step, whether a known size's plan changed).

        Recompiles are the dominant cost of chunked dispatch: every new
        row split means new chunk shapes, and on near-equal groups the
        EWMA's response to timing noise would produce a new split almost
        every step — each recompile then poisons the next measurement,
        drifting the shares further (the positive-feedback loop behind
        the old 4x online-vs-static gap in BENCH_runtime.json).  Two
        regimes break it:

          * ``rebalance=False`` — the caller manages the shares (e.g. a
            split tuner sweeping fractions): the freshly computed plan is
            always honored, so measurements reflect the assigned split;
          * ``rebalance=True`` — controller-driven: the cached plan (and
            with it every compiled chunk shape) is reused until the
            freshly computed plan **deviates from it on two consecutive
            steps**.  A single noisy measurement moves the shares once
            and the next clean measurement pulls them back, so one-step
            flicker never recompiles; persistent movement (real skew,
            convergence) lands its new plan one step later.

        Plans are cached per batch size, so a stream whose row count
        alternates between known sizes reuses each size's compiled
        shapes and keeps rebalancing on every step.  ``step`` skips the
        controller update on share-driven replan steps (their measured
        times include compilation of the new shapes and would re-poison
        the shares); a first-seen batch size does not suppress the
        update — freezing the shares on an all-new-sizes stream would be
        worse than one noisy measurement per size.
        """
        fresh = self.plan_rows(n)
        plan = self._plans.get(n)
        if plan is not None:
            if fresh == plan["rows"]:
                plan["pending"] = None
                return plan["rows"], False
            if rebalance and plan["pending"] is None:
                plan["pending"] = list(fresh)    # first deviation: debounce
                return plan["rows"], False
        if len(self._plans) >= 64 and n not in self._plans:
            self._plans.pop(next(iter(self._plans)))   # bound the cache
        self._plans[n] = {"rows": list(fresh), "pending": None,
                          "chunks": [self._chunk_sizes(r, len(g.devices))
                                     for r, g in zip(fresh, self.groups)]}
        # a replan of a known size is share-driven (possibly
        # compile-tainted measurement); a new size is just a new plan
        return self._plans[n]["rows"], plan is not None

    def _chunk_sizes(self, rows: int, align: int) -> list[int]:
        """Split one group's share into up to ``chunks_per_group`` aligned
        chunks (first chunk takes any residual); rounding uses the row
        quantum so chunk shapes stay stable as shares drift."""
        q = align * self.row_quantum
        per = rows // (self.chunks_per_group * q) * q
        if per == 0:
            per = rows // (self.chunks_per_group * align) * align
        if per == 0:
            return [rows]
        sizes = [per] * self.chunks_per_group
        sizes[0] += rows - per * self.chunks_per_group
        return [s for s in sizes if s > 0]

    @staticmethod
    def _block(result) -> None:
        for leaf in jax.tree.leaves(result):
            blocker = getattr(leaf, "block_until_ready", None)
            if blocker is not None:
                blocker()

    @property
    def _drain_pool(self) -> ThreadPoolExecutor:
        # lazy: schedulers built in tests/benches that never step should
        # not spawn threads (an unreferenced scheduler's idle workers
        # also exit on GC via the executor's weakref sentinel)
        pool = getattr(self, "_pool", None)
        if pool is None:
            pool = self._pool = ThreadPoolExecutor(
                max_workers=len(self.groups),
                thread_name_prefix="chunked-drain")
        return pool

    def close(self) -> None:
        """Release the drain worker threads of a long-lived scheduler."""
        pool = getattr(self, "_pool", None)
        if pool is not None:
            pool.shutdown(wait=False)
            self._pool = None

    # -- the online step ---------------------------------------------------
    def step(self, batch: dict, rebalance: bool = True) -> dict:
        """Dispatch one batch; returns the step record (and appends it to
        ``history``)."""
        n = jax.tree.leaves(batch)[0].shape[0]
        rows, plan_changed = self._planned_rows(n, rebalance)

        # contiguous per-group row ranges, then per-group chunk slices
        # (sizes come from the plan cache — no recompute per step)
        offsets = np.concatenate([[0], np.cumsum(rows)])
        chunks: list[list[dict]] = []
        for gi, g in enumerate(self.groups):
            sizes = self._plans[n]["chunks"][gi]
            lo = int(offsets[gi])
            group_chunks = []
            for s in sizes:
                sl = jax.tree.map(lambda x, lo=lo, s=s: x[lo:lo + s], batch)
                group_chunks.append(constrain_leading(sl))
                lo += s
            chunks.append(group_chunks)

        t0 = time.perf_counter()
        pending: list[deque] = [deque() for _ in self.groups]
        # per-group clocks start at the group's own first dispatch:
        # measuring every group from the common t0 would bill group k the
        # dispatch latency of groups 0..k-1, and the controller would
        # "rebalance" that constant bias into a real share drift on
        # equal-speed groups (new shapes, recompiles) — group times must
        # estimate device speed, not dispatch order
        t_start = [None] * len(self.groups)
        t_done = [0.0] * len(self.groups)
        t_done_abs = [0.0] * len(self.groups)
        chunk_times: list[list[float]] = [[] for _ in self.groups]

        def record(gi: int) -> None:
            now = time.perf_counter()
            chunk_times[gi].append(now - t_start[gi])
            t_done[gi] = now - t_start[gi]
            t_done_abs[gi] = now - t0

        def drain_one(gi: int) -> None:
            self._block(pending[gi].popleft())
            record(gi)

        # interleave dispatch round-robin by chunk index so every group
        # starts working immediately; bound the per-group queue depth
        max_chunks = max(len(c) for c in chunks)
        for ci in range(max_chunks):
            for gi in range(len(self.groups)):
                if ci >= len(chunks[gi]):
                    continue
                if len(pending[gi]) >= self.inflight:
                    drain_one(gi)
                if t_start[gi] is None:
                    t_start[gi] = time.perf_counter()
                pending[gi].append(self._fns[gi](chunks[gi][ci]))
        # drain each group in its own worker thread: block_until_ready
        # releases the GIL, so every group's completion is timestamped
        # exactly when it happens (a later-indexed fast group is never
        # measured at a slower group's completion), with zero host-side
        # polling — the old is_ready/sleep loop cost ~ms per step in
        # redundant host syncs
        def drain_group(gi: int) -> None:
            while pending[gi]:
                drain_one(gi)

        futures = [self._drain_pool.submit(drain_group, gi)
                   for gi in range(len(self.groups)) if pending[gi]]
        for f in futures:
            f.result()                 # re-raises worker exceptions

        times = [max(t, 1e-9) for t in t_done]
        rec = {
            "shares": self.controller.shares.copy(),
            "rows": list(rows),
            "n_chunks": [len(c) for c in chunks],
            "t_group": times,
            "t_chunks": chunk_times,
            # makespan on the common clock (dispatch latency included);
            # t_group above are per-group durations from each group's
            # own first dispatch (what the controller consumes)
            "t_step": max(max(t, 1e-9) for t in t_done_abs),
            "plan_changed": plan_changed,
        }
        self.history.append(rec)
        if rebalance and not plan_changed:
            # a plan-change step's times include compiling the new chunk
            # shapes — feeding them to the controller would re-poison the
            # shares the moment the plan stabilizes
            self.controller.update(times, rows=rows)
        return rec

    def run(self, batches, rebalance: bool = True) -> list[dict]:
        """Drive a stream of batches; returns the step records."""
        return [self.step(b, rebalance=rebalance) for b in batches]
