"""Kill-switch guardrail for the online serving path.

The online EWMA controller is normally the best split policy available
— it tracks drift the offline tuner cannot see.  But it is also a
feedback loop, and feedback loops have failure modes: a mis-set damping
or floor, a poisoned measurement stream, or plain controller bugs can
walk the shares away from the optimum while every individual step looks
plausible.  The guardrail for that class of failure is a **kill
switch** (the circuit-breaker pattern): watch the realized step-time
trajectory against a rolling baseline, and when it regresses past a
threshold for several consecutive steps, stop trusting the controller —
pin the split to the last known-good static configuration (the offline
tuner's stored winner when available) until a cool-down probe shows the
online path is healthy again.

Two pieces, separable for testing:

  * :class:`KillSwitch` — the pure state machine.  Feed it one step
    time per step; it answers "should the controller be driving?".  No
    clocks, no scheduler knowledge: cool-down is counted in steps, so
    trips and re-arms are exactly reproducible under the fault harness.
  * :class:`ServeGuard` — wraps a ``ChunkedScheduler``: while armed it
    steps with online rebalance; while tripped it pins the fallback
    shares (``rebalance=False``) and re-arms after ``cooldown`` healthy
    probe steps.  The fallback resolves, in order: explicit shares →
    the tuning store's best stored split for the workload
    (``TuningStore.best_record``) → the best split the controller
    itself has visited (tracked continuously as a running min over
    observed step times).

Thresholds and the failure model are documented in
``docs/resilience.md``.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field

import numpy as np

from ..obs import as_observer
from .scheduler import ChunkedScheduler, _project_simplex_floor

__all__ = ["KillSwitch", "ServeGuard", "fallback_from_store"]


@dataclass
class KillSwitch:
    """Step-time circuit breaker (pure state machine, no clocks).

    ``observe(t_step)`` returns a verdict string and moves the state:

      * ``armed`` — healthy observations feed a rolling window; the
        baseline is its median (robust to single outliers).  An
        observation above ``threshold * baseline`` is ``"regressing"``;
        ``patience`` *consecutive* regressing steps trip the switch
        (verdict ``"trip"``); anything else is ``"ok"`` and resets the
        streak.  The first ``min_samples`` observations only build the
        baseline — no verdicts but ``"ok"`` (an empty baseline cannot
        regress).
      * ``tripped`` — observations are cool-down probes (the caller is
        expected to be pinning its fallback, so these measure the
        fallback's health): a probe within ``threshold * baseline``
        counts toward re-arming, one above it resets the count.  After
        ``cooldown`` consecutive healthy probes the switch re-arms
        (verdict ``"rearm"``); until then probes answer ``"cooling"``.
        Healthy probes also feed the baseline, so the post-trip
        baseline reflects the fallback's level, not the pre-trip one.

    Regressing observations never enter the baseline — otherwise a slow
    regression would drag the baseline up with it and never trip.
    """

    threshold: float = 1.5
    patience: int = 5
    window: int = 16
    cooldown: int = 3
    min_samples: int = 4

    def __post_init__(self):
        if self.threshold <= 1.0:
            raise ValueError("threshold must be > 1 (a ratio over baseline)")
        if min(self.patience, self.window, self.cooldown,
               self.min_samples) < 1:
            raise ValueError("patience, window, cooldown and min_samples "
                             "must be >= 1")
        self._times: deque = deque(maxlen=self.window)
        self.tripped = False
        self.streak = 0            # consecutive regressing (armed) or
        #                            healthy-probe (tripped) steps
        self.n_trips = 0

    @property
    def baseline(self) -> float | None:
        """Rolling median of healthy step times (None until warm)."""
        if len(self._times) < self.min_samples:
            return None
        return float(np.median(self._times))

    def reset_baseline(self) -> None:
        """Forget the baseline (e.g. after a membership change: the
        step-time level legitimately moved, comparing against the old
        one would false-trip)."""
        self._times.clear()
        self.streak = 0

    def observe(self, t_step: float) -> str:
        base = self.baseline
        if self.tripped:
            if base is not None and t_step > self.threshold * base:
                self.streak = 0
                return "cooling"
            self.streak += 1
            self._times.append(t_step)
            if self.streak >= self.cooldown:
                self.tripped = False
                self.streak = 0
                return "rearm"
            return "cooling"
        if base is not None and t_step > self.threshold * base:
            self.streak += 1
            if self.streak >= self.patience:
                self.tripped = True
                self.streak = 0
                self.n_trips += 1
                return "trip"
            return "regressing"
        self.streak = 0
        self._times.append(t_step)
        return "ok"

    # -- durability (runtime.checkpoint snapshots) -------------------------
    def state_dict(self) -> dict:
        """JSON-ready recoverable state: baseline window + trip state
        (the config knobs are reconstructed by the caller, not
        persisted — a restart may legitimately retune them)."""
        return {"times": [float(t) for t in self._times],
                "tripped": bool(self.tripped),
                "streak": int(self.streak),
                "n_trips": int(self.n_trips)}

    def load_state(self, state: dict) -> None:
        """Restore a :meth:`state_dict` snapshot; the baseline window
        refills from the saved tail (bounded by ``window``)."""
        self._times.clear()
        self._times.extend(float(t) for t in state.get("times", ()))
        self.tripped = bool(state.get("tripped", False))
        self.streak = int(state.get("streak", 0))
        self.n_trips = int(state.get("n_trips", 0))


def fallback_from_store(store, workload: dict,
                        n_groups: int = 2) -> np.ndarray | None:
    """Last known-good static shares from a tuning store, or ``None``.

    ``tune_stream_split`` (``launch/serve.py``) records its winners as
    ``fraction`` percent configs keyed by workload signature;
    ``TuningStore.best_record`` resolves the lowest-measured-time record
    across strategies.  Only the two-group fraction layout is stored
    today, so ``n_groups > 2`` returns ``None`` and the guard falls back
    to its learned snapshot instead.
    """
    if store is None or n_groups != 2:
        return None
    rec = store.best_record("stream_split", workload)
    if rec is None or "fraction" not in getattr(rec, "best_config", {}):
        return None
    f = rec.best_config["fraction"] / 100.0
    return np.asarray([f, 1.0 - f])


@dataclass
class ServeGuard:
    """Kill-switch wrapper around a :class:`ChunkedScheduler`.

    ``step(batch)`` is a drop-in for ``scheduler.step``: while the
    switch is armed the controller drives (online rebalance); after a
    trip the guard pins ``fallback`` (projected onto the currently live
    groups) with ``rebalance=False`` and lets the switch's cool-down
    probes decide when the controller may drive again.  Membership
    changes (a demotion mid-step, or an external drop/restore routed
    through the guard) reset the baseline — the step-time level
    legitimately moved.

    The guard continuously snapshots the best shares it has seen
    (running min over healthy step times), so a fallback exists even
    with no tuning store; an explicit ``fallback`` or a stored split
    (:func:`fallback_from_store`) takes precedence.
    """

    scheduler: ChunkedScheduler | None
    switch: KillSwitch = field(default_factory=KillSwitch)
    fallback: np.ndarray | None = None
    observer: object = field(default=None, repr=False)

    def __post_init__(self):
        # scheduler may be None at construction (StreamingPipeline binds
        # its own scheduler and re-runs this validation)
        if self.fallback is not None:
            self.fallback = np.asarray(self.fallback, np.float64)
            if self.scheduler is not None and self.fallback.shape != (
                    self.scheduler.controller.n_groups,):
                raise ValueError("fallback shares must have one entry "
                                 "per group")
        self._best_shares: np.ndarray | None = None
        self._best_t: float = float("inf")
        # inherit the scheduler's observer unless one was given: the
        # guard's journal events must interleave with the scheduler's
        # (demotion -> re-dispatch -> trip) on one sequence
        self._obs = as_observer(self.observer)
        if self._obs is None and self.scheduler is not None:
            self._obs = self.scheduler._obs
        self._armed_logged = False

    # -- membership passthrough (so a FaultInjector can attach the guard)
    def drop_group(self, i: int) -> None:
        self.scheduler.drop_group(i)
        self.switch.reset_baseline()

    def restore_group(self, i: int, share: float | None = None) -> None:
        self.scheduler.restore_group(i, share)
        self.switch.reset_baseline()

    @property
    def tripped(self) -> bool:
        return self.switch.tripped

    @property
    def degraded(self) -> bool:
        """True while the serving path is in degraded mode: the kill
        switch is tripped (controller untrusted, pinned fallback) or
        group membership has shrunk (reduced capacity).  The
        request-level admission layer (``repro.serve.admission``)
        consults this to make per-request retry/shed decisions."""
        return self.switch.tripped \
            or not bool(self.scheduler.controller.live.all())

    def state(self) -> dict:
        """Snapshot of the guard's observable state for layers above
        (admission control, CLI status lines): kill-switch state,
        baseline, live membership and the combined degraded flag."""
        ctrl = self.scheduler.controller
        return {
            "tripped": self.switch.tripped,
            "baseline": self.switch.baseline,
            "streak": self.switch.streak,
            "n_trips": self.switch.n_trips,
            "live": [bool(x) for x in ctrl.live],
            "n_live": ctrl.n_live,
            "degraded": self.degraded,
        }

    # -- durability (runtime.checkpoint snapshots) -------------------------
    def state_dict(self) -> dict:
        """JSON-ready recoverable state: the kill switch plus the
        learned known-good snapshot the fallback resolves to."""
        return {
            "switch": self.switch.state_dict(),
            "best_shares": None if self._best_shares is None
            else [float(s) for s in self._best_shares],
            "best_t": None if self._best_t == float("inf")
            else float(self._best_t),
        }

    def load_state(self, state: dict) -> None:
        self.switch.load_state(state.get("switch", {}))
        bs = state.get("best_shares")
        self._best_shares = None if bs is None \
            else np.asarray(bs, np.float64)
        bt = state.get("best_t")
        self._best_t = float("inf") if bt is None else float(bt)

    def _fallback_shares(self) -> np.ndarray:
        ctrl = self.scheduler.controller
        shares = self.fallback if self.fallback is not None \
            else self._best_shares
        if shares is None:                    # nothing known yet: equal
            shares = np.ones(ctrl.n_groups)
        out = np.zeros(ctrl.n_groups)
        live = ctrl.live
        sub = np.asarray(shares, np.float64)[live]
        out[live] = _project_simplex_floor(sub / max(sub.sum(), 1e-12),
                                           ctrl.min_share)
        return out

    def step(self, batch: dict) -> dict:
        ctrl = self.scheduler.controller
        if self._obs is not None and not self._armed_logged:
            self._armed_logged = True
            self._obs.journal.event(
                "killswitch_armed", threshold=self.switch.threshold,
                patience=self.switch.patience, window=self.switch.window,
                cooldown=self.switch.cooldown)
        live_before = ctrl.live.copy()
        if self.switch.tripped:
            ctrl.shares = self._fallback_shares()
            rec = self.scheduler.step(batch, rebalance=False)
        else:
            rec = self.scheduler.step(batch, rebalance=True)
        if not np.array_equal(live_before, ctrl.live):
            # a demotion happened inside the step: the achievable
            # step-time level changed, the old baseline is void (and the
            # failure step's own time is recovery-tainted — skip it)
            self.switch.reset_baseline()
            if self._obs is not None:
                self._obs.metrics.counter(
                    "guard.verdict.membership-change").inc()
                self._obs.journal.event(
                    "guard_membership_change",
                    live=[bool(x) for x in ctrl.live],
                    tripped=self.switch.tripped)
            rec["guard"] = {"verdict": "membership-change",
                            "tripped": self.switch.tripped,
                            "baseline": None}
            return rec
        verdict = self.switch.observe(rec["t_step"])
        if verdict == "ok" and rec["t_step"] < self._best_t \
                and ctrl.live.all():
            # learned known-good snapshot (full membership only — a
            # degraded-mode split would be a bad fallback after repair)
            self._best_t = rec["t_step"]
            self._best_shares = rec["shares"].copy()
        if self._obs is not None:
            self._obs.metrics.counter(f"guard.verdict.{verdict}").inc()
            if verdict == "trip":
                self._obs.journal.event(
                    "killswitch_tripped", t_step=round(rec["t_step"], 9),
                    baseline=self.switch.baseline, n_trips=self.switch.n_trips,
                    fallback=[round(float(s), 6)
                              for s in self._fallback_shares()])
                self._obs.tracer.instant(
                    "killswitch.trip", tid=ctrl.n_groups,
                    args={"t_step": round(rec["t_step"], 9)})
            elif verdict == "rearm":
                self._obs.journal.event(
                    "killswitch_rearmed", baseline=self.switch.baseline)
                self._obs.tracer.instant("killswitch.rearm",
                                         tid=ctrl.n_groups)
        rec["guard"] = {"verdict": verdict, "tripped": self.switch.tripped,
                        "baseline": self.switch.baseline}
        return rec

    def run(self, batches) -> list[dict]:
        return [self.step(b) for b in batches]
