"""Crash durability: write-ahead logs, checksummed snapshots, ledgers.

The paper's budget claim — a near-optimal configuration from ~5% of the
space — is an accounting over *measurements performed*; a process fault
(OOM kill, preemption, ``kill -9``) that forfeits them silently breaks
it.  PR 7 hardened the stack against device faults; this module closes
the host/process half of the failure model (``docs/resilience.md``)
with three small, separately testable pieces:

``WalWriter`` / ``read_wal`` — the write-ahead request log.
    Append-only JSONL; each record carries a dense ``lsn``, the payload
    and a ``crc`` (truncated SHA-256 over the record minus the crc
    field).  Appends flush per line and ``fsync`` every ``fsync_every``
    records, so a crash loses at most the unsynced suffix — and a *torn*
    final write (the classic partial ``write(2)``) is detected, not
    misread: :func:`read_wal` stops at the first unparsable / checksum-
    mismatched / lsn-discontinuous line and returns the valid prefix
    plus a description of the torn tail.  Reopening a WAL for append
    truncates the torn tail first, so the resumed run's records continue
    a clean prefix.  ``ServeEngine`` logs ``admit``/``retire`` records
    through this: on restart, admitted-but-unretired requests replay
    through admission (at-least-once execution, exactly-once terminal
    accounting — one valid ``retire`` per rid).

``save_snapshot`` / ``load_snapshot`` — checksummed state snapshots.
    Atomic (tmp + ``os.replace``) JSON ``{"checksum", "state"}``; a load
    that fails to parse or whose checksum mismatches **quarantines** the
    file to ``<name>.corrupt-<sha8>`` (:func:`quarantine`) and returns
    ``None`` — corrupted durable state is preserved for forensics and
    never crashes a restart.

``MeasurementLedger`` — resumable tuning.
    A WAL of (config -> metrics) measurements wrapped around any
    evaluator: a config measured before the crash is served from the
    ledger at zero real cost, so a resumed ``TuningSession`` replays the
    deterministic search trajectory through cache hits and only spends
    budget on configs the crashed run never reached.

:class:`SimulatedCrash` is the in-process process-fault (raised by the
fault injector's ``crash`` events in ``raise`` mode); :func:`tear`
truncates a file mid-record to build torn-tail fixtures.
"""

from __future__ import annotations

import hashlib
import json
import os
from pathlib import Path
from typing import Any, Callable, Mapping

__all__ = ["MeasurementLedger", "SimulatedCrash", "WalWriter", "quarantine",
           "load_snapshot", "read_wal", "save_snapshot", "tear"]


class SimulatedCrash(BaseException):
    """An injected process fault (``FaultPlan.crash`` in ``raise`` mode).

    Derives from ``BaseException`` so no recovery-minded ``except
    Exception`` handler on the dispatch path can absorb it — exactly
    like the ``SystemExit``/``KeyboardInterrupt`` it stands in for.
    """


def _record_crc(rec: Mapping[str, Any]) -> str:
    """Truncated SHA-256 of a record minus its ``crc`` field."""
    body = {k: v for k, v in rec.items() if k != "crc"}
    blob = json.dumps(body, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(blob.encode()).hexdigest()[:8]


def quarantine(path: str | os.PathLike, reason: str = "corrupt") -> Path:
    """Move a corrupt durable file aside to ``<name>.corrupt-<sha8>``.

    The suffix is a hash of the file's raw bytes, so repeated
    quarantines of distinct corruptions never collide and identical
    corruptions are idempotent.  The original path is free afterwards
    (the caller starts fresh).  Returns the quarantine path.
    """
    p = Path(path)
    sha8 = hashlib.sha256(p.read_bytes()).hexdigest()[:8]
    dest = p.with_name(p.name + f".corrupt-{sha8}")
    os.replace(p, dest)
    from ..obs import get_logger
    log = get_logger("repro.checkpoint")
    log.warning(f"quarantined corrupt file {p} -> {dest.name} ({reason})",
                path=str(p), quarantined=dest.name, reason=reason)
    if log.journal is not None:
        log.journal.event("store_quarantined", path=str(p),
                          quarantined=dest.name, reason=reason)
    return dest


def read_wal(path: str | os.PathLike) -> tuple[list[dict], dict | None]:
    """Parse a WAL; returns ``(valid_records, torn)``.

    ``torn`` is ``None`` for a fully valid file, else a description of
    the invalid tail: ``{"line": first bad line index, "valid_bytes":
    byte offset where the valid prefix ends, "reason": ...}``.  Parsing
    stops at the first bad line — records beyond a corruption are
    unordered garbage by the WAL contract (appends are sequential), so
    the valid prefix is exactly the recoverable history.
    """
    p = Path(path)
    records: list[dict] = []
    if not p.exists():
        return records, None
    raw = p.read_bytes()
    offset = 0
    for i, line in enumerate(raw.split(b"\n")):
        if not line.strip():
            offset += len(line) + 1
            continue
        reason = None
        try:
            rec = json.loads(line.decode("utf-8"))
        except (ValueError, UnicodeDecodeError):
            rec, reason = None, "unparsable line"
        if rec is not None and not isinstance(rec, dict):
            rec, reason = None, "record is not an object"
        if rec is not None and rec.get("crc") != _record_crc(rec):
            rec, reason = None, "checksum mismatch"
        if rec is not None and rec.get("lsn") != len(records):
            rec, reason = None, (f"lsn {rec.get('lsn')!r} breaks the dense "
                                 f"sequence at {len(records)}")
        if rec is None:
            return records, {"line": i, "valid_bytes": offset,
                             "reason": reason}
        records.append(rec)
        offset += len(line) + 1
    return records, None


class WalWriter:
    """Append-only write-ahead log with fsync batching.

    Opening an existing file recovers its valid prefix (torn tails are
    truncated away) and continues the lsn sequence — the resume path and
    the first run share one code path.  ``fsync_every=1`` makes every
    record durable before ``append`` returns (the real ``kill -9``
    drill's setting); larger values batch the fsyncs and bound the loss
    window to ``fsync_every - 1`` records.
    """

    def __init__(self, path: str | os.PathLike, *, fsync_every: int = 8):
        if fsync_every < 1:
            raise ValueError("fsync_every must be >= 1")
        self.path = Path(path)
        self.fsync_every = int(fsync_every)
        self.path.parent.mkdir(parents=True, exist_ok=True)
        self.recovered, self.torn = read_wal(self.path)
        if self.torn is not None:
            with open(self.path, "r+b") as f:
                f.truncate(self.torn["valid_bytes"])
        self.lsn = len(self.recovered)
        self._f = open(self.path, "a", encoding="utf-8")
        self._since_sync = 0

    def append(self, kind: str, **fields) -> dict:
        """Durably append one record; returns it (with lsn + crc)."""
        rec = {"lsn": self.lsn, "kind": kind, **fields}
        rec["crc"] = _record_crc(rec)
        self._f.write(json.dumps(rec, default=str) + "\n")
        self._f.flush()
        self.lsn += 1
        self._since_sync += 1
        if self._since_sync >= self.fsync_every:
            self.sync()
        return rec

    def append_torn(self, kind: str, **fields) -> None:
        """Simulate a torn write: flush only a prefix of the encoded
        record (no newline, no crc close) — the fault injector's
        ``torn`` event, producing exactly the tail :func:`read_wal`
        detects and the reopen path truncates."""
        rec = {"lsn": self.lsn, "kind": kind, **fields}
        line = json.dumps(rec, default=str)
        self._f.write(line[:max(len(line) // 2, 1)])
        self.sync()

    def sync(self) -> None:
        self._f.flush()
        os.fsync(self._f.fileno())
        self._since_sync = 0

    def close(self) -> None:
        if not self._f.closed:
            self.sync()
            self._f.close()

    def __enter__(self) -> "WalWriter":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def save_snapshot(path: str | os.PathLike, state: Mapping[str, Any]) -> Path:
    """Atomically write a checksummed snapshot (tmp + ``os.replace``)."""
    p = Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    body = {"checksum": _sha_state(state), "state": state}
    tmp = p.with_suffix(p.suffix + ".tmp")
    tmp.write_text(json.dumps(body, indent=1, sort_keys=True, default=str))
    os.replace(tmp, p)
    return p


def _sha_state(state: Mapping[str, Any]) -> str:
    blob = json.dumps(state, sort_keys=True, separators=(",", ":"),
                      default=str)
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


def load_snapshot(path: str | os.PathLike) -> dict | None:
    """Load a snapshot's state; quarantine + ``None`` on corruption.

    Missing file -> ``None`` (a fresh start, not an error).  A parse
    failure or checksum mismatch moves the file aside via
    :func:`quarantine` so the restart proceeds from the WAL alone.
    """
    p = Path(path)
    if not p.exists():
        return None
    try:
        body = json.loads(p.read_text())
        state = body["state"]
        if body.get("checksum") != _sha_state(state):
            raise ValueError("checksum mismatch")
    except (ValueError, KeyError, TypeError) as exc:
        quarantine(p, reason=f"snapshot: {exc}")
        return None
    return state


def tear(path: str | os.PathLike, keep_fraction: float = 0.5) -> None:
    """Truncate a file to a fraction of its last line (test fixture for
    the torn-write failure mode: the tail is mid-record garbage)."""
    p = Path(path)
    raw = p.read_bytes()
    cut = raw.rstrip(b"\n").rfind(b"\n") + 1      # start of the last line
    last_len = len(raw) - cut
    with open(p, "r+b") as f:
        f.truncate(cut + max(int(last_len * keep_fraction), 1))


class MeasurementLedger:
    """WAL-backed (config -> metrics) cache making tuning resumable.

    ``wrap(evaluator)`` returns a drop-in evaluator: a config already in
    the ledger is served from it (``n_replayed`` += 1, zero real cost);
    a miss calls through, durably appends the measurement, and counts
    toward ``n_real``.  Because every registered strategy is
    deterministic given its seed, a crashed-and-resumed
    ``TuningSession`` re-walks the identical config trajectory — the
    prefix hits the ledger, and only the configs beyond the crash point
    spend real measurements.  ``total_real`` (valid WAL records) is the
    cross-restart budget the recovery bench asserts against the
    single-run budget.
    """

    def __init__(self, path: str | os.PathLike, *, fsync_every: int = 1):
        self._wal = WalWriter(path, fsync_every=fsync_every)
        self._cache: dict[str, Any] = {}
        for rec in self._wal.recovered:
            if rec.get("kind") == "measure":
                self._cache[rec["key"]] = rec["value"]
        self.n_real = 0          # real measurements this process
        self.n_replayed = 0      # ledger hits this process

    @property
    def path(self) -> Path:
        return self._wal.path

    @property
    def total_real(self) -> int:
        """Real measurements across every run sharing this ledger file."""
        return len(self._cache)

    @staticmethod
    def _key(cfg: Mapping[str, Any]) -> str:
        return json.dumps({str(k): cfg[k] for k in sorted(cfg, key=str)},
                          sort_keys=True, separators=(",", ":"), default=str)

    def lookup(self, cfg: Mapping[str, Any]) -> Any | None:
        return self._cache.get(self._key(cfg))

    @staticmethod
    def _jsonable(value: Any) -> Any:
        """Round-trip the value through JSON now (numpy scalars ->
        floats), so in-process hits and post-restart replays serve the
        *identical* object shape."""
        return json.loads(json.dumps(value, default=float))

    def record(self, cfg: Mapping[str, Any], value: Any) -> None:
        key = self._key(cfg)
        value = self._jsonable(value)
        self._cache[key] = value
        self._wal.append("measure", key=key, value=value)

    def wrap(self, evaluator: Callable[[Mapping[str, Any]], Any]
             ) -> Callable[[Mapping[str, Any]], Any]:
        """Ledger-through evaluator: hit -> replay, miss -> measure+log."""
        def measured(cfg):
            key = self._key(cfg)
            if key in self._cache:
                self.n_replayed += 1
                return self._cache[key]
            value = self._jsonable(evaluator(cfg))
            self.n_real += 1
            self._cache[key] = value
            self._wal.append("measure", key=key, value=value)
            return value
        return measured

    def close(self) -> None:
        self._wal.close()
