"""Streaming pipeline: overlapped transfer/compute over device groups.

The paper's workload is a stream: DNA text flows host -> device, the DFA
runs per chunk, counts flow back.  This module runs that shape on JAX
device groups through the chunked scheduler — each incoming batch is
sliced into chunks, every chunk does an async ``device_put`` onto its
group (the *transfer* stage) followed by the jitted automaton/count
compute (the *compute* stage), and because dispatch is asynchronous the
transfer of chunk k+1 overlaps the compute of chunk k.  The EWMA
controller adapts the per-group split while the stream runs.

``dna_stream_builder`` builds the per-group step function for the
paper's motif-count workload (pure-XLA scan path of
``repro.kernels.dna_automaton``; the Pallas kernel path stays available
through ``fa_match`` on TPU).  ``StreamingPipeline`` drives any step
builder — ``launch/serve.py`` uses it with a prefill+decode builder so
serving sessions adapt their split per request mix.
"""

from __future__ import annotations

from typing import Callable, Iterable, Sequence

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..core.hetero import DeviceGroup
from .guard import ServeGuard
from .scheduler import ChunkedScheduler, EwmaController

__all__ = ["StreamingPipeline", "dna_stream_builder"]


def dna_stream_builder(table: np.ndarray, accept: np.ndarray,
                       ) -> Callable[[DeviceGroup], Callable]:
    """Step-builder for streaming DNA motif counting.

    ``step_builder(group)`` returns ``fn(chunk)`` where ``chunk`` is
    ``{"text": (rows, T) uint8}``; rows are sharded across the group's
    devices, and the per-row match count comes from one scan over T with
    a (rows,)-vector automaton state (the batched form of
    ``kernels.dna_automaton.ref.fa_match_ref``).
    """
    table = np.asarray(table, np.int32)
    accept = np.asarray(accept)

    def build(group: DeviceGroup):
        mesh = group.mesh()
        sh = NamedSharding(mesh, P("data"))
        table_j = jax.device_put(jnp.asarray(table),
                                 NamedSharding(mesh, P()))
        accept_j = jax.device_put(jnp.asarray(accept),
                                  NamedSharding(mesh, P()))
        reps = group.work_multiplier   # test/bench hook: emulate slow group

        @jax.jit
        def count(texts):                       # (rows, T) uint8
            syms = texts.T.astype(jnp.int32)    # scan over T
            state0 = jnp.zeros(texts.shape[0], jnp.int32)

            def one_pass(_, carry):
                # start state depends on the carry (it is always state0 in
                # value) so XLA cannot hoist the scan out of the loop and
                # defeat the slow-group emulation
                s0 = jnp.maximum(state0, jnp.minimum(carry, 0))

                def step(state, sym):
                    state = table_j[state, sym]
                    return state, accept_j[state]

                _, hits = jax.lax.scan(step, s0, syms)
                return carry + hits.sum(axis=0, dtype=jnp.int32)

            return jax.lax.fori_loop(
                0, reps, one_pass,
                jnp.zeros(texts.shape[0], jnp.int32)) // reps

        def fn(chunk):
            texts = jax.device_put(chunk["text"], sh)   # async transfer
            return count(texts)                         # overlapped compute
        return fn

    return build


class StreamingPipeline:
    """Drive a stream of batches through the chunked scheduler and keep
    throughput accounting per batch."""

    def __init__(self, step_builder: Callable[[DeviceGroup], Callable],
                 groups: Sequence[DeviceGroup], *,
                 controller: EwmaController | None = None,
                 chunks_per_group: int = 2, inflight: int = 2,
                 row_quantum: int = 1, clock=None,
                 dispatch_timeout_s: float | None = None,
                 guard: "ServeGuard | bool | None" = None,
                 observer=None):
        """``guard=True`` wraps the scheduler in a default
        :class:`~repro.runtime.guard.ServeGuard` (kill-switch fallback
        to the best split seen); pass a preconfigured ``ServeGuard``
        (unbound: ``scheduler=None``) to set thresholds or a stored
        fallback split.  ``clock``/``dispatch_timeout_s`` pass through
        to the scheduler (see ``docs/resilience.md``); ``observer``
        (a ``repro.obs.Observer``, default off) flows into the
        scheduler and the guard, and additionally records a per-batch
        stream-latency histogram reported by :meth:`summary`."""
        self.scheduler = ChunkedScheduler(
            step_builder, groups, controller=controller,
            chunks_per_group=chunks_per_group, inflight=inflight,
            row_quantum=row_quantum, clock=clock,
            dispatch_timeout_s=dispatch_timeout_s, observer=observer)
        if guard is True:
            guard = ServeGuard(self.scheduler)
        elif guard is not None and guard.scheduler is None:
            guard.scheduler = self.scheduler
            guard.__post_init__()       # re-validate fallback vs groups
        self.guard = guard or None
        self.records: list[dict] = []
        self._obs = self.scheduler._obs
        if self._obs is not None:
            self._h_batch = self._obs.metrics.histogram("stream.t_step_s")
            self._h_queue = self._obs.metrics.histogram(
                "stream.queue_delay_s")

    @property
    def shares(self) -> np.ndarray:
        return self.scheduler.shares

    def run(self, batches: Iterable[dict], *,
            rebalance: bool = True,
            arrivals: Sequence[float] | None = None) -> list[dict]:
        """Process every batch; returns (and accumulates) per-batch
        records with rows/s throughput and the latency decomposition
        (``queue_delay_s`` waiting before dispatch vs ``service_s`` in
        the scheduler) added.

        ``arrivals`` gives each batch's arrival instant on the
        scheduler's clock; without it every batch counts as having
        arrived when ``run`` was called — batch k's queue delay is then
        the time batches 0..k-1 spent in service ahead of it, which is
        the honest decomposition for a pre-materialized stream."""
        out = []
        t_run0 = self.scheduler._now()
        for i, batch in enumerate(batches):
            arrival = float(arrivals[i]) if arrivals is not None else t_run0
            queue_delay = max(self.scheduler._now() - arrival, 0.0)
            if self.guard is not None:
                rec = self.guard.step(batch)   # guard owns the rebalance flag
            else:
                rec = self.scheduler.step(batch, rebalance=rebalance)
            done = sum(rec["rows_completed"])
            rec = dict(rec, rows_total=int(done),
                       rows_per_s=done / max(rec["t_step"], 1e-9),
                       queue_delay_s=queue_delay,
                       service_s=rec["t_step"],
                       e2e_s=queue_delay + rec["t_step"])
            if self._obs is not None:
                self._h_batch.observe(rec["t_step"])
                self._h_queue.observe(queue_delay)
            out.append(rec)
        self.records.extend(out)
        return out

    def summary(self) -> dict:
        """Aggregate throughput + the share trajectory + decomposed
        latency percentiles (queue delay vs service time — the same
        split the request-level serving path reports)."""
        if not self.records:
            return {"batches": 0}
        t = [r["t_step"] for r in self.records]
        out = {
            "batches": len(self.records),
            "rows_total": int(sum(r["rows_total"] for r in self.records)),
            "t_total_s": float(sum(t)),
            "rows_per_s_mean": float(np.mean([r["rows_per_s"]
                                              for r in self.records])),
            "t_step_last": float(t[-1]),
            "shares_final": [float(s) for s in self.scheduler.shares],
            "live_final": [bool(x) for x in self.scheduler.live],
            "failures": sum(len(r["failures"]) for r in self.records),
        }
        if self.guard is not None:
            out["guard_trips"] = self.guard.switch.n_trips
            out["guard_tripped"] = self.guard.tripped
        if self._obs is not None and self._h_batch.count:
            # bucket-estimated tail latencies, decomposed: service time
            # (one scheduler step; t_step_p* kept as the legacy alias)
            # vs queue delay (waiting before dispatch)
            for q, tag in ((0.50, "p50"), (0.95, "p95"), (0.99, "p99")):
                est = self._h_batch.percentile(q)
                out[f"t_step_{tag}"] = est
                out[f"service_{tag}"] = est
                out[f"queue_delay_{tag}"] = self._h_queue.percentile(q)
        return out
