"""Online surrogate feedback: warm-refit the BDTR pair from live data.

The offline pipeline (``core.autotuner.fit_emil_surrogates``) trains the
per-side ``BoostedTreesRegressor`` pair once, on a synthetic grid.  In a
live system the measured (config, time) pairs keep arriving — from the
chunked scheduler, from serving sessions, from the autotuner's own
search — and the platform drifts (thermal throttling, contention, a
degraded group).  ``OnlineSurrogateLoop`` closes the loop:

  * ``observe(cfg, t_host, t_device)`` appends one live observation per
    side (features via the pair's own feature builders);
  * every ``refit_every`` observations (or on ``refit(force=True)``)
    both models are **warm-refit**: ``BoostedTreesRegressor.fit_more``
    appends trees that chase the residuals on the live data, reusing the
    ``tree_method="hist"`` binning — the quantile pass runs once, and
    every later batch of rows is a ``searchsorted`` against frozen edges
    (``bdtr.append_rows``).

The refit mutates the pair's models **in place**, so any search holding
the ``SurrogatePair`` picks up the refreshed surrogate on its next
``saml``/``eml`` run (both the scalar and the vectorized engines rebuild
their prediction functions per call) — i.e. the search restarts from
live data instead of the offline grid.  Observations can be
persisted/restored through a ``TuningStore`` NPZ side-car
(``save_to``/``load_from``).

The unified facade integration (``repro.tune``): pass the loop as the
``online=`` of a ``TuningSession`` — or call :meth:`session` — and the
session (a) folds pending observations into the surrogate before every
search and (b) feeds each measurement whose metrics carry per-side times
(``t_host``/``t_device``) back into the loop, closing search -> measure
-> refit -> search in one object graph.
"""

from __future__ import annotations

from typing import Any, Mapping

import numpy as np

from ..core.bdtr import BinnedFeatures, append_rows, bin_features
from ..core.evaluators import SurrogatePair
from ..obs import as_observer

__all__ = ["OnlineSurrogateLoop"]


class _SideState:
    """Observation buffer + incremental binning for one model side."""

    def __init__(self, model):
        self.model = model
        self.X: list[np.ndarray] = []
        self.y: list[float] = []
        self.n_fitted = 0                      # rows already binned
        self.binned: BinnedFeatures | None = None

    def append(self, x: np.ndarray, t: float) -> None:
        self.X.append(np.asarray(x, dtype=np.float64))
        self.y.append(float(t))

    def matrix(self) -> tuple[np.ndarray, np.ndarray]:
        return np.stack(self.X), np.asarray(self.y, dtype=np.float64)

    def refit(self, n_new_trees: int, max_trees: int) -> None:
        X, y = self.matrix()
        if len(self.model.trees_) + n_new_trees > max_trees:
            # compaction: a long-running loop would otherwise grow the
            # ensemble (and every predict) without bound — retrain from
            # scratch on the live window, which is the ground truth the
            # refits were chasing anyway
            self.model.fit(X, y)
            self.binned = None
            self.n_fitted = len(X)
            return
        if self.model.tree_method == "hist":
            if self.binned is None:
                self.binned = bin_features(X, self.model.max_bins)
            elif len(X) > self.n_fitted:
                self.binned = append_rows(self.binned, X[self.n_fitted:])
            self.model.fit_more(X, y, n_new_trees, binned=self.binned)
        else:
            self.model.fit_more(X, y, n_new_trees)
        self.n_fitted = len(X)


class OnlineSurrogateLoop:
    """Append live (config, time) observations and warm-refit the pair."""

    def __init__(self, surrogate: SurrogatePair, *, refit_every: int = 32,
                 n_new_trees: int = 20, max_observations: int = 8192,
                 max_trees: int = 512, observer=None):
        """``refit_every`` observations trigger a refit on the next
        ``observe`` (or call ``refit(force=True)`` yourself);
        ``n_new_trees`` is the boosting budget per side per refit;
        ``max_observations`` caps the buffers (oldest rows are dropped,
        which also resets the incremental binning so the edges track the
        live window); ``max_trees`` caps each ensemble — a refit that
        would exceed it retrains the model from scratch on the live
        window instead (bounded predict cost over a process lifetime).
        """
        self.surrogate = surrogate
        self.refit_every = refit_every
        self.n_new_trees = n_new_trees
        self.max_observations = max_observations
        self.max_trees = max_trees
        self._host = _SideState(surrogate.host)
        self._device = _SideState(surrogate.device)
        self._since_refit = 0
        self.n_refits = 0
        self._obs = as_observer(observer)

    # -- observations -------------------------------------------------------
    @property
    def n_observations(self) -> int:
        return len(self._host.y) + len(self._device.y)

    def observe(self, cfg: Mapping[str, Any], t_host: float | None,
                t_device: float | None, *, auto_refit: bool = True) -> None:
        """Record one measured configuration.

        Pass ``None`` for a side that did no work (e.g. fraction 0/100 —
        a zero time is the E=max(...) collapse, not a measurement).
        """
        if t_host is not None:
            self._host.append(self.surrogate.host_features(cfg), t_host)
        if t_device is not None:
            self._device.append(self.surrogate.device_features(cfg),
                                t_device)
        self._since_refit += 1
        self._trim()
        if auto_refit and self._since_refit >= self.refit_every:
            self.refit(force=True)

    def _trim(self) -> None:
        for side in (self._host, self._device):
            drop = len(side.y) - self.max_observations
            if drop > 0:
                side.X = side.X[drop:]
                side.y = side.y[drop:]
                side.binned = None          # window moved: re-bin on refit
                side.n_fitted = 0

    # -- refit --------------------------------------------------------------
    def refit(self, force: bool = False) -> bool:
        """Warm-refit both sides from the accumulated observations.

        Returns True when a refit ran.  Without ``force`` the refit only
        runs once ``refit_every`` observations have accumulated since
        the last one.
        """
        if not force and self._since_refit < self.refit_every:
            return False
        token = self._obs.tracer.begin("surrogate.refit") \
            if self._obs is not None else None
        ran = False
        for side in (self._host, self._device):
            if len(side.y) >= 2 * side.model.min_samples_leaf:
                side.refit(self.n_new_trees, self.max_trees)
                ran = True
        if ran:
            self._since_refit = 0
            self.n_refits += 1
        if self._obs is not None:
            self._obs.tracer.end(token, args={"ran": ran})
            if ran:
                self._obs.metrics.counter("surrogate.refits").inc()
                self._obs.journal.event(
                    "surrogate_refit", n_refits=self.n_refits,
                    n_observations=self.n_observations,
                    n_trees=[len(self._host.model.trees_),
                             len(self._device.model.trees_)])
        return ran

    # -- the unified tuning facade ------------------------------------------
    def session(self, space, **session_kw):
        """A ``repro.tune.TuningSession`` wired to this loop.

        The session searches this loop's (live-refit) surrogate pair and
        streams its measurements back in::

            loop = OnlineSurrogateLoop(pair)
            session = loop.session(paper_space(),
                                   evaluator=platform.evaluator(gb))
            session.run("sam", iterations=50)     # measures -> observes
            session.run("saml", engine="vectorized")  # live-data restart
        """
        from ..tune import TuningSession
        return TuningSession(space, online=self, **session_kw)

    # -- persistence (TuningStore NPZ side-car) -----------------------------
    def save_to(self, store, sig: str) -> None:
        """Persist the observation buffers under ``sig`` in ``store``."""
        arrays = {}
        for name, side in (("host", self._host), ("device", self._device)):
            if side.y:
                X, y = side.matrix()
                arrays[f"{name}_X"], arrays[f"{name}_y"] = X, y
        store.save_observations(sig, **arrays)

    def load_from(self, store, sig: str) -> int:
        """Restore observation buffers recorded under ``sig``.

        Returns the number of rows restored (0 on a miss).  Restored
        rows count as un-refit observations — call ``refit(force=True)``
        to fold them in immediately.
        """
        arrays = store.load_observations(sig)
        if not arrays:
            return 0
        n = 0
        for name, side in (("host", self._host), ("device", self._device)):
            if f"{name}_y" in arrays:
                X, y = arrays[f"{name}_X"], arrays[f"{name}_y"]
                for row, t in zip(X, y):
                    side.append(row, t)
                n += len(y)
        self._since_refit += n
        self._trim()
        return n
