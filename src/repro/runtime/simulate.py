"""Simulated serial device groups for tests and benchmarks.

Forced host devices share one CPU thread pool, so wall-clock ratios
between *concurrently* dispatched groups are meaningless there (see
``docs/dist.md``).  Schedulers are therefore exercised against this
timing model: dispatch returns immediately (async, like JAX), but a
group's chunks execute serially — chunk k+1 starts when chunk k
finishes — at ``per_row_s * work_multiplier / n_devices`` seconds per
row.  ``SimReadyAt`` mimics ``jax.Array``'s completion surface
(``block_until_ready`` + ``is_ready``), so the chunked scheduler's
poll-based completion timestamps are exact for sims too.

Shared by ``tests/helpers.py`` and ``benchmarks/bench_runtime.py`` —
one copy of the semantics.
"""

from __future__ import annotations

import time

import jax

from ..core.hetero import DeviceGroup

__all__ = ["FakeDevice", "SimReadyAt", "make_serial_sim_builder",
           "sim_skew_groups"]


class SimReadyAt:
    """jax.Array-style result of an emulated dispatch: ready at an
    absolute ``time.perf_counter()`` instant."""

    def __init__(self, value, done_at: float):
        self.value = value
        self._done_at = done_at

    def is_ready(self) -> bool:
        return time.perf_counter() >= self._done_at

    def block_until_ready(self):
        time.sleep(max(0.0, self._done_at - time.perf_counter()))
        return self


class FakeDevice:
    """Placeholder device for sim-only DeviceGroups (never dispatched to)."""


def make_serial_sim_builder(per_row_s: float = 0.0005):
    """Step-builder factory emulating groups of serial devices (one
    queue tail per group; see module docstring for the timing model)."""
    tails: dict[int, float] = {}

    def builder(group: DeviceGroup):
        key = id(group)
        per = per_row_s * group.work_multiplier / len(group.devices)

        def fn(chunk):
            n = jax.tree.leaves(chunk)[0].shape[0]
            start = max(time.perf_counter(), tails.get(key, 0.0))
            tails[key] = start + per * n
            return SimReadyAt(None, tails[key])

        return fn

    return builder


def sim_skew_groups(skew: int = 3, n_fast: int = 4, n_slow: int = 4,
                    fast_first: bool = True) -> list[DeviceGroup]:
    """A fast + slow group pair with a per-row speed skew; ``fast_first``
    flips the ordering (schedulers must not care)."""
    fast = DeviceGroup("fast", [FakeDevice()] * n_fast)
    slow = DeviceGroup("slow", [FakeDevice()] * n_slow, work_multiplier=skew)
    return [fast, slow] if fast_first else [slow, fast]
