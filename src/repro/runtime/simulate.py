"""Simulated serial device groups, simulated clocks and fault injection.

Forced host devices share one CPU thread pool, so wall-clock ratios
between *concurrently* dispatched groups are meaningless there (see
``docs/dist.md``).  Schedulers are therefore exercised against this
timing model: dispatch returns immediately (async, like JAX), but a
group's chunks execute serially — chunk k+1 starts when chunk k
finishes — at ``per_row_s * work_multiplier / n_devices`` seconds per
row.  ``SimReadyAt`` mimics ``jax.Array``'s completion surface
(``block_until_ready`` + ``is_ready``) and additionally exposes
``ready_at`` so schedulers timestamp completions exactly.

Two clocks drive the model:

  * wall clock (the default) — ``block_until_ready`` really sleeps, so
    the sim occupies real time;
  * :class:`VirtualClock` — a deterministic simulated timeline:
    blocking *advances the clock number* instead of sleeping, so a
    whole convergence or failure trajectory runs in microseconds and is
    bit-identical across runs and machines (no ``time.sleep``-calibrated
    assertions anywhere — the de-flake contract of the test suite).

Fault injection rides the same layer: a :class:`FaultPlan` scripts
failures per scheduler step (kill group i at step s, slow it by f×,
raise one transient, recover at step r) and a :class:`FaultInjector`
applies the plan to any step builder — natively inside
``make_serial_sim_builder`` (exact slow factors) or wrapped around a
real-dispatch builder via :meth:`FaultInjector.wrap` — raising
``repro.dist.fault.GroupFailure`` so every scenario exercises the
production demotion path of ``ChunkedScheduler`` (docs/resilience.md).

Shared by ``tests/helpers.py``, ``tests/test_runtime_faults.py`` and
``benchmarks/bench_runtime.py`` — one copy of the semantics.
"""

from __future__ import annotations

import math
import threading
import time
from dataclasses import dataclass

import jax

from ..core.hetero import DeviceGroup
from ..dist.fault import GroupFailure

__all__ = ["FakeDevice", "FaultEvent", "FaultInjector", "FaultPlan",
           "GroupFailure", "SimReadyAt", "VirtualClock",
           "make_serial_sim_builder", "parse_fault_plan", "sim_skew_groups"]


class VirtualClock:
    """Deterministic simulated timeline for schedulers and sims.

    ``now()`` returns the current simulated instant; ``advance_to``
    moves it forward monotonically (never backward — concurrent drain
    threads may race, and the max keeps the timeline consistent).
    Passing one clock to both ``make_serial_sim_builder`` and
    ``ChunkedScheduler`` replaces every wall-clock read and sleep in the
    dispatch loop, so trajectories are exact functions of the timing
    model — independent of CI load, thread scheduling, or host speed.
    """

    def __init__(self, start: float = 0.0):
        self._now = float(start)
        self._lock = threading.Lock()

    def now(self) -> float:
        with self._lock:
            return self._now

    def advance_to(self, t: float) -> float:
        with self._lock:
            self._now = max(self._now, float(t))
            return self._now

    def advance(self, dt: float) -> float:
        if dt < 0:
            raise ValueError("cannot advance a clock backward")
        with self._lock:
            self._now += float(dt)
            return self._now


class SimReadyAt:
    """jax.Array-style result of an emulated dispatch: ready at an
    absolute instant — ``time.perf_counter()`` by default, or a
    :class:`VirtualClock` instant when ``clock`` is given (blocking then
    advances the clock instead of sleeping)."""

    def __init__(self, value, done_at: float, clock: VirtualClock | None = None):
        self.value = value
        self.ready_at = float(done_at)   # schedulers read exact completion
        self._clock = clock

    def is_ready(self) -> bool:
        now = self._clock.now() if self._clock is not None \
            else time.perf_counter()
        return now >= self.ready_at

    def block_until_ready(self):
        if self._clock is not None:
            self._clock.advance_to(self.ready_at)
        else:
            time.sleep(max(0.0, self.ready_at - time.perf_counter()))
        return self


class FakeDevice:
    """Placeholder device for sim-only DeviceGroups (never dispatched to)."""


def make_serial_sim_builder(per_row_s: float = 0.0005, *,
                            clock: VirtualClock | None = None,
                            injector: "FaultInjector | None" = None):
    """Step-builder factory emulating groups of serial devices (one
    queue tail per group; see module docstring for the timing model).

    ``clock`` switches the sim onto a deterministic virtual timeline.
    ``injector`` applies a :class:`FaultPlan` natively: killed groups
    raise :class:`GroupFailure` at dispatch, slow factors scale the
    per-row time exactly (no rounding to whole repeats).
    """
    tails: dict[int, float] = {}

    def now() -> float:
        return clock.now() if clock is not None else time.perf_counter()

    def builder(group: DeviceGroup):
        key = id(group)
        per = per_row_s * group.work_multiplier / len(group.devices)

        def fn(chunk):
            if injector is not None:
                injector.check(group)
            factor = injector.slow_factor(group) if injector is not None \
                else 1.0
            n = jax.tree.leaves(chunk)[0].shape[0]
            start = max(now(), tails.get(key, 0.0))
            tails[key] = start + per * factor * n
            return SimReadyAt(None, tails[key], clock)

        return fn

    return builder


def sim_skew_groups(skew: int = 3, n_fast: int = 4, n_slow: int = 4,
                    fast_first: bool = True) -> list[DeviceGroup]:
    """A fast + slow group pair with a per-row speed skew; ``fast_first``
    flips the ordering (schedulers must not care)."""
    fast = DeviceGroup("fast", [FakeDevice()] * n_fast)
    slow = DeviceGroup("slow", [FakeDevice()] * n_slow, work_multiplier=skew)
    return [fast, slow] if fast_first else [slow, fast]


# -- fault injection ------------------------------------------------------------

# device-level kinds target a group; process-level kinds (crash, torn)
# take down the whole process — group is carried but ignored
_FAULT_KINDS = ("kill", "slow", "transient", "recover", "crash", "torn")
_PROCESS_KINDS = ("crash", "torn")


@dataclass(frozen=True)
class FaultEvent:
    """One scripted event: at scheduler step ``step``, do ``kind`` to
    group index ``group`` (``factor`` scales per-row time for slow;
    process-level kinds ignore ``group``)."""

    step: int
    kind: str
    group: int
    factor: float = 1.0

    def __post_init__(self):
        if self.kind not in _FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {_FAULT_KINDS}")
        if self.step < 0:
            raise ValueError("fault step must be >= 0")
        if self.group < 0:
            raise ValueError("group index must be >= 0")
        if self.kind == "slow" and self.factor <= 0:
            raise ValueError("slow factor must be > 0")


class FaultPlan:
    """A deterministic failure script, built by chaining:

        plan = (FaultPlan()
                .slow(1, at=3, factor=4.0)   # group 1 drops to 1/4 speed
                .kill(0, at=6)               # group 0 dies mid-stream
                .recover(0, at=12))          # ... and comes back

    One plan drives one run: a :class:`FaultInjector` consumes it step
    by step (``tick`` before each scheduler step).  The same plan runs
    identically against the serial-device sim and real dispatch, so
    every failure scenario is a fast, seeded, deterministic test.
    """

    def __init__(self, events: "list[FaultEvent] | tuple[FaultEvent, ...]" = ()):
        self.events: list[FaultEvent] = sorted(events, key=lambda e: e.step)

    def _add(self, **kw) -> "FaultPlan":
        self.events.append(FaultEvent(**kw))
        self.events.sort(key=lambda e: e.step)
        return self

    def kill(self, group: int, *, at: int) -> "FaultPlan":
        """Group ``group``'s dispatches raise from step ``at`` on."""
        return self._add(step=at, kind="kill", group=group)

    def slow(self, group: int, *, at: int, factor: float) -> "FaultPlan":
        """Scale the group's per-row time by ``factor`` from step ``at``."""
        return self._add(step=at, kind="slow", group=group, factor=factor)

    def transient(self, group: int, *, at: int) -> "FaultPlan":
        """Raise exactly one ``GroupFailure`` at step ``at`` (the group
        is healthy again afterwards, but the scheduler will have demoted
        it — pair with :meth:`recover` to bring it back)."""
        return self._add(step=at, kind="transient", group=group)

    def recover(self, group: int, *, at: int) -> "FaultPlan":
        """Clear kill/slow state at step ``at`` and (when the injector
        is attached to a scheduler) restore the group's membership."""
        return self._add(step=at, kind="recover", group=group)

    def crash(self, *, at: int) -> "FaultPlan":
        """Process fault at step ``at``: the injector's ``crash_mode``
        decides how it dies — ``"raise"`` throws
        :class:`~repro.runtime.checkpoint.SimulatedCrash` out of the
        serving loop (the in-process drill), ``"sigkill"`` delivers a
        real ``SIGKILL`` to the process (the subprocess drill).  On a
        resumed run, :meth:`FaultInjector.fast_forward` suppresses
        already-fired crashes so the plan does not re-kill the
        recovery."""
        return self._add(step=at, kind="crash", group=0)

    def torn(self, *, at: int) -> "FaultPlan":
        """Torn-write process fault at step ``at``: flush a *partial*
        record to the attached WAL (:meth:`FaultInjector.attach_wal`),
        then die exactly like :meth:`crash` — the restart must detect
        and truncate the torn tail."""
        return self._add(step=at, kind="torn", group=0)

    def at(self, step: int) -> list[FaultEvent]:
        return [e for e in self.events if e.step == step]

    @property
    def last_step(self) -> int:
        return max((e.step for e in self.events), default=-1)


def parse_fault_plan(spec: str) -> FaultPlan:
    """Parse a CLI fault-plan spec into a :class:`FaultPlan`.

    Comma-separated events, each ``kind:group@step`` with an extra
    ``:factor`` for slow::

        kill:0@3,slow:1@9:4,transient:0@5,recover:0@12,crash:0@8

    kills group 0 at step 3, slows group 1 to 1/4 speed from step 9,
    raises one transient on group 0 at step 5, recovers group 0 at step
    12.  Process-level kinds (``crash``, ``torn``) carry a group index
    for spelling uniformity but ignore it.  This is the surface behind
    ``launch/serve.py --fault-plan`` (the CI fault drill) — the parsed
    plan is the same object the tests build by chaining, so a drill
    spec is exactly reproducible in code.
    """
    plan = FaultPlan()
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        try:
            kind, rest = part.split(":", 1)
            factor = None
            if kind == "slow":
                rest, factor_s = rest.split(":", 1)
                factor = float(factor_s)
            group_s, step_s = rest.split("@", 1)
            group, step = int(group_s), int(step_s)
        except ValueError as exc:
            raise ValueError(
                f"bad fault-plan event {part!r}: expected kind:group@step "
                "(slow:group@step:factor), e.g. 'kill:0@3,slow:1@9:4'"
            ) from exc
        if kind == "kill":
            plan.kill(group, at=step)
        elif kind == "slow":
            plan.slow(group, at=step, factor=factor)
        elif kind == "transient":
            plan.transient(group, at=step)
        elif kind == "recover":
            plan.recover(group, at=step)
        elif kind == "crash":
            plan.crash(at=step)
        elif kind == "torn":
            plan.torn(at=step)
        else:
            raise ValueError(f"unknown fault kind {kind!r} in {part!r}; "
                             f"expected one of {_FAULT_KINDS}")
    return plan


class FaultInjector:
    """Applies a :class:`FaultPlan` to step builders, one scheduler step
    at a time.

    The harness calls :meth:`tick` *before* each scheduler step; the
    events scripted for that step take effect (kills and slow factors
    persist until a recover event).  Dispatch-side state is consulted by
    the builders — natively by ``make_serial_sim_builder(injector=...)``
    or through :meth:`wrap` for any real builder.  ``attach`` a
    scheduler (or guard) so recover events call ``restore_group`` —
    demotion needs no attachment: the raised ``GroupFailure`` triggers
    it inside ``ChunkedScheduler.step``.

    Process-level events (``crash``/``torn``) fire inside :meth:`tick`
    — before the step's dispatch, outside the engine's failure
    handling, so they take the whole process down rather than demoting
    a group.  ``crash_mode="raise"`` throws ``SimulatedCrash`` (the
    in-process drill: the caller's ``except`` is the "restart");
    ``crash_mode="sigkill"`` delivers a real ``SIGKILL`` (the
    subprocess drill: nothing downstream of the kernel runs).  A
    ``torn`` event additionally flushes a partial record to the WAL
    attached via :meth:`attach_wal` first.  On resume,
    :meth:`fast_forward` replays the pre-crash steps' persistent
    effects (kills, slows) and marks fired process faults as spent.
    """

    def __init__(self, plan: FaultPlan, groups: "list[DeviceGroup]", *,
                 crash_mode: str = "raise"):
        for ev in plan.events:
            if ev.kind not in _PROCESS_KINDS and ev.group >= len(groups):
                raise ValueError(f"fault event {ev} references group "
                                 f"{ev.group}, but only {len(groups)} "
                                 "groups exist")
        if crash_mode not in ("raise", "sigkill"):
            raise ValueError("crash_mode must be 'raise' or 'sigkill'")
        self.plan = plan
        self.groups = list(groups)
        self.crash_mode = crash_mode
        self.step = -1                       # tick() moves to step 0
        self._dead: set[int] = set()
        self._slow: dict[int, float] = {}
        self._transient: set[int] = set()
        self._spent_crashes: set[int] = set()   # steps whose crash fired
        self._target = None
        self._wal = None

    def attach(self, target) -> "FaultInjector":
        """``target`` must expose ``restore_group(i)`` (a
        ``ChunkedScheduler`` or ``ServeGuard``); recover events call it."""
        self._target = target
        return self

    def attach_wal(self, wal) -> "FaultInjector":
        """``wal`` must expose ``append_torn(kind, **fields)`` (a
        ``runtime.checkpoint.WalWriter``); ``torn`` events flush a
        partial record through it before dying."""
        self._wal = wal
        return self

    def fast_forward(self, n_steps: int) -> "FaultInjector":
        """Resume support: re-apply steps ``0..n_steps-1`` — persistent
        device faults (kill/slow/recover) re-establish their state,
        one-shot transients are consumed silently, and process faults
        are marked spent so the crash that ended the previous run does
        not re-fire when the resumed run passes its step."""
        for _ in range(n_steps):
            self.step += 1
            for ev in self.plan.at(self.step):
                if ev.kind == "kill":
                    self._dead.add(ev.group)
                elif ev.kind == "slow":
                    if ev.factor == 1.0:
                        self._slow.pop(ev.group, None)
                    else:
                        self._slow[ev.group] = ev.factor
                elif ev.kind == "recover":
                    self._dead.discard(ev.group)
                    self._slow.pop(ev.group, None)
                elif ev.kind in _PROCESS_KINDS:
                    self._spent_crashes.add(ev.step)
        return self

    def _die(self, ev: FaultEvent) -> None:
        self._spent_crashes.add(ev.step)
        if self.crash_mode == "sigkill":
            import os
            import signal
            os.kill(os.getpid(), signal.SIGKILL)
        from .checkpoint import SimulatedCrash
        raise SimulatedCrash(
            f"injected {ev.kind} fault at step {ev.step}")

    def tick(self) -> list[FaultEvent]:
        """Advance to the next scheduler step; apply its events."""
        self.step += 1
        fired = self.plan.at(self.step)
        for ev in fired:
            if ev.kind == "kill":
                self._dead.add(ev.group)
            elif ev.kind == "slow":
                if ev.factor == 1.0:
                    self._slow.pop(ev.group, None)
                else:
                    self._slow[ev.group] = ev.factor
            elif ev.kind == "transient":
                self._transient.add(ev.group)
            elif ev.kind == "recover":
                self._dead.discard(ev.group)
                self._slow.pop(ev.group, None)
                self._transient.discard(ev.group)
                if self._target is not None:
                    self._target.restore_group(ev.group)
            elif ev.kind in _PROCESS_KINDS \
                    and ev.step not in self._spent_crashes:
                if ev.kind == "torn" and self._wal is not None:
                    self._wal.append_torn("admit", torn=True)
                self._die(ev)
        return fired

    # -- dispatch-side state -----------------------------------------------
    def index_of(self, group: DeviceGroup) -> int:
        for i, g in enumerate(self.groups):
            if g is group:
                return i
        raise KeyError(f"group {group.name!r} is not under this injector")

    def check(self, group: DeviceGroup) -> None:
        """Raise ``GroupFailure`` if the group is scripted to fail now."""
        gi = self.index_of(group)
        if gi in self._dead:
            raise GroupFailure(
                f"group {group.name!r} killed at step {self.step}")
        if gi in self._transient:
            self._transient.discard(gi)      # exactly once
            raise GroupFailure(
                f"transient failure on group {group.name!r} "
                f"at step {self.step}")

    def slow_factor(self, group: DeviceGroup) -> float:
        return self._slow.get(self.index_of(group), 1.0)

    def wrap(self, step_builder):
        """Wrap any step builder (same contract as the scheduler's):
        kills/transients raise before dispatch; slow factors re-dispatch
        the chunk ``ceil(factor) - 1`` extra times (the same devices
        serialize the repeats, so the group measures ~factor× slower —
        exact for integer factors, the sim path scales exactly)."""
        def wrapped_builder(group: DeviceGroup):
            fn = step_builder(group)

            def wrapped(chunk):
                self.check(group)
                result = fn(chunk)
                extra = math.ceil(self.slow_factor(group)) - 1
                if extra > 0:
                    result = (result,) + tuple(fn(chunk)
                                               for _ in range(extra))
                return result

            return wrapped

        return wrapped_builder
