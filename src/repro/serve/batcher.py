"""Continuous batching: coalesce admitted requests into scheduler batches.

The scheduler's unit of work is a batch of rows with one jitted step
function per shape; the serving layer's unit of work is a request.  The
batcher closes the gap with *continuous batching*: instead of a fixed
cohort that runs to completion before the next forms, every scheduler
step re-forms its batch from whatever is queued *right now* — new
requests join mid-stream (next step), finished requests retire
individually, and a step never waits for stragglers of a previous
cohort.

Formation policy (``form``):

  * the queue is priority-ordered ((-priority, admit time, rid) — FIFO
    within a class, interactive ahead of best-effort);
  * the head request pins the batch **shape**; same-shape requests are
    taken in queue order up to ``max_batch_rows`` (a different shape
    would force a retrace, so it waits for a later batch);
  * **coalesce window**: when the batch is not full and another arrival
    is due within ``coalesce_window_s`` of the head's admission,
    formation holds until then — trading a bounded head-of-line delay
    for larger (more device-efficient) batches.  ``coalesce_window_s=0``
    dispatches eagerly;
  * the formed batch is padded up to a multiple of ``align`` (the
    scheduler's live row quantum, Σ live device counts × row_quantum)
    with throwaway rows appended *after* the request rows — each
    request occupies one contiguous row span, so its completion instant
    is the max of the scheduler's per-row ``row_done_at`` over that
    span.

The three knobs (``max_batch_rows``, ``coalesce_window_s``,
``queue_depth_rows``) trade latency against throughput in a
workload-dependent way — exactly the shape of problem the paper's
tuning methodology solves, so :func:`tune_batcher` exposes them as a
``ConfigSpace`` (210 configs) driven through ``TuningSession`` against
a latency-percentile objective, with results persisted in the
``TuningStore`` (a repeat workload re-serves the tuned config with zero
new measurements).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Sequence

from ..core.space import ConfigSpace, Param
from ..tune.session import TuningSession
from .request import Request

__all__ = ["BatcherConfig", "ContinuousBatcher", "FormedBatch",
           "batcher_space", "tune_batcher"]


def batcher_space() -> ConfigSpace:
    """The batcher's tuning space (7 x 5 x 6 = 210 configs).

    ``max_batch_rows`` spans device-starved to throughput-saturated;
    ``coalesce_window_ms`` spans eager dispatch to aggressive
    coalescing; ``queue_depth_rows`` is the admission backpressure bound
    (it shapes the latency/goodput trade under overload).  A ``sam``
    tuning run with ~10 measurements is 4.8% of the space — inside the
    paper's ~5% envelope.
    """
    return ConfigSpace([
        Param("max_batch_rows", (16, 24, 32, 48, 64, 96, 128)),
        Param("coalesce_window_ms", (0, 2, 5, 10, 20)),
        Param("queue_depth_rows", (64, 128, 192, 256, 384, 512)),
    ])


@dataclass(frozen=True)
class BatcherConfig:
    """One point of the batcher space (seconds, not the space's ms)."""

    max_batch_rows: int = 64
    coalesce_window_s: float = 0.002
    queue_depth_rows: int = 256

    def __post_init__(self):
        if self.max_batch_rows < 1:
            raise ValueError("max_batch_rows must be >= 1")
        if self.coalesce_window_s < 0:
            raise ValueError("coalesce_window_s must be >= 0")
        if self.queue_depth_rows < 1:
            raise ValueError("queue_depth_rows must be >= 1")

    @classmethod
    def from_config(cls, cfg: dict) -> "BatcherConfig":
        """From a tuning-space config dict (``coalesce_window_ms``)."""
        return cls(max_batch_rows=int(cfg["max_batch_rows"]),
                   coalesce_window_s=float(cfg["coalesce_window_ms"]) / 1e3,
                   queue_depth_rows=int(cfg["queue_depth_rows"]))


@dataclass(frozen=True)
class FormedBatch:
    """One scheduler batch worth of requests: ``requests`` in row
    order (request i occupies rows ``[spans[i], spans[i] + rows_i)``),
    padded to ``padded_rows`` total."""

    requests: tuple[Request, ...]
    shape: tuple[int, int]
    rows: int           # request rows (sum over requests)
    padded_rows: int    # rows after alignment padding

    @property
    def spans(self) -> list[tuple[int, int]]:
        """Per-request ``(lo, rows)`` row spans within the batch."""
        out, lo = [], 0
        for r in self.requests:
            out.append((lo, r.rows))
            lo += r.rows
        return out


class ContinuousBatcher:
    """Priority queue + batch formation under one :class:`BatcherConfig`.

    ``push`` admits requests into the queue; ``form`` either returns a
    :class:`FormedBatch` (requests transitioned to ``batched``), a
    ``float`` hold-until instant (coalesce window active — call again
    at/after it), or ``None`` (queue empty).
    """

    def __init__(self, config: BatcherConfig | None = None):
        self.config = config or BatcherConfig()
        self.queue: list[Request] = []

    def push(self, req: Request) -> None:
        self.queue.append(req)
        # stable priority order; t_admit tie-breaks FIFO within a class,
        # rid makes the order total (deterministic across runs)
        self.queue.sort(key=lambda r: (-r.priority, r.t_admit, r.rid))

    @property
    def queued_rows(self) -> int:
        return sum(r.rows for r in self.queue)

    def remove(self, reqs: Sequence[Request]) -> None:
        gone = {r.rid for r in reqs}
        self.queue = [r for r in self.queue if r.rid not in gone]

    def form(self, now: float, *, next_arrival: float | None = None,
             align: int = 1, flush: bool = False,
             ) -> "FormedBatch | float | None":
        """Form the next batch from the queue head (see class doc).

        ``next_arrival`` is the source's next arrival instant (for the
        coalesce hold); ``flush=True`` disables the hold (drain mode —
        the source is exhausted, nothing more is coming).
        """
        if not self.queue:
            return None
        head = self.queue[0]
        take: list[Request] = []
        rows = 0
        for req in self.queue:
            if req.shape != head.shape:
                continue                     # different retrace key
            if rows + req.rows > self.config.max_batch_rows:
                break
            take.append(req)
            rows += req.rows
        if not take:
            # head alone exceeds max_batch_rows: take it anyway (it
            # could never dispatch otherwise) — the scheduler handles
            # oversized batches fine, the cap is a latency knob
            take, rows = [head], head.rows
        # coalesce: hold a non-full batch while another arrival is due
        # within the window of the head's admission
        if not flush and rows < self.config.max_batch_rows \
                and self.config.coalesce_window_s > 0 \
                and next_arrival is not None:
            hold_until = head.t_admit + self.config.coalesce_window_s
            if now < hold_until and next_arrival <= hold_until:
                return hold_until
        align = max(int(align), 1)
        padded = -(-rows // align) * align
        self.remove(take)
        for r in take:
            r.batched()
        return FormedBatch(requests=tuple(take), shape=head.shape,
                           rows=rows, padded_rows=padded)


def tune_batcher(evaluate: Callable[[BatcherConfig], dict], *,
                 store=None, workload: dict | None = None,
                 strategy: str = "sam", iterations: int = 9,
                 seed: int = 0, observer=None):
    """Tune the batcher knobs through the paper's tuning machinery.

    ``evaluate(BatcherConfig) -> metrics`` must return a dict with a
    ``"time"`` entry (the objective — the serving drills use admitted
    p95 end-to-end latency with a goodput-weighted penalty for sheds).
    Results persist in ``store`` keyed by ``workload``; a repeat call
    with the same workload re-serves the stored winner with zero new
    measurements (``TuneResult.from_cache``).

    Returns ``(BatcherConfig, TuneResult)``.  With the default ``sam``
    strategy and ``iterations=9``, n_experiments is ~10 of 210 configs
    (≈4.8% — the paper's ~5% envelope).
    """
    space = batcher_space()

    def _eval(cfg: dict) -> dict:
        return evaluate(BatcherConfig.from_config(cfg))

    session = TuningSession(space, evaluator=_eval, store=store,
                            workload={"task": "serve_batcher",
                                      **(workload or {})},
                            seed=seed, observer=observer)
    result = session.run(strategy, iterations=iterations)
    return BatcherConfig.from_config(result.best_config), result
