"""SLO-aware admission control and load shedding.

The serving path has a finite capacity; an open-loop arrival process
does not care.  The admission layer is the valve between the two: every
submitted request is either **admitted** (it will terminally complete
or be explicitly shed later — never silently lost) or **shed
immediately** with a journaled reason.  The shedding policy, in the
order the checks run:

  1. ``queue_full`` — the batcher's queue already holds
     ``max_queue_rows`` rows.  Backpressure bound: without it an
     over-capacity offered load grows the queue (and every queued
     request's latency) without bound.  Shedding at the door keeps the
     *admitted* latency distribution bounded — the classic
     goodput-over-throughput trade.
  2. ``degraded`` — the serve guard reports degraded mode (kill-switch
     trip or membership shrink).  Capacity is reduced and/or untrusted,
     so requests with ``priority <= degraded_shed_priority`` (the
     best-effort classes) are shed to preserve headroom for the
     latency-sensitive ones.  Higher-priority classes still pass
     through checks 1 and 3.
  3. ``infeasible`` — deadline feasibility.  With the live per-row
     service estimate ``s`` (EWMA over observed scheduler steps), a
     request arriving ``now`` behind ``q`` queued rows completes no
     earlier than ``now + s * (q + rows)``; if that already misses the
     request's deadline, admitting it wastes capacity that feasible
     requests could use.  ``slack`` scales the estimate (>1 =
     conservative admission).

Failed dispatches route through :meth:`AdmissionController.retry_or_shed`
— a bounded-retry policy (``max_retries``), with the same feasibility
check applied at retry time (a request whose deadline became hopeless
while it waited is shed as ``infeasible``, not re-queued).  After a
capacity shrink, :meth:`reevaluate` re-runs feasibility over the queue
so already-admitted requests that can no longer make their deadlines
are shed *now* rather than after burning a dispatch slot.

All decisions are pure functions of (request, clock, queue state,
estimator state), so a fault drill on a ``VirtualClock`` journals the
identical decision sequence every run.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

from .request import Request

__all__ = ["ServiceEstimator", "SloPolicy", "AdmissionController",
           "SHED_REASONS"]

SHED_REASONS = ("queue_full", "degraded", "infeasible", "retries_exhausted",
                "drained")


class ServiceEstimator:
    """EWMA estimate of per-row service time, capacity-shift aware.

    Feed it ``observe(t_step, rows)`` after every scheduler step; it
    tracks ``per_row_s`` (seconds of wall time per batch row) with the
    same exponential smoothing the scheduler's own controller uses.
    ``rescale(ratio)`` handles discrete capacity changes (a group
    demotion roughly multiplies per-row time by old/new capacity) so
    feasibility checks react to a shrink immediately instead of waiting
    for the EWMA to drift there.
    """

    def __init__(self, *, init_per_row_s: float = 1e-3,
                 smoothing: float = 0.4):
        if init_per_row_s <= 0:
            raise ValueError("init_per_row_s must be > 0")
        if not 0 < smoothing <= 1:
            raise ValueError("smoothing must be in (0, 1]")
        self.per_row_s = float(init_per_row_s)
        self.smoothing = float(smoothing)
        self.n_obs = 0

    @property
    def ready(self) -> bool:
        """False until the first real observation: the initial estimate
        is a prior, not a measurement, so admission treats feasibility
        checks as advisory until this flips."""
        return self.n_obs > 0

    def observe(self, t_step: float, rows: int) -> None:
        if rows < 1 or t_step < 0:
            return
        x = t_step / rows
        a = self.smoothing
        self.per_row_s = x if self.n_obs == 0 \
            else (1 - a) * self.per_row_s + a * x
        self.n_obs += 1

    def rescale(self, ratio: float) -> None:
        """Multiply the estimate by ``ratio`` (= old_capacity /
        new_capacity for a shrink: fewer device-seconds per second means
        proportionally more wall time per row)."""
        if ratio > 0:
            self.per_row_s *= float(ratio)

    def eta(self, queued_rows: int, rows: int) -> float:
        """Estimated seconds until a request of ``rows`` rows placed
        behind ``queued_rows`` rows completes."""
        return self.per_row_s * (queued_rows + rows)

    # -- durability (runtime.checkpoint snapshots) -------------------------
    def state_dict(self) -> dict:
        return {"per_row_s": float(self.per_row_s),
                "n_obs": int(self.n_obs)}

    def load_state(self, state: dict) -> None:
        self.per_row_s = float(state["per_row_s"])
        self.n_obs = int(state["n_obs"])


@dataclass(frozen=True)
class SloPolicy:
    """Knobs of the admission policy (defaults documented in
    ``docs/serving.md``).

    ``max_queue_rows``: backpressure bound — queue rows beyond which
    new arrivals are shed ``queue_full``.  ``max_retries``: dispatch
    failures a request may survive before ``retries_exhausted``.
    ``degraded_shed_priority``: in degraded mode, requests with
    priority <= this are shed (default 0 = shed best-effort, keep
    interactive).  ``slack``: feasibility safety factor on the service
    estimate (>1 admits conservatively).
    """

    max_queue_rows: int = 256
    max_retries: int = 1
    degraded_shed_priority: int = 0
    slack: float = 1.0

    def __post_init__(self):
        if self.max_queue_rows < 1:
            raise ValueError("max_queue_rows must be >= 1")
        if self.max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        if self.slack <= 0:
            raise ValueError("slack must be > 0")


class AdmissionController:
    """Stateless-per-decision admission valve (state lives in the
    estimator and the policy)."""

    def __init__(self, policy: SloPolicy | None = None,
                 estimator: ServiceEstimator | None = None):
        self.policy = policy or SloPolicy()
        self.estimator = estimator or ServiceEstimator()

    def _infeasible(self, req: Request, now: float,
                    queued_rows: int) -> bool:
        if not self.estimator.ready:
            return False          # prior only — don't shed on a guess
        eta = self.policy.slack * self.estimator.eta(queued_rows, req.rows)
        return now + eta > req.deadline

    def admit(self, req: Request, now: float, queued_rows: int, *,
              degraded: bool = False) -> str | None:
        """Admission decision for a submitted request: ``None`` =
        admit; otherwise the shed reason (policy order: queue_full,
        degraded, infeasible).  The caller performs the actual state
        transition + journaling."""
        if queued_rows + req.rows > self.policy.max_queue_rows:
            return "queue_full"
        if degraded and req.priority <= self.policy.degraded_shed_priority:
            return "degraded"
        if self._infeasible(req, now, queued_rows):
            return "infeasible"
        return None

    def retry_or_shed(self, req: Request, now: float,
                      queued_rows: int) -> str | None:
        """Post-failure decision: ``None`` = retry (re-queue);
        otherwise the shed reason.  Bounded retries, then the same
        feasibility check as at admission — waiting through a failure
        may have made the deadline hopeless."""
        if req.retries >= self.policy.max_retries:
            return "retries_exhausted"
        if self._infeasible(req, now, queued_rows):
            return "infeasible"
        return None

    def reevaluate(self, queue: Sequence[Request], now: float, *,
                   degraded: bool = False) -> list[tuple[Request, str]]:
        """Re-check already-admitted queued requests after a capacity
        change; returns ``(request, reason)`` pairs to shed (the caller
        removes them from the queue and journals).  Feasibility is
        evaluated against each request's position in the queue, so
        requests that still fit ahead of the cut keep their admission.
        """
        sheds = []
        ahead = 0
        for req in queue:
            if degraded \
                    and req.priority <= self.policy.degraded_shed_priority:
                sheds.append((req, "degraded"))
                continue
            if self._infeasible(req, now, ahead):
                sheds.append((req, "infeasible"))
                continue
            ahead += req.rows
        return sheds
