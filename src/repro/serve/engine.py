"""The serving run loop: source -> admission -> batcher -> scheduler.

``ServeEngine`` is the long-lived request-level loop above the chunked
scheduler.  One iteration:

  1. **ingest** — pull every arrival up to ``now`` from the source and
     run the admission policy on each: admitted requests enter the
     batcher's priority queue (journal ``request_admitted``), the rest
     are shed with a journaled reason (``request_shed``);
  2. **form** — ask the batcher for the next batch.  An empty queue
     advances the clock to the next arrival; a coalesce hold advances
     it to the hold horizon (new arrivals may join); a formed batch
     proceeds;
  3. **dispatch** — build the payload (``payload_fn(shape, rows)``),
     mark requests dispatched, tick the fault injector, and run one
     scheduler (or guard) step.  The scheduler advancing the clock
     while the step runs is what makes the batching *continuous*:
     requests arriving during the step are ingested at the top of the
     next iteration and join the very next batch;
  4. **retire** — on success, each request's completion instant is the
     max of the scheduler's per-row ``row_done_at`` over the request's
     contiguous row span (exact attribution, not step-end rounding);
     journal ``request_retired`` with the queue-delay/service
     decomposition.  On step failure (every live group failed — single
     -group failures are absorbed inside the scheduler by orphan
     re-dispatch), every in-flight request transitions to ``failed``
     and the admission layer decides retry (re-queue, journal
     ``request_retried``) or shed;
  5. **capacity watch** — if live membership shrank during the step,
     the service estimator rescales immediately (old/new capacity
     ratio) and the queue is re-evaluated: requests whose deadlines
     became infeasible are shed now instead of after burning a
     dispatch.

The loop ends when the source is exhausted and the queue is drained;
every admitted request is then terminal (completed or shed with a
reason) — the zero-lost-requests invariant the fault drill asserts.

**Crash durability** (``docs/resilience.md``): with ``wal=`` the engine
appends an ``admit`` record the moment a request is admitted (and again
on each retry re-queue, so the retry budget survives a restart) and a
``retire`` record at every terminal transition; a ``step`` record per
scheduler step pins the simulated clock and the fault plan's position.
``snapshot_path=`` adds periodic checksummed snapshots of the soft
state the WAL does not carry (controller shares + live mask, kill
switch, guard fallback, service estimator).  After a crash,
:meth:`ServeEngine.restore` replays the WAL: admitted-but-unretired
requests are rebuilt (``replayed`` marker set) and re-enter admission —
at-least-once execution, exactly-once terminal accounting (exactly one
valid ``retire`` per rid across both runs' WAL, which resumes in
place).

``make_sim_engine`` wires the whole stack onto the deterministic sim
rig (skewed fake device groups, ``VirtualClock``, optional
``FaultPlan``), shared by the bench, the CLI drill and the tests; with
``wal=``/``resume=True`` it is also the crash-recovery rig.
"""

from __future__ import annotations

import time
from typing import Callable

import numpy as np

from ..obs import as_observer
from ..runtime.checkpoint import WalWriter, load_snapshot, save_snapshot
from ..runtime.guard import ServeGuard
from ..runtime.scheduler import ChunkedScheduler
from ..runtime.simulate import (FaultInjector, FaultPlan, VirtualClock,
                                make_serial_sim_builder, sim_skew_groups)
from .admission import AdmissionController, ServiceEstimator, SloPolicy
from .batcher import BatcherConfig, ContinuousBatcher, FormedBatch
from .request import Request, RequestSource

__all__ = ["ServeEngine", "make_sim_engine"]


def _zeros_payload(shape: tuple[int, int], rows: int) -> dict:
    """Default payload builder: the sim path only counts rows, so the
    feature dimension just needs to exist."""
    return {"x": np.zeros((rows, max(shape[0], 1)), np.float32)}


class ServeEngine:
    """Request-level serving loop (see module docstring)."""

    def __init__(self, target: "ServeGuard | ChunkedScheduler", *,
                 source: RequestSource,
                 admission: AdmissionController | None = None,
                 batcher: ContinuousBatcher | None = None,
                 payload_fn: Callable[[tuple[int, int], int], dict]
                 = _zeros_payload,
                 injector: FaultInjector | None = None,
                 observer=None, max_steps: int | None = None,
                 wal: WalWriter | None = None,
                 snapshot_path=None, snapshot_every: int = 8):
        """``target`` is a ``ServeGuard`` (degraded-mode aware path) or
        a bare ``ChunkedScheduler``.  ``observer`` defaults to the
        scheduler's (so request events share the run's journal
        sequence); ``max_steps`` is a safety valve — when hit, the
        remaining queue is shed as ``drained``.  ``wal`` (an open
        ``runtime.checkpoint.WalWriter``) makes every admission and
        retirement durable; ``snapshot_path`` + ``snapshot_every``
        checkpoint the soft state every N steps (see module
        docstring)."""
        if isinstance(target, ServeGuard):
            self.guard: ServeGuard | None = target
            self.scheduler = target.scheduler
        else:
            self.guard = None
            self.scheduler = target
        self.source = source
        self.admission = admission or AdmissionController()
        self.batcher = batcher or ContinuousBatcher()
        self.payload_fn = payload_fn
        self.injector = injector
        self.max_steps = max_steps
        self.wal = wal
        self.snapshot_path = snapshot_path
        self.snapshot_every = max(int(snapshot_every), 1)
        self.replayed = 0                  # requests re-queued on restore
        self.done: list[Request] = []      # terminal requests, any state
        self.steps = 0
        if wal is not None and self.injector is not None:
            self.injector.attach_wal(wal)
        self._obs = as_observer(observer) or self.scheduler._obs
        if self._obs is not None:
            m = self._obs.metrics
            self._h_queue = m.histogram("serve.queue_delay_s")
            self._h_service = m.histogram("serve.service_s")
            self._h_e2e = m.histogram("serve.e2e_s")

    # -- clock / capacity ---------------------------------------------------
    def _now(self) -> float:
        return self.scheduler._now()

    def _wait_until(self, t: float) -> None:
        clock = self.scheduler.clock
        if clock is not None and hasattr(clock, "advance_to"):
            clock.advance_to(t)
        else:
            time.sleep(max(t - self._now(), 0.0))

    def _degraded(self) -> bool:
        if self.guard is not None:
            return self.guard.degraded
        return not bool(self.scheduler.controller.live.all())

    def _capacity(self) -> float:
        """Relative serving capacity: device-rows per unit time, summed
        over live groups (the sim model's exact throughput; a faithful
        proxy for real groups)."""
        return sum(len(g.devices) / g.work_multiplier
                   for g, l in zip(self.scheduler.groups,
                                   self.scheduler.live) if l)

    def _align(self) -> int:
        live_align = sum(len(g.devices)
                         for g, l in zip(self.scheduler.groups,
                                         self.scheduler.live) if l)
        return max(live_align, 1) * self.scheduler.row_quantum

    # -- journal helpers ----------------------------------------------------
    def _j(self, kind: str, **fields) -> None:
        if self._obs is not None:
            self._obs.journal.event(kind, **fields)

    def _count(self, name: str) -> None:
        if self._obs is not None:
            self._obs.metrics.counter(name).inc()

    # -- lifecycle steps ----------------------------------------------------
    def _ingest(self, now: float) -> None:
        degraded = self._degraded()
        for req in self.source.take_until(now):
            reason = self.admission.admit(req, now, self.batcher.queued_rows,
                                          degraded=degraded)
            if reason is None:
                req.admit(now)
                self.batcher.push(req)
                if self.wal is not None:
                    self.wal.append("admit", **req.wal_fields(),
                                    replayed=req.replayed)
                self._count("serve.admitted")
                self._j("request_admitted", rid=req.rid, rows=req.rows,
                        shape=list(req.shape), klass=req.klass,
                        queued_rows=self.batcher.queued_rows)
            else:
                self._shed(req, now, reason)

    def _shed(self, req: Request, now: float, reason: str) -> None:
        req.shed(now, reason)
        self.done.append(req)
        if self.wal is not None:
            # shed-at-the-door requests get a retire record too: the WAL
            # then names every delivered rid, which is what fast-forwards
            # the arrival source exactly on restore
            self.wal.append("retire", rid=req.rid, status="shed",
                            reason=reason, t_done=req.t_done,
                            retries=req.retries)
        self._count(f"serve.shed.{reason}")
        self._j("request_shed", rid=req.rid, reason=reason, klass=req.klass,
                retries=req.retries)

    def _retire(self, fb: FormedBatch, rec: dict) -> None:
        done_at = rec.get("row_done_at")
        fallback = self._now()
        for (lo, rows), req in zip(fb.spans, fb.requests):
            span = None if done_at is None else done_at[lo:lo + rows]
            t_done = fallback if span is None or np.isnan(span).any() \
                else float(np.max(span))
            req.completed(t_done)
            self.done.append(req)
            if self.wal is not None:
                self.wal.append("retire", rid=req.rid, status="completed",
                                t_done=req.t_done, retries=req.retries)
            self._count("serve.completed")
            if self._obs is not None:
                self._h_queue.observe(req.queue_delay_s)
                self._h_service.observe(req.service_s)
                self._h_e2e.observe(req.latency_s)
            self._j("request_retired", rid=req.rid, klass=req.klass,
                    retries=req.retries, replayed=req.replayed,
                    queue_delay_s=round(req.queue_delay_s, 9),
                    service_s=round(req.service_s, 9),
                    e2e_s=round(req.latency_s, 9),
                    slo_ok=bool(req.slo_ok))

    def _handle_failure(self, fb: FormedBatch, error: str) -> None:
        now = self._now()
        for req in fb.requests:
            req.failed()
            reason = self.admission.retry_or_shed(
                req, now, self.batcher.queued_rows)
            if reason is None:
                req.retry(now)
                self.batcher.push(req)
                if self.wal is not None:
                    # a fresh admit record with the bumped retry count:
                    # the latest admit per rid wins at replay, so the
                    # retry budget is crash-durable (a request cannot
                    # earn extra retries by crashing the process)
                    self.wal.append("admit", **req.wal_fields(),
                                    replayed=req.replayed)
                self._count("serve.retried")
                self._j("request_retried", rid=req.rid, retries=req.retries,
                        error=error)
            else:
                self._shed(req, now, reason)

    def _after_step(self, cap_before: float) -> None:
        cap_after = self._capacity()
        if cap_after < cap_before and cap_after > 0:
            self.admission.estimator.rescale(cap_before / cap_after)
            now = self._now()
            for req, reason in self.admission.reevaluate(
                    self.batcher.queue, now, degraded=self._degraded()):
                self.batcher.remove([req])
                self._shed(req, now, reason)

    def _dispatch(self, fb: FormedBatch) -> None:
        now = self._now()
        payload = self.payload_fn(fb.shape, fb.padded_rows)
        for req in fb.requests:
            req.dispatched(now)
        if self.injector is not None:
            self.injector.tick()
        cap_before = self._capacity()
        try:
            rec = self.guard.step(payload) if self.guard is not None \
                else self.scheduler.step(payload)
        except RuntimeError as e:
            # every live group failed this step; single-group failures
            # never surface here (scheduler-internal re-dispatch)
            self._handle_failure(fb, str(e))
            self._after_step(cap_before)
            return
        self.admission.estimator.observe(rec["t_step"], fb.padded_rows)
        self._retire(fb, rec)
        self._after_step(cap_before)

    # -- durability ---------------------------------------------------------
    def save_state_snapshot(self) -> None:
        """Checksummed snapshot of the soft recoverable state — what the
        WAL's request records cannot reconstruct: controller shares +
        live mask, kill-switch baseline/trip state, the guard's learned
        fallback, and the service estimator (``docs/resilience.md``)."""
        state = {
            "now": round(self._now(), 9),
            "steps": self.steps,
            "controller": self.scheduler.controller.state_dict(),
            "estimator": self.admission.estimator.state_dict(),
            "guard": None if self.guard is None else self.guard.state_dict(),
        }
        save_snapshot(self.snapshot_path, state)
        self._j("snapshot_saved", step=self.steps,
                wal_lsn=None if self.wal is None else self.wal.lsn)

    def restore(self, records: list[dict], state: dict | None = None, *,
                torn: bool = False) -> dict:
        """Rebuild run state from a recovered WAL (+ optional snapshot).

        The WAL is the source of truth for *hard* state — which rids
        were delivered, which were retired, how far the clock and the
        fault plan got; the snapshot restores the *soft* state
        (controller/guard/estimator) when present and fresh.  Admitted-
        but-unretired requests are rebuilt from their latest ``admit``
        record (``replayed`` marker set, retry budget preserved) and
        re-enter admission at the recovered instant: the ones that still
        fit re-queue, the rest shed with a journaled reason — either
        way every pre-crash admission reaches exactly one valid
        ``retire`` record.  Returns a summary dict (also journaled as
        ``wal_recovered``).
        """
        admits: dict[int, dict] = {}
        retired: set[int] = set()
        delivered: set[int] = set()
        steps, now = 0, 0.0
        for rec in records:
            kind = rec.get("kind")
            if kind == "admit":
                admits[int(rec["rid"])] = rec          # latest wins
                delivered.add(int(rec["rid"]))
            elif kind == "retire":
                retired.add(int(rec["rid"]))
                delivered.add(int(rec["rid"]))
                now = max(now, float(rec.get("t_done") or 0.0))
            elif kind == "step":
                steps = max(steps, int(rec["step"]))
                now = max(now, float(rec["now"]))
        if state is not None:
            steps = max(steps, int(state.get("steps", 0)))
            now = max(now, float(state.get("now", 0.0)))
            self.scheduler.controller.load_state(state["controller"])
            self.admission.estimator.load_state(state["estimator"])
            if self.guard is not None and state.get("guard") is not None:
                self.guard.load_state(state["guard"])
        self.steps = steps
        clock = self.scheduler.clock
        if clock is not None and hasattr(clock, "advance_to"):
            clock.advance_to(now)
        if self.injector is not None:
            # re-apply the pre-crash fault timeline: persistent device
            # faults re-establish, fired process faults are spent.  The
            # +1 covers the tick that died mid-flight — its step record
            # was never written, but its events (including the crash)
            # all fired before the process went down.
            self.injector.fast_forward(steps + 1)
        # groups the snapshot remembers as dead re-run the scheduler's
        # demotion (plan-cache keying, journal) — straight on the
        # scheduler, not the guard, so the restored kill-switch baseline
        # is not reset by a membership "change" that is only a restore
        for i, live in enumerate(self.scheduler.controller.live):
            if not live:
                self.scheduler.controller.live[i] = True  # let drop re-run
                self.scheduler.drop_group(i, reason="wal-restore")
        n_requeued = n_shed = 0
        now = self._now()
        degraded = self._degraded()
        for rid in sorted(set(admits) - retired):
            req = Request.from_wal(admits[rid])
            self.replayed += 1
            reason = self.admission.admit(req, now,
                                          self.batcher.queued_rows,
                                          degraded=degraded)
            self._j("request_replayed", rid=req.rid, rows=req.rows,
                    retries=req.retries,
                    disposition="requeued" if reason is None else reason)
            if reason is None:
                req.admit(now)
                self.batcher.push(req)
                self._count("serve.replayed")
                n_requeued += 1
            else:
                self._shed(req, now, reason)
                n_shed += 1
        # the source delivers rids in order: everything the WAL names
        # was handed out before the crash
        fast_forward_to = max(delivered, default=-1) + 1
        self.source._next = max(self.source._next, fast_forward_to)
        out = {"wal_records": len(records), "admitted": len(admits),
               "retired": len(retired), "replayed": self.replayed,
               "requeued": n_requeued, "shed_on_replay": n_shed,
               "steps": self.steps, "now": round(now, 9),
               "torn": bool(torn)}
        self._j("wal_recovered", **out)
        return out

    # -- run ---------------------------------------------------------------
    def run(self) -> dict:
        """Serve the whole source to drained; returns :meth:`summary`."""
        while True:
            now = self._now()
            self._ingest(now)
            fb = self.batcher.form(now, next_arrival=self.source.next_time(),
                                   align=self._align(),
                                   flush=self.source.exhausted)
            if fb is None:
                nxt = self.source.next_time()
                if nxt is None:
                    break                    # drained: source + queue empty
                self._wait_until(nxt)
                continue
            if isinstance(fb, float):        # coalesce hold
                nxt = self.source.next_time()
                self._wait_until(min(fb, nxt) if nxt is not None else fb)
                continue
            self._dispatch(fb)
            self.steps += 1
            if self.wal is not None:
                # pins the clock and the fault plan's position, so a
                # restart resumes the exact simulated timeline even when
                # the last snapshot is several steps stale
                self.wal.append("step", step=self.steps,
                                now=round(self._now(), 9))
            if self.snapshot_path is not None \
                    and self.steps % self.snapshot_every == 0:
                self.save_state_snapshot()
            if self.max_steps is not None and self.steps >= self.max_steps:
                now = self._now()
                for req in list(self.batcher.queue):
                    self.batcher.remove([req])
                    self._shed(req, now, "drained")
                break
        if self.wal is not None:
            self.wal.sync()
        if self.snapshot_path is not None:
            self.save_state_snapshot()
        return self.summary()

    def summary(self) -> dict:
        """Exact (not bucket-estimated) end-to-end percentiles over the
        terminal requests, plus shed accounting and goodput."""
        completed = [r for r in self.done if r.status == "completed"]
        shed = [r for r in self.done if r.status == "shed"]
        out = {
            "requests": len(self.done),
            "completed": len(completed),
            "shed": len(shed),
            "shed_rate": len(shed) / max(len(self.done), 1),
            "shed_reasons": {},
            "retries": sum(r.retries for r in self.done),
            "replayed": self.replayed,
            "steps": self.steps,
            "slo_violations": sum(1 for r in completed if not r.slo_ok),
        }
        for r in shed:
            out["shed_reasons"][r.shed_reason] = \
                out["shed_reasons"].get(r.shed_reason, 0) + 1
        if completed:
            e2e = np.asarray([r.latency_s for r in completed])
            qd = np.asarray([r.queue_delay_s for r in completed])
            sv = np.asarray([r.service_s for r in completed])
            for q, tag in ((50, "p50"), (95, "p95"), (99, "p99")):
                out[f"e2e_{tag}"] = float(np.percentile(e2e, q))
                out[f"queue_delay_{tag}"] = float(np.percentile(qd, q))
                out[f"service_{tag}"] = float(np.percentile(sv, q))
            t0 = min(r.t_arrival for r in completed)
            t1 = max(r.t_done for r in completed)
            rows_done = sum(r.rows for r in completed)
            out["goodput_rows_per_s"] = rows_done / max(t1 - t0, 1e-9)
        return out


def make_sim_engine(*, n_requests: int = 200, rate_rps: float = 400.0,
                    seed: int = 0, per_row_s: float = 4e-4, skew: int = 3,
                    batcher_config: BatcherConfig | None = None,
                    policy: SloPolicy | None = None,
                    fault_plan: FaultPlan | None = None,
                    guard: bool = False, observer=None,
                    source: RequestSource | None = None,
                    row_quantum: int = 1,
                    max_steps: int | None = None,
                    wal=None, snapshot=None, snapshot_every: int = 8,
                    resume: bool = False, crash_mode: str = "raise",
                    wal_fsync_every: int = 1) -> ServeEngine:
    """The deterministic serving rig: skewed sim groups on a
    ``VirtualClock``, optionally fault-injected and guard-wrapped.

    Identical parameters + seed produce identical journals on any
    machine (the bench, CLI drill and tests all ride this).  Capacity
    of the default rig: 2 groups x 4 devices with skew 3 gives
    ``(4 + 4/3) / per_row_s`` rows/s ≈ 13.3k rows/s at the default
    ``per_row_s`` — pick ``rate_rps`` (x mean rows/request) relative to
    that for under/over-capacity regimes.

    ``wal`` (a path) makes the run crash-durable; ``snapshot`` (a path)
    adds the periodic soft-state checkpoint; ``resume=True`` recovers
    both before serving (torn WAL tails truncate, corrupt snapshots
    quarantine) and replays unretired requests — the crash-recovery
    drill is "same call, plus ``resume=True``".  ``crash_mode`` selects
    how scripted ``crash``/``torn`` faults die (``"raise"`` for the
    in-process drill, ``"sigkill"`` for the real-subprocess one).
    """
    clock = VirtualClock()
    groups = sim_skew_groups(skew)
    injector = FaultInjector(fault_plan, groups, crash_mode=crash_mode) \
        if fault_plan is not None else None
    builder = make_serial_sim_builder(per_row_s, clock=clock,
                                      injector=injector)
    obs = as_observer(observer)
    if obs is not None and obs.clock is None:
        # the rig owns the VirtualClock; rebind a wall-clock observer so
        # journal/trace timestamps ride the deterministic timeline
        obs.clock = clock
        obs.tracer.clock = clock
        obs.journal.clock = clock
    scheduler = ChunkedScheduler(builder, groups, clock=clock,
                                 row_quantum=row_quantum, observer=obs)
    target: ServeGuard | ChunkedScheduler = scheduler
    if guard:
        target = ServeGuard(scheduler)
    if injector is not None:
        injector.attach(target)
    if source is None:
        source = RequestSource(n_requests=n_requests, rate_rps=rate_rps,
                               seed=seed)
    estimator = ServiceEstimator(init_per_row_s=per_row_s)
    bc = batcher_config or BatcherConfig()
    if policy is None:
        # the batcher's tuned queue-depth knob IS the admission
        # backpressure bound — one knob, one policy
        policy = SloPolicy(max_queue_rows=bc.queue_depth_rows)
    admission = AdmissionController(policy, estimator=estimator)
    batcher = ContinuousBatcher(bc)
    wal_writer = WalWriter(wal, fsync_every=wal_fsync_every) \
        if wal is not None else None
    engine = ServeEngine(target, source=source, admission=admission,
                         batcher=batcher, injector=injector, observer=obs,
                         max_steps=max_steps, wal=wal_writer,
                         snapshot_path=snapshot,
                         snapshot_every=snapshot_every)
    if resume:
        if wal_writer is None:
            raise ValueError("resume=True needs a wal path to recover from")
        state = load_snapshot(snapshot) if snapshot is not None else None
        engine.restore(wal_writer.recovered, state,
                       torn=wal_writer.torn is not None)
    return engine
