"""repro.serve: request-level serving above the chunked scheduler.

The runtime (``repro.runtime``) moves *batches*; real serving moves
*requests* — they arrive whenever they arrive, carry deadlines and
priorities, and the system's job is to keep the admitted latency
distribution inside the SLO while shedding what it cannot serve.  This
package is that layer, built as four small pieces:

  * :mod:`~repro.serve.request` — the request lifecycle state machine
    and the deterministic (seeded, ``VirtualClock``-friendly) arrival
    source;
  * :mod:`~repro.serve.admission` — SLO-aware admission, load shedding
    and bounded retry (the documented policy: queue backpressure,
    degraded-mode priority shedding, deadline feasibility on a live
    EWMA service estimate);
  * :mod:`~repro.serve.batcher` — continuous batching (join
    mid-stream, retire per-request) with the three knobs exposed as a
    ``ConfigSpace`` tuned through the paper's ``TuningSession``;
  * :mod:`~repro.serve.engine` — the run loop binding source ->
    admission -> batcher -> scheduler/guard, instrumented through
    ``repro.obs``, plus the shared sim rig (``make_sim_engine``).

Everything is wall-clock independent under the sim rig: the same seed
and fault plan journal the same decision sequence on any machine.
``docs/serving.md`` documents the policies and the latency anatomy.
"""

from .admission import (AdmissionController, ServiceEstimator,  # noqa: F401
                        SHED_REASONS, SloPolicy)
from .batcher import (BatcherConfig, ContinuousBatcher,  # noqa: F401
                      FormedBatch, batcher_space, tune_batcher)
from .engine import ServeEngine, make_sim_engine  # noqa: F401
from .request import (Request, RequestClass, RequestSource,  # noqa: F401
                      REQUEST_STATES)

__all__ = [
    "AdmissionController", "ServiceEstimator", "SloPolicy", "SHED_REASONS",
    "BatcherConfig", "ContinuousBatcher", "FormedBatch", "batcher_space",
    "tune_batcher",
    "ServeEngine", "make_sim_engine",
    "Request", "RequestClass", "RequestSource", "REQUEST_STATES",
]
