"""Request lifecycle and deterministic request sources.

The unit of work one level above the scheduler's batch: a ``Request``
asks for ``rows`` batch rows of a given ``(prompt_len, gen)`` shape,
arrives at an instant on the serving clock, and carries a deadline
(``t_arrival + slo_s``) and a priority class.  Its lifecycle is an
explicit state machine —

    submitted ──▶ admitted ──▶ batched ──▶ dispatched ──▶ completed
        │            │                          │
        └──▶ shed ◀──┴──────────(failed ────────┘──▶ admitted | shed)

— every transition is validated (an illegal one raises), timestamped on
the serving clock, and the terminal states are exactly ``completed``
and ``shed``: the zero-lost-requests invariant of the serving engine is
"every admitted request ends in one of the two, with sheds carrying a
journaled reason".

``RequestSource`` is the deterministic arrival process: all arrivals
(Poisson interarrivals at ``rate_rps``, mixed shapes/rows/classes) are
precomputed from one seed in ``__init__``, so every test, bench and CI
drill that shares a seed sees bit-identical request streams on a
``VirtualClock`` — wall-clock independence exactly like the PR 7/8
fault and observability harnesses.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Sequence

import numpy as np

__all__ = ["Request", "RequestClass", "RequestSource", "REQUEST_STATES"]

REQUEST_STATES = ("submitted", "admitted", "shed", "batched", "dispatched",
                  "completed", "failed")

# state machine: legal transitions (see module docstring).  ``failed ->
# admitted`` is the retry re-queue; ``failed -> shed`` is the give-up.
_TRANSITIONS = {
    "submitted": {"admitted", "shed"},
    "admitted": {"batched", "shed"},
    "batched": {"dispatched"},
    "dispatched": {"completed", "failed"},
    "failed": {"admitted", "shed"},
    "completed": set(),
    "shed": set(),
}


@dataclass(frozen=True)
class RequestClass:
    """One priority class of the request mix: a name, the class SLO
    (deadline = arrival + ``slo_s``), a priority (higher dispatches
    first; lower is shed first under degraded capacity) and the mix
    weight the source draws with."""

    name: str
    slo_s: float
    priority: int = 0
    weight: float = 1.0

    def __post_init__(self):
        if self.slo_s <= 0:
            raise ValueError("slo_s must be > 0")
        if self.weight < 0:
            raise ValueError("weight must be >= 0")


@dataclass
class Request:
    """One serving request: ``rows`` batch rows of one prompt/gen shape
    with an arrival time, deadline and priority class."""

    rid: int
    rows: int
    prompt_len: int
    gen: int
    t_arrival: float
    slo_s: float
    klass: str = "interactive"
    priority: int = 0
    status: str = "submitted"
    retries: int = 0
    t_admit: float | None = None
    t_dispatch: float | None = None
    t_done: float | None = None
    shed_reason: str | None = field(default=None)
    # True when this request was rebuilt from the write-ahead log after
    # a crash and re-entered admission (at-least-once replay); completion
    # records and journal events carry the marker so recovered lifecycles
    # are distinguishable in latency anatomy (docs/serving.md)
    replayed: bool = False

    def __post_init__(self):
        if self.rows < 1:
            raise ValueError("a request needs at least one row")
        if self.slo_s <= 0:
            raise ValueError("slo_s must be > 0")

    # -- derived ------------------------------------------------------------
    @property
    def shape(self) -> tuple[int, int]:
        """Batching compatibility key: only same-shape requests coalesce
        into one scheduler batch (one jitted step per shape)."""
        return (self.prompt_len, self.gen)

    @property
    def deadline(self) -> float:
        return self.t_arrival + self.slo_s

    @property
    def terminal(self) -> bool:
        return self.status in ("completed", "shed")

    @property
    def queue_delay_s(self) -> float | None:
        """Arrival -> dispatch wait (None until dispatched)."""
        if self.t_dispatch is None:
            return None
        return self.t_dispatch - self.t_arrival

    @property
    def service_s(self) -> float | None:
        """Dispatch -> completion (None until completed)."""
        if self.t_done is None or self.t_dispatch is None:
            return None
        return self.t_done - self.t_dispatch

    @property
    def latency_s(self) -> float | None:
        """End-to-end arrival -> completion (None until completed)."""
        if self.t_done is None:
            return None
        return self.t_done - self.t_arrival

    @property
    def slo_ok(self) -> bool | None:
        if self.t_done is None:
            return None
        return self.t_done <= self.deadline

    # -- transitions --------------------------------------------------------
    def _to(self, state: str) -> None:
        if state not in _TRANSITIONS[self.status]:
            raise ValueError(
                f"request {self.rid}: illegal transition "
                f"{self.status!r} -> {state!r}")
        self.status = state

    def admit(self, now: float) -> "Request":
        self._to("admitted")
        if self.t_admit is None:         # a retry keeps its first admit
            self.t_admit = float(now)
        return self

    def shed(self, now: float, reason: str) -> "Request":
        self._to("shed")
        self.t_done = float(now)
        self.shed_reason = str(reason)
        return self

    def batched(self) -> "Request":
        self._to("batched")
        return self

    def dispatched(self, now: float) -> "Request":
        self._to("dispatched")
        self.t_dispatch = float(now)
        return self

    def completed(self, done_at: float) -> "Request":
        self._to("completed")
        self.t_done = float(done_at)
        return self

    def failed(self) -> "Request":
        """The dispatch carrying this request died before completing it;
        the admission layer decides retry (back to ``admitted``) or
        shed."""
        self._to("failed")
        self.t_dispatch = None           # the next dispatch re-stamps it
        return self

    def retry(self, now: float) -> "Request":
        self.retries += 1
        return self.admit(now)

    def record(self) -> dict:
        """JSON-ready completion record (terminal states only)."""
        return {
            "rid": self.rid, "rows": self.rows, "shape": list(self.shape),
            "klass": self.klass, "priority": self.priority,
            "status": self.status, "retries": self.retries,
            "shed_reason": self.shed_reason,
            "t_arrival": self.t_arrival, "t_done": self.t_done,
            "queue_delay_s": self.queue_delay_s,
            "service_s": self.service_s,
            "latency_s": self.latency_s,
            "slo_ok": self.slo_ok,
            "replayed": self.replayed,
        }

    # -- write-ahead log round trip (runtime.checkpoint) --------------------
    def wal_fields(self) -> dict:
        """The identity fields an ``admit`` WAL record persists — enough
        to rebuild the request for post-crash replay (timing state is
        re-derived on replay, not restored)."""
        return {
            "rid": self.rid, "rows": self.rows,
            "prompt_len": self.prompt_len, "gen": self.gen,
            "t_arrival": self.t_arrival, "slo_s": self.slo_s,
            "klass": self.klass, "priority": self.priority,
            "retries": self.retries,
        }

    @classmethod
    def from_wal(cls, rec: dict) -> "Request":
        """Rebuild a replayable request from an ``admit`` WAL record:
        fresh ``submitted`` status (it re-enters admission), original
        arrival/deadline/retry budget, ``replayed`` marker set."""
        return cls(rid=int(rec["rid"]), rows=int(rec["rows"]),
                   prompt_len=int(rec["prompt_len"]), gen=int(rec["gen"]),
                   t_arrival=float(rec["t_arrival"]),
                   slo_s=float(rec["slo_s"]), klass=str(rec["klass"]),
                   priority=int(rec["priority"]),
                   retries=int(rec.get("retries", 0)), replayed=True)


class RequestSource:
    """Deterministic request arrival process.

    Every arrival is precomputed in ``__init__`` from one seeded
    generator: exponential interarrivals at ``rate_rps`` (a Poisson
    process — the standard open-loop offered-load model), request rows
    drawn from ``rows_choices``, shapes from ``shapes`` and priority
    classes from ``classes`` (weights normalized).  The source is
    consumed by time: ``take_until(now)`` hands over everything that
    has arrived, ``next_time()`` tells the engine how far to advance an
    idle clock.  Two sources with the same parameters and seed produce
    identical streams on any machine.
    """

    def __init__(self, *, n_requests: int, rate_rps: float, seed: int = 0,
                 shapes: Sequence[tuple[int, int]] = ((32, 16),),
                 shape_weights: Sequence[float] | None = None,
                 rows_choices: Sequence[int] = (1, 2, 4),
                 row_weights: Sequence[float] | None = None,
                 classes: Sequence[RequestClass] | None = None,
                 start: float = 0.0):
        if n_requests < 1:
            raise ValueError("need at least one request")
        if rate_rps <= 0:
            raise ValueError("rate_rps must be > 0")
        if classes is None:
            classes = (RequestClass("interactive", slo_s=1.0, priority=1,
                                    weight=0.7),
                      RequestClass("batch", slo_s=4.0, priority=0,
                                   weight=0.3))
        self.classes = tuple(classes)
        rng = np.random.default_rng(seed)

        def norm(w, n):
            w = np.full(n, 1.0 / n) if w is None else np.asarray(w, float)
            return w / w.sum()

        arrivals = start + np.cumsum(rng.exponential(1.0 / rate_rps,
                                                     n_requests))
        shape_idx = rng.choice(len(shapes), n_requests,
                               p=norm(shape_weights, len(shapes)))
        rows = rng.choice(np.asarray(rows_choices, int), n_requests,
                          p=norm(row_weights, len(rows_choices)))
        class_idx = rng.choice(
            len(self.classes), n_requests,
            p=norm([c.weight for c in self.classes], len(self.classes)))
        self.requests = [
            Request(rid=i, rows=int(rows[i]),
                    prompt_len=int(shapes[shape_idx[i]][0]),
                    gen=int(shapes[shape_idx[i]][1]),
                    t_arrival=float(arrivals[i]),
                    slo_s=self.classes[class_idx[i]].slo_s,
                    klass=self.classes[class_idx[i]].name,
                    priority=self.classes[class_idx[i]].priority)
            for i in range(n_requests)
        ]
        self._next = 0

    def __len__(self) -> int:
        return len(self.requests)

    @property
    def exhausted(self) -> bool:
        return self._next >= len(self.requests)

    @property
    def remaining(self) -> int:
        return len(self.requests) - self._next

    def next_time(self) -> float | None:
        """Arrival instant of the next undelivered request (None when
        exhausted) — the engine's idle-clock advance target."""
        if self.exhausted:
            return None
        return self.requests[self._next].t_arrival

    def take_until(self, now: float) -> list[Request]:
        """All requests with ``t_arrival <= now`` not yet handed over,
        in arrival order."""
        out = []
        while not self.exhausted \
                and self.requests[self._next].t_arrival <= now:
            out.append(self.requests[self._next])
            self._next += 1
        return out

    @property
    def total_rows(self) -> int:
        return sum(r.rows for r in self.requests)
