"""Benchmark: hardcoded vs autotuned kernel launch parameters.

For every registered Pallas kernel (``repro.tune.kernels``) — forward
*and* backward passes are separate registered spaces (``mamba_scan`` /
``mamba_scan_bwd``, ...) — this tunes the launch-parameter space with
the paper's headline method (SAML: BDTR surrogate + simulated
annealing; measured experiments capped at ~5% of each space), then
reports per kernel:

  * time at the hardcoded defaults vs the tuned configuration,
  * experiments performed vs space size (the <=5% claim),
  * a repeat tune of the same (kernel, shape, dtype, backend) workload,
    which must be served from the ``TuningStore`` with **zero** new
    measurements (the serve-time ``tuned=`` fast path).

A second section (``fwd_bwd``) times ``jax.value_and_grad`` through the
differentiable kernel ops end to end — defaults vs the tuned store —
showing that training steps through ``models/{mamba,rwkv6}.py`` pick up
both the tuned forward and the tuned backward launch parameters.

On CPU the kernels run in Pallas interpret mode — the launch-parameter
cost model there (grid-cell count) is real but different from Mosaic's;
on a TPU backend the same script times compiled kernels.  Results land
in ``BENCH_kernels.json``; the tuning store itself is written next to
it (``BENCH_kernels_store.json``) so a serving session can point
``--tuned-kernels`` at it.

Usage:
    PYTHONPATH=src python benchmarks/bench_kernels.py [--smoke] [--out PATH]
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
from pathlib import Path

os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

ROOT = Path(__file__).resolve().parents[1]


def bench_kernel(name: str, store, *, strategy: str, smoke: bool,
                 seed: int = 0) -> dict:
    from repro.tune import kernels as ktune

    t0 = time.perf_counter()
    out = ktune.tune_kernel(name, strategy=strategy, store=store,
                            smoke=smoke, seed=seed)
    t_default = out.default_time()
    t_tuned = out.best_time()
    # repeat the identical workload: must be a pure cache hit
    out2 = ktune.tune_kernel(name, strategy=strategy, store=store,
                             smoke=smoke, seed=seed)
    rec = {
        "shape": out.shape,
        "dtype": out.dtype,
        "strategy": strategy.upper(),
        "space_size": out.space_size,
        "experiments_performed": out.n_measured,
        "measured_fraction": round(out.measured_fraction, 4),
        "default_config": out.default_config,
        "tuned_config": out.best_config,
        "t_default_s": round(t_default, 6),
        "t_tuned_s": round(t_tuned, 6),
        "speedup": round(t_default / t_tuned, 3) if t_tuned > 0 else None,
        "cache_hit": bool(out2.result.from_cache),
        "cache_hit_measurements": out2.n_measured,
        "wall_s": round(time.perf_counter() - t0, 3),
    }
    # repeated tuning of a known workload must never measure anything
    assert out2.result.from_cache and out2.n_measured == 0, rec
    return rec


# the ops with a Pallas custom_vjp: loss builders for the fwd+bwd section
def _grad_fns():
    import jax

    from repro.kernels.mamba_scan import ops as ms_ops
    from repro.kernels.rwkv6_wkv import ops as wkv_ops

    def mamba(inputs, tuned):
        def loss(x):
            y, h = ms_ops.selective_scan(x, *inputs[1:], tuned=tuned)
            return y.sum() + h.sum()
        return jax.jit(jax.value_and_grad(loss)), inputs[0]

    def rwkv(inputs, tuned):
        def loss(r):
            y, s = wkv_ops.wkv6(r, *inputs[1:], tuned=tuned)
            return y.sum() + s.sum()
        return jax.jit(jax.value_and_grad(loss)), inputs[0]

    return {"mamba_scan": mamba, "rwkv6_wkv": rwkv}


def _time_best(fn, arg, repeats: int = 3) -> float:
    import jax

    jax.block_until_ready(fn(arg))               # compile + warm
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(arg))
        best = min(best, time.perf_counter() - t0)
    return best


def bench_fwd_bwd(name: str, store, *, smoke: bool) -> dict:
    """Time ``jax.value_and_grad`` through the kernel op: hardcoded
    defaults vs tuned launch params (forward and backward resolved
    independently from the bench store, as a training step would)."""
    import numpy as np

    import jax.numpy as jnp

    from repro.tune import kernels as ktune

    spec = ktune.get_kernel(name)
    meta = dict(spec.smoke_shape if smoke else spec.default_shape)
    inputs = spec.make_inputs(meta, "float32", np.random.default_rng(0))
    build = _grad_fns()[name]
    fn, arg = build(inputs, False)
    t_default = _time_best(fn, arg)
    ktune.configure(store, enabled=False)
    try:
        fn, arg = build(inputs, True)
        t_tuned = _time_best(fn, arg)
        tuned_fwd = ktune.resolve_config(name, meta, jnp.float32)
        tuned_bwd = ktune.resolve_config(f"{name}_bwd", meta, jnp.float32)
    finally:
        ktune.disable()
    return {
        "shape": meta,
        "t_default_s": round(t_default, 6),
        "t_tuned_s": round(t_tuned, 6),
        "speedup": round(t_default / t_tuned, 3) if t_tuned > 0 else None,
        "tuned_fwd_config": tuned_fwd,
        "tuned_bwd_config": tuned_bwd,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (tiny shapes, interpret mode)")
    ap.add_argument("--strategy", default="saml",
                    help="registered session strategy (default: the "
                    "paper's SAML)")
    ap.add_argument("--out", default=str(ROOT / "BENCH_kernels.json"))
    args = ap.parse_args()

    from repro.runtime.store import TuningStore

    out_path = Path(args.out)
    store_path = out_path.with_name(out_path.stem + "_store.json")
    if store_path.exists():
        store_path.unlink()                      # fresh search every run
    store = TuningStore(store_path)

    from repro.tune import kernels as ktune

    t0 = time.perf_counter()
    results: dict = {"kernels": {}}
    for name in ktune.list_kernels():
        rec = bench_kernel(name, store, strategy=args.strategy,
                           smoke=args.smoke)
        results["kernels"][name] = rec
        print(f"{name}: default {rec['t_default_s']}s -> tuned "
              f"{rec['t_tuned_s']}s ({rec['speedup']}x) with "
              f"{rec['experiments_performed']}/{rec['space_size']} "
              f"measured ({100 * rec['measured_fraction']:.1f}%), "
              f"repeat tune: {rec['cache_hit_measurements']} measurements")

    results["fwd_bwd"] = {}
    for name in ("mamba_scan", "rwkv6_wkv"):
        rec = bench_fwd_bwd(name, store, smoke=args.smoke)
        results["fwd_bwd"][name] = rec
        print(f"{name} fwd+bwd: default {rec['t_default_s']}s -> tuned "
              f"{rec['t_tuned_s']}s ({rec['speedup']}x) "
              f"[fwd {rec['tuned_fwd_config']} | bwd "
              f"{rec['tuned_bwd_config']}]")

    import jax
    recs = results["kernels"].values()
    results["backend"] = jax.default_backend()
    results["smoke"] = bool(args.smoke)
    results["store"] = store_path.name
    results["n_speedup_1p15_within_5pct"] = sum(
        1 for r in recs
        if (r["speedup"] or 0) >= 1.15 and r["measured_fraction"] <= 0.05)
    results["wall_s"] = round(time.perf_counter() - t0, 3)

    # acceptance bars (full run): >= 2 kernels at >= 1.15x found with
    # <= 5% of the space measured, and the chunked-scan kernels must
    # beat their serial-scan defaults by >= 1.3x under the same budget.
    # Smoke spaces are too small for the fraction bound, so smoke only
    # enforces the cache contract above.
    if not args.smoke:
        assert results["n_speedup_1p15_within_5pct"] >= 2, results
        for name in ("mamba_scan", "rwkv6_wkv"):
            r = results["kernels"][name]
            assert (r["speedup"] or 0) >= 1.3, (name, r)
            assert r["measured_fraction"] <= 0.05, (name, r)

    out_path.write_text(json.dumps(results, indent=1) + "\n")
    print(f"wrote {out_path} (store: {store_path})")


if __name__ == "__main__":
    main()
