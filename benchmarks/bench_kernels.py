"""Benchmark: hardcoded vs autotuned kernel launch parameters.

For every registered Pallas kernel (``repro.tune.kernels``) this tunes
the launch-parameter space with the paper's headline method (SAML:
BDTR surrogate + simulated annealing; measured experiments capped at
~5% of each space), then reports per kernel:

  * time at the hardcoded defaults vs the tuned configuration,
  * experiments performed vs space size (the <=5% claim),
  * a repeat tune of the same (kernel, shape, dtype, backend) workload,
    which must be served from the ``TuningStore`` with **zero** new
    measurements (the serve-time ``tuned=`` fast path).

On CPU the kernels run in Pallas interpret mode — the launch-parameter
cost model there (grid-cell count) is real but different from Mosaic's;
on a TPU backend the same script times compiled kernels.  Results land
in ``BENCH_kernels.json``; the tuning store itself is written next to
it (``BENCH_kernels_store.json``) so a serving session can point
``--tuned-kernels`` at it.

Usage:
    PYTHONPATH=src python benchmarks/bench_kernels.py [--smoke] [--out PATH]
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
from pathlib import Path

os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

ROOT = Path(__file__).resolve().parents[1]


def bench_kernel(name: str, store, *, strategy: str, smoke: bool,
                 seed: int = 0) -> dict:
    from repro.tune import kernels as ktune

    t0 = time.perf_counter()
    out = ktune.tune_kernel(name, strategy=strategy, store=store,
                            smoke=smoke, seed=seed)
    t_default = out.default_time()
    t_tuned = out.best_time()
    # repeat the identical workload: must be a pure cache hit
    out2 = ktune.tune_kernel(name, strategy=strategy, store=store,
                             smoke=smoke, seed=seed)
    rec = {
        "shape": out.shape,
        "dtype": out.dtype,
        "strategy": strategy.upper(),
        "space_size": out.space_size,
        "experiments_performed": out.n_measured,
        "measured_fraction": round(out.measured_fraction, 4),
        "default_config": out.default_config,
        "tuned_config": out.best_config,
        "t_default_s": round(t_default, 6),
        "t_tuned_s": round(t_tuned, 6),
        "speedup": round(t_default / t_tuned, 3) if t_tuned > 0 else None,
        "cache_hit": bool(out2.result.from_cache),
        "cache_hit_measurements": out2.n_measured,
        "wall_s": round(time.perf_counter() - t0, 3),
    }
    # repeated tuning of a known workload must never measure anything
    assert out2.result.from_cache and out2.n_measured == 0, rec
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (tiny shapes, interpret mode)")
    ap.add_argument("--strategy", default="saml",
                    help="registered session strategy (default: the "
                    "paper's SAML)")
    ap.add_argument("--out", default=str(ROOT / "BENCH_kernels.json"))
    args = ap.parse_args()

    from repro.runtime.store import TuningStore

    out_path = Path(args.out)
    store_path = out_path.with_name(out_path.stem + "_store.json")
    if store_path.exists():
        store_path.unlink()                      # fresh search every run
    store = TuningStore(store_path)

    from repro.tune import kernels as ktune

    t0 = time.perf_counter()
    results: dict = {"kernels": {}}
    for name in ktune.list_kernels():
        rec = bench_kernel(name, store, strategy=args.strategy,
                           smoke=args.smoke)
        results["kernels"][name] = rec
        print(f"{name}: default {rec['t_default_s']}s -> tuned "
              f"{rec['t_tuned_s']}s ({rec['speedup']}x) with "
              f"{rec['experiments_performed']}/{rec['space_size']} "
              f"measured ({100 * rec['measured_fraction']:.1f}%), "
              f"repeat tune: {rec['cache_hit_measurements']} measurements")

    import jax
    recs = results["kernels"].values()
    results["backend"] = jax.default_backend()
    results["smoke"] = bool(args.smoke)
    results["store"] = store_path.name
    results["n_speedup_1p15_within_5pct"] = sum(
        1 for r in recs
        if (r["speedup"] or 0) >= 1.15 and r["measured_fraction"] <= 0.05)
    results["wall_s"] = round(time.perf_counter() - t0, 3)

    # acceptance bar (full run): >= 2 kernels at >= 1.15x found with
    # <= 5% of the space measured.  Smoke spaces are too small for the
    # fraction bound, so smoke only enforces the cache contract above.
    if not args.smoke:
        assert results["n_speedup_1p15_within_5pct"] >= 2, results

    out_path.write_text(json.dumps(results, indent=1) + "\n")
    print(f"wrote {out_path} (store: {store_path})")


if __name__ == "__main__":
    main()
