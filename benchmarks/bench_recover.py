"""Benchmark: crash durability and recovery (runtime.checkpoint).

Sections, written to BENCH_recover.json:

  1. ``serve_recovery`` — the WAL-backed serving drill: a scripted
     ``crash`` fault kills the engine mid-run, the same call plus
     ``resume=True`` recovers from the WAL + snapshot and finishes.
     Asserts the recovery acceptance bar: **100% of admitted requests
     accounted** across both runs (every admitted rid reaches exactly
     one valid ``retire`` record — none lost, none double-retired), and
     reports the recovery latency (wall time of WAL read + replay).
  2. ``torn_write`` — the partial-``write(2)`` failure mode: a ``torn``
     fault leaves a half-record tail; asserts the reader detects it,
     the reopen truncates it, and the resumed run still closes the
     accounting with a clean (CRC-valid, dense-LSN) WAL.
  3. ``resumed_tune`` — the resumable-tuning bar: a ``TuningSession``
     crashed mid-search and resumed through a ``MeasurementLedger``
     replays its measured prefix from the ledger and spends **<= 1.1x
     the single-run measurement budget** in total across both runs
     (the paper's ~5% budget claim survives a process fault).

Everything runs the deterministic sim rig (``VirtualClock``), so the
drills are step-exact and the bars hold on any machine; the *real*
``kill -9`` variant of section 1 runs as a subprocess drill in the CI
recover-smoke job (and ``tests/test_recover.py``).

Usage:
    PYTHONPATH=src python benchmarks/bench_recover.py [--smoke] [--out PATH]
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time
from pathlib import Path

os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core.space import ConfigSpace, Param  # noqa: E402
from repro.obs import Observer  # noqa: E402
from repro.runtime import (MeasurementLedger, SimulatedCrash,  # noqa: E402
                           read_wal)
from repro.runtime.simulate import FaultPlan  # noqa: E402
from repro.serve import BatcherConfig, make_sim_engine  # noqa: E402
from repro.tune import TuningSession  # noqa: E402

ROOT = Path(__file__).resolve().parents[1]

# sim rig constants (see make_sim_engine): 2 groups x 4 devices, skew 3
PER_ROW_S = 4e-4
CAPACITY_ROWS_PER_S = (4 + 4 / 3) / PER_ROW_S
MEAN_ROWS_PER_REQ = 2.1


def _wal_accounting(path) -> dict:
    """Admit/retire accounting of a WAL file (the drill's ground truth)."""
    records, torn = read_wal(path)
    admits: set[int] = set()
    retires: dict[int, int] = {}
    double: list[int] = []
    for rec in records:
        if rec["kind"] == "admit":
            admits.add(rec["rid"])
        elif rec["kind"] == "retire":
            if rec["rid"] in retires:
                double.append(rec["rid"])
            retires[rec["rid"]] = rec["lsn"]
    return {"records": len(records), "torn": torn,
            "admitted": len(admits), "retired": len(retires),
            "lost": sorted(admits - set(retires)),
            "double_retired": double}


def bench_serve_recovery(n_requests: int = 120, crash_at: int = 6) -> dict:
    """Crash mid-run, resume, account for every admitted request."""
    rate = 0.6 * CAPACITY_ROWS_PER_S / MEAN_ROWS_PER_REQ
    plan = FaultPlan().crash(at=crash_at)
    cfg = BatcherConfig(max_batch_rows=16, coalesce_window_s=0.0)
    d = Path(tempfile.mkdtemp(prefix="bench_recover_"))
    wal, snap = d / "wal.jsonl", d / "snap.json"

    def rig(resume, observer=None):
        return make_sim_engine(
            n_requests=n_requests, rate_rps=rate, seed=7,
            per_row_s=PER_ROW_S, fault_plan=plan, guard=True,
            batcher_config=cfg, observer=observer,
            wal=str(wal), snapshot=str(snap), resume=resume)

    eng = rig(resume=False)
    crashed = False
    try:
        eng.run()
    except SimulatedCrash:
        crashed = True
    pre = _wal_accounting(wal)

    obs = Observer()
    t0 = time.perf_counter()
    eng2 = rig(resume=True, observer=obs)
    recovery_s = time.perf_counter() - t0      # WAL read + replay, pre-serve
    s = eng2.run()
    post = _wal_accounting(wal)
    recovered = obs.journal.by_kind("wal_recovered")[0]

    out = {
        "crash_at_step": crash_at,
        "crashed": crashed,
        "wal_records_at_crash": pre["records"],
        "admitted_at_crash": pre["admitted"],
        "retired_at_crash": pre["retired"],
        "in_flight_at_crash": pre["admitted"] - pre["retired"],
        "replayed": s["replayed"],
        "requeued_on_replay": recovered["requeued"],
        "shed_on_replay": recovered["shed_on_replay"],
        "recovery_latency_s": round(recovery_s, 6),
        "resumed_completed": s["completed"],
        "resumed_shed": s["shed"],
        "admitted_total": post["admitted"],
        "retired_total": post["retired"],
        "lost": post["lost"],
        "double_retired": post["double_retired"],
        "accounted_fraction": post["retired"] / max(post["admitted"], 1),
    }
    assert crashed, out                              # the fault actually fired
    assert out["in_flight_at_crash"] > 0, out        # the drill had stakes
    assert out["replayed"] == out["in_flight_at_crash"], out
    # the recovery acceptance bar: every admitted request reaches exactly
    # one terminal retire record across both runs
    assert out["accounted_fraction"] == 1.0, out
    assert out["lost"] == [] and out["double_retired"] == [], out
    return out


def bench_torn_write(n_requests: int = 100, torn_at: int = 5) -> dict:
    """A torn final write is detected, truncated, and recovered over."""
    rate = 0.6 * CAPACITY_ROWS_PER_S / MEAN_ROWS_PER_REQ
    plan = FaultPlan().torn(at=torn_at)
    cfg = BatcherConfig(max_batch_rows=16, coalesce_window_s=0.0)
    d = Path(tempfile.mkdtemp(prefix="bench_recover_"))
    wal = d / "wal.jsonl"

    eng = make_sim_engine(n_requests=n_requests, rate_rps=rate, seed=9,
                          per_row_s=PER_ROW_S, fault_plan=plan,
                          batcher_config=cfg, wal=str(wal))
    try:
        eng.run()
        crashed = False
    except SimulatedCrash:
        crashed = True
    _, torn = read_wal(wal)
    eng2 = make_sim_engine(n_requests=n_requests, rate_rps=rate, seed=9,
                           per_row_s=PER_ROW_S, fault_plan=plan,
                           batcher_config=cfg, wal=str(wal), resume=True)
    eng2.run()
    post = _wal_accounting(wal)
    out = {
        "crashed": crashed,
        "torn_detected": torn is not None,
        "torn_reason": None if torn is None else torn["reason"],
        "clean_after_resume": post["torn"] is None,
        "admitted_total": post["admitted"],
        "retired_total": post["retired"],
        "lost": post["lost"],
        "double_retired": post["double_retired"],
    }
    assert crashed and out["torn_detected"], out
    assert out["clean_after_resume"], out
    assert out["lost"] == [] and out["double_retired"] == [], out
    return out


def bench_resumed_tune(iterations: int = 30, crash_after: int = 8) -> dict:
    """Crash a tuning run mid-search; the ledger-resumed run replays the
    measured prefix and the two runs together spend <= 1.1x the
    single-run budget."""
    space = ConfigSpace([
        Param("chunk", (8, 16, 32, 64, 128)),
        Param("fraction", tuple(range(10, 100, 10))),
        Param("unroll", (1, 2, 4)),
    ])

    def raw_evaluate(cfg):
        # deterministic synthetic landscape (sim stand-in for a real
        # measurement): bowl in fraction, mild preference in chunk/unroll
        f = cfg["fraction"] / 100.0
        t = (abs(f - 0.7) + 0.02 * abs(cfg["chunk"] - 32) / 32.0
             + 0.01 * cfg["unroll"])
        return {"time": t}

    d = Path(tempfile.mkdtemp(prefix="bench_recover_"))
    ledger_path = d / "measurements.jsonl"

    # the single-run reference budget: same space/strategy/seed, no crash
    ref_ledger = MeasurementLedger(d / "reference.jsonl")
    ref = TuningSession(space, evaluator=raw_evaluate, ledger=ref_ledger)
    ref_result = ref.run("sam", iterations=iterations, seed=13)
    budget_single = ref_ledger.total_real
    ref_ledger.close()

    # run 1: the evaluator dies after crash_after real measurements
    calls = {"n": 0}

    def crashing_evaluate(cfg):
        if calls["n"] >= crash_after:
            raise SimulatedCrash(
                f"injected crash after {crash_after} measurements")
        calls["n"] += 1
        return raw_evaluate(cfg)

    ledger1 = MeasurementLedger(ledger_path)
    crashed = False
    try:
        TuningSession(space, evaluator=crashing_evaluate,
                      ledger=ledger1).run("sam", iterations=iterations,
                                          seed=13)
    except SimulatedCrash:
        crashed = True
    ledger1.close()

    # run 2: fresh process state, same ledger file — the deterministic
    # seeded search re-walks the same trajectory, hitting the ledger for
    # the pre-crash prefix
    ledger2 = MeasurementLedger(ledger_path)
    result = TuningSession(space, evaluator=raw_evaluate,
                           ledger=ledger2).run("sam",
                                               iterations=iterations,
                                               seed=13)
    out = {
        "crashed": crashed,
        "space_size": space.size(),
        "budget_single_run": budget_single,
        "measured_before_crash": crash_after,
        "replayed_on_resume": ledger2.n_replayed,
        "measured_on_resume": ledger2.n_real,
        "budget_total": ledger2.total_real,
        "budget_ratio": round(ledger2.total_real / max(budget_single, 1), 4),
        "best_config": dict(result.best_config),
        "best_matches_reference":
            result.best_config == ref_result.best_config,
    }
    ledger2.close()
    assert crashed, out
    assert out["replayed_on_resume"] >= crash_after, out
    # the resumable-tuning acceptance bar: a crash costs <= 10% extra
    # real measurements over the single-run budget
    assert out["budget_ratio"] <= 1.1, out
    assert out["best_matches_reference"], out
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (fewer requests per section)")
    ap.add_argument("--out", default=str(ROOT / "BENCH_recover.json"))
    ap.add_argument("--date", default=None,
                    help="wall date stamped into the meta block (CI passes "
                         "it; defaults to the BENCH_DATE env var, else null)")
    args = ap.parse_args()

    t0 = time.perf_counter()
    results = {
        "serve_recovery": bench_serve_recovery(
            n_requests=80 if args.smoke else 120),
        "torn_write": bench_torn_write(
            n_requests=60 if args.smoke else 100),
        "resumed_tune": bench_resumed_tune(
            iterations=20 if args.smoke else 30,
            crash_after=6 if args.smoke else 8),
    }
    results["smoke"] = bool(args.smoke)
    results["wall_s"] = round(time.perf_counter() - t0, 3)
    from repro.obs.provenance import build_meta
    results["meta"] = build_meta(args.date)

    out = Path(args.out)
    out.write_text(json.dumps(results, indent=1) + "\n")
    sr = results["serve_recovery"]
    print(f"serve_recovery: crash@{sr['crash_at_step']}, "
          f"{sr['in_flight_at_crash']} in flight, "
          f"{sr['replayed']} replayed, "
          f"{sr['retired_total']}/{sr['admitted_total']} accounted, "
          f"recovery {sr['recovery_latency_s'] * 1e3:.1f}ms")
    tw = results["torn_write"]
    print(f"torn_write: detected={tw['torn_detected']} "
          f"({tw['torn_reason']}), clean after resume: "
          f"{tw['clean_after_resume']}")
    rt = results["resumed_tune"]
    print(f"resumed_tune: {rt['replayed_on_resume']} replayed + "
          f"{rt['measured_on_resume']} new = {rt['budget_total']} total "
          f"vs {rt['budget_single_run']} single-run "
          f"({rt['budget_ratio']}x), best matches reference: "
          f"{rt['best_matches_reference']}")
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
