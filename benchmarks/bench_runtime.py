"""Benchmark: static split vs the online chunked scheduler.

Sections, written to BENCH_runtime.json:

  1. ``sim_convergence`` — a simulated 2-group setup with a 3:1 per-row
     speed skew (serial device queues on a ``VirtualClock``, the timing
     model the rebalancer sees on real hardware).  Measures the oracle
     static split (0.75), the naive static 50/50 split, and the online
     scheduler starting blind at 50/50 — recording the step it converges
     (first step whose time is within 10% of oracle and stays there) and
     the steady-state ratio.  Asserts convergence within 20 steps and a
     steady state within 10% of the oracle (the repo's acceptance bar).
  2. ``real_dispatch`` — 8 forced host devices split into two groups of
     4 running a real jitted reduction: one-shot static dispatch
     (``HeterogeneousRunner``) vs the chunked double-buffered scheduler
     (``ChunkedScheduler``), so the chunking overhead on equal-speed
     groups is visible in the trajectory.
  3. ``degraded`` (with ``--degraded``, and in full runs) — resilience
     bars from ``docs/resilience.md``: kill one of two groups mid-stream
     and assert throughput recovers to within 1.15x of the survivor-only
     static oracle within 10 steps; script a controller regression under
     a ``ServeGuard`` and assert the kill switch pins the stored
     known-good split to within 1.10x of its step time within
     ``patience`` steps of the regression onset.

Usage:
    PYTHONPATH=src python benchmarks/bench_runtime.py [--smoke]
        [--degraded] [--out PATH]
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
from pathlib import Path

# 8 forced host devices for the real-dispatch section; must be set before
# jax (imported transitively by repro) initializes
_FLAG = "--xla_force_host_platform_device_count=8"
if _FLAG not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = f"{_FLAG} " + os.environ.get("XLA_FLAGS", "")
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np  # noqa: E402

from repro.core.hetero import DeviceGroup, HeterogeneousRunner  # noqa: E402
from repro.runtime import (ChunkedScheduler, EwmaController,  # noqa: E402
                           KillSwitch, ServeGuard)
from repro.runtime.simulate import (FaultInjector, FaultPlan,  # noqa: E402
                                    VirtualClock, make_serial_sim_builder,
                                    sim_skew_groups)

ROOT = Path(__file__).resolve().parents[1]


# -- section 1: simulated convergence -------------------------------------------

def bench_sim_convergence(*, skew: int = 3, steps: int = 20,
                          per_row_s: float = 0.0004,
                          batch_rows: int = 128) -> dict:
    batch = {"x": np.zeros((batch_rows, 4), np.float32)}

    def run(shares, n, rebalance):
        clock = VirtualClock()       # deterministic, CI-load independent
        sched = ChunkedScheduler(
            make_serial_sim_builder(per_row_s, clock=clock),
            sim_skew_groups(skew),
            controller=EwmaController(2, shares=np.asarray(shares),
                                      min_share=0.02), clock=clock)
        return sched, [sched.step(batch, rebalance=rebalance)
                       for _ in range(n)]

    oracle_share = skew / (skew + 1.0)
    _, oracle = run([oracle_share, 1 - oracle_share], 6, rebalance=False)
    t_oracle = float(np.median([r["t_step"] for r in oracle]))
    _, naive = run([0.5, 0.5], 6, rebalance=False)
    t_naive = float(np.median([r["t_step"] for r in naive]))

    sched, online = run([0.5, 0.5], steps, rebalance=True)
    t_steps = [r["t_step"] for r in online]
    t_steady = float(np.median(t_steps[-5:]))

    converged_at = None
    for i, t in enumerate(t_steps):
        if t <= 1.10 * t_oracle and all(u <= 1.10 * t_oracle
                                        for u in t_steps[i:]):
            converged_at = i + 1
            break

    out = {
        "skew": skew,
        "steps": steps,
        "batch_rows": batch_rows,
        "t_oracle_static_s": round(t_oracle, 6),
        "t_naive_static_s": round(t_naive, 6),
        "t_online_steady_s": round(t_steady, 6),
        "online_vs_oracle": round(t_steady / t_oracle, 4),
        "online_vs_naive_speedup": round(t_naive / t_steady, 3),
        "converged_at_step": converged_at,
        "shares_final": [round(float(s), 4) for s in sched.shares],
        "t_step_trajectory_s": [round(t, 6) for t in t_steps],
    }
    # the repo's acceptance bar — fail loudly (CI smoke runs this)
    assert converged_at is not None and converged_at <= 20, out
    assert t_steady <= 1.10 * t_oracle, out
    return out


# -- section 1b: offline split tuning through the unified facade ----------------

def bench_session_tuned_split(*, skew: int = 3, iterations: int = 14,
                              per_row_s: float = 0.0004,
                              batch_rows: int = 128) -> dict:
    """Tune the 2-group split offline with a ``repro.tune`` session (the
    paper's SAM over the fraction space, measured through the chunked
    scheduler) and compare the tuned static split against the oracle."""
    from repro.core.space import ConfigSpace, Param
    from repro.tune import TuningSession

    batch = {"x": np.zeros((batch_rows, 4), np.float32)}
    controller = EwmaController(2, min_share=0.02)
    clock = VirtualClock()
    sched = ChunkedScheduler(make_serial_sim_builder(per_row_s, clock=clock),
                             sim_skew_groups(skew), controller=controller,
                             clock=clock)

    def measure(cfg):
        f = cfg["fraction"] / 100.0
        controller.shares = np.asarray([f, 1.0 - f])
        rec = sched.step(batch, rebalance=False)
        return {"time": rec["t_step"], "t_host": rec["t_group"][0],
                "t_device": rec["t_group"][1]}

    space = ConfigSpace([Param("fraction", tuple(range(5, 100, 5)))])
    session = TuningSession(space, evaluator=measure)
    result = session.run("sam", iterations=iterations, seed=0)

    oracle = skew / (skew + 1.0)
    tuned = result.best_config["fraction"] / 100.0
    out = {
        "skew": skew,
        "iterations": iterations,
        "oracle_fraction": round(oracle, 4),
        "tuned_fraction": round(tuned, 4),
        "n_measurements": result.n_experiments,
        "t_tuned_static_s": round(result.best_energy_measured, 6),
        "tuned_within": round(abs(tuned - oracle), 4),
    }
    # the tuned static split must land within one grid step of the oracle
    assert abs(tuned - oracle) <= 0.101, out
    return out


# -- section 2: real dispatch on 8 forced host devices --------------------------

def bench_real_dispatch(*, steps: int = 20, rows: int = 256,
                        cols: int = 4096) -> dict:
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    devs = jax.devices()
    groups = [DeviceGroup("a", devs[:4]), DeviceGroup("b", devs[4:])]

    def builder(group):
        mesh = group.mesh()
        sh = NamedSharding(mesh, P("data"))
        f = jax.jit(lambda v: jnp_work(v), in_shardings=sh)

        def fn(chunk):
            return f(jax.device_put(chunk["x"], sh))
        return fn

    import jax.numpy as jnp

    def jnp_work(v):
        # a few flops per row so the dispatch overhead does not dominate
        return jnp.tanh(v @ v.T).sum(axis=1)

    rng = np.random.default_rng(0)
    batch = {"x": rng.standard_normal((rows, cols)).astype(np.float32)}

    static = HeterogeneousRunner(builder, *groups, fraction=0.5)
    sched = ChunkedScheduler(builder, groups)
    for _ in range(2):                                   # warm both paths
        static.step(batch, rebalance=False)
        sched.step(batch, rebalance=False)
    t_static = [static.step(batch, rebalance=False)["t_step"]
                for _ in range(steps)]
    recs = [sched.step(batch) for _ in range(steps)]
    t_online = [r["t_step"] for r in recs]

    out = {
        "devices": len(devs),
        "rows": rows,
        "cols": cols,
        "steps": steps,
        "t_static_split_s": round(float(np.median(t_static)), 6),
        "t_online_sched_s": round(float(np.median(t_online)), 6),
        # plan adoptions recompile the new chunk shapes (rare: the plan
        # cache debounces noise); their count bounds how many steps paid
        # a compile inside the window above
        "plan_changes": sum(1 for r in recs if r["plan_changed"]),
        "shares_final": [round(float(s), 4) for s in sched.shares],
    }
    out["online_vs_static"] = round(out["t_online_sched_s"]
                                    / out["t_static_split_s"], 4)
    return out


# -- section 3: degraded-mode resilience (docs/resilience.md) -------------------

def bench_degraded_kill(*, skew: int = 3, kill_at: int = 6, steps: int = 20,
                        per_row_s: float = 0.0004,
                        batch_rows: int = 128) -> dict:
    """Kill the dominant (fast) group mid-stream and measure recovery.

    The surviving slow group must absorb the whole batch: the bar is
    step time within **1.15x of the survivor-only static oracle within
    10 steps** of the kill.  The oracle comes from a fresh single-group
    scheduler over the same timing model, so the ratio is exact (virtual
    clock, no noise)."""
    batch = {"x": np.zeros((batch_rows, 4), np.float32)}

    # survivor-only static oracle: the slow group alone takes everything
    oclock = VirtualClock()
    survivor = sim_skew_groups(skew)[1:]
    osched = ChunkedScheduler(
        make_serial_sim_builder(per_row_s, clock=oclock), survivor,
        controller=EwmaController(1), clock=oclock)
    t_survivor = float(np.median(
        [osched.step(batch, rebalance=False)["t_step"] for _ in range(5)]))

    clock = VirtualClock()
    groups = sim_skew_groups(skew)
    injector = FaultInjector(FaultPlan().kill(0, at=kill_at), groups)
    sched = ChunkedScheduler(
        make_serial_sim_builder(per_row_s, clock=clock, injector=injector),
        groups, controller=EwmaController(2, min_share=0.02), clock=clock)
    injector.attach(sched)

    recs = []
    for _ in range(steps):
        injector.tick()
        recs.append(sched.step(batch))
    t_steps = [r["t_step"] for r in recs]
    assert all(sum(r["rows_completed"]) == batch_rows for r in recs)

    recovered_at = None                  # steps after the kill until the
    for i in range(kill_at, steps):      # survivor-only bar is met
        if t_steps[i] <= 1.15 * t_survivor:
            recovered_at = i - kill_at
            break

    out = {
        "skew": skew,
        "kill_at_step": kill_at,
        "t_healthy_s": round(float(np.median(t_steps[:kill_at])), 6),
        "t_survivor_oracle_s": round(t_survivor, 6),
        "t_after_recovery_s": round(float(np.median(t_steps[-5:])), 6),
        "recovered_within_steps": recovered_at,
        "recovered_vs_survivor_oracle": round(
            float(np.median(t_steps[-5:])) / t_survivor, 4),
        "rows_redispatched": int(recs[kill_at]["redispatched_rows"]),
        "rows_lost": int(sum(batch_rows - sum(r["rows_completed"])
                             for r in recs)),
        "t_step_trajectory_s": [round(t, 6) for t in t_steps],
    }
    # acceptance bars (ISSUE 7): recovery <= 1.15x survivor oracle
    # within <= 10 steps; no batch ever loses rows
    assert recovered_at is not None and recovered_at <= 10, out
    assert out["recovered_vs_survivor_oracle"] <= 1.15, out
    assert out["rows_lost"] == 0, out
    return out


def bench_killswitch(*, skew: int = 3, poison_from: int = 10,
                     steps: int = 30, per_row_s: float = 0.0004,
                     batch_rows: int = 128,
                     known_good_fraction: float = 0.75) -> dict:
    """Script a controller regression and measure the kill switch.

    From step ``poison_from`` the controller pushes the shares to a bad
    split every update (a controller-trajectory failure — the scenario
    the guard exists for; a hardware fault would not be fixed by a
    stored split).  The guard's fallback is the stored known-good
    split (``tune_stream_split`` caches it via ``TuningStore``; here the
    tuned fraction feeds in directly).  Bars: the switch trips within
    ``patience`` = 5 steps of the first regressing observation, and the
    first pinned step lands within **1.10x of the known-good split's
    step time**."""

    class PoisonedController(EwmaController):
        def update(self, times, rows=None):
            self.updates = getattr(self, "updates", 0) + 1
            if self.updates >= poison_from:
                self.shares = np.asarray([0.15, 0.85])
                return self.shares
            return super().update(times, rows=rows)

    batch = {"x": np.zeros((batch_rows, 4), np.float32)}
    known_good = np.asarray([known_good_fraction, 1 - known_good_fraction])

    # the known-good split's own step time (the restore target)
    oclock = VirtualClock()
    osched = ChunkedScheduler(
        make_serial_sim_builder(per_row_s, clock=oclock),
        sim_skew_groups(skew),
        controller=EwmaController(2, shares=known_good.copy(),
                                  min_share=0.02), clock=oclock)
    t_known_good = float(np.median(
        [osched.step(batch, rebalance=False)["t_step"] for _ in range(5)]))

    clock = VirtualClock()
    sched = ChunkedScheduler(
        make_serial_sim_builder(per_row_s, clock=clock),
        sim_skew_groups(skew),
        controller=PoisonedController(2, min_share=0.02), clock=clock)
    switch = KillSwitch(threshold=1.5, patience=5, cooldown=3)
    guard = ServeGuard(sched, switch=switch, fallback=known_good)

    recs = [guard.step(batch) for _ in range(steps)]
    verdicts = [r["guard"]["verdict"] for r in recs]
    t_steps = [r["t_step"] for r in recs]
    onset = verdicts.index("regressing")
    trip = verdicts.index("trip")

    out = {
        "skew": skew,
        "patience": switch.patience,
        "threshold": switch.threshold,
        "known_good_shares": [float(s) for s in known_good],
        "t_known_good_s": round(t_known_good, 6),
        "regression_onset_step": onset,
        "tripped_at_step": trip,
        "trip_latency_steps": trip - onset + 1,
        "t_first_pinned_s": round(t_steps[trip + 1], 6),
        "pinned_vs_known_good": round(t_steps[trip + 1] / t_known_good, 4),
        "n_trips": switch.n_trips,
        "rearmed": "rearm" in verdicts,
        "verdicts": verdicts,
    }
    # acceptance bars (ISSUE 7): trip within K=5 steps of the scripted
    # regression, fallback restores <= 1.10x of the stored known-good
    assert out["trip_latency_steps"] <= switch.patience, out
    assert out["pinned_vs_known_good"] <= 1.10, out
    assert out["rearmed"], out
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (fewer steps, smaller arrays)")
    ap.add_argument("--degraded", action="store_true",
                    help="run the degraded/kill-switch resilience "
                    "sections (always on in full runs)")
    ap.add_argument("--out", default=str(ROOT / "BENCH_runtime.json"))
    ap.add_argument("--date", default=None,
                    help="wall date stamped into the meta block (CI passes "
                         "it; defaults to the BENCH_DATE env var, else null)")
    args = ap.parse_args()

    t0 = time.perf_counter()
    results = {"sim_convergence": bench_sim_convergence(),
               "session_tuned_split": bench_session_tuned_split()}
    if args.degraded or not args.smoke:
        # the virtual-clock resilience sections are instant; full runs
        # always include them so BENCH_runtime.json carries the bars
        results["degraded"] = bench_degraded_kill()
        results["killswitch"] = bench_killswitch(
            known_good_fraction=results["session_tuned_split"]
            ["tuned_fraction"])
    if args.smoke:
        results["real_dispatch"] = bench_real_dispatch(steps=3, rows=64,
                                                       cols=512)
    else:
        results["real_dispatch"] = bench_real_dispatch()
        # acceptance bar: the online scheduler's chunked double-buffered
        # dispatch costs at most 30% over a one-shot static split on
        # equal-speed groups (CI smoke steps are too few for a stable
        # median, so full runs only)
        assert results["real_dispatch"]["online_vs_static"] <= 1.3, \
            results["real_dispatch"]
    results["smoke"] = bool(args.smoke)
    results["wall_s"] = round(time.perf_counter() - t0, 3)
    from repro.obs.provenance import build_meta
    results["meta"] = build_meta(args.date)

    out = Path(args.out)
    out.write_text(json.dumps(results, indent=1) + "\n")
    sim = results["sim_convergence"]
    print(f"sim: online/oracle {sim['online_vs_oracle']}x, converged at "
          f"step {sim['converged_at_step']}, "
          f"{sim['online_vs_naive_speedup']}x over naive 50/50")
    ts = results["session_tuned_split"]
    print(f"session: SAM-tuned split {ts['tuned_fraction']} vs oracle "
          f"{ts['oracle_fraction']} in {ts['n_measurements']} measurements")
    rd = results["real_dispatch"]
    print(f"real: static {rd['t_static_split_s']}s vs online "
          f"{rd['t_online_sched_s']}s ({rd['online_vs_static']}x, "
          f"{rd['plan_changes']} plan changes) on {rd['devices']} devices")
    if "degraded" in results:
        dg, ks = results["degraded"], results["killswitch"]
        print(f"degraded: kill at step {dg['kill_at_step']}, recovered in "
              f"{dg['recovered_within_steps']} steps to "
              f"{dg['recovered_vs_survivor_oracle']}x of survivor oracle, "
              f"{dg['rows_lost']} rows lost")
        print(f"killswitch: tripped {ks['trip_latency_steps']} steps after "
              f"onset, pinned split at {ks['pinned_vs_known_good']}x of "
              f"known-good{', re-armed' if ks['rearmed'] else ''}")
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
