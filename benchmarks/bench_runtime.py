"""Benchmark: static split vs the online chunked scheduler.

Two sections, written to BENCH_runtime.json:

  1. ``sim_convergence`` — a simulated 2-group setup with a 3:1 per-row
     speed skew (serial device queues, the timing model the rebalancer
     sees on real hardware).  Measures the oracle static split (0.75),
     the naive static 50/50 split, and the online scheduler starting
     blind at 50/50 — recording the step it converges (first step whose
     time is within 10% of oracle and stays there) and the steady-state
     ratio.  Asserts convergence within 20 steps and a steady state
     within 10% of the oracle (the repo's acceptance bar).
  2. ``real_dispatch`` — 8 forced host devices split into two groups of
     4 running a real jitted reduction: one-shot static dispatch
     (``HeterogeneousRunner``) vs the chunked double-buffered scheduler
     (``ChunkedScheduler``), so the chunking overhead on equal-speed
     groups is visible in the trajectory.

Usage:
    PYTHONPATH=src python benchmarks/bench_runtime.py [--smoke] [--out PATH]
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
from pathlib import Path

# 8 forced host devices for the real-dispatch section; must be set before
# jax (imported transitively by repro) initializes
_FLAG = "--xla_force_host_platform_device_count=8"
if _FLAG not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = f"{_FLAG} " + os.environ.get("XLA_FLAGS", "")
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np  # noqa: E402

from repro.core.hetero import DeviceGroup, HeterogeneousRunner  # noqa: E402
from repro.runtime import ChunkedScheduler, EwmaController  # noqa: E402
from repro.runtime.simulate import (make_serial_sim_builder,  # noqa: E402
                                    sim_skew_groups)

ROOT = Path(__file__).resolve().parents[1]


# -- section 1: simulated convergence -------------------------------------------

def bench_sim_convergence(*, skew: int = 3, steps: int = 20,
                          per_row_s: float = 0.0004,
                          batch_rows: int = 128) -> dict:
    batch = {"x": np.zeros((batch_rows, 4), np.float32)}

    def run(shares, n, rebalance):
        sched = ChunkedScheduler(
            make_serial_sim_builder(per_row_s), sim_skew_groups(skew),
            controller=EwmaController(2, shares=np.asarray(shares),
                                      min_share=0.02))
        return sched, [sched.step(batch, rebalance=rebalance)
                       for _ in range(n)]

    oracle_share = skew / (skew + 1.0)
    _, oracle = run([oracle_share, 1 - oracle_share], 6, rebalance=False)
    t_oracle = float(np.median([r["t_step"] for r in oracle]))
    _, naive = run([0.5, 0.5], 6, rebalance=False)
    t_naive = float(np.median([r["t_step"] for r in naive]))

    sched, online = run([0.5, 0.5], steps, rebalance=True)
    t_steps = [r["t_step"] for r in online]
    t_steady = float(np.median(t_steps[-5:]))

    converged_at = None
    for i, t in enumerate(t_steps):
        if t <= 1.10 * t_oracle and all(u <= 1.10 * t_oracle
                                        for u in t_steps[i:]):
            converged_at = i + 1
            break

    out = {
        "skew": skew,
        "steps": steps,
        "batch_rows": batch_rows,
        "t_oracle_static_s": round(t_oracle, 6),
        "t_naive_static_s": round(t_naive, 6),
        "t_online_steady_s": round(t_steady, 6),
        "online_vs_oracle": round(t_steady / t_oracle, 4),
        "online_vs_naive_speedup": round(t_naive / t_steady, 3),
        "converged_at_step": converged_at,
        "shares_final": [round(float(s), 4) for s in sched.shares],
        "t_step_trajectory_s": [round(t, 6) for t in t_steps],
    }
    # the repo's acceptance bar — fail loudly (CI smoke runs this)
    assert converged_at is not None and converged_at <= 20, out
    assert t_steady <= 1.10 * t_oracle, out
    return out


# -- section 1b: offline split tuning through the unified facade ----------------

def bench_session_tuned_split(*, skew: int = 3, iterations: int = 14,
                              per_row_s: float = 0.0004,
                              batch_rows: int = 128) -> dict:
    """Tune the 2-group split offline with a ``repro.tune`` session (the
    paper's SAM over the fraction space, measured through the chunked
    scheduler) and compare the tuned static split against the oracle."""
    from repro.core.space import ConfigSpace, Param
    from repro.tune import TuningSession

    batch = {"x": np.zeros((batch_rows, 4), np.float32)}
    controller = EwmaController(2, min_share=0.02)
    sched = ChunkedScheduler(make_serial_sim_builder(per_row_s),
                             sim_skew_groups(skew), controller=controller)

    def measure(cfg):
        f = cfg["fraction"] / 100.0
        controller.shares = np.asarray([f, 1.0 - f])
        rec = sched.step(batch, rebalance=False)
        return {"time": rec["t_step"], "t_host": rec["t_group"][0],
                "t_device": rec["t_group"][1]}

    space = ConfigSpace([Param("fraction", tuple(range(5, 100, 5)))])
    session = TuningSession(space, evaluator=measure)
    result = session.run("sam", iterations=iterations, seed=0)

    oracle = skew / (skew + 1.0)
    tuned = result.best_config["fraction"] / 100.0
    out = {
        "skew": skew,
        "iterations": iterations,
        "oracle_fraction": round(oracle, 4),
        "tuned_fraction": round(tuned, 4),
        "n_measurements": result.n_experiments,
        "t_tuned_static_s": round(result.best_energy_measured, 6),
        "tuned_within": round(abs(tuned - oracle), 4),
    }
    # the tuned static split must land within one grid step of the oracle
    assert abs(tuned - oracle) <= 0.101, out
    return out


# -- section 2: real dispatch on 8 forced host devices --------------------------

def bench_real_dispatch(*, steps: int = 20, rows: int = 256,
                        cols: int = 4096) -> dict:
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    devs = jax.devices()
    groups = [DeviceGroup("a", devs[:4]), DeviceGroup("b", devs[4:])]

    def builder(group):
        mesh = group.mesh()
        sh = NamedSharding(mesh, P("data"))
        f = jax.jit(lambda v: jnp_work(v), in_shardings=sh)

        def fn(chunk):
            return f(jax.device_put(chunk["x"], sh))
        return fn

    import jax.numpy as jnp

    def jnp_work(v):
        # a few flops per row so the dispatch overhead does not dominate
        return jnp.tanh(v @ v.T).sum(axis=1)

    rng = np.random.default_rng(0)
    batch = {"x": rng.standard_normal((rows, cols)).astype(np.float32)}

    static = HeterogeneousRunner(builder, *groups, fraction=0.5)
    sched = ChunkedScheduler(builder, groups)
    for _ in range(2):                                   # warm both paths
        static.step(batch, rebalance=False)
        sched.step(batch, rebalance=False)
    t_static = [static.step(batch, rebalance=False)["t_step"]
                for _ in range(steps)]
    recs = [sched.step(batch) for _ in range(steps)]
    t_online = [r["t_step"] for r in recs]

    out = {
        "devices": len(devs),
        "rows": rows,
        "cols": cols,
        "steps": steps,
        "t_static_split_s": round(float(np.median(t_static)), 6),
        "t_online_sched_s": round(float(np.median(t_online)), 6),
        # plan adoptions recompile the new chunk shapes (rare: the plan
        # cache debounces noise); their count bounds how many steps paid
        # a compile inside the window above
        "plan_changes": sum(1 for r in recs if r["plan_changed"]),
        "shares_final": [round(float(s), 4) for s in sched.shares],
    }
    out["online_vs_static"] = round(out["t_online_sched_s"]
                                    / out["t_static_split_s"], 4)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (fewer steps, smaller arrays)")
    ap.add_argument("--out", default=str(ROOT / "BENCH_runtime.json"))
    args = ap.parse_args()

    t0 = time.perf_counter()
    results = {"sim_convergence": bench_sim_convergence(),
               "session_tuned_split": bench_session_tuned_split()}
    if args.smoke:
        results["real_dispatch"] = bench_real_dispatch(steps=3, rows=64,
                                                       cols=512)
    else:
        results["real_dispatch"] = bench_real_dispatch()
        # acceptance bar: the online scheduler's chunked double-buffered
        # dispatch costs at most 30% over a one-shot static split on
        # equal-speed groups (CI smoke steps are too few for a stable
        # median, so full runs only)
        assert results["real_dispatch"]["online_vs_static"] <= 1.3, \
            results["real_dispatch"]
    results["smoke"] = bool(args.smoke)
    results["wall_s"] = round(time.perf_counter() - t0, 3)

    out = Path(args.out)
    out.write_text(json.dumps(results, indent=1) + "\n")
    sim = results["sim_convergence"]
    print(f"sim: online/oracle {sim['online_vs_oracle']}x, converged at "
          f"step {sim['converged_at_step']}, "
          f"{sim['online_vs_naive_speedup']}x over naive 50/50")
    ts = results["session_tuned_split"]
    print(f"session: SAM-tuned split {ts['tuned_fraction']} vs oracle "
          f"{ts['oracle_fraction']} in {ts['n_measurements']} measurements")
    rd = results["real_dispatch"]
    print(f"real: static {rd['t_static_split_s']}s vs online "
          f"{rd['t_online_sched_s']}s ({rd['online_vs_static']}x, "
          f"{rd['plan_changes']} plan changes) on {rd['devices']} devices")
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
