"""Benchmark: seed scalar search path vs the batched/vectorized engine.

All searches run through the unified facade (``repro.tune.TuningSession``
— the legacy ``Autotuner`` is a deprecated shim over the same strategy
registry, so the timed engines are identical).  Times four hot paths and
writes the results as JSON (BENCH_search.json):

  1. ``bdtr_fit``  — exact-splitter vs histogram-splitter BDTR fitting on
     the paper's 7200-row Emil training grid (2880 host + 4320 device
     rows), with held-out percent error for both, asserting the histogram
     fit stays within a point of the exact one.
  2. ``eml_sweep`` — full-space EML sweep: per-config Python loop
     (``engine="scalar"``) vs one batched scoring pass
     (``engine="batched"``); both must pick the same best config.
  3. ``saml``      — 1000-iteration SAML: the paper's scalar chain vs the
     jitted multi-chain vectorized engine (``engine="vectorized"``).
     Total wall-clock (including jit compile) and steady-state (second
     call) are reported separately.
  4. ``objective_weighted`` — the energy-aware extension (after Memeti &
     Pllana, arXiv:2106.01441): batched EM under ``Time``, ``Energy`` and
     ``Weighted(Time, Energy)`` objectives on the simulated platform,
     reporting how the optimal split moves with the objective.

Usage:
    PYTHONPATH=src python benchmarks/bench_search.py [--smoke] [--json]
        [--out PATH]

``--smoke`` (alias ``--quick``) shrinks the space/model so the whole
script runs in well under a minute (CI smoke); ``--json`` additionally
prints the result blob to stdout.  The committed BENCH_search.json comes
from a full run.
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np

from repro.core import (BoostedTreesRegressor, DATASETS_GB,
                        EmilPlatformModel, emil_training_grids,
                        fit_emil_surrogates, paper_space, percent_error)
from repro.tune import Energy, Time, TuningSession, Weighted

GB = DATASETS_GB["human"]


def _timed(fn):
    t0 = time.perf_counter()
    out = fn()
    return time.perf_counter() - t0, out


def _session(space, surrogate, n_train, *, batch: bool = False,
             objective=None) -> TuningSession:
    plat = EmilPlatformModel()
    return TuningSession(
        space,
        evaluator=lambda c: plat.metrics(c, GB, None),
        evaluator_batch=(lambda cols: plat.metrics_batch(cols, GB, None))
        if batch else None,
        objective=objective, surrogate=surrogate,
        n_training_experiments=n_train)


def bench_bdtr_fit(n_estimators: int, max_depth: int = 5) -> dict:
    """Exact vs hist boosting on the paper's host+device training grids
    (the exact grids the shipped training path builds)."""
    host, dev = emil_training_grids(
        EmilPlatformModel(), datasets_gb=list(DATASETS_GB.values()), seed=0)
    n_rows = len(host[1]) + len(dev[1])

    out: dict = {"n_rows": n_rows, "n_estimators": n_estimators,
                 "max_depth": max_depth, "pct_err": {}}
    for method in ("exact", "hist"):
        total = 0.0
        errs = {}
        for name, (X, y) in (("host", host), ("device", dev)):
            # timing: fit on the full grid (the 7200 rows combined)
            model = BoostedTreesRegressor(
                n_estimators=n_estimators, max_depth=max_depth,
                tree_method=method)
            dt, _ = _timed(lambda: model.fit(X, y))
            total += dt
            # accuracy: paper-style half train / half held-out eval
            idx = np.random.default_rng(1).permutation(len(y))
            half = len(y) // 2
            tr, ev = idx[:half], idx[half:]
            m_half = BoostedTreesRegressor(
                n_estimators=n_estimators, max_depth=max_depth,
                tree_method=method).fit(X[tr], y[tr])
            errs[name] = float(percent_error(y[ev],
                                             m_half.predict(X[ev])).mean())
        out[f"t_{method}_s"] = round(total, 4)
        out["pct_err"][method] = errs
    out["speedup"] = round(out["t_exact_s"] / out["t_hist_s"], 2)
    out["pct_err_gap"] = round(max(
        abs(out["pct_err"]["hist"][s] - out["pct_err"]["exact"][s])
        for s in ("host", "device")), 4)
    return out


def bench_eml_sweep(space, surrogate, n_train) -> dict:
    session = _session(space, surrogate, n_train)
    t_scalar, rep_s = _timed(lambda: session.run("eml", engine="scalar"))
    t_batched, rep_b = _timed(lambda: session.run("eml", engine="batched"))
    return {
        "space_size": space.size(),
        "t_scalar_s": round(t_scalar, 4),
        "t_batched_s": round(t_batched, 4),
        "speedup": round(t_scalar / t_batched, 1),
        "same_best_config": rep_s.best_config == rep_b.best_config,
        "best_energy_scalar": rep_s.best_energy_search,
        "best_energy_batched": rep_b.best_energy_search,
        "best_config": rep_b.best_config,
    }


def bench_saml(space, surrogate, n_train, iterations: int,
               n_chains: int) -> dict:
    """Equal-work comparison: ``n_chains`` seed-path scalar chains run one
    after another (what the seed engine needs for the same search effort)
    vs one vectorized launch advancing all chains in lockstep."""
    session = _session(space, surrogate, n_train)

    def run_scalar_chains():
        return [session.run("saml", iterations=iterations, seed=1 + k)
                for k in range(n_chains)]

    t_scalar, reps_s = _timed(run_scalar_chains)
    best_s = min(reps_s, key=lambda r: r.best_energy_search)
    t_vec_total, rep_v = _timed(lambda: session.run(
        "saml", engine="vectorized", iterations=iterations, seed=1,
        n_chains=n_chains))
    # second call reuses nothing across calls except warm jit caches —
    # this is the steady-state per-search cost
    t_vec_steady, rep_v2 = _timed(lambda: session.run(
        "saml", engine="vectorized", iterations=iterations, seed=1,
        n_chains=n_chains))
    eml = session.run("eml")
    n_evals_scalar = sum(r.n_predictions for r in reps_s)
    return {
        "iterations": iterations,
        "n_chains": n_chains,
        "t_scalar_s": round(t_scalar, 4),
        "t_scalar_one_chain_s": round(t_scalar / n_chains, 4),
        "t_vectorized_total_s": round(t_vec_total, 4),
        "t_vectorized_steady_s": round(t_vec_steady, 4),
        "speedup_total": round(t_scalar / t_vec_total, 1),
        "speedup_steady": round(t_scalar / t_vec_steady, 1),
        "scalar_evals_per_s": round(n_evals_scalar / t_scalar, 1),
        "vectorized_evals_per_s": round(
            rep_v2.n_predictions / t_vec_steady, 1),
        "best_energy_scalar": best_s.best_energy_search,
        "best_energy_vectorized": rep_v.best_energy_search,
        "best_energy_exhaustive": eml.best_energy_search,
        "best_energy_rel_diff": round(
            abs(rep_v.best_energy_search - best_s.best_energy_search)
            / best_s.best_energy_search, 6),
        "same_best_config": best_s.best_config == rep_v.best_config,
        "vectorized_deterministic": rep_v.best_config == rep_v2.best_config,
        "best_config_scalar": best_s.best_config,
        "best_config_vectorized": rep_v.best_config,
        "vectorized_within_pct_of_exhaustive": round(
            100.0 * (rep_v.best_energy_search - eml.best_energy_search)
            / eml.best_energy_search, 3),
    }


def bench_objective_weighted(space) -> dict:
    """Batched full-space EM under three objectives: the time-optimal,
    energy-optimal and weighted-compromise configs differ (the Phi is the
    power-hungry side), and the weighted run must land between them."""
    out: dict = {"space_size": space.size()}
    ref = {}
    for name, objective in (
            ("time", Time()),
            ("energy", Energy()),
            ("weighted", Weighted(Time(), Energy(),
                                  scales=(1.0, 300.0)))):
        dt, rep = _timed(lambda: _session(space, None, 0, batch=True,
                                          objective=objective)
                         .run("em", engine="batched"))
        ref[name] = rep
        out[name] = {
            "t_search_s": round(dt, 4),
            "best_config": rep.best_config,
            "best_metrics": {k: round(v, 4)
                             for k, v in rep.best_metrics.items()
                             if k in ("time", "energy")},
        }
    t_t = ref["time"].best_metrics
    t_e = ref["energy"].best_metrics
    t_w = ref["weighted"].best_metrics
    # positive-weight scalarization: the weighted optimum sits between the
    # extremes on both axes (it can't beat the time-opt's time or the
    # energy-opt's energy, and can't be worse than the *other* extreme)
    out["weighted_between"] = bool(
        t_t["time"] - 1e-9 <= t_w["time"] <= t_e["time"] + 1e-9
        and t_e["energy"] - 1e-9 <= t_w["energy"] <= t_t["energy"] + 1e-9)
    assert out["weighted_between"], out
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", "--quick", dest="smoke", action="store_true",
                    help="small space / small models (CI smoke)")
    ap.add_argument("--json", action="store_true",
                    help="also print the result blob to stdout")
    ap.add_argument("--out", default=str(Path(__file__).resolve()
                                        .parent.parent / "BENCH_search.json"))
    ap.add_argument("--iterations", type=int, default=1000)
    ap.add_argument("--n-chains", type=int, default=32)
    args = ap.parse_args()
    out_path = Path(args.out)
    if not out_path.parent.is_dir():
        ap.error(f"--out directory does not exist: {out_path.parent}")

    # surrogate shared by the search benchmarks; modest ensemble so the
    # *scalar* sweep finishes in minutes — both engines use the same model
    n_est_search = 10 if args.smoke else 40
    space = paper_space(workload_step=10 if args.smoke else 1)
    plat = EmilPlatformModel()
    t_fit, (surrogate, n_train) = _timed(lambda: fit_emil_surrogates(
        plat, GB, datasets_gb=list(DATASETS_GB.values()),
        n_estimators=n_est_search, seed=0))
    print(f"[bench] surrogate fit ({n_est_search} estimators/side): "
          f"{t_fit:.2f}s")

    results = {
        "quick": bool(args.smoke),
        "space_size": space.size(),
        "bdtr_fit": bench_bdtr_fit(40 if args.smoke else 150),
    }
    b = results["bdtr_fit"]
    print(f"[bench] bdtr_fit: exact {b['t_exact_s']}s vs hist "
          f"{b['t_hist_s']}s -> {b['speedup']}x "
          f"(pct-err gap {b['pct_err_gap']})")

    results["eml_sweep"] = bench_eml_sweep(space, surrogate, n_train)
    e = results["eml_sweep"]
    print(f"[bench] eml_sweep ({e['space_size']} configs): scalar "
          f"{e['t_scalar_s']}s vs batched {e['t_batched_s']}s -> "
          f"{e['speedup']}x (same best: {e['same_best_config']})")

    iters = 200 if args.smoke else args.iterations
    results["saml"] = bench_saml(space, surrogate, n_train, iters,
                                 args.n_chains)
    s = results["saml"]
    print(f"[bench] saml ({iters} iters x {s['n_chains']} chains): scalar "
          f"{s['t_scalar_s']}s vs vectorized {s['t_vectorized_total_s']}s "
          f"total / {s['t_vectorized_steady_s']}s steady -> "
          f"{s['speedup_total']}x / {s['speedup_steady']}x "
          f"({s['vectorized_evals_per_s']:.0f} evals/s)")

    ow_space = paper_space(workload_step=10 if args.smoke else 2)
    results["objective_weighted"] = bench_objective_weighted(ow_space)
    w = results["objective_weighted"]
    print(f"[bench] objectives: time-opt split "
          f"{w['time']['best_config']['host_fraction']} vs energy-opt "
          f"{w['energy']['best_config']['host_fraction']} vs weighted "
          f"{w['weighted']['best_config']['host_fraction']} "
          f"(between: {w['weighted_between']})")

    blob = json.dumps(results, indent=2) + "\n"
    Path(args.out).write_text(blob)
    if args.json:
        print(blob)
    print(f"[bench] wrote {args.out}")


if __name__ == "__main__":
    main()
