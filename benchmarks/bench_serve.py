"""Benchmark: request-level serving under offered load (repro.serve).

Sections, written to BENCH_serve.json:

  1. ``offered_load`` — the serving engine's latency/goodput profile
     across an offered-load sweep (under / at / over the sim rig's
     capacity): per-regime p50/p95/p99 end-to-end latency, the
     queue-delay vs service-time decomposition, shed rate and goodput.
     Asserts the two serving acceptance bars: under capacity the
     admitted p99 end-to-end latency stays within 2x the no-queue
     service time (batching cost bounded), and over capacity the
     admission layer sheds (shed rate > 0) while the *admitted* p99
     stays bounded — goodput over throughput, never an unbounded queue.
  2. ``tuned_batcher`` — the batcher's three knobs tuned through a
     ``TuningSession`` (``sam``, ~10 of 210 configs ≈ 4.8% — the
     paper's ~5% envelope), compared against the default config on the
     same workload; asserts the tuned objective is no worse than
     default and that a repeat tuning call re-serves from the
     ``TuningStore`` with zero new measurements.
  3. ``degraded_drill`` — a mid-run group kill under a ``FaultPlan``
     (with transients forcing the per-request retry path): asserts
     **zero lost requests** (every admitted request terminally
     completes or is shed with a journaled reason) and that two
     identical drills produce bit-identical decision journals.

Everything runs the deterministic sim rig (``VirtualClock``; wall-time
independent), so the recorded latencies are simulated instants and the
bars hold on any machine.

Usage:
    PYTHONPATH=src python benchmarks/bench_serve.py [--smoke] [--out PATH]
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
from pathlib import Path

os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np  # noqa: E402

from repro.obs import Observer  # noqa: E402
from repro.runtime import TuningStore  # noqa: E402
from repro.runtime.simulate import FaultPlan  # noqa: E402
from repro.serve import (BatcherConfig, make_sim_engine,  # noqa: E402
                         tune_batcher)

ROOT = Path(__file__).resolve().parents[1]

# sim rig constants (see make_sim_engine): 4 fast + 4 slow (skew 3)
# devices at PER_ROW_S per fast row -> capacity (4 + 4/3)/PER_ROW_S
# rows/s; the source's default row mix averages ~2.1 rows/request
PER_ROW_S = 4e-4
CAPACITY_ROWS_PER_S = (4 + 4 / 3) / PER_ROW_S
MEAN_ROWS_PER_REQ = 2.1


def bench_offered_load(n_requests: int = 400) -> dict:
    """Latency/goodput across under-/at-/over-capacity offered loads.

    The sweep runs the latency-first batcher (eager dispatch,
    ``coalesce_window_s=0`` — the coalesce trade is what
    ``bench_tuned_batcher`` explores), so queue delay in the records is
    genuine contention, not a configured hold.
    """
    cap_rps = CAPACITY_ROWS_PER_S / MEAN_ROWS_PER_REQ
    eager = BatcherConfig(coalesce_window_s=0.0)
    regimes = {"under": 0.3, "at": 0.9, "over": 3.0}
    out: dict = {"capacity_rows_per_s": round(CAPACITY_ROWS_PER_S, 1),
                 "capacity_rps": round(cap_rps, 1), "regimes": {}}
    for name, load in regimes.items():
        # overload needs enough arrivals to actually fill the
        # backpressure bound (queue_depth_rows) before the source dries
        n_reg = max(n_requests, 300) if name == "over" else n_requests
        eng = make_sim_engine(n_requests=n_reg,
                              rate_rps=load * cap_rps, seed=11,
                              per_row_s=PER_ROW_S, batcher_config=eager)
        s = eng.run()
        out["regimes"][name] = {
            "offered_fraction": load,
            "rate_rps": round(load * cap_rps, 1),
            "completed": s["completed"], "shed": s["shed"],
            "shed_rate": round(s["shed_rate"], 4),
            "shed_reasons": s["shed_reasons"],
            "goodput_rows_per_s": round(s.get("goodput_rows_per_s", 0.0), 1),
            **{k: round(s[k], 6) for k in s
               if k.startswith(("e2e_", "queue_delay_", "service_"))},
        }
    under, over = out["regimes"]["under"], out["regimes"]["over"]
    # the no-queue service floor: p99 of the service component
    # (dispatch -> completion, every waiting term excluded) under light
    # load — what a request pays with an empty queue in front of it
    floor = under["service_p99"]
    out["service_floor_s"] = floor
    out["underloaded_p99_vs_service_floor"] = round(
        under["e2e_p99"] / max(floor, 1e-12), 3)
    # bar 1: under capacity, queueing at most doubles the no-queue
    # service time at the p99, and nothing is shed
    assert under["e2e_p99"] <= 2.0 * floor, out
    assert under["shed_rate"] == 0.0, out
    # bar 2: over capacity the valve sheds rather than queueing without
    # bound — the *admitted* p99 stays under the backpressure bound
    # (queue_depth_rows of backlog at capacity drain rate, x2 slack,
    # plus the service floor), independent of how far over the load is
    queue_bound = (eager.queue_depth_rows / CAPACITY_ROWS_PER_S) * 2 + floor
    out["overload_queue_bound_s"] = round(queue_bound, 6)
    assert over["shed_rate"] > 0.0, out
    assert over["e2e_p99"] <= queue_bound, out
    return out


def bench_tuned_batcher(n_requests: int = 250,
                        iterations: int = 15) -> dict:
    """Tune (max_batch_rows, coalesce_window, queue_depth) through the
    paper's tuning machinery at <= 5% of the space; the sim rig is cheap
    enough to also enumerate the exhaustive oracle, so the section
    reports the paper's central ratio directly (tuned objective vs the
    true optimum at a ~20x measurement discount).  A repeat workload
    re-serves the stored winner with zero new measurements.
    """
    from repro.serve import batcher_space

    cap_rps = CAPACITY_ROWS_PER_S / MEAN_ROWS_PER_REQ
    rate = 1.2 * cap_rps                     # mild overload: knobs matter
    workload = {"n_requests": n_requests, "rate_rps": round(rate, 1),
                "seed": 21}

    def objective(cfg: BatcherConfig) -> dict:
        eng = make_sim_engine(n_requests=n_requests, rate_rps=rate,
                              seed=21, per_row_s=PER_ROW_S,
                              batcher_config=cfg)
        s = eng.run()
        # admitted tail latency, shed-penalized: a config must not win
        # by shedding its way to an empty queue
        obj = s.get("e2e_p95", 10.0) + 0.1 * s["shed_rate"]
        return {"time": obj, "shed_rate": s["shed_rate"],
                "e2e_p95": s.get("e2e_p95")}

    store_path = ROOT / "BENCH_serve_store.json"
    if store_path.exists():
        store_path.unlink()
    store = TuningStore(store_path)
    # the annealing schedule length is sized so distinct measurements
    # stay inside the 5% envelope (sam dedups revisited configs)
    cfg, res = tune_batcher(objective, store=store, workload=workload,
                            iterations=iterations)
    cfg2, res2 = tune_batcher(objective, store=store, workload=workload,
                              iterations=iterations)
    # the exhaustive baseline the paper's method is measured against
    space = batcher_space()
    oracle_obj, oracle_cfg = min(
        ((objective(BatcherConfig.from_config(c))["time"], c)
         for c in space.enumerate()), key=lambda x: x[0])
    default = objective(BatcherConfig())["time"]
    tuned = objective(cfg)["time"]
    out = {
        "space_size": space.size(),
        "n_experiments": res.n_experiments,
        "experiments_fraction": round(res.experiments_fraction, 4),
        "best_config": {"max_batch_rows": cfg.max_batch_rows,
                        "coalesce_window_s": cfg.coalesce_window_s,
                        "queue_depth_rows": cfg.queue_depth_rows},
        "oracle_config": dict(oracle_cfg),
        "objective_tuned": round(tuned, 6),
        "objective_oracle": round(oracle_obj, 6),
        "objective_default": round(default, 6),
        "tuned_vs_oracle": round(tuned / oracle_obj, 4),
        "repeat_from_cache": bool(res2.from_cache),
        "repeat_new_measurements": 0 if res2.from_cache
        else res2.n_experiments,
    }
    assert res.experiments_fraction <= 0.05, out      # the ~5% envelope
    # near-optimality bar: the ~5% search lands within 2x of the
    # exhaustive optimum of a space whose worst configs are ~10x it
    assert tuned <= 2.0 * oracle_obj, out
    assert res2.from_cache and cfg2 == cfg, out       # zero re-measurement
    return out


def bench_degraded_drill(n_requests: int = 250) -> dict:
    """Mid-run kill + transient retry path: zero lost requests and
    run-to-run identical journals."""
    plan = (FaultPlan().transient(0, at=3).transient(1, at=3)
            .kill(0, at=6).recover(0, at=12))
    cap_rps = CAPACITY_ROWS_PER_S / MEAN_ROWS_PER_REQ
    # small eager batches: enough scheduler steps that the scripted
    # fault sequence lands mid-run at both smoke and full sizes
    drill_cfg = BatcherConfig(max_batch_rows=16, coalesce_window_s=0.0)

    def drill():
        obs = Observer()
        eng = make_sim_engine(n_requests=n_requests, rate_rps=0.5 * cap_rps,
                              seed=31, per_row_s=PER_ROW_S, fault_plan=plan,
                              guard=True, observer=obs,
                              batcher_config=drill_cfg)
        s = eng.run()
        return s, [json.dumps(e) for e in obs.journal.events]

    s1, j1 = drill()
    s2, j2 = drill()
    kinds: dict[str, int] = {}
    for line in j1:
        k = json.loads(line)["kind"]
        kinds[k] = kinds.get(k, 0) + 1
    out = {
        "requests": s1["requests"], "completed": s1["completed"],
        "shed": s1["shed"], "shed_reasons": s1["shed_reasons"],
        "retries": s1["retries"],
        "accounted": s1["completed"] + s1["shed"],
        "journal_events": len(j1),
        "journal_kinds": kinds,
        "journals_identical": j1 == j2,
    }
    # zero lost requests: every request is terminal (completed or shed
    # with a reason)
    assert out["accounted"] == n_requests, out
    assert all(r is not None
               for r in s1["shed_reasons"]), out
    # the decision chain is journal-visible and deterministic
    assert kinds.get("group_demoted", 0) >= 1, out
    assert kinds.get("request_retried", 0) >= 1, out
    assert out["journals_identical"], "journals differ between runs"
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (fewer requests per section)")
    ap.add_argument("--out", default=str(ROOT / "BENCH_serve.json"))
    ap.add_argument("--date", default=None,
                    help="wall date stamped into the meta block (CI passes "
                         "it; defaults to the BENCH_DATE env var, else null)")
    args = ap.parse_args()

    n = 150 if args.smoke else 400
    t0 = time.perf_counter()
    results = {
        "offered_load": bench_offered_load(n_requests=n),
        "tuned_batcher": bench_tuned_batcher(
            n_requests=100 if args.smoke else 250,
            iterations=12 if args.smoke else 15),
        "degraded_drill": bench_degraded_drill(
            n_requests=100 if args.smoke else 250),
    }
    results["smoke"] = bool(args.smoke)
    results["wall_s"] = round(time.perf_counter() - t0, 3)
    from repro.obs.provenance import build_meta
    results["meta"] = build_meta(args.date)

    out = Path(args.out)
    out.write_text(json.dumps(results, indent=1) + "\n")
    ol = results["offered_load"]
    print(f"offered_load: under p99 "
          f"{ol['regimes']['under']['e2e_p99'] * 1e3:.2f}ms "
          f"({ol['underloaded_p99_vs_service_floor']}x service floor), "
          f"over shed_rate {ol['regimes']['over']['shed_rate']}")
    tb = results["tuned_batcher"]
    print(f"tuned_batcher: {tb['n_experiments']} of {tb['space_size']} "
          f"configs ({100 * tb['experiments_fraction']:.1f}%), "
          f"tuned/oracle {tb['tuned_vs_oracle']}x, "
          f"repeat cached={tb['repeat_from_cache']}")
    dd = results["degraded_drill"]
    print(f"degraded_drill: {dd['accounted']}/{dd['requests']} accounted "
          f"({dd['completed']} completed, {dd['shed']} shed, "
          f"{dd['retries']} retries), journals identical: "
          f"{dd['journals_identical']}")
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
