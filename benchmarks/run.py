"""Benchmark harness: one function per paper table/figure + beyond-paper.

Prints ``name,us_per_call,derived`` CSV (us_per_call = wall time of the
whole benchmark function) and writes full tables to results/bench/.
``--json`` additionally writes one ``BENCH_<name>.json`` per benchmark
at the repo root (wall time, derived metric, full rows) — the perf
trajectory the stand-alone benches (``bench_search.py``,
``bench_runtime.py``) already follow.

    PYTHONPATH=src python -m benchmarks.run [--only fig2_motivation,...]
                                            [--json] [--date 2026-08-07]

``--json`` artifacts carry a ``meta`` provenance block (git SHA, jax
version, device topology — ``repro.obs.provenance.build_meta``); the
wall date comes only from ``--date`` / the ``BENCH_DATE`` env var (CI
passes it), never the system clock, so re-runs stay byte-reproducible.
"""

from __future__ import annotations

import argparse
import csv
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core import EmilPlatformModel  # noqa: E402

from . import beyond_paper, paper_tables  # noqa: E402

ROOT = Path(__file__).resolve().parents[1]
RESULTS = ROOT / "results" / "bench"


def benches():
    plat = EmilPlatformModel()
    return {
        "fig2_motivation": lambda: paper_tables.fig2_motivation(plat),
        "tables_4_5_prediction_accuracy":
            lambda: paper_tables.tables_4_5_prediction_accuracy(plat),
        "tables_6_7_saml_vs_em":
            lambda: paper_tables.tables_6_7_saml_vs_em(plat),
        "tables_8_9_speedup": lambda: paper_tables.tables_8_9_speedup(plat),
        "table_2_strategy_costs":
            lambda: paper_tables.table_2_strategy_costs(plat),
        "real_dna_autotune": beyond_paper.real_dna_autotune,
        "sharding_tuner": beyond_paper.sharding_tuner_bench,
        "kernel_microbench": beyond_paper.kernel_microbench,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names")
    ap.add_argument("--json", action="store_true",
                    help="also write BENCH_<name>.json at the repo root")
    ap.add_argument("--date", default=None,
                    help="wall date stamped into the meta block (CI passes "
                         "it; defaults to the BENCH_DATE env var, else null)")
    args = ap.parse_args()
    meta = None
    if args.json:
        from repro.obs.provenance import build_meta
        meta = build_meta(args.date)
    selected = set(args.only.split(",")) if args.only else None
    RESULTS.mkdir(parents=True, exist_ok=True)
    print("name,us_per_call,derived")
    for name, fn in benches().items():
        if selected and name not in selected:
            continue
        t0 = time.perf_counter()
        rows, derived = fn()
        us = (time.perf_counter() - t0) * 1e6
        print(f"{name},{us:.0f},{derived}", flush=True)
        out = RESULTS / f"{name}.csv"
        if rows:
            with out.open("w", newline="") as f:
                w = csv.DictWriter(f, fieldnames=list(rows[0]))
                w.writeheader()
                w.writerows(rows)
        if args.json:
            (ROOT / f"BENCH_{name}.json").write_text(json.dumps({
                "name": name,
                "wall_s": round(us / 1e6, 6),
                "derived": derived,
                "meta": meta,
                "rows": rows,
            }, indent=1, default=str) + "\n")


if __name__ == "__main__":
    main()
