"""Reproduction benchmarks: one function per paper table/figure.

Each function returns (rows, derived) where rows are CSV-ready dicts and
``derived`` is the headline number the paper claims.  ``run.py`` times and
prints everything in ``name,us_per_call,derived`` format and writes the
full tables to results/.
"""

from __future__ import annotations

import numpy as np

from repro.core import (DATASETS_GB, EmilPlatformModel,
                        fit_emil_surrogates, paper_space, percent_error)
from repro.tune import TuningSession

CHECKPOINTS = (250, 500, 750, 1000, 1250, 1500, 1750, 2000)


def _normalize_1_10(values):
    v = np.asarray(values, float)
    lo, hi = v.min(), v.max()
    return 1 + 9 * (v - lo) / max(hi - lo, 1e-12)


def fig2_motivation(platform: EmilPlatformModel):
    """Fig. 2: execution time vs split ratio for 3 scenarios (normalized 1-10)."""
    scenarios = [
        ("exp1_190MB_48thr", 0.19, 48),
        ("exp2_3250MB_48thr", 3.25, 48),
        ("exp3_3250MB_4thr", 3.25, 4),
    ]
    rows = []
    best = {}
    for name, gb, threads in scenarios:
        fractions = list(range(0, 101, 10))
        times = [platform.energy({"host_threads": threads,
                                  "device_threads": 240,
                                  "host_affinity": "scatter",
                                  "device_affinity": "balanced",
                                  "host_fraction": f}, gb)
                 for f in fractions]
        norm = _normalize_1_10(times)
        best[name] = fractions[int(np.argmin(times))]
        for f, t, nv in zip(fractions, times, norm):
            rows.append({"scenario": name, "host_fraction": f,
                         "time_s": round(t, 4), "normalized": round(nv, 2)})
    # paper: exp1 -> host-only best; exp2 -> 60-70; exp3 -> device-heavy
    derived = (f"best_splits exp1={best['exp1_190MB_48thr']} "
               f"exp2={best['exp2_3250MB_48thr']} "
               f"exp3={best['exp3_3250MB_4thr']}")
    return rows, derived


def tables_4_5_prediction_accuracy(platform: EmilPlatformModel):
    """Tables IV-V (+Figs 5-8): BDTR accuracy per thread count + histograms."""
    sur, n_exp, ev = fit_emil_surrogates(
        platform, DATASETS_GB["human"],
        datasets_gb=list(DATASETS_GB.values()), return_eval=True, seed=0)
    rows = []
    headline = {}
    for side in ("host", "device"):
        X, y, yp = ev[side]
        threads = X[:, 1]
        for t in sorted(set(threads.tolist())):
            m = threads == t
            rows.append({
                "side": side, "threads": int(t),
                "absolute_s": round(float(np.abs(y[m] - yp[m]).mean()), 4),
                "percent": round(float(percent_error(y[m], yp[m]).mean()), 3),
                "n": int(m.sum()),
            })
        headline[side] = float(percent_error(y, yp).mean())
        # error histogram (Figs 7-8)
        hist, edges = np.histogram(np.abs(y - yp), bins=10)
        for h, e0, e1 in zip(hist, edges[:-1], edges[1:]):
            rows.append({"side": side + "_hist", "threads": -1,
                         "absolute_s": round(float(e0), 4),
                         "percent": round(float(e1), 4), "n": int(h)})
    derived = (f"avg_pct_err host={headline['host']:.2f}% "
               f"device={headline['device']:.2f}% "
               f"(paper: 5.24%/3.13%), n_experiments={n_exp}")
    return rows, derived


def _session_for(platform, dataset_gb, sur, n_train, step=3):
    space = paper_space(workload_step=step)
    rng = np.random.default_rng(0)
    return TuningSession(
        space,
        evaluator=lambda c: platform.energy(c, dataset_gb, rng),
        truth=lambda c: platform.energy(c, dataset_gb, None),
        surrogate=sur, n_training_experiments=n_train)


def tables_6_7_saml_vs_em(platform: EmilPlatformModel):
    """Tables VI-VII + Fig 9: SAML-vs-EM percent/absolute difference."""
    rows = []
    pct_at_1000 = []
    frac = None
    for name, gb in DATASETS_GB.items():
        sur, n_train = fit_emil_surrogates(
            platform, gb, datasets_gb=list(DATASETS_GB.values()), seed=0)
        tuner = _session_for(platform, gb, sur, n_train)
        em = tuner.run("em")
        saml = tuner.run("saml", iterations=2000, seed=7,
                               checkpoints=CHECKPOINTS)
        for it in CHECKPOINTS:
            e, _ = saml.checkpoints[it]
            pct = 100 * (e - em.best_energy_measured) / em.best_energy_measured
            rows.append({"dna": name, "iterations": it,
                         "percent_diff": round(pct, 3),
                         "absolute_diff_s": round(
                             e - em.best_energy_measured, 4)})
            if it == 1000:
                pct_at_1000.append(pct)
        frac = 1000 / em.space_size
    derived = (f"avg_pct_diff@1000={np.mean(pct_at_1000):.2f}% "
               f"(paper: 10.13%), search_budget={frac*100:.1f}% of EM "
               f"(paper: ~5%)")
    return rows, derived


def tables_8_9_speedup(platform: EmilPlatformModel):
    """Tables VIII-IX: tuned-config speedup vs host-only / device-only."""
    rows = []
    sp_host_1000, sp_dev_1000 = [], []
    for name, gb in DATASETS_GB.items():
        sur, n_train = fit_emil_surrogates(
            platform, gb, datasets_gb=list(DATASETS_GB.values()), seed=0)
        tuner = _session_for(platform, gb, sur, n_train)
        em = tuner.run("em")
        saml = tuner.run("saml", iterations=2000, seed=11,
                               checkpoints=CHECKPOINTS)
        t_host = platform.host_only_time(gb)
        t_dev = platform.device_only_time(gb)
        for it in CHECKPOINTS:
            e, _ = saml.checkpoints[it]
            rows.append({"dna": name, "config": str(it),
                         "speedup_vs_host": round(t_host / e, 2),
                         "speedup_vs_device": round(t_dev / e, 2)})
            if it == 1000:
                sp_host_1000.append(t_host / e)
                sp_dev_1000.append(t_dev / e)
        rows.append({"dna": name, "config": "EM",
                     "speedup_vs_host": round(
                         t_host / em.best_energy_measured, 2),
                     "speedup_vs_device": round(
                         t_dev / em.best_energy_measured, 2)})
    derived = (f"max_speedup@1000 vs_host={max(sp_host_1000):.2f}x "
               f"(paper 1.74x) vs_device={max(sp_dev_1000):.2f}x "
               f"(paper 2.18x)")
    return rows, derived


def table_2_strategy_costs(platform: EmilPlatformModel):
    """Table II: effort/accuracy accounting for EM / EML / SAM / SAML."""
    gb = DATASETS_GB["cat"]
    sur, n_train = fit_emil_surrogates(
        platform, gb, datasets_gb=list(DATASETS_GB.values()), seed=0)
    tuner = _session_for(platform, gb, sur, n_train, step=5)
    em = tuner.run("em")
    eml = tuner.run("eml")
    sam = tuner.run("sam", iterations=1000, seed=0)
    saml = tuner.run("saml", iterations=1000, seed=0)
    rows = []
    for rep in (em, eml, sam, saml):
        rows.append({
            "method": rep.strategy,
            "search_experiments": rep.n_experiments,
            "predictions": rep.n_predictions,
            "training_experiments": rep.n_training_experiments,
            "measured_best_s": round(rep.best_energy_measured, 4),
            "pct_vs_EM": round(100 * (rep.best_energy_measured
                                      - em.best_energy_measured)
                               / em.best_energy_measured, 2),
        })
    derived = (f"SAM/EM effort={sam.n_experiments}/{em.n_experiments}"
               f"={100*sam.n_experiments/em.n_experiments:.1f}%")
    return rows, derived
