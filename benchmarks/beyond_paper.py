"""Beyond-paper benchmarks: real-measured autotuning + kernel micro-bench.

1. ``real_dna_autotune`` — the paper's method with REAL wall-clock
   measurements: tune the JAX DNA matcher's execution parameters (chunk
   size, dtype paths) on this container's CPU; SAM finds a near-best
   configuration with a fraction of enumeration's measurements.
2. ``sharding_tuner_bench`` — SAML over the 256-chip distribution space
   with the analytic roofline evaluator (the compiled evaluator is used
   in the §Perf hillclimb; here the fast oracle keeps the benchmark
   quick) — reports tuned vs default step-time bound.
3. ``kernel_microbench`` — wall-clock of the DNA kernel pipeline vs the
   sequential reference (the one kernel whose compiled XLA path is
   meaningful on CPU).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import ConfigSpace, Param
from repro.tune import TuningSession
from repro.core.sharding_tuner import ShardingTuner
from repro.kernels.dna_automaton import ops as dna_ops
from repro.kernels.dna_automaton.ref import fa_match_ref
from repro.launch import shapes


def _timed(fn, *args, reps=3):
    fn(*args)                                   # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps


def real_dna_autotune(n_bytes: int = 2_000_000, budget: int = 18):
    """SAM with real wall-clock on the chunked DNA matcher's parameters."""
    rng = np.random.default_rng(0)
    text = jnp.asarray(rng.integers(0, 4, n_bytes).astype(np.uint8))
    table, accept = dna_ops.build_motif_dfa("ACGTACGT")
    table_j = jnp.asarray(table)
    accept_j = jnp.asarray(accept)

    space = ConfigSpace([
        Param("chunk", (512, 1024, 2048, 4096, 8192, 16384, 32768)),
        Param("two_pass", (True, False), ordinal=False),
    ])

    def run_cfg(cfg):
        if cfg["two_pass"]:
            fn = jax.jit(lambda t: dna_ops.fa_match(
                t, table_j, accept_j, chunk=cfg["chunk"], interpret=True))
        else:
            fn = jax.jit(lambda t: fa_match_ref(t, table_j, accept_j)[0])
        return _timed(fn, text, reps=1)

    em = TuningSession(space, evaluator=run_cfg).run("em")
    sam = TuningSession(space, evaluator=run_cfg).run(
        "sam", iterations=budget, seed=0)
    rows = [{"method": "EM", "best_s": round(em.best_energy_measured, 4),
             "config": str(em.best_config),
             "experiments": em.n_experiments},
            {"method": "SAM", "best_s": round(sam.best_energy_measured, 4),
             "config": str(sam.best_config),
             "experiments": sam.n_experiments}]
    gap = 100 * (sam.best_energy_measured - em.best_energy_measured) \
        / em.best_energy_measured
    derived = (f"SAM within {gap:.1f}% of EM using "
               f"{sam.n_experiments}/{em.n_experiments} real measurements")
    return rows, derived


def sharding_tuner_bench(arch: str = "qwen2-moe-a2.7b",
                         cell_name: str = "train_4k"):
    cell = shapes.SHAPE_CELLS[cell_name]
    tuner = ShardingTuner(configs.get(arch), cell, mode="analytic")
    base = tuner.baseline()
    res = tuner.tune_saml(train_samples=48, iterations=1500, seed=0)
    rows = [{
        "config": "default-policy",
        "bound_s": round(base["step_time_bound_s"], 4),
        "dominant": base["dominant"],
    }, {
        "config": str(res.best_config),
        "bound_s": round(res.best_energy_measured, 4),
        "dominant": "-",
    }]
    gain = base["step_time_bound_s"] / max(res.best_energy_measured, 1e-12)
    derived = (f"{arch} x {cell_name}: tuned/default = "
               f"{gain:.2f}x bound improvement, "
               f"{tuner.n_measurements} analytic measurements")
    return rows, derived


def kernel_microbench(n_bytes: int = 4_194_304, chunk: int = 4096):
    """Chunk-parallel DFA matching (the PaREM decomposition) vs the
    sequential scan, both XLA-compiled on CPU.  (The Pallas kernels are
    TPU-target; interpret mode is a correctness path, not a perf path.)"""
    from repro.kernels.dna_automaton.ref import chunk_state_map_ref
    from repro.kernels.dna_automaton.ops import compose_maps
    rng = np.random.default_rng(1)
    text = jnp.asarray(rng.integers(0, 4, n_bytes).astype(np.uint8))
    table, accept = dna_ops.build_motif_dfa("ACGTAC")
    table_j = jnp.asarray(table)
    accept_j = jnp.asarray(accept)

    def parallel(t):
        chunks = t.reshape(-1, chunk)
        maps = jax.vmap(lambda c: chunk_state_map_ref(c, table_j))(chunks)
        prefix = compose_maps(maps)
        starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                                  prefix[:-1, 0].astype(jnp.int32)])

        def count(c, s0):
            def stepf(state, sym):
                state = table_j[state, sym]
                return state, accept_j[state]
            _, hits = jax.lax.scan(stepf, s0, c.astype(jnp.int32))
            return hits.sum(dtype=jnp.int32)

        return jax.vmap(count)(chunks, starts).sum()

    t_par = _timed(jax.jit(parallel), text)
    t_seq = _timed(jax.jit(lambda t: fa_match_ref(t, table_j, accept_j)[0]),
                   text)
    n_par = int(jax.jit(parallel)(text))
    n_seq = int(jax.jit(lambda t: fa_match_ref(t, table_j, accept_j)[0])(text))
    assert n_par == n_seq
    rows = [{"impl": "chunk-parallel (PaREM decomposition)",
             "s": round(t_par, 4)},
            {"impl": "sequential scan", "s": round(t_seq, 4)}]
    return rows, (f"chunk-parallel speedup = {t_seq/t_par:.2f}x "
                  f"on {n_bytes/1e6:.0f}MB (1 CPU core)")
