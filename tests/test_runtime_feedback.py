"""repro.runtime.feedback + the incremental BDTR machinery it rides on:
binning reuse (bin_rows/append_rows), warm refits (fit_more), the online
loop's drift correction, and SAML restarting from live data."""

import numpy as np
import pytest

from repro.core import (Autotuner, BoostedTreesRegressor, ConfigSpace, Param,
                        SurrogatePair)
from repro.core.bdtr import append_rows, bin_features, bin_rows
from repro.runtime import OnlineSurrogateLoop, TuningStore


def toy_data(n=200, seed=0, shift=0.0):
    rng = np.random.default_rng(seed)
    X = rng.uniform(0, 1, (n, 3))
    y = 3.0 * X[:, 0] + np.sin(4 * X[:, 1]) + 0.5 * X[:, 2] + shift
    return X, y


# -- binning reuse ---------------------------------------------------------------

def test_bin_rows_matches_original_codes():
    X, _ = toy_data(300)
    binned = bin_features(X, max_bins=32)
    np.testing.assert_array_equal(bin_rows(binned, X), binned.codes)


def test_bin_rows_clamps_out_of_range():
    X = np.linspace(0, 1, 50)[:, None]
    binned = bin_features(X, max_bins=16)
    codes = bin_rows(binned, np.array([[-5.0], [0.5], [99.0]]))
    assert codes[0, 0] == 0
    assert codes[2, 0] == binned.n_bins[0] - 1


def test_append_rows_extends_codes_only():
    X, _ = toy_data(100)
    binned = bin_features(X, max_bins=16)
    X2, _ = toy_data(40, seed=1)
    ext = append_rows(binned, X2)
    assert len(ext.codes) == 140
    np.testing.assert_array_equal(ext.codes[:100], binned.codes)
    assert ext.split_value is binned.split_value    # bins are frozen


# -- fit_more --------------------------------------------------------------------

@pytest.mark.parametrize("method", ["exact", "hist"])
def test_fit_more_reduces_error_on_new_data(method):
    X, y = toy_data(300)
    model = BoostedTreesRegressor(n_estimators=40, max_depth=3,
                                  tree_method=method).fit(X, y)
    Xn, yn = toy_data(200, seed=7, shift=2.0)       # drifted platform
    err_before = np.abs(model.predict(Xn) - yn).mean()
    model.fit_more(Xn, yn, 40)
    err_after = np.abs(model.predict(Xn) - yn).mean()
    assert len(model.trees_) == 80
    assert err_after < 0.5 * err_before


def test_fit_more_requires_fit_and_invalidates_pack():
    X, y = toy_data(100)
    with pytest.raises(ValueError):
        BoostedTreesRegressor().fit_more(X, y, 5)
    model = BoostedTreesRegressor(n_estimators=10, tree_method="hist")
    model.fit(X, y)
    jax_pred = model.predict_fn_jax()               # forces pack
    before = np.asarray(jax_pred(X[:5]))
    model.fit_more(X, y + 1.0, 20)
    after = np.asarray(model.predict_fn_jax()(X[:5]))
    # the packed JAX predictor reflects the new trees...
    assert not np.allclose(before, after)
    # ...and agrees with the numpy path
    np.testing.assert_allclose(after, model.predict(X[:5]), rtol=1e-5,
                               atol=1e-5)


def test_fit_more_with_incremental_binning_matches_fresh_binning():
    X, y = toy_data(300)
    Xn, yn = toy_data(100, seed=3, shift=1.0)
    allX, ally = np.vstack([X, Xn]), np.concatenate([y, yn])

    def fitted():
        return BoostedTreesRegressor(n_estimators=20, max_depth=3, seed=0,
                                     tree_method="hist").fit(X, y)

    a = fitted().fit_more(allX, ally, 10,
                          binned=append_rows(bin_features(X, 64), Xn))
    b = fitted().fit_more(allX, ally, 10)
    # same data, frozen-edge vs fresh binning: predictions stay close on
    # the training hull (bins differ only where new rows moved quantiles)
    q = toy_data(50, seed=9)[0]
    np.testing.assert_allclose(a.predict(q), b.predict(q), atol=0.2)


# -- the online loop -------------------------------------------------------------

def tiny_surrogate(host_bias=0.0, dev_bias=0.0, n_estimators=30):
    """A SurrogatePair over {threads, host_fraction} with analytic truth:
    t_host = f/100 * 8/threads + bias,  t_dev = (1-f/100) * 1.0 + bias."""
    rng = np.random.default_rng(0)
    threads = np.array([1, 2, 4, 8])
    fracs = np.arange(0, 101, 5)
    T, F = np.meshgrid(threads, fracs, indexing="ij")
    Xh = np.column_stack([T.ravel(), F.ravel()]).astype(float)
    yh = F.ravel() / 100.0 * 8.0 / T.ravel() + host_bias
    Xd = np.column_stack([T.ravel(), F.ravel()]).astype(float)
    yd = (1.0 - F.ravel() / 100.0) * 1.0 + dev_bias
    host = BoostedTreesRegressor(n_estimators=n_estimators, max_depth=3,
                                 tree_method="hist").fit(Xh, yh)
    dev = BoostedTreesRegressor(n_estimators=n_estimators, max_depth=3,
                                tree_method="hist").fit(Xd, yd)

    def feats(cfg):
        return np.asarray([float(cfg["threads"]),
                           float(cfg["host_fraction"])])

    return SurrogatePair(host=host, device=dev, host_features=feats,
                         device_features=feats)


def test_observe_refit_corrects_drift():
    pair = tiny_surrogate()
    loop = OnlineSurrogateLoop(pair, refit_every=16, n_new_trees=40)
    cfg = {"threads": 4, "host_fraction": 50}
    base = pair.host.predict(pair.host_features(cfg)[None, :])[0]

    # live platform runs 0.5s slower on the host side
    rng = np.random.default_rng(2)
    for _ in range(16):
        c = {"threads": int(rng.choice([1, 2, 4, 8])),
             "host_fraction": int(rng.choice(np.arange(0, 101, 5)))}
        t_true = c["host_fraction"] / 100.0 * 8.0 / c["threads"] + 0.5
        loop.observe(c, t_true, None)
    assert loop.n_refits == 1                       # auto-refit fired
    updated = pair.host.predict(pair.host_features(cfg)[None, :])[0]
    assert updated == pytest.approx(base + 0.5, abs=0.2)


def test_saml_restarts_from_live_data():
    """After live observations show the device 3x slower than the offline
    grid claimed, tune_saml's optimum moves host-ward."""
    pair = tiny_surrogate()
    space = ConfigSpace([
        Param("threads", (1, 2, 4, 8)),
        Param("host_fraction", tuple(range(0, 101, 5))),
    ])

    def tune():
        return Autotuner(space, lambda c: 0.0, surrogate=pair).tune_saml(
            iterations=400, seed=0)

    before = tune().best_config["host_fraction"]

    loop = OnlineSurrogateLoop(pair, refit_every=200, n_new_trees=60)
    rng = np.random.default_rng(3)
    for _ in range(120):
        c = {"threads": int(rng.choice([1, 2, 4, 8])),
             "host_fraction": int(rng.choice(np.arange(0, 101, 5)))}
        t_dev = (1.0 - c["host_fraction"] / 100.0) * 3.0   # 3x slower now
        loop.observe(c, None, t_dev, auto_refit=False)
    assert loop.refit(force=True)
    after = tune().best_config["host_fraction"]
    assert after > before, (before, after)


def test_max_trees_compaction_bounds_ensemble():
    pair = tiny_surrogate(n_estimators=30)
    loop = OnlineSurrogateLoop(pair, refit_every=8, n_new_trees=10,
                               max_trees=45)
    rng = np.random.default_rng(5)
    for _ in range(40):                     # 5 auto-refits
        c = {"threads": int(rng.choice([1, 2, 4, 8])),
             "host_fraction": int(rng.choice(np.arange(0, 101, 5)))}
        loop.observe(c, 1.0, 1.0)
    assert loop.n_refits == 5
    # growth is bounded: 30 +10 (=40) then compaction retrains to 30,
    # never exceeding max_trees
    assert len(pair.host.trees_) <= 45
    assert len(pair.device.trees_) <= 45


def test_observation_persistence_via_store(tmp_path):
    pair = tiny_surrogate()
    store = TuningStore(tmp_path / "t.json", devices="pinned")
    loop = OnlineSurrogateLoop(pair, refit_every=1000)
    for f in (10, 50, 90):
        loop.observe({"threads": 2, "host_fraction": f}, 0.5, 0.7,
                     auto_refit=False)
    loop.save_to(store, "sig0")

    fresh = OnlineSurrogateLoop(tiny_surrogate(), refit_every=1000)
    assert fresh.load_from(store, "sig0") == 6      # 3 host + 3 device rows
    assert fresh.n_observations == 6
    assert fresh.load_from(store, "missing") == 0
