"""repro.obs: tracer format and determinism, histogram percentiles,
journal schema and causal order, zero-cost disabled path, and the
instrumented runtime/guard/tuning call sites (ISSUE 8)."""

import io
import json
import os
import subprocess
import sys
import threading
import tracemalloc
from bisect import bisect_left
from pathlib import Path

import numpy as np
import pytest

from helpers import REPO, SRC, make_serial_sim_builder, sim_skew_groups

import repro.obs as obs_pkg
from repro.obs import (EVENT_KINDS, Histogram, Journal, MetricsRegistry,
                       Observer, Tracer, as_observer, configure, get_logger,
                       load_journal, load_trace, validate_events,
                       validate_trace)
from repro.obs.__main__ import check_required_order
from repro.obs.metrics import default_latency_buckets
from repro.runtime import (ChunkedScheduler, EwmaController, FaultInjector,
                           FaultPlan, KillSwitch, ServeGuard,
                           StreamingPipeline, VirtualClock, parse_fault_plan)

BATCH = {"x": np.zeros((128, 4), np.float32)}


def sim_rig(observer="on", *, plan=None, skew=3, per_row_s=4e-4):
    """A 2-group serial-sim scheduler on a VirtualClock with an observer
    sharing the clock — the rig the benches and the serve drill use.
    ``observer``: "on" | "off" (disabled Observer) | None (absent)."""
    clock = VirtualClock()
    obs = None if observer is None else Observer(
        enabled=observer == "on", clock=clock)
    groups = sim_skew_groups(skew)
    injector = FaultInjector(plan, groups) if plan is not None else None
    sched = ChunkedScheduler(
        make_serial_sim_builder(per_row_s, clock=clock, injector=injector),
        groups, controller=EwmaController(2, min_share=0.02),
        clock=clock, observer=obs)
    if injector is not None:
        injector.attach(sched)
    return sched, obs, injector, clock


# -- histogram percentiles vs numpy ---------------------------------------------

def _bucket_window(h, value):
    """The [lo, hi] bounds of the bucket owning ``value``."""
    i = bisect_left(h.bounds, value)
    lo = h.bounds[i - 1] if i > 0 else h.min
    hi = h.bounds[i] if i < len(h.bounds) else h.max
    return lo, hi


def test_histogram_percentiles_match_numpy_within_bucket():
    rng = np.random.default_rng(0)
    data = rng.lognormal(mean=-6.0, sigma=1.2, size=800)
    h = Histogram("t")
    for v in data:
        h.observe(v)
    assert h.count == 800
    assert h.sum == pytest.approx(data.sum())
    assert h.summary()["mean"] == pytest.approx(data.mean())
    prev = 0.0
    for q in (0.50, 0.95, 0.99):
        exact = float(np.percentile(data, q * 100, method="linear"))
        est = h.percentile(q)
        # bucket-censored: the estimate must land in (or clamp to) the
        # bucket owning the exact quantile, and stay monotone in q
        lo, hi = _bucket_window(h, exact)
        assert lo * (1 - 1e-9) <= est <= hi * (1 + 1e-9), (q, exact, est)
        assert est >= prev
        prev = est


def test_histogram_single_bucket_interpolation():
    # all samples inside one geometric bucket: the interpolated estimate
    # lands within that bucket's width of numpy's exact answer
    rng = np.random.default_rng(1)
    lo, hi = 1e-3, 10 ** (-3 + 0.25)
    data = rng.uniform(lo * 1.01, hi * 0.99, size=200)
    h = Histogram("t")
    for v in data:
        h.observe(v)
    for q in (0.5, 0.95):
        exact = float(np.percentile(data, q * 100))
        assert abs(h.percentile(q) - exact) <= hi - lo
    bounds = default_latency_buckets()
    assert bounds == tuple(sorted(bounds))


def test_histogram_edges_overflow_and_clamping():
    h = Histogram("t", buckets=(1.0, 10.0))
    for v in (0.5, 5.0, 500.0):       # one per bucket + overflow
        h.observe(v)
    assert h.counts == [1, 1, 1]
    assert h.min <= h.percentile(0.0) <= 1.0
    assert h.percentile(1.0) == 500.0     # clamped to observed max
    with pytest.raises(ValueError):
        h.percentile(1.5)
    with pytest.raises(ValueError):
        Histogram("bad", buckets=(2.0, 1.0))
    empty = Histogram("e")
    assert empty.percentile(0.5) is None
    assert empty.summary() == {"count": 0, "sum": 0.0}


# -- metrics registry ------------------------------------------------------------

def test_registry_get_or_create_and_snapshot():
    m = MetricsRegistry()
    c = m.counter("a")
    c.inc()
    c.inc(2)
    assert m.counter("a") is c and c.value == 3
    m.gauge("g").set(0.5)
    m.histogram("h").observe(1e-3)
    snap = m.to_dict()
    assert snap["counters"] == {"a": 3}
    assert snap["gauges"] == {"g": 0.5}
    assert snap["histograms"]["h"]["count"] == 1


def test_disabled_registry_hands_out_noops():
    m = MetricsRegistry(enabled=False)
    c, g, h = m.counter("a"), m.gauge("g"), m.histogram("h")
    c.inc(10)
    g.set(1.0)
    h.observe(2.0)
    assert h.percentile(0.5) is None
    assert m.counter("other") is c          # shared singletons
    assert m.to_dict() == {"counters": {}, "gauges": {}, "histograms": {}}


# -- journal ---------------------------------------------------------------------

def test_journal_round_trip_and_schema(tmp_path):
    clock = VirtualClock()
    j = Journal(clock=clock)
    j.event("tuning_start", strategy="sam", space_size=19)
    clock.advance(0.5)
    j.event("store_miss", strategy="sam", key="k")
    clock.advance(0.5)
    j.event("tuning_stop", strategy="sam", from_cache=False)
    assert len(j) == 3
    assert j.by_kind("store_miss")[0]["key"] == "k"
    assert j.kinds() == {"tuning_start": 1, "store_miss": 1,
                         "tuning_stop": 1}

    path = j.save(tmp_path / "journal.jsonl")
    events = load_journal(path)
    assert events == j.events
    assert validate_events(events) == []
    assert [e["seq"] for e in events] == [0, 1, 2]
    assert events[1]["ts"] == pytest.approx(0.5)

    with pytest.raises(ValueError, match="unknown journal event kind"):
        j.event("not_a_kind")
    tampered = [dict(events[0], seq=7), dict(events[1], kind="bogus")]
    errs = validate_events(tampered)
    assert any("not dense" in e for e in errs)
    assert any("unknown kind" in e for e in errs)


def test_journal_live_sink_mirrors_events():
    sink = io.StringIO()
    j = Journal(sink=sink)
    j.event("store_hit", key="k")
    line = json.loads(sink.getvalue())
    assert line["kind"] == "store_hit" and line["seq"] == 0


# -- tracer ----------------------------------------------------------------------

def test_trace_format_and_round_trip(tmp_path):
    clock = VirtualClock()
    t = Tracer(clock=clock)
    t.thread_name(0, "group:fast")
    t.complete("chunk", 0.0, 0.002, tid=0, args={"rows": 64})
    clock.advance(0.01)
    t.instant("demote", tid=0)
    with t.span("tune.sam", args={"objective": "time"}):
        clock.advance(0.25)
    assert len(t) == 4
    path = t.save(tmp_path / "trace.json")
    events = load_trace(path)
    assert validate_trace(events) == []
    by_name = {e["name"]: e for e in events}
    assert by_name["chunk"]["ph"] == "X"
    assert by_name["chunk"]["dur"] == pytest.approx(2000.0)   # microseconds
    assert by_name["demote"]["ph"] == "i" and by_name["demote"]["s"] == "t"
    assert by_name["thread_name"]["ph"] == "M"
    assert by_name["tune.sam"]["dur"] == pytest.approx(0.25e6)
    # chrome://tracing container shape
    doc = json.loads(path.read_text())
    assert set(doc) == {"traceEvents", "displayTimeUnit"}

    assert validate_trace([{"ph": "Q"}]) != []
    assert any("missing key" in e
               for e in validate_trace([{"ph": "X", "name": "x"}]))


def test_trace_cross_thread_begin_end():
    clock = VirtualClock()
    t = Tracer(clock=clock)
    token = t.begin("drain", tid=1)
    clock.advance(0.003)

    th = threading.Thread(target=t.end, args=(token,),
                          kwargs={"args": {"rows": 32}})
    th.start()
    th.join()
    t.end(9999)                       # unknown token: silent no-op
    assert len(t) == 1
    ev = t.events[0]
    assert ev["dur"] == pytest.approx(3000.0)
    assert ev["args"] == {"rows": 32}


# -- zero-cost disabled path -----------------------------------------------------

def test_disabled_observer_resolves_to_none_and_stays_empty():
    on = Observer()
    assert as_observer(on) is on
    assert as_observer(None) is None

    sched, off, _, _ = sim_rig("off")
    assert as_observer(off) is None
    assert sched._obs is None
    for _ in range(4):
        sched.step(BATCH)
    assert len(off.tracer) == 0
    assert len(off.journal) == 0
    assert off.metrics.to_dict() == {"counters": {}, "gauges": {},
                                     "histograms": {}}


def test_disabled_observer_allocates_nothing_per_step():
    """The disabled path must not touch repro.obs at all: tracemalloc
    filtered to the obs package sees zero allocations across steps."""
    sched, _, _, _ = sim_rig("off")
    for _ in range(3):                               # warm every cache
        sched.step(BATCH)
    obs_dir = str(Path(obs_pkg.__file__).parent)
    tracemalloc.start()
    try:
        for _ in range(5):
            sched.step(BATCH)
        snap = tracemalloc.take_snapshot()
    finally:
        tracemalloc.stop()
    obs_allocs = snap.filter_traces(
        [tracemalloc.Filter(True, obs_dir + "/*")]).statistics("filename")
    assert sum(s.size for s in obs_allocs) == 0, obs_allocs


# -- instrumented scheduler ------------------------------------------------------

def test_scheduler_metrics_and_rebalance_journal():
    sched, obs, _, _ = sim_rig("on")
    for _ in range(6):
        sched.step(BATCH)
    m = obs.metrics.to_dict()["counters"]
    assert m["scheduler.steps"] == 6
    assert m["scheduler.rows_completed"] == 6 * 128
    assert m["scheduler.plan_cache_hits"] + \
        m["scheduler.plan_cache_misses"] == 6
    # plan-change/failure steps never feed the controller
    assert m["controller.updates"] == sum(
        1 for r in sched.history if not r["plan_changed"]
        and not r["failures"]) > 0
    adopted = obs.journal.by_kind("rebalance_adopted")
    assert adopted and {"batch", "old", "new"} <= set(adopted[0])
    gauges = obs.metrics.to_dict()["gauges"]
    assert gauges["controller.share.g0"] == pytest.approx(
        float(sched.shares[0]), abs=1e-6)
    # lanes are named, step spans exist on the scheduler lane
    names = {e["name"] for e in obs.tracer.events}
    assert {"scheduler.step", "chunk", "dispatch"} <= names
    assert validate_trace(obs.tracer.events) == []


def test_scheduler_demote_redispatch_restore_causal_order():
    plan = FaultPlan().kill(0, at=3).recover(0, at=8)
    sched, obs, injector, _ = sim_rig("on", plan=plan)
    for _ in range(10):
        injector.tick()
        sched.step(BATCH)
    demoted = obs.journal.by_kind("group_demoted")
    redisp = obs.journal.by_kind("chunks_redispatched")
    restored = obs.journal.by_kind("group_restored")
    assert demoted and redisp and restored
    assert demoted[0]["group"] == "fast"
    assert "killed at step 3" in demoted[0]["reason"]
    assert redisp[0]["from_groups"] == ["fast"]
    assert redisp[0]["rows"] > 0
    # causal: demotion -> re-dispatch -> restore, on one dense sequence
    assert demoted[0]["seq"] < redisp[0]["seq"] < restored[0]["seq"]
    assert demoted[0]["ts"] <= redisp[0]["ts"] <= restored[0]["ts"]
    assert validate_events(obs.journal.events) == []


def test_trace_is_deterministic_under_fault_plan():
    """Same FaultPlan on a VirtualClock => identical trace (modulo drain
    append order) and identical journal, run to run."""
    def drill():
        plan = FaultPlan().kill(0, at=3).slow(1, at=6, factor=2.0)
        sched, obs, injector, _ = sim_rig("on", plan=plan)
        for _ in range(8):
            injector.tick()
            sched.step(BATCH)
        key = ("ts", "dur", "name", "tid", "ph")
        trace = sorted(obs.tracer.events,
                       key=lambda e: tuple(str(e.get(k)) for k in key))
        return trace, obs.journal.events, obs.metrics.to_dict()

    t1, j1, m1 = drill()
    t2, j2, m2 = drill()
    assert t1 == t2
    assert j1 == j2
    assert m1 == m2


# -- guard / kill switch ---------------------------------------------------------

def test_guard_journal_armed_tripped_rearmed():
    class Poisoned(EwmaController):
        def update(self, times, rows=None):
            self.updates = getattr(self, "updates", 0) + 1
            if self.updates >= 8:
                self.shares = np.asarray([0.15, 0.85])
                return self.shares
            return super().update(times, rows=rows)

    clock = VirtualClock()
    obs = Observer(clock=clock)
    sched = ChunkedScheduler(
        make_serial_sim_builder(4e-4, clock=clock), sim_skew_groups(3),
        controller=Poisoned(2, min_share=0.02), clock=clock, observer=obs)
    guard = ServeGuard(sched, switch=KillSwitch(threshold=1.5, patience=3,
                                                cooldown=3),
                       fallback=np.asarray([0.75, 0.25]))
    assert guard._obs is obs            # inherited from the scheduler
    recs = [guard.step(BATCH) for _ in range(25)]
    verdicts = [r["guard"]["verdict"] for r in recs]
    assert "trip" in verdicts and "rearm" in verdicts

    armed = obs.journal.by_kind("killswitch_armed")
    tripped = obs.journal.by_kind("killswitch_tripped")
    rearmed = obs.journal.by_kind("killswitch_rearmed")
    assert len(armed) == 1 and armed[0]["patience"] == 3
    assert tripped and tripped[0]["t_step"] > tripped[0]["baseline"]
    assert rearmed
    assert armed[0]["seq"] < tripped[0]["seq"] < rearmed[0]["seq"]
    counters = obs.metrics.to_dict()["counters"]
    assert counters["guard.verdict.trip"] == verdicts.count("trip")
    assert counters["guard.verdict.ok"] == verdicts.count("ok")


# -- tuning session accounting ---------------------------------------------------

def test_session_accounting_and_store_events(tmp_path):
    from repro.core import ConfigSpace, Param
    from repro.runtime import TuningStore
    from repro.tune import TuningSession

    space = ConfigSpace([Param("x", tuple(range(12)))])
    store = TuningStore(tmp_path / "t.json", devices="pinned")
    obs = Observer()
    session = TuningSession(space, evaluator=lambda c: (c["x"] - 7) ** 2,
                            store=store, observer=obs)
    res = session.run("sam", iterations=8, seed=0)
    assert res.space_size == space.size() == 12
    assert 0 < res.n_measured <= res.n_experiments
    assert res.experiments_fraction == \
        pytest.approx(res.n_experiments / 12)
    assert obs.journal.by_kind("store_miss")
    stops = obs.journal.by_kind("tuning_stop")
    assert stops[-1]["from_cache"] is False
    assert stops[-1]["n_measured"] == res.n_measured
    assert stops[-1]["space_size"] == 12

    res2 = session.run("sam", iterations=8, seed=0)     # served from store
    assert res2.best_config == res.best_config
    assert obs.journal.by_kind("store_hit")
    assert obs.journal.by_kind("tuning_stop")[-1]["from_cache"] is True
    c = obs.metrics.to_dict()["counters"]
    assert c["tune.store_hits"] == 1 and c["tune.store_misses"] == 1
    starts = obs.journal.by_kind("tuning_start")
    assert len(starts) == 2 and starts[0]["seq"] < stops[0]["seq"]
    # the strategy run is a trace span
    assert any(e["name"] == "tune.sam" for e in obs.tracer.events)


def test_kernel_timer_counts_deduplicated_executions():
    from repro.tune import kernels as ktune
    from repro.tune.kernels.evaluate import KernelTimer

    spec = ktune.get_kernel("flash_attention")
    meta = spec.smoke_shape
    space = spec.space(meta)
    obs = Observer()
    timer = KernelTimer(spec, meta, "float32", repeats=1, seed=0,
                        observer=obs)
    cfg = spec.default_config(space, meta)
    t1 = timer(cfg)
    t2 = timer(cfg)                     # memoized: no second execution
    assert np.isfinite(t1) and t1 == t2
    assert timer.n_measured == 1
    c = obs.metrics.to_dict()["counters"]
    assert c[f"kernel.{spec.name}.measured"] == 1
    assert c[f"kernel.{spec.name}.cache_hits"] == 1


# -- streaming pipeline ----------------------------------------------------------

def test_stream_summary_reports_latency_percentiles():
    clock = VirtualClock()
    obs = Observer(clock=clock)
    pipe = StreamingPipeline(
        make_serial_sim_builder(4e-4, clock=clock), sim_skew_groups(3),
        controller=EwmaController(2, min_share=0.02), clock=clock,
        observer=obs)
    pipe.run([BATCH] * 6)
    s = pipe.summary()
    assert s["batches"] == 6
    assert 0 < s["t_step_p50"] <= s["t_step_p95"] <= s["t_step_p99"]


# -- structured logger -----------------------------------------------------------

def test_logger_levels_journal_mirror_and_configure():
    out = io.StringIO()
    j = Journal()
    log = get_logger("repro.test_obs")
    try:
        configure(level="info", journal=j, stream=out)
        log.debug("hidden")
        log.info("shown line", batches=4)
        log.warning("warned")
        assert out.getvalue() == "shown line\nwarned\n"   # verbatim, filtered
        assert [e["msg"] for e in j.events] == ["shown line", "warned"]
        assert j.events[0]["kind"] == "log"
        assert j.events[0]["batches"] == 4
        assert j.events[0]["logger"] == "repro.test_obs"

        configure(level="error")                  # retroactive on the handle
        log.warning("now hidden")
        assert out.getvalue() == "shown line\nwarned\n"

        configure(level="debug", journal=False)   # detach the mirror
        log.debug("visible again")
        assert out.getvalue().endswith("visible again\n")
        assert len(j.events) == 2
        with pytest.raises(ValueError, match="unknown log level"):
            configure(level="loud")
    finally:
        configure(level="info", journal=False, stream=False)
    assert get_logger("repro.test_obs") is log    # registry is process-wide


# -- fault-plan CLI surface ------------------------------------------------------

def test_parse_fault_plan_round_trips_the_chained_builder():
    parsed = parse_fault_plan("kill:0@3, slow:1@9:4, transient:0@5,"
                              "recover:0@12")
    chained = (FaultPlan().kill(0, at=3).slow(1, at=9, factor=4.0)
               .transient(0, at=5).recover(0, at=12))
    assert parsed.events == chained.events
    with pytest.raises(ValueError, match="unknown fault kind"):
        parse_fault_plan("explode:0@3")
    for bad in ("kill:0", "slow:1@9", "kill:a@b"):
        with pytest.raises(ValueError, match="bad fault-plan event"):
            parse_fault_plan(bad)


# -- validator CLI helpers -------------------------------------------------------

def test_check_required_order():
    events = [{"seq": 0, "ts": 0.0, "kind": "group_demoted"},
              {"seq": 1, "ts": 1.0, "kind": "chunks_redispatched"},
              {"seq": 2, "ts": 2.0, "kind": "killswitch_tripped"}]
    assert check_required_order(
        events, ["group_demoted", "chunks_redispatched",
                 "killswitch_tripped"]) == []
    assert any("never occurred" in e for e in check_required_order(
        events, ["group_restored"]))
    assert check_required_order(
        events, ["killswitch_tripped", "group_demoted"]) != []


def test_schema_file_matches_event_catalog():
    schema = json.loads((REPO / "docs" / "obs_schema.json").read_text())
    assert set(schema["journal"]["kinds"]) == set(EVENT_KINDS)
    # the serving layer's kinds ride in the same catalog: the schema file
    # and EVENT_KINDS must grow together (see docs/observability.md table)
    for kind in ("request_admitted", "request_shed", "request_retired",
                 "request_retried"):
        assert kind in schema["journal"]["kinds"]


# -- report ----------------------------------------------------------------------

def test_summary_report_and_render(tmp_path):
    obs = Observer()
    obs.metrics.counter("scheduler.steps").inc(4)
    obs.metrics.histogram("scheduler.t_step_s").observe(2e-3)
    obs.journal.event("store_hit", key="k")
    obs.tracer.instant("demote")
    path = tmp_path / "obs_summary.json"
    summary = obs.write_summary(path, extra={"stream": {"batches": 4}},
                                date="2026-08-07")
    on_disk = json.loads(path.read_text())
    assert on_disk["metrics"]["counters"]["scheduler.steps"] == 4
    assert on_disk["journal"]["by_kind"] == {"store_hit": 1}
    assert on_disk["trace"]["n_events"] == 1
    assert on_disk["meta"]["date"] == "2026-08-07"
    assert on_disk["stream"] == {"batches": 4}
    text = obs.render()
    assert "scheduler.steps" in text and "store_hit" in text
    assert summary["journal"]["n_events"] == 1


# -- end-to-end: the serve fault drill (the CI obs-smoke job) --------------------

def test_serve_fault_drill_produces_causal_artifacts(tmp_path):
    trace = tmp_path / "trace.json"
    journal = tmp_path / "journal.jsonl"
    metrics = tmp_path / "obs_summary.json"
    env = dict(os.environ)
    env["PYTHONPATH"] = f"{SRC}:{env.get('PYTHONPATH', '')}"
    env["JAX_PLATFORMS"] = "cpu"
    cmd = [sys.executable, "-m", "repro.launch.serve", "--smoke", "--stream",
           "--batch", "16", "--stream-batches", "16", "--slow", "4",
           "--guard", "--guard-patience", "2",
           "--fault-plan", "kill:0@3,slow:1@9:4",
           "--trace-out", str(trace), "--journal-out", str(journal),
           "--metrics-out", str(metrics)]
    proc = subprocess.run(cmd, env=env, capture_output=True, text=True,
                          timeout=600)
    assert proc.returncode == 0, proc.stderr[-4000:]

    events = load_trace(trace)
    assert validate_trace(events) == []
    assert len(events) > 20

    jev = load_journal(journal)
    assert validate_events(jev) == []
    order = ["group_demoted", "chunks_redispatched", "killswitch_tripped"]
    assert check_required_order(jev, order) == []
    demoted = [e for e in jev if e["kind"] == "group_demoted"][0]
    assert demoted["group"] == "fast" and "killed at step 3" in \
        demoted["reason"]

    summary = json.loads(metrics.read_text())
    assert summary["metrics"]["counters"]["scheduler.steps"] == 16
    # the "wrote <artifact>" log lines land in the journal after it is
    # saved, so the summary may count a few more events than the file
    assert summary["journal"]["n_events"] >= len(jev)

    # the CI validator passes on its own artifacts
    check = subprocess.run(
        [sys.executable, "-m", "repro.obs", "--trace", str(trace),
         "--journal", str(journal),
         "--schema", str(REPO / "docs" / "obs_schema.json"),
         "--require", ",".join(order)],
        env=env, capture_output=True, text=True, timeout=120)
    assert check.returncode == 0, check.stdout + check.stderr
    assert "[obs] OK" in check.stdout
