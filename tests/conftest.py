"""Shared test configuration.

One concern: **hypothesis fallback** — the property tests use
``hypothesis`` when it is installed (``pip install -e .[dev]``, and CI
installs it), but the bare container only ships pytest.  When
``hypothesis`` is absent we install a *working* mini-implementation into
``sys.modules``: ``@given`` runs the test body over ``max_examples``
seeded draws from the declared strategies instead of skipping, so
tier-1 exercises the property tests everywhere.  The four property
tests only use ``st.integers(lo, hi)``; add strategies here if a new
test needs them (an unsupported strategy raises at collection, not
silently passes).

The distributed suites (``test_distributed.py``, ``test_roofline.py``,
``test_fault_tolerance.py``, ``test_dryrun_integration.py``, the
compression tests in ``test_substrates.py``) run unconditionally against
the real ``repro.dist`` subsystem; multi-device cases isolate themselves
in subprocesses via ``helpers.run_subprocess``.
"""

from __future__ import annotations

import sys
import types

# -- 1. hypothesis fallback ---------------------------------------------------
try:
    import hypothesis  # noqa: F401
except ImportError:
    import zlib as _zlib

    import numpy as _np

    _hyp = types.ModuleType("hypothesis")
    _st = types.ModuleType("hypothesis.strategies")
    _DEFAULT_MAX_EXAMPLES = 100

    class _IntegersStrategy:
        def __init__(self, lo, hi):
            self.lo, self.hi = int(lo), int(hi)

        def draw(self, rng):
            return int(rng.integers(self.lo, self.hi + 1))

    def _integers(min_value, max_value):
        return _IntegersStrategy(min_value, max_value)

    def _unsupported(name):
        def make(*_a, **_k):
            raise NotImplementedError(
                f"mini-hypothesis shim has no strategy {name!r} — install "
                "hypothesis (pip install -e .[dev]) or extend the shim")
        return make

    def _given(*a, **strategies):
        if a or not strategies:
            raise NotImplementedError(
                "mini-hypothesis shim supports keyword strategies only")

        def deco(fn):
            def runner():
                n = getattr(fn, "_shim_max_examples", _DEFAULT_MAX_EXAMPLES)
                # seeded off the test name so runs are reproducible (crc32,
                # not hash(): str hashing is salted per process)
                rng = _np.random.default_rng(
                    _zlib.crc32(fn.__qualname__.encode()))
                names = sorted(strategies)
                for _ in range(n):
                    kw = {k: strategies[k].draw(rng) for k in names}
                    try:
                        fn(**kw)
                    except Exception as e:
                        raise AssertionError(
                            f"property falsified on {kw!r}") from e
            # copy identity but NOT the signature (no functools.wraps /
            # __wrapped__: pytest would introspect the wrapped parameters
            # and demand fixtures for them)
            for attr in ("__name__", "__qualname__", "__doc__", "__module__"):
                setattr(runner, attr, getattr(fn, attr))
            runner._shim_target = fn
            return runner
        return deco

    def _settings(*a, **kw):
        # usable both as @settings and @settings(max_examples=..., ...)
        if len(a) == 1 and callable(a[0]) and not kw:
            return a[0]
        n = kw.get("max_examples")

        def deco(fn):
            if n is not None:
                # works in either decorator order: @given reads the attr
                # off its target at call time, so mark both the function
                # and (when @settings sits above @given) its shim target
                getattr(fn, "_shim_target", fn)._shim_max_examples = n
                fn._shim_max_examples = n
            return fn
        return deco

    _st.integers = _integers
    _st.__getattr__ = _unsupported
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    _hyp.HealthCheck = types.SimpleNamespace(all=lambda: [])
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
