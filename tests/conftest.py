"""Shared test configuration.

One concern: **hypothesis fallback** — the property tests use
``hypothesis`` when it is installed (``pip install -e .[dev]``), but the
bare container only ships pytest.  When ``hypothesis`` is absent we
install a tiny shim into ``sys.modules`` whose ``@given`` marks the test
as skipped, so the rest of each module still collects and runs.

The distributed suites (``test_distributed.py``, ``test_roofline.py``,
``test_fault_tolerance.py``, ``test_dryrun_integration.py``, the
compression tests in ``test_substrates.py``) run unconditionally against
the real ``repro.dist`` subsystem; multi-device cases isolate themselves
in subprocesses via ``helpers.run_subprocess``.
"""

from __future__ import annotations

import sys
import types

import pytest

# -- 1. hypothesis shim -------------------------------------------------------
try:
    import hypothesis  # noqa: F401
except ImportError:
    _hyp = types.ModuleType("hypothesis")
    _st = types.ModuleType("hypothesis.strategies")

    def _given(*_a, **_k):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed (pip install -e .[dev])"
            )(fn)
        return deco

    def _settings(*_a, **_k):
        # usable both as @settings and @settings(...)
        if len(_a) == 1 and callable(_a[0]) and not _k:
            return _a[0]
        return lambda fn: fn

    def _strategy(*_a, **_k):
        return None

    _st.__getattr__ = lambda name: _strategy  # integers(), floats(), ...
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    _hyp.HealthCheck = types.SimpleNamespace(all=lambda: [])
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
