"""Shared test configuration.

Two concerns:

1. **hypothesis fallback** — the property tests use ``hypothesis`` when it
   is installed (``pip install -e .[dev]``), but the bare container only
   ships pytest.  When ``hypothesis`` is absent we install a tiny shim into
   ``sys.modules`` whose ``@given`` marks the test as skipped, so the rest
   of each module still collects and runs.

2. **dist-stub skips** — ``repro.dist`` is currently a stub package
   (``repro.dist.IS_STUB``): the API surface exists so model/launch modules
   import, but sharding/compression/fault/seq_decode raise
   ``NotImplementedError`` when exercised.  Tests that exercise the real
   distributed subsystem are skipped until it lands.
"""

from __future__ import annotations

import sys
import types

import pytest

# -- 1. hypothesis shim -------------------------------------------------------
try:
    import hypothesis  # noqa: F401
except ImportError:
    _hyp = types.ModuleType("hypothesis")
    _st = types.ModuleType("hypothesis.strategies")

    def _given(*_a, **_k):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed (pip install -e .[dev])"
            )(fn)
        return deco

    def _settings(*_a, **_k):
        # usable both as @settings and @settings(...)
        if len(_a) == 1 and callable(_a[0]) and not _k:
            return _a[0]
        return lambda fn: fn

    def _strategy(*_a, **_k):
        return None

    _st.__getattr__ = lambda name: _strategy  # integers(), floats(), ...
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    _hyp.HealthCheck = types.SimpleNamespace(all=lambda: [])
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st

# -- 2. dist-stub skips -------------------------------------------------------
try:
    from repro import dist as _dist
    _DIST_IS_STUB = bool(getattr(_dist, "IS_STUB", False))
except ImportError:
    _DIST_IS_STUB = True

# Whole modules that drive the distributed subsystem end-to-end — not even
# imported while dist is a stub (some also need launch/mesh features beyond
# the container's JAX version).
collect_ignore = [
    "test_distributed.py",
    "test_roofline.py",
    "test_fault_tolerance.py",
    "test_dryrun_integration.py",
] if _DIST_IS_STUB else []

# Individual tests inside otherwise-runnable modules.
_DIST_TESTS = {
    ("test_substrates.py", "test_int8_roundtrip_bound"),
    ("test_substrates.py", "test_topk_keeps_largest"),
    ("test_substrates.py", "test_error_feedback_preserves_convergence"),
    ("test_substrates.py", "test_wire_bytes_accounting"),
}


def pytest_collection_modifyitems(config, items):
    if not _DIST_IS_STUB:
        return
    marker = pytest.mark.skip(
        reason="repro.dist is a stub package; distributed subsystem is a "
               "future PR")
    for item in items:
        fname = item.path.name if hasattr(item, "path") else \
            item.fspath.basename
        base = item.originalname if getattr(item, "originalname", None) \
            else item.name
        if (fname, base.split("[")[0]) in _DIST_TESTS:
            item.add_marker(marker)
