"""Batched search-engine tests: the vectorized paths must agree with the
seed scalar paths (same best configs, same accounting) while doing the
work in a handful of array ops."""

import numpy as np
import pytest

from repro.core import (Autotuner, BatchedLearnedEvaluator,
                        BoostedTreesRegressor, ConfigSpace, DATASETS_GB,
                        EmilPlatformModel, Param, fit_emil_surrogates,
                        paper_space, percent_error, vectorized_sa)

GB = DATASETS_GB["human"]


def small_space():
    return ConfigSpace([
        Param("threads", (2, 4, 8, 16)),
        Param("affinity", ("none", "scatter", "compact"), ordinal=False),
        Param("fraction", tuple(range(0, 101, 10))),
    ])


# -- batched enumeration ------------------------------------------------------

def test_encode_all_matches_stacked_encode():
    s = small_space()
    want = np.stack([s.encode(c) for c in s.enumerate()])
    np.testing.assert_allclose(s.encode_all(), want)


def test_index_grid_matches_enumerate_order():
    s = small_space()
    grid = s.index_grid()
    assert grid.shape == (s.size(), len(s.params))
    for k, cfg in enumerate(s.enumerate()):
        if k % 7 == 0:  # spot-check across the space
            assert s.from_indices(grid[k]) == cfg


def test_enumerate_columns_align_with_enumerate():
    s = paper_space(workload_step=25)
    cols = s.enumerate_columns()
    cfgs = list(s.enumerate())
    assert set(cols) == set(s.names)
    for k in (0, 1, len(cfgs) // 2, len(cfgs) - 1):
        for name in s.names:
            assert cols[name][k] == cfgs[k][name]


def test_enumerate_encoded_pairs_grid_and_features():
    s = small_space()
    grid, X = s.enumerate_encoded()
    np.testing.assert_allclose(X, s.encode_all())
    np.testing.assert_allclose(s.encode_indices(grid), X)


# -- histogram BDTR -----------------------------------------------------------

def test_hist_fit_identical_on_discrete_grid():
    """On grids whose features have <= max_bins distinct values the
    histogram splitter considers exactly the exact splitter's candidate
    splits, so the fitted ensembles are identical."""
    rng = np.random.default_rng(0)
    n = 1500
    t = rng.choice([2, 6, 12, 24, 36, 48], n)
    f = rng.choice(np.arange(2.5, 101, 2.5), n)
    aff = rng.integers(0, 3, n)
    X = np.column_stack([t, np.eye(3)[aff], f])
    y = (f / 100) / (2.0 * t / (t + 6.0)) * (1 + 0.1 * aff) \
        * np.exp(rng.normal(0, 0.015, n))
    ex = BoostedTreesRegressor(n_estimators=60, max_depth=4).fit(X, y)
    hist = BoostedTreesRegressor(n_estimators=60, max_depth=4,
                                 tree_method="hist").fit(X, y)
    np.testing.assert_allclose(hist.predict(X), ex.predict(X), atol=1e-9)


def test_hist_fit_close_on_continuous_data():
    rng = np.random.default_rng(1)
    X = rng.uniform(-2, 2, (1200, 4))
    y = np.sin(X[:, 0] * 2) + 0.5 * X[:, 1] ** 2 + 0.05 * \
        rng.standard_normal(1200)
    Xev = rng.uniform(-2, 2, (800, 4))
    yev = np.sin(Xev[:, 0] * 2) + 0.5 * Xev[:, 1] ** 2
    ex = BoostedTreesRegressor(n_estimators=80, max_depth=4).fit(X, y)
    hist = BoostedTreesRegressor(n_estimators=80, max_depth=4,
                                 tree_method="hist").fit(X, y)

    def rmse(m):
        return float(np.sqrt(np.mean((yev - m.predict(Xev)) ** 2)))

    assert rmse(hist) < 1.3 * rmse(ex) + 1e-3


def test_hist_emil_percent_error_within_point_of_exact():
    """Acceptance bound: hist-fit surrogate accuracy within 1 percent-error
    point of the exact splitter on the Emil eval tables."""
    errs = {}
    for method in ("exact", "hist"):
        _, _, ev = fit_emil_surrogates(
            EmilPlatformModel(), GB, datasets_gb=list(DATASETS_GB.values()),
            n_estimators=60, seed=0, tree_method=method, return_eval=True)
        for side in ("host", "device"):
            _, y, yp = ev[side]
            errs[(method, side)] = float(percent_error(y, yp).mean())
    for side in ("host", "device"):
        assert abs(errs[("hist", side)] - errs[("exact", side)]) < 1.0, errs


# -- batched strategies -------------------------------------------------------

@pytest.fixture(scope="module")
def emil_setup():
    plat = EmilPlatformModel()
    sur, n_train = fit_emil_surrogates(
        plat, GB, datasets_gb=list(DATASETS_GB.values()), n_estimators=50,
        seed=0)
    space = paper_space(workload_step=10)
    tuner = Autotuner(
        space,
        measure=lambda c: plat.energy(c, GB, None),
        truth=lambda c: plat.energy(c, GB, None),
        surrogate=sur, n_training_experiments=n_train,
        measure_batch=lambda cols: plat.energy_batch(cols, GB, None))
    return plat, sur, space, tuner


def test_eml_batched_matches_scalar(emil_setup):
    _, _, space, tuner = emil_setup
    scalar = tuner.tune_eml(engine="scalar")
    batched = tuner.tune_eml(engine="batched")
    assert batched.best_config == scalar.best_config
    assert batched.best_energy_search == pytest.approx(
        scalar.best_energy_search, rel=1e-12)
    # identical effort accounting
    assert batched.n_predictions == scalar.n_predictions == space.size()
    assert batched.n_experiments == 0


def test_em_batched_matches_scalar(emil_setup):
    _, _, space, tuner = emil_setup
    scalar = tuner.tune_em(engine="scalar")
    batched = tuner.tune_em(engine="batched")
    assert batched.best_config == scalar.best_config
    assert batched.best_energy_search == pytest.approx(
        scalar.best_energy_search, rel=1e-12)
    assert batched.n_experiments == scalar.n_experiments == space.size()


def test_batched_evaluator_counts_predictions(emil_setup):
    _, sur, space, _ = emil_setup
    ev = BatchedLearnedEvaluator(sur)
    cols = space.enumerate_columns()
    e = ev(cols)
    assert e.shape == (space.size(),)
    assert ev.n_predictions == space.size()
    # batch energies agree with the scalar oracle config-by-config
    for k in (0, space.size() // 3, space.size() - 1):
        cfg = space.from_indices(space.index_grid()[k])
        assert e[k] == pytest.approx(sur.predict_energy(cfg), rel=1e-9)


def test_saml_vectorized_finds_surrogate_optimum(emil_setup):
    """The vectorized multi-chain SA must land on the same best config the
    exhaustive (batched EML) sweep finds — the surrogate argmin — on a
    seeded small space, with SAML's zero-experiment accounting."""
    _, _, _, tuner = emil_setup
    eml = tuner.tune_eml()
    saml = tuner.tune_saml(engine="vectorized", iterations=800, seed=0,
                           n_chains=24, checkpoints=(200, 800))
    assert saml.n_experiments == 0
    assert saml.n_predictions == 24 * 801
    assert saml.best_energy_search == pytest.approx(
        eml.best_energy_search, rel=0.01)
    assert saml.best_config["host_fraction"] == \
        eml.best_config["host_fraction"]
    assert set(saml.checkpoints) == {200, 800}
    # checkpoints are truth-re-measured by TuneReport (only the surrogate
    # best-so-far is monotone), so just sanity-check the values
    for it in (200, 800):
        e, cfg = saml.checkpoints[it]
        assert np.isfinite(e) and e > 0
        assert set(cfg) == set(tuner.space.names)


def test_vectorized_sa_categorical_moves_explore_all_values():
    """Regression test for the PRNG key-reuse bug: the categorical
    resample used the same key as the step-direction bernoulli, so only
    values correlated with the direction draw were ever proposed."""
    s = ConfigSpace([
        Param("color", ("a", "b", "c", "d", "e"), ordinal=False),
    ])
    target = {"a": 3.0, "b": 2.0, "c": 1.0, "d": 0.0, "e": 2.5}

    import jax.numpy as jnp
    vals = jnp.asarray([target[v] for v in ("a", "b", "c", "d", "e")])

    def energy_jax(feats):  # one-hot (n, 5)
        return feats @ vals

    res = vectorized_sa(s, energy_jax, n_chains=4, n_iterations=200, seed=0)
    assert res.best_config == {"color": "d"}


def test_platform_energy_batch_matches_scalar():
    plat = EmilPlatformModel()
    space = paper_space(workload_step=20)
    cols = space.enumerate_columns()
    e = plat.energy_batch(cols, GB, None)
    for k, cfg in enumerate(space.enumerate()):
        if k % 11 == 0:
            assert e[k] == pytest.approx(plat.energy(cfg, GB, None),
                                         rel=1e-12)
