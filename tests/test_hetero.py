"""Heterogeneous work distribution: the rebalance controller and the
two-group runner (multi-device CPU via subprocess, per CI's
``XLA_FLAGS=--xla_force_host_platform_device_count``)."""

import pytest

from helpers import SIM_DEVICE_SNIPPET, run_subprocess

from repro.core.hetero import proportional_rebalance


# -- proportional_rebalance (pure controller math) ------------------------------

def test_rebalance_fixed_point_when_rates_equal():
    # both groups finish together -> the split is already optimal
    assert proportional_rebalance(0.5, 1.0, 1.0) == pytest.approx(0.5)
    assert proportional_rebalance(0.8, 1.0, 1.0) == pytest.approx(0.8)


def test_rebalance_moves_toward_faster_group():
    # A finished first -> A's rate is higher -> A gets more work
    f1 = proportional_rebalance(0.5, 1.0, 2.0)
    assert f1 > 0.5
    # and the move is damped, not a jump to the instantaneous target
    target = (0.5 / 1.0) / (0.5 / 1.0 + 0.5 / 2.0)
    assert f1 == pytest.approx(0.5 + 0.5 * (target - 0.5))
    assert proportional_rebalance(0.5, 2.0, 1.0) < 0.5


def test_rebalance_converges_to_rate_ratio():
    # group B is 4x slower per row: equal finish time at fraction 0.8
    f = 0.5
    for _ in range(30):
        f = proportional_rebalance(f, f / 1.0, (1 - f) / 0.25)
    assert f == pytest.approx(0.8, abs=1e-3)


def test_rebalance_no_damping_jumps_to_target():
    assert proportional_rebalance(0.5, 1.0, 3.0, damping=1.0) \
        == pytest.approx(0.75)


def test_rebalance_survives_degenerate_inputs():
    # zero times / extreme fractions must not divide by zero or leave (0, 1)
    for f in (0.0, 1.0, 0.5):
        for ta, tb in ((0.0, 1.0), (1.0, 0.0), (0.0, 0.0)):
            out = proportional_rebalance(f, ta, tb)
            assert 0.0 < out < 1.0


def test_rebalance_nonpositive_times_keep_fraction():
    # zero/negative times carry no rate information: the (clamped)
    # current split is returned unchanged, never a jump
    assert proportional_rebalance(0.7, 0.0, 1.0) == pytest.approx(0.7)
    assert proportional_rebalance(0.7, 1.0, -3.0) == pytest.approx(0.7)
    assert proportional_rebalance(0.7, -1.0, -1.0) == pytest.approx(0.7)


def test_rebalance_output_clamped_away_from_0_and_1():
    # an arbitrarily faster group cannot drive the other side's share to
    # exactly 0/1, even undamped
    hi = proportional_rebalance(0.5, 1e-12, 10.0, damping=1.0)
    lo = proportional_rebalance(0.5, 10.0, 1e-12, damping=1.0)
    assert hi <= 1.0 - 1e-3
    assert lo >= 1e-3
    # and the floor is tunable
    assert proportional_rebalance(0.5, 1e-12, 10.0, damping=1.0,
                                  min_fraction=0.05) == pytest.approx(0.95)


def test_rebalance_recovers_from_near_starvation():
    # group B was starved to the floor while degraded; once it recovers
    # (now 1x speed) the controller must hand work back
    f = 1.0 - 1e-3
    for _ in range(40):
        f = proportional_rebalance(f, f / 1.0, (1 - f) / 1.0)
    assert f == pytest.approx(0.5, abs=1e-2)


# -- HeterogeneousRunner (multi-device) -----------------------------------------

def test_runner_split_and_tune_fraction_sa():
    out = run_subprocess(SIM_DEVICE_SNIPPET + """
import jax, jax.numpy as jnp, numpy as np
from repro.core.hetero import DeviceGroup, HeterogeneousRunner
from jax.sharding import NamedSharding, PartitionSpec as P

devs = jax.devices()
ga = DeviceGroup("fast", devs[:4])
gb = DeviceGroup("slow", devs[4:], work_multiplier=3)

def jit_builder(group):
    mesh = group.mesh()
    per_row_s = 0.002 * group.work_multiplier / len(group.devices)
    def fn(batch):
        x = batch["x"]
        sh = NamedSharding(mesh, P("data"))
        y = jax.jit(lambda v: v.sum(), in_shardings=sh)(jax.device_put(x, sh))
        return SimReady(y, per_row_s * x.shape[0])
    return fn

batch = {"x": np.random.default_rng(0).standard_normal((64, 128)).astype(np.float32)}
runner = HeterogeneousRunner(jit_builder, ga, gb, fraction=0.5, clock=SIM_CLOCK)

# split invariants: group shares are device-aligned and cover the batch
a, b = runner._split(batch)
assert a["x"].shape[0] % len(ga.devices) == 0
assert a["x"].shape[0] + b["x"].shape[0] == 64
np.testing.assert_array_equal(
    np.concatenate([a["x"], b["x"]]), batch["x"])
runner.step(batch)   # real sharded dispatch through both groups
rec = runner.step(batch)
assert rec["rows_a"] + rec["rows_b"] == 64

# the paper's offline loop: SAM over the fraction space with measured
# step times as the energy -> near the 3:1 optimum (0.75).  The energies
# come from a pure simulated device pair on the virtual clock, so the
# measured times are exact functions of the fraction — scheduler noise
# cannot reorder candidate fractions and nothing sleeps.
def sim_builder(group):
    per_row_s = 0.01 * group.work_multiplier / len(group.devices)
    def fn(batch):
        return SimReady(None, per_row_s * batch["x"].shape[0])
    return fn

sim = HeterogeneousRunner(sim_builder, ga, gb, fraction=0.5, clock=SIM_CLOCK)
e_half = sim.step(batch, rebalance=False)["t_step"]
best = sim.tune_fraction_sa(batch, iterations=40, seed=0)
assert 0.6 <= best <= 0.9, best
e_best = sim.step(batch, rebalance=False)["t_step"]
# optimum halves the 50/50 step time; allow generous scheduling slack
assert e_best < 0.8 * e_half + 0.02, (e_best, e_half, best)
print("HETERO_TUNE_OK", best, e_half, e_best)
""")
    assert "HETERO_TUNE_OK" in out
