"""Resilience guardrails under deterministic fault injection.

Every scenario here is scripted through ``repro.runtime.simulate``
(``FaultPlan`` + ``FaultInjector``) and runs on a ``VirtualClock``:
trajectories are exact functions of the timing model — seeded,
wall-clock independent, and identical across machines (no
``time.sleep``-calibrated assertions anywhere).  The same plans run
against the serial-device sim and real sharded dispatch (subprocess),
so the demotion / re-dispatch / kill-switch paths tested here are the
production ones.  Failure model and thresholds: ``docs/resilience.md``.
"""

import threading

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from helpers import run_subprocess

from repro.runtime import (ChunkedScheduler, EwmaController, KillSwitch,
                           ServeGuard, StreamingPipeline, VirtualClock,
                           fallback_from_store, make_serial_sim_builder,
                           sim_skew_groups)
from repro.runtime.simulate import (FakeDevice, FaultEvent, FaultInjector,
                                    FaultPlan, GroupFailure)
from repro.core.hetero import DeviceGroup


def make_sim(groups=None, *, plan=None, per_row_s=0.0005, skew=3,
             controller=None, **sched_kw):
    """Scheduler + injector on a fresh virtual clock (one line per test)."""
    clock = VirtualClock()
    groups = groups or sim_skew_groups(skew=skew)
    injector = FaultInjector(plan or FaultPlan(), groups)
    sched = ChunkedScheduler(
        make_serial_sim_builder(per_row_s, clock=clock, injector=injector),
        groups, clock=clock,
        controller=controller or EwmaController(len(groups), min_share=0.02),
        **sched_kw)
    injector.attach(sched)
    return sched, injector, clock


def drive(sched, injector, batch, steps):
    recs = []
    for _ in range(steps):
        injector.tick()
        recs.append(sched.step(batch))
    return recs


def three_equal_groups():
    return [DeviceGroup(n, [FakeDevice()] * 4) for n in ("a", "b", "c")]


BATCH = {"x": np.zeros((64, 4), np.float32)}


# -- FaultPlan / FaultEvent / FaultInjector -------------------------------------

def test_fault_event_validation():
    with pytest.raises(ValueError):
        FaultEvent(step=0, kind="explode", group=0)
    with pytest.raises(ValueError):
        FaultEvent(step=-1, kind="kill", group=0)
    with pytest.raises(ValueError):
        FaultEvent(step=0, kind="kill", group=-1)
    with pytest.raises(ValueError):
        FaultEvent(step=0, kind="slow", group=0, factor=0.0)


def test_fault_plan_chaining_sorts_events():
    plan = (FaultPlan().recover(0, at=9).kill(0, at=3)
            .slow(1, at=5, factor=2.0).transient(1, at=1))
    assert [e.step for e in plan.events] == [1, 3, 5, 9]
    assert plan.last_step == 9
    assert [e.kind for e in plan.at(5)] == ["slow"]
    assert plan.at(7) == []


def test_injector_rejects_event_for_unknown_group():
    with pytest.raises(ValueError):
        FaultInjector(FaultPlan().kill(5, at=0), sim_skew_groups())


def test_injector_kill_persists_until_recover():
    groups = sim_skew_groups()
    inj = FaultInjector(FaultPlan().kill(0, at=1).recover(0, at=3), groups)
    inj.tick()                                    # step 0: healthy
    inj.check(groups[0])
    inj.tick()                                    # step 1: killed
    with pytest.raises(GroupFailure):
        inj.check(groups[0])
    inj.check(groups[1])                          # other group unaffected
    inj.tick()                                    # step 2: still dead
    with pytest.raises(GroupFailure):
        inj.check(groups[0])
    inj.tick()                                    # step 3: recovered
    inj.check(groups[0])


def test_injector_transient_raises_exactly_once():
    groups = sim_skew_groups()
    inj = FaultInjector(FaultPlan().transient(1, at=0), groups)
    inj.tick()
    with pytest.raises(GroupFailure):
        inj.check(groups[1])
    inj.check(groups[1])                          # healthy on the retry


def test_injector_slow_factor_scales_sim_times_exactly():
    group = [DeviceGroup("solo", [FakeDevice()] * 4)]
    plan = FaultPlan().slow(0, at=1, factor=2.5).recover(0, at=2)
    sched, inj, _ = make_sim(group, plan=plan,
                             controller=EwmaController(1))
    recs = drive(sched, inj, BATCH, 3)
    t0, t1, t2 = (r["t_group"][0] for r in recs)
    assert t1 == pytest.approx(2.5 * t0)          # exact scaling, no noise
    assert t2 == pytest.approx(t0)                # recover clears the factor


def test_injector_wrap_repeats_dispatch_for_slow():
    calls = []

    def builder(group):
        def fn(chunk):
            calls.append(group.name)
            return chunk
        return fn

    groups = sim_skew_groups()
    inj = FaultInjector(FaultPlan().slow(0, at=0, factor=3.0), groups)
    wrapped = inj.wrap(builder)(groups[0])
    inj.tick()
    wrapped({"x": np.zeros(4)})
    assert len(calls) == 3                        # ceil(3.0) repeats


def test_injector_wrap_raises_for_killed_group():
    groups = sim_skew_groups()
    inj = FaultInjector(FaultPlan().kill(1, at=0), groups)
    wrapped = inj.wrap(lambda g: lambda c: c)(groups[1])
    inj.tick()
    with pytest.raises(GroupFailure):
        wrapped({"x": np.zeros(4)})


# -- EwmaController elastic membership ------------------------------------------

def test_drop_zeroes_share_and_renormalizes_survivors():
    c = EwmaController(3, shares=np.array([0.5, 0.3, 0.2]), min_share=0.02)
    c.drop(1)
    assert c.shares[1] == 0.0
    assert c.shares.sum() == pytest.approx(1.0)
    # survivors keep their relative proportion (0.5 : 0.2), modulo the
    # min-share floor the simplex projection maintains
    assert c.shares[0] / c.shares[2] == pytest.approx(2.5, rel=0.05)
    assert list(c.live) == [True, False, True]


def test_drop_is_idempotent_and_protects_last_group():
    c = EwmaController(2)
    c.drop(0)
    before = c.shares.copy()
    c.drop(0)                                     # no-op
    np.testing.assert_array_equal(c.shares, before)
    with pytest.raises(RuntimeError):
        c.drop(1)                                 # last live group
    with pytest.raises(IndexError):
        c.drop(7)


def test_restore_readmits_and_is_idempotent():
    c = EwmaController(2, min_share=0.02)
    c.drop(0)
    c.restore(0)
    assert list(c.live) == [True, True]
    assert c.shares[0] == pytest.approx(0.5)      # default: 1 / n_groups
    assert c.shares.sum() == pytest.approx(1.0)
    before = c.shares.copy()
    c.restore(0)                                  # no-op
    np.testing.assert_array_equal(c.shares, before)


def test_update_ignores_dead_groups():
    c = EwmaController(3, min_share=0.02, damping=1.0)
    c.drop(2)
    # group 1 twice as slow as group 0; dead group's entry is garbage
    c.update([1.0, 2.0, 123.0])
    assert c.shares[2] == 0.0
    assert c.shares.sum() == pytest.approx(1.0)
    assert c.shares[0] > c.shares[1]


# -- ChunkedScheduler: elastic membership + redispatch --------------------------

def test_kill_at_dispatch_loses_no_rows():
    sched, inj, _ = make_sim(plan=FaultPlan().kill(0, at=2))
    recs = drive(sched, inj, BATCH, 5)
    for rec in recs:
        assert sum(rec["rows_completed"]) == 64   # every batch completes
    killed = recs[2]
    assert killed["failures"] and killed["redispatched_rows"] > 0
    assert killed["rows_completed"][0] == 0       # all on the survivor
    for rec in recs[3:]:
        assert rec["live"] == [False, True]
        assert rec["rows"][0] == 0


def test_kill_at_drain_redispatches_unconfirmed_chunks():
    # the failure surfaces at block time (result poisoned), not dispatch
    class PoisonedResult:
        def block_until_ready(self):
            raise GroupFailure("died while computing")

    armed = {"on": False}

    def builder(group):
        def fn(chunk):
            if group.name == "fast" and armed["on"]:
                return PoisonedResult()
            return chunk["x"]                     # plain ndarray: no block
        return fn

    sched = ChunkedScheduler(builder, sim_skew_groups(),
                             controller=EwmaController(2, min_share=0.02))
    rec = sched.step(BATCH, rebalance=False)
    assert not rec["failures"]
    armed["on"] = True
    rec = sched.step(BATCH, rebalance=False)
    assert "fast" in rec["failures"]
    assert sum(rec["rows_completed"]) == 64
    assert rec["rows_completed"][0] == 0


def test_plan_cache_is_keyed_by_membership():
    """Regression: ``_plans`` used to key on batch rows alone, so a
    batch size seen before a drop could replay its stale plan and
    dispatch rows to the dead group."""
    sched, inj, _ = make_sim()
    sched.step(BATCH)                             # cache the 2-live plan
    sched.drop_group(0)
    rec = sched.step(BATCH)
    assert rec["rows"][0] == 0                    # stale plan not replayed
    assert rec["rows_completed"] == [0, 64]
    sched.restore_group(0)
    rec = sched.step(BATCH)
    assert rec["rows"][0] > 0                     # pre-drop key valid again


def test_transient_failure_demotes_until_recover():
    plan = FaultPlan().transient(1, at=2).recover(1, at=5)
    sched, inj, _ = make_sim(plan=plan)
    recs = drive(sched, inj, BATCH, 8)
    assert recs[2]["failures"]                    # the transient step
    assert recs[3]["live"] == [True, False]       # demoted, not retried
    assert recs[5]["live"] == [True, True]        # recover re-admits
    assert all(sum(r["rows_completed"]) == 64 for r in recs)


def test_kill_then_recover_converges_back_to_oracle():
    plan = FaultPlan().kill(0, at=6).recover(0, at=10)
    sched, inj, _ = make_sim(plan=plan, per_row_s=0.0004)
    drive(sched, inj, {"x": np.zeros((128, 4), np.float32)}, 30)
    # 3:1 skew: the fast group's share returns to the 0.75 oracle
    assert sched.shares[0] == pytest.approx(0.75, abs=0.05)
    assert list(sched.live) == [True, True]


def test_all_groups_failing_raises():
    sched, inj, _ = make_sim(plan=FaultPlan().kill(0, at=0).kill(1, at=0))
    inj.tick()
    with pytest.raises(RuntimeError, match="failed"):
        sched.step(BATCH)


def test_slow_fault_shifts_shares_away_from_straggler():
    plan = FaultPlan().slow(0, at=5, factor=12.0)
    sched, inj, _ = make_sim(plan=plan, skew=1, per_row_s=0.0004)
    drive(sched, inj, {"x": np.zeros((128, 4), np.float32)}, 25)
    # equal groups, then group 0 degrades 12x: its share collapses
    assert sched.shares[0] < 0.2, sched.shares
    assert list(sched.live) == [True, True]       # slow is not dead


def test_combined_kill_and_slow_faults():
    groups = three_equal_groups()
    plan = FaultPlan().slow(1, at=3, factor=6.0).kill(2, at=5)
    sched, inj, _ = make_sim(groups, plan=plan, per_row_s=0.0004)
    recs = drive(sched, inj, {"x": np.zeros((96, 4), np.float32)}, 20)
    assert recs[-1]["live"] == [True, True, False]
    assert all(sum(r["rows_completed"]) == 96 for r in recs)
    # group 0 (healthy) ends with the dominant share over slowed group 1
    assert sched.shares[0] > sched.shares[1] > 0
    assert sched.shares[2] == 0.0


def test_cascading_kills_leave_last_group_serving():
    groups = three_equal_groups()
    plan = FaultPlan().kill(0, at=2).kill(1, at=4)
    sched, inj, _ = make_sim(groups, plan=plan)
    recs = drive(sched, inj, {"x": np.zeros((96, 4), np.float32)}, 7)
    assert recs[-1]["live"] == [False, False, True]
    assert recs[-1]["rows_completed"] == [0, 0, 96]
    assert all(sum(r["rows_completed"]) == 96 for r in recs)


def test_failure_step_skips_controller_update():
    groups = three_equal_groups()
    sched, inj, _ = make_sim(groups, plan=FaultPlan().kill(2, at=3))
    batch = {"x": np.zeros((96, 4), np.float32)}
    drive(sched, inj, batch, 3)
    ratio_before = sched.shares[0] / sched.shares[1]
    inj.tick()
    sched.step(batch)                             # the kill step
    # survivors renormalize but the EWMA must not move on tainted times
    assert sched.shares[0] / sched.shares[1] == pytest.approx(ratio_before)


def test_dispatch_timeout_demotes_hung_group():
    release = threading.Event()

    class HangingResult:
        def block_until_ready(self):
            release.wait()                        # hung until test cleanup

    armed = {"on": False}

    def builder(group):
        def fn(chunk):
            if group.name == "fast" and armed["on"]:
                return HangingResult()
            return chunk["x"]
        return fn

    sched = ChunkedScheduler(builder, sim_skew_groups(),
                             controller=EwmaController(2, min_share=0.02),
                             dispatch_timeout_s=0.05)
    try:
        rec = sched.step(BATCH, rebalance=False)
        assert not rec["failures"]
        armed["on"] = True
        rec = sched.step(BATCH, rebalance=False)
        assert "fast" in rec["failures"]
        assert "timed out" in rec["failures"]["fast"]
        assert rec["rows_completed"] == [0, 64]   # orphans re-dispatched
        assert list(sched.live) == [False, True]
    finally:
        release.set()                             # unblock the worker
        sched.close()


def test_fault_trajectories_are_deterministic():
    def run():
        plan = (FaultPlan().slow(1, at=2, factor=4.0).kill(0, at=5)
                .recover(0, at=9).recover(1, at=9))
        sched, inj, clock = make_sim(plan=plan)
        recs = drive(sched, inj, BATCH, 14)
        return ([r["t_step"] for r in recs], [r["rows"] for r in recs],
                [r["live"] for r in recs], clock.now())

    assert run() == run()                         # bit-identical replays


# -- KillSwitch state machine ---------------------------------------------------

def test_killswitch_warms_up_then_trips_after_patience():
    ks = KillSwitch(threshold=1.5, patience=3, min_samples=4)
    assert all(ks.observe(1.0) == "ok" for _ in range(4))
    assert ks.baseline == pytest.approx(1.0)
    assert ks.observe(2.0) == "regressing"
    assert ks.observe(2.0) == "regressing"
    assert ks.observe(2.0) == "trip"
    assert ks.tripped and ks.n_trips == 1


def test_killswitch_healthy_step_resets_streak():
    ks = KillSwitch(threshold=1.5, patience=2, min_samples=2)
    ks.observe(1.0), ks.observe(1.0)
    assert ks.observe(2.0) == "regressing"
    assert ks.observe(1.0) == "ok"                # streak broken
    assert ks.observe(2.0) == "regressing"        # needs patience again
    assert not ks.tripped


def test_killswitch_rearms_after_cooldown_probes():
    ks = KillSwitch(threshold=1.5, patience=1, cooldown=2, min_samples=2)
    ks.observe(1.0), ks.observe(1.0)
    assert ks.observe(5.0) == "trip"
    assert ks.observe(1.0) == "cooling"
    assert ks.observe(1.0) == "rearm"
    assert not ks.tripped
    assert ks.observe(1.0) == "ok"


def test_killswitch_unhealthy_probe_restarts_cooldown():
    ks = KillSwitch(threshold=1.5, patience=1, cooldown=2, min_samples=2)
    ks.observe(1.0), ks.observe(1.0)
    ks.observe(5.0)
    assert ks.observe(1.0) == "cooling"
    assert ks.observe(5.0) == "cooling"           # fallback still unhealthy
    assert ks.tripped                             # ... so no re-arm yet
    assert ks.observe(1.0) == "cooling"
    assert ks.observe(1.0) == "rearm"


def test_killswitch_regressions_never_enter_baseline():
    # a slow regression must not drag the baseline up and evade the trip
    ks = KillSwitch(threshold=1.5, patience=10, window=4, min_samples=2)
    ks.observe(1.0), ks.observe(1.0)
    for _ in range(8):
        ks.observe(1.8)                           # regressing, not stored
    assert ks.baseline == pytest.approx(1.0)


def test_killswitch_reset_baseline_forgets_history():
    ks = KillSwitch(min_samples=2)
    ks.observe(1.0), ks.observe(1.0)
    assert ks.baseline is not None
    ks.reset_baseline()
    assert ks.baseline is None
    assert ks.observe(99.0) == "ok"               # no baseline, no verdict


def test_killswitch_validates_parameters():
    with pytest.raises(ValueError):
        KillSwitch(threshold=0.9)
    with pytest.raises(ValueError):
        KillSwitch(patience=0)


# -- ServeGuard -----------------------------------------------------------------

class PoisonedController(EwmaController):
    """Scripted controller regression: from step ``poison_from`` on it
    pushes the shares to a fixed bad split — the failure mode the kill
    switch exists for (plausible per-step behavior, bad trajectory)."""

    def __init__(self, n, poison_from, bad, **kw):
        super().__init__(n, **kw)
        self.poison_from = poison_from
        self.bad = np.asarray(bad, np.float64)
        self.updates = 0

    def update(self, times, rows=None):
        self.updates += 1
        if self.updates >= self.poison_from:
            self.shares = self.bad.copy()
            return self.shares
        return super().update(times, rows=rows)


def make_guarded(poison_from=8, fallback=(0.75, 0.25), **switch_kw):
    clock = VirtualClock()
    groups = sim_skew_groups(skew=3)
    ctrl = PoisonedController(2, poison_from, [0.15, 0.85], min_share=0.02)
    sched = ChunkedScheduler(make_serial_sim_builder(0.0005, clock=clock),
                             groups, controller=ctrl, clock=clock)
    kw = dict(threshold=1.5, patience=5, cooldown=3)
    kw.update(switch_kw)
    guard = ServeGuard(sched, switch=KillSwitch(**kw),
                       fallback=None if fallback is None
                       else np.asarray(fallback))
    return guard, sched


def test_guard_trips_within_patience_and_pins_fallback():
    guard, sched = make_guarded()
    recs = [guard.step(BATCH) for _ in range(20)]
    verdicts = [r["guard"]["verdict"] for r in recs]
    trip = verdicts.index("trip")
    # exactly patience=5 consecutive regressing steps before the trip
    assert verdicts[trip - 4:trip] == ["regressing"] * 4
    healthy = recs[trip - 5]["t_step"]            # last pre-regression step
    # fallback restores the known-good level within one step of the trip
    assert recs[trip + 1]["t_step"] <= 1.10 * healthy
    np.testing.assert_allclose(recs[trip + 1]["shares"], [0.75, 0.25])


def test_guard_rearm_returns_control_to_controller():
    guard, sched = make_guarded()
    recs = [guard.step(BATCH) for _ in range(40)]
    verdicts = [r["guard"]["verdict"] for r in recs]
    assert "rearm" in verdicts
    # the poisoned controller regresses again after re-arm -> re-trip
    assert guard.switch.n_trips >= 2


def test_guard_learns_fallback_when_none_given():
    guard, sched = make_guarded(fallback=None)
    recs = [guard.step(BATCH) for _ in range(20)]
    trip = [r["guard"]["verdict"] for r in recs].index("trip")
    pinned = recs[trip + 1]["shares"]
    # the learned snapshot is the best split the controller visited —
    # near the 3:1 oracle, nowhere near the poisoned [0.15, 0.85]
    assert pinned[0] == pytest.approx(0.75, abs=0.05)


def test_guard_membership_change_resets_baseline():
    clock = VirtualClock()
    groups = sim_skew_groups(skew=3)
    plan = FaultPlan().kill(0, at=6)
    inj = FaultInjector(plan, groups)
    sched = ChunkedScheduler(
        make_serial_sim_builder(0.0005, clock=clock, injector=inj),
        groups, controller=EwmaController(2, min_share=0.02), clock=clock)
    guard = ServeGuard(sched, switch=KillSwitch(threshold=1.3, patience=2))
    inj.attach(guard)
    recs = []
    for _ in range(14):
        inj.tick()
        recs.append(guard.step(BATCH))
    assert recs[6]["guard"]["verdict"] == "membership-change"
    # survivor-only steps are ~3-4x slower, but the guard must NOT trip:
    # the regression is a real capacity loss, not a controller failure
    assert guard.switch.n_trips == 0
    assert all(sum(r["rows_completed"]) == 64 for r in recs)


def test_guard_projects_fallback_onto_live_membership():
    guard, sched = make_guarded()
    sched.controller.drop(0)
    shares = guard._fallback_shares()
    assert shares[0] == 0.0
    assert shares[1] == pytest.approx(1.0)


def test_fallback_from_store_resolves_tuned_fraction():
    class Rec:
        best_config = {"fraction": 70}

    class Store:
        def best_record(self, space, workload):
            assert space == "stream_split"
            return Rec()

    np.testing.assert_allclose(fallback_from_store(Store(), {}),
                               [0.7, 0.3])
    assert fallback_from_store(None, {}) is None
    assert fallback_from_store(Store(), {}, n_groups=3) is None


# -- StreamingPipeline integration ----------------------------------------------

def test_pipeline_with_guard_survives_kill_and_counts_rows():
    clock = VirtualClock()
    groups = sim_skew_groups(skew=3)
    plan = FaultPlan().kill(0, at=5)
    inj = FaultInjector(plan, groups)
    pipe = StreamingPipeline(
        make_serial_sim_builder(0.0005, clock=clock, injector=inj),
        groups, controller=EwmaController(2, min_share=0.02),
        clock=clock, guard=True)
    inj.attach(pipe.guard)
    for _ in range(12):
        inj.tick()
        pipe.run([BATCH])
    s = pipe.summary()
    assert s["batches"] == 12
    assert s["rows_total"] == 12 * 64             # no lost rows, ever
    assert s["live_final"] == [False, True]
    assert s["failures"] == 1
    assert s["guard_trips"] == 0                  # capacity loss != trip


# -- sim / real dispatch agreement ----------------------------------------------

def test_same_fault_plan_drives_sim_and_real_dispatch_identically():
    """The acceptance criterion for the fault layer: one ``FaultPlan``
    produces the same membership / completion trajectory against the
    serial-device sim and against real sharded dispatch (8 forced host
    devices, subprocess-isolated)."""
    out = run_subprocess("""
import numpy as np, jax
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core.hetero import DeviceGroup
from repro.runtime import (ChunkedScheduler, EwmaController, VirtualClock,
                           make_serial_sim_builder)
from repro.runtime.simulate import FaultInjector, FaultPlan

def scripted_plan():
    return (FaultPlan().transient(1, at=2).recover(1, at=3)
            .kill(0, at=5).recover(0, at=8))

def trajectory(sched, inj, steps=10):
    batch = {"x": np.zeros((64, 16), np.float32)}
    out = []
    for _ in range(steps):
        inj.tick()
        rec = sched.step(batch)
        out.append((rec["live"], sorted(rec["rows_completed"]),
                    bool(rec["failures"])))
        assert sum(rec["rows_completed"]) == 64
    return out

# -- sim side
clock = VirtualClock()
groups = [DeviceGroup("a", [object()] * 4), DeviceGroup("b", [object()] * 4)]
inj = FaultInjector(scripted_plan(), groups)
sched = ChunkedScheduler(
    make_serial_sim_builder(0.0005, clock=clock, injector=inj), groups,
    controller=EwmaController(2, min_share=0.02), clock=clock)
inj.attach(sched)
sim_traj = trajectory(sched, inj)

# -- real side: the same plan wraps a jitted sharded step
devs = jax.devices()
rgroups = [DeviceGroup("a", devs[:4]), DeviceGroup("b", devs[4:])]
rinj = FaultInjector(scripted_plan(), rgroups)

def builder(group):
    mesh = group.mesh()
    sh = NamedSharding(mesh, P("data"))
    f = jax.jit(lambda v: v.sum(axis=1), in_shardings=sh)
    def fn(chunk):
        return f(jax.device_put(chunk["x"], sh))
    return fn

rsched = ChunkedScheduler(rinj.wrap(builder), rgroups,
                          controller=EwmaController(2, min_share=0.02))
rinj.attach(rsched)
real_traj = trajectory(rsched, rinj)

# identical membership + failure trajectory; rows land per the live set
assert [t[0] for t in sim_traj] == [t[0] for t in real_traj], (
    sim_traj, real_traj)
assert [t[2] for t in sim_traj] == [t[2] for t in real_traj]
print("SIM_REAL_FAULT_OK")
""")
    assert "SIM_REAL_FAULT_OK" in out


# -- property tests: controller invariants under arbitrary sequences ------------

def _apply_ops(ctrl, rng, n_ops):
    """Random interleaving of drop / restore / update ops; returns the
    indices currently live."""
    n = ctrl.n_groups
    for _ in range(n_ops):
        op = rng.integers(0, 3)
        gi = int(rng.integers(0, n))
        if op == 0:
            if ctrl.live[gi] and ctrl.n_live > 1:
                ctrl.drop(gi)
        elif op == 1:
            ctrl.restore(gi)
        else:
            ctrl.update(rng.uniform(0.1, 5.0, n))


@settings(max_examples=40)
@given(seed=st.integers(min_value=0, max_value=10**6))
def test_property_shares_stay_on_simplex_under_drop_restore(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(2, 6))
    ctrl = EwmaController(n, min_share=0.02)
    _apply_ops(ctrl, rng, n_ops=int(rng.integers(1, 30)))
    assert ctrl.shares.sum() == pytest.approx(1.0)
    assert ctrl.live.any()
    for gi in range(n):
        if ctrl.live[gi]:
            assert ctrl.shares[gi] >= ctrl.min_share - 1e-12
        else:
            assert ctrl.shares[gi] == 0.0         # exactly, not approximately


@settings(max_examples=25)
@given(seed=st.integers(min_value=0, max_value=10**6))
def test_property_plan_never_assigns_rows_to_dropped_group(seed):
    rng = np.random.default_rng(seed)
    groups = three_equal_groups()
    sched, inj, _ = make_sim(groups)
    _apply_ops(sched.controller, rng, n_ops=int(rng.integers(1, 20)))
    n = int(rng.integers(3, 17)) * 12             # >= one row per device
    rows = sched.plan_rows(n)
    assert sum(rows) == n
    for gi in range(3):
        if not sched.controller.live[gi]:
            assert rows[gi] == 0
        else:
            assert rows[gi] >= len(groups[gi].devices)
    # and a real step honors the plan: no dispatch on dead groups
    rec = sched.step({"x": np.zeros((n, 4), np.float32)}, rebalance=False)
    for gi in range(3):
        if not sched.controller.live[gi]:
            assert rec["rows_completed"][gi] == 0
