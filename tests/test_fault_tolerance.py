"""Fault tolerance: injected failure -> restart -> bitwise-identical result."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.dist.fault import run_with_restarts
from repro.launch.train import train_loop

CFG = configs.get("qwen2.5-3b").smoke()
KW = dict(steps_total=12, batch=4, seq_len=32, ckpt_every=4, log_every=0)


@pytest.fixture(scope="module")
def uninterrupted(tmp_path_factory):
    d = tmp_path_factory.mktemp("ckpt_clean")
    return train_loop(CFG, ckpt_dir=d, **KW)


def test_injected_failure_then_restart_bitwise(tmp_path, uninterrupted):
    report = run_with_restarts(
        lambda **kw: train_loop(CFG, **kw),
        ckpt_dir=tmp_path, fail_at_step=7, **KW)
    assert report.attempts == 2
    assert "injected failure" in report.failures[0]
    # resumed from the step-4 checkpoint
    assert report.result["resumed_from"] == 4
    # final parameters bitwise equal to the uninterrupted run
    a = jax.tree.leaves(report.result["state"]["params"])
    b = jax.tree.leaves(uninterrupted["state"]["params"])
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    # and the post-restart loss trajectory matches exactly
    assert report.result["losses"][-1] == uninterrupted["losses"][-1]


def test_restart_gives_up_after_max_attempts(tmp_path):
    def always_fails(**kw):
        raise RuntimeError("node down")

    with pytest.raises(RuntimeError):
        run_with_restarts(always_fails, max_restarts=2)


def test_training_reduces_loss():
    out = train_loop(CFG, steps_total=40, batch=8, seq_len=64,
                     log_every=0)
    first = np.mean(out["losses"][:5])
    last = np.mean(out["losses"][-5:])
    assert last < first - 0.01


def test_training_with_int8_grad_compression():
    """Error-feedback int8 gradient compression trains comparably."""
    from repro.dist.sharding import ShardingConfig
    scfg = ShardingConfig(data_axes=("data",), model_axes=(), fsdp_axes=(),
                          remat=False, grad_compression="int8")
    from repro.launch.mesh import make_host_mesh
    out = train_loop(CFG, steps_total=25, batch=8, seq_len=64, log_every=0,
                     mesh=make_host_mesh(1), scfg=scfg)
    base = train_loop(CFG, steps_total=25, batch=8, seq_len=64, log_every=0,
                      mesh=make_host_mesh(1))
    assert abs(out["final_loss"] - base["final_loss"]) < 0.1
    assert out["losses"][-1] < out["losses"][0]
