"""Request-level serving (repro.serve): lifecycle, batching, admission,
fault drills and batcher tuning.

Every engine-level test runs the deterministic sim rig
(``make_sim_engine``: skewed fake groups + ``VirtualClock``), so
latency numbers are exact simulated instants and journals are
bit-identical run to run.  The serving invariants under test:

  * lifecycle — requests move through the explicit state machine;
    illegal transitions raise;
  * continuous batching — same-shape coalescing, priority order,
    alignment padding, the coalesce hold, per-request spans;
  * admission — the documented shed policy (queue_full / degraded /
    infeasible), bounded retries, post-shrink re-evaluation;
  * zero lost requests — a mid-run group kill (with transients forcing
    the retry path) leaves every admitted request terminally completed
    or explicitly shed with a journaled reason;
  * tuning — the batcher knobs tune through ``TuningSession`` inside
    the ~5% envelope, and a repeat workload re-serves from the
    ``TuningStore`` with zero new measurements.
"""

import json

import numpy as np
import pytest

from helpers import run_subprocess

from repro.obs import Observer
from repro.obs.journal import EVENT_KINDS, validate_events
from repro.runtime import TuningStore
from repro.runtime.simulate import FaultPlan
from repro.serve import (AdmissionController, BatcherConfig,
                         ContinuousBatcher, Request, RequestClass,
                         RequestSource, ServiceEstimator, SloPolicy,
                         batcher_space, make_sim_engine, tune_batcher)

CAP_ROWS_PER_S = (4 + 4 / 3) / 4e-4     # the sim rig's drain rate
CAP_RPS = CAP_ROWS_PER_S / 2.1          # ~rows per request in the mix


def _req(rid=0, rows=1, t=0.0, slo=1.0, priority=0, shape=(32, 16)):
    return Request(rid=rid, rows=rows, prompt_len=shape[0], gen=shape[1],
                   t_arrival=t, slo_s=slo, priority=priority)


# -- lifecycle ---------------------------------------------------------------

def test_request_lifecycle_happy_path():
    r = _req()
    r.admit(0.1).batched()
    r.dispatched(0.2)
    r.completed(0.3)
    assert r.status == "completed" and r.terminal
    assert r.queue_delay_s == pytest.approx(0.2)
    assert r.service_s == pytest.approx(0.1)
    assert r.latency_s == pytest.approx(0.3)
    assert r.slo_ok is True
    rec = r.record()
    assert rec["status"] == "completed" and rec["shed_reason"] is None


def test_request_retry_keeps_first_admit_and_restamps_dispatch():
    r = _req()
    r.admit(0.1).batched()
    r.dispatched(0.2)
    r.failed()
    assert r.t_dispatch is None
    r.retry(0.4)
    assert r.retries == 1 and r.status == "admitted"
    assert r.t_admit == pytest.approx(0.1)
    r.batched()
    r.dispatched(0.5)
    r.completed(0.6)
    assert r.queue_delay_s == pytest.approx(0.5)


def test_request_illegal_transitions_raise():
    r = _req()
    with pytest.raises(ValueError, match="illegal transition"):
        r.completed(1.0)
    r.admit(0.0)
    with pytest.raises(ValueError, match="illegal transition"):
        r.dispatched(0.1)                 # must be batched first
    r.shed(0.2, "queue_full")
    assert r.terminal and r.shed_reason == "queue_full"
    with pytest.raises(ValueError, match="illegal transition"):
        r.admit(0.3)                      # terminal states are final


def test_source_is_deterministic_and_time_ordered():
    kw = dict(n_requests=50, rate_rps=100.0, seed=9)
    a, b = RequestSource(**kw), RequestSource(**kw)
    assert [r.record() for r in a.requests] \
        == [r.record() for r in b.requests]
    times = [r.t_arrival for r in a.requests]
    assert times == sorted(times) and times[0] > 0
    got = a.take_until(times[9])
    assert [r.rid for r in got] == list(range(10))
    assert a.remaining == 40
    assert a.next_time() == pytest.approx(times[10])


# -- continuous batcher ------------------------------------------------------

def test_batcher_coalesces_same_shape_in_priority_order():
    b = ContinuousBatcher(BatcherConfig(max_batch_rows=8,
                                        coalesce_window_s=0.0))
    lo = _req(rid=0, rows=2, priority=0)
    hi = _req(rid=1, rows=2, priority=1)
    other = _req(rid=2, rows=2, shape=(64, 8))
    for r in (lo, hi, other):
        b.push(r.admit(0.0))
    fb = b.form(1.0, align=1)
    # the high-priority request heads the queue and pins the shape;
    # the (64, 8) request must wait for a later batch
    assert [r.rid for r in fb.requests] == [1, 0]
    assert fb.shape == (32, 16) and fb.rows == 4
    assert b.queued_rows == 2
    fb2 = b.form(1.0, align=1)
    assert [r.rid for r in fb2.requests] == [2]


def test_batcher_respects_row_cap_and_alignment():
    b = ContinuousBatcher(BatcherConfig(max_batch_rows=4,
                                        coalesce_window_s=0.0))
    for i in range(3):
        b.push(_req(rid=i, rows=2).admit(0.0))
    fb = b.form(1.0, align=8)
    assert fb.rows == 4                    # 2 requests of 2; third waits
    assert fb.padded_rows == 8             # padded to the align multiple
    assert fb.spans == [(0, 2), (2, 2)]    # contiguous per-request spans


def test_batcher_oversized_request_dispatches_alone():
    b = ContinuousBatcher(BatcherConfig(max_batch_rows=4,
                                        coalesce_window_s=0.0))
    b.push(_req(rid=0, rows=9).admit(0.0))
    fb = b.form(1.0, align=1)
    assert [r.rid for r in fb.requests] == [0] and fb.rows == 9


def test_batcher_coalesce_hold_then_flush():
    b = ContinuousBatcher(BatcherConfig(max_batch_rows=64,
                                        coalesce_window_s=0.010))
    b.push(_req(rid=0, rows=2).admit(1.0))
    # another arrival is due within the window: hold until admit+window
    hold = b.form(1.001, next_arrival=1.005, align=1)
    assert hold == pytest.approx(1.010)
    # flush (source exhausted) overrides the hold
    fb = b.form(1.001, next_arrival=1.005, align=1, flush=True)
    assert fb.rows == 2
    # no arrival inside the window: dispatch immediately
    b.push(_req(rid=1, rows=2).admit(2.0))
    fb2 = b.form(2.001, next_arrival=5.0, align=1)
    assert fb2.rows == 2


# -- admission ---------------------------------------------------------------

def test_admission_queue_backpressure():
    adm = AdmissionController(SloPolicy(max_queue_rows=4))
    assert adm.admit(_req(rows=2), 0.0, queued_rows=0) is None
    assert adm.admit(_req(rows=2), 0.0, queued_rows=3) == "queue_full"


def test_admission_degraded_sheds_by_priority():
    adm = AdmissionController(SloPolicy(degraded_shed_priority=0))
    lo, hi = _req(rows=1, priority=0), _req(rows=1, priority=1)
    assert adm.admit(lo, 0.0, 0, degraded=True) == "degraded"
    assert adm.admit(hi, 0.0, 0, degraded=True) is None
    assert adm.admit(lo, 0.0, 0, degraded=False) is None


def test_admission_feasibility_uses_live_estimate():
    est = ServiceEstimator()
    adm = AdmissionController(SloPolicy(max_queue_rows=10_000),
                              estimator=est)
    hopeless = _req(rows=1, t=0.0, slo=0.5)
    # estimator not ready: feasibility is advisory, request admitted
    assert adm.admit(hopeless, 0.0, queued_rows=5000) is None
    est.observe(t_step=1.0, rows=1000)     # 1 ms per row, now ready
    # 5000 queued rows ahead -> ~5 s eta against a 0.5 s deadline
    assert adm.admit(hopeless, 0.0, queued_rows=5000) == "infeasible"
    assert adm.admit(_req(rows=1, t=0.0, slo=10.0), 0.0, 5000) is None


def test_admission_retry_bounds_and_reevaluation():
    est = ServiceEstimator()
    est.observe(1.0, 1000)                  # 1 ms/row
    adm = AdmissionController(SloPolicy(max_retries=1), estimator=est)
    r = _req(rows=1, slo=10.0)
    assert adm.retry_or_shed(r, 0.0, 0) is None
    r.retries = 1
    assert adm.retry_or_shed(r, 0.0, 0) == "retries_exhausted"
    # capacity shrink: rescale doubles per-row time; a queue of
    # tight-deadline requests behind a long backlog sheds infeasible
    queue = [_req(rid=i, rows=400, t=0.0, slo=0.5) for i in range(3)]
    est.rescale(2.0)                        # 2 ms/row now
    sheds = adm.reevaluate(queue, now=0.0)
    # first fits (0.8 s eta > 0.5 deadline -> actually infeasible too)
    assert [s[1] for s in sheds] == ["infeasible"] * 3


# -- engine ------------------------------------------------------------------

def test_engine_under_capacity_completes_everything():
    eng = make_sim_engine(n_requests=150, rate_rps=0.3 * CAP_RPS, seed=5)
    s = eng.run()
    assert s["completed"] == 150 and s["shed"] == 0
    assert s["slo_violations"] == 0
    # the decomposition adds up per request
    for r in eng.done:
        assert r.latency_s == pytest.approx(r.queue_delay_s + r.service_s)
    assert s["e2e_p99"] < 0.05


def test_engine_over_capacity_sheds_and_bounds_admitted_latency():
    eng = make_sim_engine(n_requests=400, rate_rps=3.0 * CAP_RPS, seed=6)
    s = eng.run()
    assert s["shed"] > 0 and "queue_full" in s["shed_reasons"]
    assert s["completed"] + s["shed"] == 400
    # admitted latency bounded by the backpressure bound, not the
    # offered load: queue_depth_rows of backlog at drain rate (x2)
    bound = 2 * 256 / CAP_ROWS_PER_S + 0.01
    assert s["e2e_p99"] <= bound


def test_engine_completion_instants_come_from_row_spans():
    eng = make_sim_engine(n_requests=60, rate_rps=0.5 * CAP_RPS, seed=8)
    eng.run()
    done = [r for r in eng.done if r.status == "completed"]
    # per-row attribution: completion instants inside a batch differ
    # from a single step-end stamp whenever chunks finish at different
    # simulated instants; at minimum every instant is dispatch-coherent
    for r in done:
        assert r.t_done > r.t_dispatch >= r.t_admit >= r.t_arrival


def test_engine_zero_lost_requests_under_kill_and_identical_journals():
    plan = (FaultPlan().transient(0, at=3).transient(1, at=3)
            .kill(0, at=6).recover(0, at=12))
    cfg = BatcherConfig(max_batch_rows=16, coalesce_window_s=0.0)

    def drill():
        obs = Observer()
        eng = make_sim_engine(n_requests=150, rate_rps=0.5 * CAP_RPS,
                              seed=31, fault_plan=plan, guard=True,
                              observer=obs, batcher_config=cfg)
        return eng.run(), obs

    s1, obs1 = drill()
    s2, obs2 = drill()
    # zero lost: every request is terminal, sheds carry reasons
    assert s1["completed"] + s1["shed"] == s1["requests"] == 150
    assert all(k is not None for k in s1["shed_reasons"])
    # the retry path fired (transients on all live groups in one step)
    assert s1["retries"] > 0
    kinds = obs1.journal.kinds()
    assert kinds.get("request_retried", 0) > 0
    assert kinds.get("group_demoted", 0) >= 1
    # decision chain is journaled per request: admitted count equals
    # one admission per admit/retry, every shed has one event
    admitted_rids = {e["rid"] for e in obs1.journal.by_kind(
        "request_admitted")}
    retired = {e["rid"] for e in obs1.journal.by_kind("request_retired")}
    shed = {e["rid"] for e in obs1.journal.by_kind("request_shed")}
    assert retired | shed >= admitted_rids        # all admitted resolved
    assert len(retired) == s1["completed"]
    # deterministic: bit-identical journals run to run
    assert [json.dumps(e) for e in obs1.journal.events] \
        == [json.dumps(e) for e in obs2.journal.events]
    # and schema-valid against the closed catalog
    assert validate_events(obs1.journal.events) == []


def test_engine_degraded_mode_sheds_low_priority():
    plan = FaultPlan().kill(0, at=2)       # no recovery: stays degraded
    cfg = BatcherConfig(max_batch_rows=16, coalesce_window_s=0.0)
    eng = make_sim_engine(n_requests=120, rate_rps=0.5 * CAP_RPS, seed=13,
                          fault_plan=plan, guard=True, batcher_config=cfg)
    s = eng.run()
    assert s["completed"] + s["shed"] == 120
    assert s["shed_reasons"].get("degraded", 0) > 0
    # degraded sheds hit the best-effort class only (priority 0)
    for r in eng.done:
        if r.shed_reason == "degraded":
            assert r.priority == 0 and r.klass == "batch"


# -- tuning ------------------------------------------------------------------

def test_batcher_space_size_and_config_mapping():
    space = batcher_space()
    assert space.size() == 210
    cfg = BatcherConfig.from_config(
        {"max_batch_rows": 32, "coalesce_window_ms": 5,
         "queue_depth_rows": 128})
    assert cfg.coalesce_window_s == pytest.approx(0.005)
    assert cfg.queue_depth_rows == 128


def test_tune_batcher_within_envelope_and_cached_repeat(tmp_path):
    store = TuningStore(tmp_path / "store.json")
    calls = {"n": 0}

    def evaluate(cfg):
        calls["n"] += 1
        eng = make_sim_engine(n_requests=80, rate_rps=1.2 * CAP_RPS,
                              seed=21, batcher_config=cfg)
        s = eng.run()
        return {"time": s.get("e2e_p95", 10.0) + 0.1 * s["shed_rate"]}

    workload = {"rate": 1.2, "n": 80}
    cfg, res = tune_batcher(evaluate, store=store, workload=workload)
    assert res.experiments_fraction <= 0.05
    assert not res.from_cache and calls["n"] >= res.n_experiments
    before = calls["n"]
    cfg2, res2 = tune_batcher(evaluate, store=store, workload=workload)
    assert res2.from_cache and cfg2 == cfg
    assert calls["n"] == before            # zero new measurements


def test_serve_journal_kinds_in_catalog():
    for kind in ("request_admitted", "request_shed", "request_retired",
                 "request_retried"):
        assert kind in EVENT_KINDS


# -- the CLI drill (subprocess, real artifact validation) --------------------

def test_cli_serve_requests_drill_validates(tmp_path):
    journal = tmp_path / "journal.jsonl"
    metrics = tmp_path / "metrics.json"
    run_subprocess(f"""
import sys
sys.argv = ["serve", "--serve-requests", "80", "--request-rate", "2000",
            "--fault-plan", "transient:0@3,transient:1@3,kill:0@6,recover:0@12",
            "--journal-out", r"{journal}", "--metrics-out", r"{metrics}"]
from repro.launch.serve import main
main()
""", devices=2)
    from repro.obs.journal import load_journal
    events = load_journal(journal)
    assert validate_events(events) == []
    kinds = {e["kind"] for e in events}
    assert "request_admitted" in kinds and "request_retired" in kinds
    summary = json.loads(metrics.read_text())
    assert summary["serve"]["completed"] + summary["serve"]["shed"] == 80
